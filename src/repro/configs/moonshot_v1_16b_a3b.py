"""Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B] — kimi/moonlight,
64 routed experts top-6 + 2 shared, deepseek-moe-style."""
from repro.models.config import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=163840, d_head=128,
    rope_theta=50_000.0,
    moe=MoESpec(num_experts=64, top_k=6, num_shared=2, d_ff_expert=1408,
                first_dense_layers=1),
)
