"""The paper's own "model UDF" stand-in: a ~100M dense LM used by the
sentiment-pipeline example and the model-UDF benchmark (AFrame §III-C applies
sklearn/CoreNLP models; our engine UDFs are JAX models)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="paper-lm", family="dense",
    n_layers=8, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=32000, d_head=64,
    rope_theta=10_000.0, loss_chunk=512, chunk_q=128,
)
