"""Whisper-base [arXiv:2212.04356; unverified] — enc-dec; conv frontend is a
STUB (input_specs supplies precomputed (B, 1500, 512) frame embeddings)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="encdec",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865, d_head=64,
    qkv_bias=True, tie_embeddings=True,
    enc_layers=6, enc_len=1500,
)
