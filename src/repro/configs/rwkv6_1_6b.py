"""RWKV6 "Finch" 1.6B [arXiv:2404.05892; unverified] — attention-free,
data-dependent decay."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b", family="rwkv",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab=65536,
    rwkv_head_dim=64, rwkv_lora=64,
)
