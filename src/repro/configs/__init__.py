"""Assigned architectures (public-literature configs) + the paper's own LM.

Each module exports CONFIG: ArchConfig with the exact published numbers from
the assignment block; ``get_config(name)`` resolves by id.
"""
from __future__ import annotations

import importlib

ALL_ARCHS = [
    "deepseek-moe-16b",
    "moonshot-v1-16b-a3b",
    "qwen2.5-14b",
    "qwen2-72b",
    "qwen3-1.7b",
    "command-r-35b",
    "rwkv6-1.6b",
    "whisper-base",
    "llava-next-mistral-7b",
    "zamba2-1.2b",
]

_MODULES = {
    "deepseek-moe-16b": "deepseek_moe_16b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "qwen2.5-14b": "qwen2_5_14b",
    "qwen2-72b": "qwen2_72b",
    "qwen3-1.7b": "qwen3_1_7b",
    "command-r-35b": "command_r_35b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "whisper-base": "whisper_base",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "zamba2-1.2b": "zamba2_1_2b",
    "paper-lm": "paper_lm",
}


def get_config(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG
