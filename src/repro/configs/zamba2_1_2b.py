"""Zamba2-1.2B [arXiv:2411.15242; hf] — Mamba2 (SSD) backbone + ONE
shared-weight attention block (input: concat(hidden, embedding), 2·d wide)
applied every 6 blocks, each invocation with its own output linear."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    attn_every=6,
)
