"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf;
unverified] — anyres vision tower is a STUB (input_specs supplies CLIP-L
patch embeddings, 576 patches, 1024-d); backbone is the Mistral-7B GQA
decoder."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, d_head=128,
    rope_theta=1_000_000.0,
    num_patches=576, patch_dim=1024,
)
