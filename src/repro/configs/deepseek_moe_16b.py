"""DeepSeek-MoE 16B [arXiv:2401.06066; hf] — fine-grained MoE: 2 shared +
64 routed experts, top-6, first layer dense."""
from repro.models.config import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400, d_head=128,
    rope_theta=10_000.0,
    moe=MoESpec(num_experts=64, top_k=6, num_shared=2, d_ff_expert=1408,
                first_dense_layers=1),
)
