"""AFrame — the Pandas-like lazy DataFrame over the engine (paper §III).

Every operation wraps the current logical plan in a new node; nothing
executes until an *action* (head/collect/len/agg/persist). ``.query`` shows
the SQL++ the paper's AFrame would have sent (Inputs 7/8 of Fig. 3).

    >>> df = AFrame("demo", "LiveTweets", session=sess)
    >>> known = df[df["coordinate"].notna()]
    >>> coords = known[["text", "coordinate"]]
    >>> coords.head(2)                       # -> LIMIT 2 pushed into the plan
    >>> known.query                          # -> SELECT VALUE t FROM ... WHERE ...
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence

import numpy as np

from repro.core import plan as P
from repro.core.expr import (Arith, Col, Compare, ElementwiseUDF, Expr, IsIn,
                             IsKnown, Lit, ModelUDF, StrLower, StrUpper, wrap)


class ColumnExpr:
    """A column-level expression bound to a source AFrame (Pandas Series
    analogue). Comparisons/arithmetic build Exprs; aggregations execute."""

    def __init__(self, frame: "AFrame", expr: Expr, name: str):
        self._frame = frame
        self.expr = expr
        self.name = name

    # -- expression building --------------------------------------------------
    def _wrap(self, e: Expr, name: str) -> "ColumnExpr":
        return ColumnExpr(self._frame, e, name)

    def __eq__(self, other):  # type: ignore[override]
        return self._wrap(Compare("==", self.expr, wrap(_unbox(other))), self.name)

    def __ne__(self, other):  # type: ignore[override]
        return self._wrap(Compare("!=", self.expr, wrap(_unbox(other))), self.name)

    def __lt__(self, other):
        return self._wrap(Compare("<", self.expr, wrap(_unbox(other))), self.name)

    def __le__(self, other):
        return self._wrap(Compare("<=", self.expr, wrap(_unbox(other))), self.name)

    def __gt__(self, other):
        return self._wrap(Compare(">", self.expr, wrap(_unbox(other))), self.name)

    def __ge__(self, other):
        return self._wrap(Compare(">=", self.expr, wrap(_unbox(other))), self.name)

    def __and__(self, other):
        from repro.core.expr import BoolOp
        return self._wrap(BoolOp("AND", self.expr, _unbox_expr(other)), self.name)

    def __or__(self, other):
        from repro.core.expr import BoolOp
        return self._wrap(BoolOp("OR", self.expr, _unbox_expr(other)), self.name)

    def __invert__(self):
        from repro.core.expr import Not
        return self._wrap(Not(self.expr), self.name)

    def __add__(self, other):
        return self._wrap(Arith("+", self.expr, wrap(_unbox(other))), self.name)

    def __sub__(self, other):
        return self._wrap(Arith("-", self.expr, wrap(_unbox(other))), self.name)

    def __mul__(self, other):
        return self._wrap(Arith("*", self.expr, wrap(_unbox(other))), self.name)

    def __mod__(self, other):
        return self._wrap(Arith("%", self.expr, wrap(_unbox(other))), self.name)

    def __truediv__(self, other):
        return self._wrap(Arith("/", self.expr, wrap(_unbox(other))), self.name)

    def __hash__(self):
        return id(self)

    def notna(self) -> "ColumnExpr":
        return self._wrap(IsKnown(self.expr), self.name)

    def isin(self, values: Sequence[Any]) -> "ColumnExpr":
        """Membership filter (pandas ``Series.isin`` / SQL++ ``IN``); on a
        dictionary-encoded string column this lowers onto per-value dict-id
        kernel range counts."""
        return self._wrap(IsIn(self.expr,
                               [wrap(_unbox(v)) for v in values]), self.name)

    def map(self, fn: Any, name: Optional[str] = None) -> "ColumnExpr":
        """Apply a function elementwise — the paper's §III-C UDF application.
        Accepts ``str.upper``/``str.lower``, any JAX-traceable callable, or a
        registered model-UDF name / ModelUDF handle."""
        from repro.udf.model_udf import ModelHandle

        if fn is str.upper:
            return self._wrap(StrUpper(self.expr), self.name)
        if fn is str.lower:
            return self._wrap(StrLower(self.expr), self.name)
        if isinstance(fn, ModelHandle):
            return self._wrap(ModelUDF(fn.name, self.expr), name or fn.name)
        if isinstance(fn, str):
            return self._wrap(ModelUDF(fn, self.expr), name or fn)
        if callable(fn):
            return self._wrap(ElementwiseUDF(fn, name or getattr(fn, "__name__", "udf"),
                                             self.expr), self.name)
        raise TypeError(f"cannot map {fn!r}")

    @property
    def str(self) -> "_StrOps":
        return _StrOps(self)

    # -- actions ---------------------------------------------------------------
    def _agg(self, op: str):
        plan = P.Agg(self._frame._project_plan([(self.name, self.expr)]),
                     [P.AggSpec(op, op, self.name if op != "count" else None)])
        return self._frame._session.execute(plan)

    def max(self):
        return self._agg("max")

    def min(self):
        return self._agg("min")

    def sum(self):
        return self._agg("sum")

    def mean(self):
        return self._agg("mean")

    def count(self):
        return self._agg("count")

    def head(self, n: int = 5) -> dict[str, np.ndarray]:
        return AFrame._from_plan(
            self._frame, self._frame._project_plan([(self.name, self.expr)])).head(n)

    @property
    def query(self) -> str:
        return self._frame._project_plan([(self.name, self.expr)]).to_sql()


class _StrOps:
    def __init__(self, col: ColumnExpr):
        self._col = col

    def upper(self) -> ColumnExpr:
        return self._col.map(str.upper)

    def lower(self) -> ColumnExpr:
        return self._col.map(str.lower)


def _unbox(v):
    return v.expr if isinstance(v, ColumnExpr) else v


def _unbox_expr(v) -> Expr:
    return v.expr if isinstance(v, ColumnExpr) else wrap(v)


class AFrame:
    """The lazy DataFrame. Construct from a registered dataset (O(1) — data
    is managed, no file scan: the paper's total-time win) or internally from
    a plan."""

    def __init__(self, dataverse: str, dataset: Optional[str] = None, *,
                 session=None, plan: Optional[P.Plan] = None):
        if session is None:
            raise ValueError("AFrame needs a Session (the engine connection)")
        self._session = session
        if plan is None:
            session.catalog.get(dataverse, dataset)  # must exist (like AsterixDB)
            plan = P.Scan(dataset, dataverse)
        self._plan = plan
        self._dataverse = dataverse

    @staticmethod
    def _from_plan(like: "AFrame", plan: P.Plan) -> "AFrame":
        return AFrame(like._dataverse, session=like._session, plan=plan)

    # -- plan access -------------------------------------------------------------
    @property
    def query(self) -> str:
        """The underlying SQL++ (paper Inputs 7/8)."""
        return self._plan.to_sql() + ";"

    @property
    def optimized_query(self) -> str:
        from repro.core.optimizer import optimize
        return optimize(self._plan, self._session.catalog).to_sql() + ";"

    def query_in(self, dialect: str) -> str:
        """Render the plan in another engine's dialect (paper §VI:
        language-layer abstraction; 'postgres' supported)."""
        from repro.core.dialect import render
        return render(self._plan, dialect)

    def explain(self, analyze: bool = False) -> str:
        """The costed physical plan: per-operator cost estimates, the access
        path the planner chose over its alternatives, and — over a fed
        dataset — which LSM runs the zone maps pruned and why.

        ``analyze=True`` executes the query and adds measured per-operator
        wall time + actual rows beside the estimates (``Session.profile``)."""
        return self._session.explain(self._plan, analyze=analyze)

    def profile(self) -> dict:
        """Execute with per-operator measurement: returns ``{"text",
        "result", "measures", "prune_report"}``."""
        return self._session.profile(self._plan)

    def _project_plan(self, outputs) -> P.Plan:
        return P.Project(self._plan, outputs)

    # -- pandas surface ------------------------------------------------------------
    def __getitem__(self, key):
        if isinstance(key, str):
            return ColumnExpr(self, Col(key), key)
        if isinstance(key, list):
            return AFrame._from_plan(self, P.Project(
                self._plan, [(k, Col(k)) for k in key]))
        if isinstance(key, ColumnExpr):
            return AFrame._from_plan(self, P.Filter(self._plan, key.expr))
        raise TypeError(f"cannot index AFrame with {type(key)}")

    def __setitem__(self, name: str, value: ColumnExpr):
        """df['sentiment'] = df['text'].map(model) — extends the projection
        (paper Input 13)."""
        expr = value.expr if isinstance(value, ColumnExpr) else wrap(value)
        cols = self._current_columns()
        outputs = [(c, Col(c)) for c in cols if c != name] + [(name, expr)]
        self._plan = P.Project(self._plan, outputs)

    def _current_columns(self) -> list[str]:
        node = self._plan
        while True:
            if isinstance(node, P.Project):
                return [n for n, _ in node.outputs]
            if isinstance(node, (P.Scan,)):
                ds = self._session.catalog.get(node.dataverse, node.dataset)
                from repro.core.catalog import INTERNAL_COLUMNS
                from repro.engine.table import is_lane_column
                return [c for c in ds.table.column_names()
                        if c not in INTERNAL_COLUMNS
                        and not is_lane_column(c)]
            if not node.children:
                raise ValueError("cannot infer columns")
            node = node.children[0]

    def __len__(self) -> int:
        return int(self._session.execute(
            P.Agg(self._plan, [P.AggSpec("count", "count", None)])))

    # -- transformations -------------------------------------------------------------
    def sort_values(self, by: str, ascending: bool = True) -> "AFrame":
        return AFrame._from_plan(self, P.Sort(self._plan, by, ascending))

    def merge(self, other: "AFrame", left_on: str, right_on: str,
              how: str = "inner") -> "AFrame":
        return AFrame._from_plan(self, P.Join(self._plan, other._plan,
                                              left_on, right_on, how))

    def groupby(self, key: str) -> "GroupBy":
        return GroupBy(self, key)

    def window(self, order_by: str, partition_by: Optional[str] = None,
               ascending: bool = True) -> "WindowBuilder":
        """Window functions (the paper's §VI future-work item):

            df['rn'] = df.window(order_by='unique1',
                                 partition_by='ten').row_number()
        """
        return WindowBuilder(self, order_by, partition_by, ascending)

    def map(self, fn, column: str, name: Optional[str] = None) -> "AFrame":
        out = self[column].map(fn, name)
        new = AFrame._from_plan(self, self._plan)
        new[name or column] = out
        return new

    # -- actions -----------------------------------------------------------------------
    def get(self, key) -> Optional[dict[str, np.ndarray]]:
        """Point lookup by primary key — ``df.get(42)`` resolves the
        equality predicate to per-component binary searches over the
        clustered key copy (newest-wins across LSM components, anti-matter
        aware), bypassing query compilation and kernel launches entirely.
        Returns the row(s) as ``{column: array}`` or None when the key is
        absent or deleted. Only valid on a bare dataset frame (no pending
        filters/projections — those need the query path)."""
        if not isinstance(self._plan, P.Scan):
            raise ValueError(
                "get() is a primary-key point lookup on the base dataset; "
                "this frame carries pending operations — use a filter query")
        return self._session.point_lookup(self._plan.dataverse,
                                          self._plan.dataset, key)

    def explain_get(self, key) -> str:
        """The PointLookup plan ``get(key)`` executes, rendered like
        ``explain()`` (per-component probe/skip counts and the newest-wins
        resolution)."""
        if not isinstance(self._plan, P.Scan):
            raise ValueError("explain_get() needs a bare dataset frame")
        return self._session.explain_lookup(self._plan.dataverse,
                                            self._plan.dataset, key)

    def head(self, n: int = 5) -> dict[str, np.ndarray]:
        return self._session.execute(P.Limit(self._plan, n))

    def collect(self) -> dict[str, np.ndarray]:
        return self._session.execute(self._plan)

    def describe(self) -> dict[str, dict[str, float]]:
        """min/max/mean/count per numeric column. String columns are skipped
        by catalog metadata (not by swallowing execution errors)."""
        meta = {}
        for node in P.walk(self._plan):
            if isinstance(node, P.Scan):
                ds = self._session.catalog.get(node.dataverse, node.dataset)
                meta = ds.table.meta
                break
        out = {}
        for c in self._current_columns():
            if c in meta and meta[c].is_string:
                continue
            specs = [P.AggSpec(f"{op}", op, c) for op in ("min", "max", "mean")]
            specs.append(P.AggSpec("count", "count", None))
            r = self._session.execute(P.Agg(self._project_plan([(c, Col(c))]), specs))
            out[c] = r if isinstance(r, dict) else {"value": r}
        return out

    def persist(self, name: str, dataverse: Optional[str] = None):
        ds = self._session.persist(self._plan, name, dataverse or self._dataverse)
        return AFrame(ds.dataverse, ds.name, session=self._session)


class WindowBuilder:
    def __init__(self, frame: AFrame, order_by: str,
                 partition_by: Optional[str], ascending: bool):
        self._f, self._o, self._p, self._asc = frame, order_by, partition_by, ascending

    def _apply(self, func: str, value_col: Optional[str] = None,
               frame_rows: int = 0, name: Optional[str] = None) -> AFrame:
        from repro.core.window import Window

        out = name or func
        plan = Window(self._f._plan, out, func, self._o, self._p,
                      value_col, frame_rows, self._asc)
        return AFrame._from_plan(self._f, plan)

    def row_number(self, name: str = "row_number") -> AFrame:
        return self._apply("row_number", name=name)

    def rank(self, name: str = "rank") -> AFrame:
        return self._apply("rank", name=name)

    def cumsum(self, col: str, name: Optional[str] = None) -> AFrame:
        return self._apply("cumsum", value_col=col, name=name or f"cumsum_{col}")

    def moving_avg(self, col: str, window: int,
                   name: Optional[str] = None) -> AFrame:
        return self._apply("moving_avg", value_col=col, frame_rows=window,
                           name=name or f"mavg{window}_{col}")


class GroupBy:
    def __init__(self, frame: AFrame, key: str):
        self._frame = frame
        self._key = key
        self._column: Optional[str] = None

    def __getitem__(self, column: str) -> "GroupBy":
        g = GroupBy(self._frame, self._key)
        g._column = column
        return g

    def agg_plan(self, spec) -> P.Plan:
        """The GroupAgg plan for ``spec`` without executing it — feed this
        to ``Session.create_view`` for a continuously-maintained aggregate."""
        if isinstance(spec, str):
            if spec == "count":
                aggs = [P.AggSpec("count", "count", None)]
            else:
                assert self._column, "select a column before agg('op')"
                aggs = [P.AggSpec(f"{spec}_{self._column}", spec, self._column)]
        elif isinstance(spec, dict):
            aggs = [P.AggSpec(f"{op}_{c}", op, c) for c, op in spec.items()]
        else:
            raise TypeError(spec)
        return P.GroupAgg(self._frame._plan, [self._key], aggs)

    def agg(self, spec) -> dict[str, np.ndarray]:
        """agg('count') / agg('max') on a selected column / agg({col: op})."""
        return self._frame._session.execute(self.agg_plan(spec))

    def count(self):
        return self.agg("count")

    def max(self):
        return self.agg("max")
