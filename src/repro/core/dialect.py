"""Language-layer abstraction — the paper's §VI future-work item:
"By separating its language module from the DataFrame operation translation
mechanism, we should also be able to deploy AFrame on other query-based data
management systems (e.g., Postgres)."

``render(plan, dialect)`` re-renders any logical plan in a target dialect.
The plan IR is the single source of truth; SQL++ remains the default
(``plan.to_sql()``) and this module maps the few divergent constructs:

  construct        SQL++ (AsterixDB)            postgres
  ---------------- ---------------------------- -----------------------------
  whole-record     SELECT VALUE t                SELECT t.*
  missing check    t.x IS KNOWN                  t.x IS NOT NULL
  dataset ref      dataverse.Dataset             schema.table (lowercased)
  index hint       /*+ index(col) */             (omitted — planner decides)
  group output     SELECT VALUE COUNT(*)         SELECT COUNT(*)
"""
from __future__ import annotations

from repro.core import plan as P
from repro.core.expr import (Arith, BoolOp, Col, Compare, ElementwiseUDF,
                             Expr, IsKnown, Lit, ModelUDF, Not, StrLower,
                             StrUpper)

DIALECTS = ("sqlpp", "postgres")


def render(plan: P.Plan, dialect: str = "sqlpp") -> str:
    assert dialect in DIALECTS, dialect
    if dialect == "sqlpp":
        return plan.to_sql() + ";"
    return _pg_plan(plan) + ";"


# -- postgres expression rendering ------------------------------------------------


def _pg_expr(e: Expr) -> str:
    if isinstance(e, Col):
        return f"t.{e.name}"
    if isinstance(e, Lit):
        return f"'{e.value}'" if isinstance(e.value, str) else repr(e.value)
    if isinstance(e, Compare):
        return f"{_pg_expr(e.children[0])} {e._SQL[e.op]} {_pg_expr(e.children[1])}"
    if isinstance(e, BoolOp):
        return f"({_pg_expr(e.children[0])} {e.op} {_pg_expr(e.children[1])})"
    if isinstance(e, Not):
        return f"NOT ({_pg_expr(e.children[0])})"
    if isinstance(e, Arith):
        op = "%" if e.op == "%" else e.op
        return f"({_pg_expr(e.children[0])} {op} {_pg_expr(e.children[1])})"
    if isinstance(e, IsKnown):
        return f"{_pg_expr(e.children[0])} IS NOT NULL"
    if isinstance(e, StrUpper):
        return f"UPPER({_pg_expr(e.children[0])})"
    if isinstance(e, StrLower):
        return f"LOWER({_pg_expr(e.children[0])})"
    if isinstance(e, (ElementwiseUDF, ModelUDF)):
        name = getattr(e, "name", None) or getattr(e, "model_name")
        args = ", ".join(_pg_expr(c) for c in e.children)
        return f"{name}({args})"  # assumes a registered pg function
    raise NotImplementedError(type(e).__name__)


def _pg_table(dataverse: str, dataset: str) -> str:
    return f"{dataverse.lower()}.{dataset.lower()}"


def _pg_plan(node: P.Plan) -> str:
    if isinstance(node, P.Scan):
        return f"SELECT t.* FROM {_pg_table(node.dataverse, node.dataset)} t"
    if isinstance(node, P.Filter):
        return (f"SELECT t.* FROM ({_pg_plan(node.children[0])}) t "
                f"WHERE {_pg_expr(node.predicate)}")
    if isinstance(node, P.Project):
        cols = ", ".join(
            _pg_expr(e) if (isinstance(e, Col) and e.name == n)
            else f"{_pg_expr(e)} AS {n}"
            for n, e in node.outputs)
        return f"SELECT {cols} FROM ({_pg_plan(node.children[0])}) t"
    if isinstance(node, P.Limit):
        return f"{_pg_plan(node.children[0])} LIMIT {node.n}"
    if isinstance(node, (P.Sort, P.TopK)):
        d = "ASC" if node.ascending else "DESC"
        sql = (f"SELECT t.* FROM ({_pg_plan(node.children[0])}) t "
               f"ORDER BY t.{node.key} {d}")
        if isinstance(node, P.TopK):
            sql += f" LIMIT {node.k}"
        return sql
    if isinstance(node, P.GroupAgg):
        aggs = ", ".join(
            f"{s.op.upper()}({'t.' + s.column if s.column else '*'}) AS {s.out_name}"
            for s in node.aggs)
        keys = ", ".join(f"t.{k}" for k in node.keys)
        return (f"SELECT {keys}, {aggs} FROM ({_pg_plan(node.children[0])}) t "
                f"GROUP BY {keys}")
    if isinstance(node, P.Agg):
        aggs = ", ".join(
            f"{s.op.upper()}({'t.' + s.column if s.column else '*'}) AS {s.out_name}"
            for s in node.aggs)
        return f"SELECT {aggs} FROM ({_pg_plan(node.children[0])}) t"
    if isinstance(node, (P.FilterCount,)):
        base = _pg_plan(node.children[0])
        if node.predicate is None:
            return f"SELECT COUNT(*) FROM ({base}) t"
        return f"SELECT COUNT(*) FROM ({base}) t WHERE {_pg_expr(node.predicate)}"
    if isinstance(node, (P.Join, P.JoinCount)):
        l = _pg_plan(node.children[0])
        r = _pg_plan(node.children[1])
        inner = (f"SELECT l.*, r.* FROM ({l}) l JOIN ({r}) r "
                 f"ON l.{node.left_on} = r.{node.right_on}")
        if isinstance(node, P.JoinCount):
            return f"SELECT COUNT(*) FROM ({inner}) t"
        return inner
    from repro.core.window import Window

    if isinstance(node, Window):
        # delegate to the node's own OVER() rendering; SELECT VALUE-free
        return node.to_sql().replace("SELECT t.*,", "SELECT t.*,")
    raise NotImplementedError(type(node).__name__)
