"""Logical query plan.

The AFrame object never executes anything; each DataFrame operation wraps the
previous plan in a new node (the paper's "incremental query formation",
§III-B). ``to_sql()`` renders the equivalent SQL++ for ``AFrame.query``;
``fingerprint()`` keys the compiled-executable cache (literal values excluded
— they are runtime parameters, so the benchmark's randomized predicates reuse
one executable).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.expr import Col, Expr

AGG_OPS = ("count", "sum", "max", "min", "mean")


class Plan:
    children: tuple["Plan", ...] = ()

    def fingerprint(self) -> str:
        raise NotImplementedError

    def to_sql(self) -> str:
        raise NotImplementedError

    def exprs(self) -> list[Expr]:
        return []

    # required output columns -> required input columns; used by the
    # projection-pushdown rule.
    def required_columns(self) -> set[str]:
        out: set[str] = set()
        for e in self.exprs():
            out |= e.columns()
        return out


@dataclasses.dataclass(frozen=True)
class AggSpec:
    out_name: str
    op: str  # one of AGG_OPS
    column: Optional[str]  # None for count(*)

    def fingerprint(self) -> str:
        return f"{self.out_name}={self.op}({self.column})"

    def to_sql(self) -> str:
        arg = f"t.{self.column}" if self.column else "*"
        return f"{self.op.upper()}({arg}) AS {self.out_name}"


class Scan(Plan):
    def __init__(self, dataset: str, dataverse: str = "Default"):
        self.dataset, self.dataverse = dataset, dataverse

    def fingerprint(self):
        return f"scan({self.dataverse}.{self.dataset})"

    def to_sql(self):
        return f"SELECT VALUE t FROM {self.dataverse}.{self.dataset} t"

    def _from(self):
        return f"FROM {self.dataverse}.{self.dataset} t"


class Filter(Plan):
    def __init__(self, child: Plan, predicate: Expr):
        self.children, self.predicate = (child,), predicate

    def fingerprint(self):
        return f"filter({self.predicate.fingerprint()},{self.children[0].fingerprint()})"

    def exprs(self):
        return [self.predicate]

    def to_sql(self):
        return f"SELECT VALUE t FROM ({self.children[0].to_sql()}) t WHERE {self.predicate.to_sql()}"


class Project(Plan):
    """Named output expressions (projection, derived columns, UDF columns)."""

    def __init__(self, child: Plan, outputs: Sequence[tuple[str, Expr]]):
        self.children, self.outputs = (child,), tuple(outputs)

    def fingerprint(self):
        items = ",".join(f"{n}:{e.fingerprint()}" for n, e in self.outputs)
        return f"project([{items}],{self.children[0].fingerprint()})"

    def exprs(self):
        return [e for _, e in self.outputs]

    def to_sql(self):
        cols = ", ".join(
            e.to_sql() if (isinstance(e, Col) and e.name == n) else f"{e.to_sql()} AS {n}"
            for n, e in self.outputs
        )
        return f"SELECT {cols} FROM ({self.children[0].to_sql()}) t"


class Limit(Plan):
    def __init__(self, child: Plan, n: int):
        self.children, self.n = (child,), int(n)

    def fingerprint(self):
        return f"limit({self.n},{self.children[0].fingerprint()})"

    def to_sql(self):
        return f"{self.children[0].to_sql()} LIMIT {self.n}"


class Sort(Plan):
    def __init__(self, child: Plan, key: str, ascending: bool = True):
        self.children, self.key, self.ascending = (child,), key, ascending

    def fingerprint(self):
        return f"sort({self.key},{self.ascending},{self.children[0].fingerprint()})"

    def required_columns(self):
        return {self.key}

    def to_sql(self):
        d = "ASC" if self.ascending else "DESC"
        return f"SELECT VALUE t FROM ({self.children[0].to_sql()}) t ORDER BY t.{self.key} {d}"


class TopK(Plan):
    """Sort + Limit fused by the optimizer (the distributed-limit-pushdown
    the paper gets from AsterixDB's ORDER BY ... LIMIT rewrite)."""

    def __init__(self, child: Plan, key: str, k: int, ascending: bool):
        self.children, self.key, self.k, self.ascending = (child,), key, int(k), ascending

    def fingerprint(self):
        return f"topk({self.key},{self.k},{self.ascending},{self.children[0].fingerprint()})"

    def required_columns(self):
        return {self.key}

    def to_sql(self):
        d = "ASC" if self.ascending else "DESC"
        return (
            f"SELECT VALUE t FROM ({self.children[0].to_sql()}) t "
            f"ORDER BY t.{self.key} {d} LIMIT {self.k}"
        )


class GroupAgg(Plan):
    def __init__(self, child: Plan, keys: Sequence[str], aggs: Sequence[AggSpec]):
        self.children, self.keys, self.aggs = (child,), tuple(keys), tuple(aggs)

    def fingerprint(self):
        a = ",".join(s.fingerprint() for s in self.aggs)
        return f"groupagg({self.keys},[{a}],{self.children[0].fingerprint()})"

    def required_columns(self):
        cols = set(self.keys)
        for s in self.aggs:
            if s.column:
                cols.add(s.column)
        return cols

    def to_sql(self):
        key_sql = ", ".join(f"t.{k} AS grp_{k}" for k in self.keys)
        aggs = ", ".join(s.to_sql() for s in self.aggs)
        keys = ", ".join(f"t.{k}" for k in self.keys)
        return (
            f"SELECT {key_sql}, {aggs} FROM ({self.children[0].to_sql()}) t "
            f"GROUP BY {keys}"
        )


class Agg(Plan):
    """Global (scalar) aggregation: len(df), df['x'].max(), describe()."""

    def __init__(self, child: Plan, aggs: Sequence[AggSpec]):
        self.children, self.aggs = (child,), tuple(aggs)

    def fingerprint(self):
        a = ",".join(s.fingerprint() for s in self.aggs)
        return f"agg([{a}],{self.children[0].fingerprint()})"

    def required_columns(self):
        return {s.column for s in self.aggs if s.column}

    def to_sql(self):
        if len(self.aggs) == 1 and self.aggs[0].op == "count" and self.aggs[0].column is None:
            return f"SELECT VALUE COUNT(*) FROM ({self.children[0].to_sql()}) t"
        aggs = ", ".join(s.to_sql() for s in self.aggs)
        return f"SELECT {aggs} FROM ({self.children[0].to_sql()}) t"


class Join(Plan):
    def __init__(self, left: Plan, right: Plan, left_on: str, right_on: str, how: str = "inner"):
        assert how == "inner", "only inner equi-joins (paper expression 12)"
        self.children = (left, right)
        self.left_on, self.right_on, self.how = left_on, right_on, how

    def fingerprint(self):
        return (
            f"join({self.left_on}={self.right_on},{self.how},"
            f"{self.children[0].fingerprint()},{self.children[1].fingerprint()})"
        )

    def to_sql(self):
        return (
            f"SELECT l, r FROM ({self.children[0].to_sql()}) l "
            f"JOIN ({self.children[1].to_sql()}) r ON l.{self.left_on} = r.{self.right_on}"
        )


class UnionRuns(Plan):
    """Base ∪ runs over a fed (LSM) dataset: children are the per-component
    streams (a Scan of the base plus one Scan per device-resident run, or
    whatever row-wise operators the optimizer pushed into them). Lowering
    concatenates component streams; results are identical to executing the
    same plan over the compacted dataset — the LSM read invariant."""

    def __init__(self, children: Sequence[Plan]):
        self.children = tuple(children)

    def fingerprint(self):
        inner = ",".join(c.fingerprint() for c in self.children)
        return f"unionruns({inner})"

    def required_columns(self):
        out: set[str] = set()
        for c in self.children:
            out |= c.required_columns()
        return out

    def to_sql(self):
        return " UNION ALL ".join(f"({c.to_sql()})" for c in self.children)


class UnionScalar(Plan):
    """Merge of per-component scalar aggregates over an LSM union: each child
    is a scalar-terminal plan (FilterCount / FusedRangeCount / Agg) over one
    component; ``merges`` maps each output name to its merge operator
    ('sum' for counts and sums, 'min'/'max' for extremes). This is what lets
    per-component index probes and kernel launches compose with a final
    psum-style merge instead of materializing the union."""

    def __init__(self, children: Sequence[Plan], merges: Sequence[tuple[str, str]]):
        self.children = tuple(children)
        self.merges = tuple(merges)

    def fingerprint(self):
        m = ",".join(f"{n}:{op}" for n, op in self.merges)
        inner = ",".join(c.fingerprint() for c in self.children)
        return f"unionscalar([{m}],{inner})"

    def to_sql(self):
        parts = " UNION ALL ".join(f"({c.to_sql()})" for c in self.children)
        aggs = ", ".join(
            f"{'SUM' if op == 'sum' else op.upper()}(t.{n}) AS {n}"
            for n, op in self.merges)
        return f"SELECT {aggs} FROM ({parts}) t"


# -- fused logical nodes introduced by the optimizer ------------------------
# (Access paths — index probes, kernel launches, run pruning — are PHYSICAL
# decisions and live in core/physical.py; these nodes only record semantic
# fusions like "this aggregate is a COUNT over a filter".)


class FilterCount(Plan):
    """Fused filter+count physical node (lowers to the ``filter_count``
    Pallas kernel on TPU; fused mask-psum in plain XLA mode)."""

    def __init__(self, child: Plan, predicate: Expr | None):
        self.children, self.predicate = (child,), predicate

    def exprs(self):
        return [self.predicate] if self.predicate is not None else []

    def fingerprint(self):
        p = self.predicate.fingerprint() if self.predicate else "true"
        return f"filtercount({p},{self.children[0].fingerprint()})"

    def to_sql(self):
        base = self.children[0].to_sql()
        if self.predicate is None:
            return f"SELECT VALUE COUNT(*) FROM ({base}) t"
        return f"SELECT VALUE COUNT(*) FROM ({base}) t WHERE {self.predicate.to_sql()}"


class JoinCount(Plan):
    """Fused join+count (paper expression 12: ``len(pd.merge(...))``)."""

    def __init__(self, left: Plan, right: Plan, left_on: str, right_on: str):
        self.children = (left, right)
        self.left_on, self.right_on = left_on, right_on

    def fingerprint(self):
        return (
            f"joincount({self.left_on}={self.right_on},"
            f"{self.children[0].fingerprint()},{self.children[1].fingerprint()})"
        )

    def to_sql(self):
        return (
            f"SELECT VALUE COUNT(*) FROM (SELECT l, r FROM ({self.children[0].to_sql()}) l "
            f"JOIN ({self.children[1].to_sql()}) r ON l.{self.left_on} = r.{self.right_on}) t"
        )


def walk(plan: Plan):
    yield plan
    for c in plan.children:
        yield from walk(c)


def all_exprs(plan: Plan) -> list[Expr]:
    out = []
    for node in walk(plan):
        out.extend(node.exprs())
    return out
