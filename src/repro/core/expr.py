"""Expression AST for the lazy DataFrame.

AFrame incrementally builds SQL++ text; we incrementally build a typed
expression tree. Two consumers:
  * ``evaluate(env, params)`` — vectorized JAX evaluation inside the compiled
    query program (columns in ``env`` are device arrays).
  * ``to_sql(ctx)``          — renders the SQL++ the paper would have sent,
    exposed through ``AFrame.query`` exactly like the paper's Inputs 7/8.

Literals are *parameterized*: ``collect_params`` lifts every ``Lit`` into a
runtime argument so changing a predicate constant (the benchmark randomizes
them per run, §IV-B) re-uses the compiled executable — the "prepared
statement" the paper gets for free from AsterixDB's plan cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------


class Expr:
    children: tuple["Expr", ...] = ()

    # -- python operator sugar (mirrors the Pandas surface AFrame exposes) --
    def _cmp(self, op, other):
        return Compare(op, self, wrap(other))

    def __eq__(self, other):  # type: ignore[override]
        return self._cmp("==", other)

    def __ne__(self, other):  # type: ignore[override]
        return self._cmp("!=", other)

    def __lt__(self, other):
        return self._cmp("<", other)

    def __le__(self, other):
        return self._cmp("<=", other)

    def __gt__(self, other):
        return self._cmp(">", other)

    def __ge__(self, other):
        return self._cmp(">=", other)

    def __and__(self, other):
        return BoolOp("AND", self, wrap(other))

    def __or__(self, other):
        return BoolOp("OR", self, wrap(other))

    def __invert__(self):
        return Not(self)

    def __add__(self, other):
        return Arith("+", self, wrap(other))

    def __sub__(self, other):
        return Arith("-", self, wrap(other))

    def __mul__(self, other):
        return Arith("*", self, wrap(other))

    def __mod__(self, other):
        return Arith("%", self, wrap(other))

    def __truediv__(self, other):
        return Arith("/", self, wrap(other))

    def __hash__(self):  # dataclasses with eq overridden need explicit hash
        return hash(self.fingerprint())

    # -- interface -----------------------------------------------------------
    def evaluate(self, env: dict[str, jax.Array], params: Sequence[jax.Array]) -> jax.Array:
        raise NotImplementedError

    def to_sql(self) -> str:
        raise NotImplementedError

    def fingerprint(self) -> str:
        """Structural identity, excluding literal *values* (they are params)."""
        raise NotImplementedError

    def columns(self) -> set[str]:
        out: set[str] = set()
        for c in self.children:
            out |= c.columns()
        return out

    def literals(self) -> list["Lit"]:
        out: list[Lit] = []
        for c in self.children:
            out.extend(c.literals())
        return out


def wrap(v: Any) -> Expr:
    return v if isinstance(v, Expr) else Lit(v)


# ---------------------------------------------------------------------------


class Col(Expr):
    def __init__(self, name: str):
        self.name = name

    def evaluate(self, env, params):
        return env[self.name]

    def to_sql(self):
        return f"t.{self.name}"

    def fingerprint(self):
        return f"col:{self.name}"

    def columns(self):
        return {self.name}


class Lit(Expr):
    """A literal. At compile time each Lit receives a slot index; at run time
    its value arrives via the params vector (jit-stable).

    ``source`` marks a literal the *optimizer* synthesized as a mirror of a
    user literal (e.g. the second bound of a ``==`` range): at plan-cache
    rebind time its value follows the source's fresh value. A Lit with no
    source that is absent from the raw plan is a true constant (sentinel
    bounds) and rebinds to its compile-time value.
    """

    def __init__(self, value: Any, source: "Lit | None" = None):
        self.value = value
        self.slot: int | None = None
        self.source = source

    def evaluate(self, env, params):
        if self.slot is None:  # un-parameterized evaluation (tests)
            return jnp.asarray(self.value)
        return params[self.slot]

    def to_sql(self):
        if isinstance(self.value, str):
            return f"'{self.value}'"
        return repr(self.value)

    def fingerprint(self):
        return f"lit:{np.asarray(self.value).dtype}"

    def literals(self):
        return [self]


class Compare(Expr):
    _OPS: dict[str, Callable] = {
        "==": lambda a, b: a == b,
        "!=": lambda a, b: a != b,
        "<": lambda a, b: a < b,
        "<=": lambda a, b: a <= b,
        ">": lambda a, b: a > b,
        ">=": lambda a, b: a >= b,
    }
    _SQL = {"==": "=", "!=": "!=", "<": "<", "<=": "<=", ">": ">", ">=": ">="}

    def __init__(self, op: str, left: Expr, right: Expr):
        assert op in self._OPS, op
        self.op, self.children = op, (left, right)

    def evaluate(self, env, params):
        a = self.children[0].evaluate(env, params)
        b = self.children[1].evaluate(env, params)
        if a.ndim == 2 or (hasattr(b, "ndim") and b.ndim == 2):  # fixed-width strings
            res = jnp.all(a == b, axis=-1)
            return res if self.op == "==" else ~res
        return self._OPS[self.op](a, b)

    def to_sql(self):
        return f"{self.children[0].to_sql()} {self._SQL[self.op]} {self.children[1].to_sql()}"

    def fingerprint(self):
        return f"cmp({self.op},{self.children[0].fingerprint()},{self.children[1].fingerprint()})"


class IsIn(Expr):
    """Membership against a literal set — SQL++ ``IN [...]`` (pandas
    ``Series.isin``). Values are ordinary ``Lit`` children, so plan-cache
    parameterization, fingerprinting, and literal rebinding all apply; the
    kernel planner lowers a string ``isin`` onto per-value dict-id range
    counts."""

    def __init__(self, child: Expr, values: Sequence[Expr]):
        self.children = (child,) + tuple(values)

    @property
    def values(self) -> tuple[Expr, ...]:
        return self.children[1:]

    def evaluate(self, env, params):
        a = self.children[0].evaluate(env, params)
        out = None
        for v in self.values:
            b = v.evaluate(env, params)
            if a.ndim == 2 or (hasattr(b, "ndim") and b.ndim == 2):
                hit = jnp.all(a == b, axis=-1)
            else:
                hit = a == b
            out = hit if out is None else (out | hit)
        if out is None:  # empty value set matches nothing
            return jnp.zeros(a.shape[:1], dtype=jnp.bool_)
        return out

    def to_sql(self):
        vals = ", ".join(v.to_sql() for v in self.values)
        return f"{self.children[0].to_sql()} IN [{vals}]"

    def fingerprint(self):
        inner = ",".join(c.fingerprint() for c in self.children)
        return f"isin({inner})"


class BoolOp(Expr):
    def __init__(self, op: str, left: Expr, right: Expr):
        assert op in ("AND", "OR")
        self.op, self.children = op, (left, right)

    def evaluate(self, env, params):
        a = self.children[0].evaluate(env, params)
        b = self.children[1].evaluate(env, params)
        return (a & b) if self.op == "AND" else (a | b)

    def to_sql(self):
        return f"({self.children[0].to_sql()} {self.op} {self.children[1].to_sql()})"

    def fingerprint(self):
        return f"bool({self.op},{self.children[0].fingerprint()},{self.children[1].fingerprint()})"


class Not(Expr):
    def __init__(self, child: Expr):
        self.children = (child,)

    def evaluate(self, env, params):
        return ~self.children[0].evaluate(env, params)

    def to_sql(self):
        return f"NOT ({self.children[0].to_sql()})"

    def fingerprint(self):
        return f"not({self.children[0].fingerprint()})"


class Arith(Expr):
    _OPS = {
        "+": jnp.add,
        "-": jnp.subtract,
        "*": jnp.multiply,
        "/": jnp.divide,
        "%": jnp.mod,
    }

    def __init__(self, op: str, left: Expr, right: Expr):
        assert op in self._OPS
        self.op, self.children = op, (left, right)

    def evaluate(self, env, params):
        return self._OPS[self.op](
            self.children[0].evaluate(env, params),
            self.children[1].evaluate(env, params),
        )

    def to_sql(self):
        return f"({self.children[0].to_sql()} {self.op} {self.children[1].to_sql()})"

    def fingerprint(self):
        return f"arith({self.op},{self.children[0].fingerprint()},{self.children[1].fingerprint()})"


class IsKnown(Expr):
    """``notna`` — SQL++ ``IS KNOWN`` (paper Input 4/7)."""

    def __init__(self, child: Expr):
        self.children = (child,)

    def evaluate(self, env, params):
        v = self.children[0].evaluate(env, params)
        if jnp.issubdtype(v.dtype, jnp.floating):
            return ~jnp.isnan(v)
        return jnp.ones(v.shape[:1], dtype=jnp.bool_)

    def to_sql(self):
        return f"{self.children[0].to_sql()} IS KNOWN"

    def fingerprint(self):
        return f"isknown({self.children[0].fingerprint()})"


class StrUpper(Expr):
    """Vectorized byte-map uppercase over fixed-width uint8 strings (VPU op)."""

    def __init__(self, child: Expr):
        self.children = (child,)

    def evaluate(self, env, params):
        v = self.children[0].evaluate(env, params)
        lower = (v >= ord("a")) & (v <= ord("z"))
        return jnp.where(lower, v - 32, v)

    def to_sql(self):
        return f"UPPER({self.children[0].to_sql()})"

    def fingerprint(self):
        return f"upper({self.children[0].fingerprint()})"


class StrLower(Expr):
    def __init__(self, child: Expr):
        self.children = (child,)

    def evaluate(self, env, params):
        v = self.children[0].evaluate(env, params)
        upper = (v >= ord("A")) & (v <= ord("Z"))
        return jnp.where(upper, v + 32, v)

    def to_sql(self):
        return f"LOWER({self.children[0].to_sql()})"

    def fingerprint(self):
        return f"lower({self.children[0].fingerprint()})"


class ElementwiseUDF(Expr):
    """A user JAX function applied elementwise to one or more columns
    (AFrame's per-row ``map``; the engine-side UDF of paper §III-C)."""

    def __init__(self, fn: Callable, name: str, *children: Expr):
        self.fn, self.name, self.children = fn, name, tuple(children)

    def evaluate(self, env, params):
        return self.fn(*[c.evaluate(env, params) for c in self.children])

    def to_sql(self):
        args = ", ".join(c.to_sql() for c in self.children)
        return f"{self.name}({args})"

    def fingerprint(self):
        inner = ",".join(c.fingerprint() for c in self.children)
        return f"udf({self.name},{inner})"


class ModelUDF(Expr):
    """Apply a registered JAX *model* to a (rows, seq) token column —
    the paper's sklearn/CoreNLP sentiment UDF (§III-C), except the model is
    a repro/models architecture running TP-sharded inside the query program.

    The callable is resolved from the UDF registry at compile time; it maps
    (rows, seq) int32 -> (rows,) prediction. Batching/microbatching is the
    compiler's job (udf/model_udf.py)."""

    def __init__(self, model_name: str, child: Expr):
        self.model_name, self.children = model_name, (child,)

    def evaluate(self, env, params):
        from repro.udf.model_udf import get_udf

        return get_udf(self.model_name)(self.children[0].evaluate(env, params))

    def to_sql(self):
        return f"{self.model_name}({self.children[0].to_sql()})"

    def fingerprint(self):
        return f"model({self.model_name},{self.children[0].fingerprint()})"


# ---------------------------------------------------------------------------


def ordered_lits(exprs: Sequence[Expr]) -> list[Lit]:
    """Every literal in plan order, *without* assigning slots (used to read a
    fresh plan instance's literal values on a plan-cache hit)."""
    lits: list[Lit] = []
    for e in exprs:
        lits.extend(e.literals())
    return lits


def collect_params(exprs: Sequence[Expr]) -> list[Lit]:
    """Assign param slots to every literal in plan order; returns the slots."""
    lits = ordered_lits(exprs)
    for i, lit in enumerate(lits):
        lit.slot = i
    return lits


def encode_param(v: Any) -> jax.Array:
    if isinstance(v, str):
        from repro.engine.table import encode_strings

        return jnp.asarray(encode_strings([v])[0])
    return jnp.asarray(v)


def param_values(lits: Sequence[Lit]) -> list[jax.Array]:
    return [encode_param(lit.value) for lit in lits]
