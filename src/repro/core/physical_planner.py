"""Cost-based physical planner: optimized logical plan → physical plan.

This is the layer AsterixDB's rule+cost optimizer provides and the paper's
AFrame rides on: the logical optimizer (core/optimizer.py) only *rewrites*
(filter fusion, limit pushdown, feed expansion, union pushdown); every
access-path and execution-strategy decision is made here, by comparing
estimated costs from the unified statistics layer (core/stats.py):

  * COUNT over a predicate — ``IndexOnlyCount`` (two binary searches) vs.
    ``KernelRangeCount`` (fused filter_count Pallas launch) vs.
    ``MaskCount`` (generic full scan): the planner costs all valid
    candidates and keeps the cheapest, instead of encoding the preference
    as rewrite-rule priority.
  * GroupAgg — ``KernelSegmentAgg`` (one-hot-matmul segment kernel, gated
    on a static f32-exactness proof) vs. ``GroupAggGeneric``.
  * JoinCount — merge_join kernel (int32-safety proof) vs. generic
    sort+searchsorted, presorted build side detected from index stats.
  * LSM unions — **zone-map run pruning**: at bind time, every run whose
    column zone span ``[lo, hi]`` misses the bound predicate range is
    dropped from the plan entirely (``PrunedUnionRuns``/``MergeScalars``
    record the rationale). Pruning never changes results: a pruned run
    provably contributes zero live rows.

Pruning depends on *literal values* (runtime parameters), so it cannot be
baked into the optimized-plan cache entry. The split:

  * ``build_pruner`` runs once per (logical plan, stats epoch): it extracts
    the prunable-union descriptors (component zone spans + the literal slots
    that bound each column).
  * ``Pruner.decide`` runs per execution with the fresh literal values —
    a few interval overlap tests — and yields the **prune signature** the
    Session's third cache level is keyed by, plus the per-run rationale.

Everything else in the cost model is deterministic given (logical
fingerprint, stats epoch, prune signature) — selectivities come from
distinct counts and default fractions, never from literal values — so a
cached executable is always the one this planner would rebuild.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.core import physical as PH
from repro.core import plan as P
from repro.core.catalog import Catalog
from repro.core.expr import Col, Compare, Expr, IsIn, Lit
from repro.core.optimizer import (_RANGE_MAX, _RANGE_MIN, _range_bounds,
                                  _split_conjuncts)
from repro.core.stats import ColumnStats, TableStats, harvest
from repro.engine.table import (canon_string, dict_lane_name, encode_strings,
                                pack_prefix, prefix_lane_name)
from repro.runtime import telemetry as tel

# -- cost model --------------------------------------------------------------
# Units: ~relative per-row work of a generic masked scan. The absolute scale
# is irrelevant; only ratios steer the plan choice.

C_ROW_SCAN = 1.0       # generic stream: evaluate predicate columns, mask
C_ROW_KERNEL = 0.35    # fused Pallas kernel row (single tiled pass, no HBM mask)
C_ROW_GROUP = 2.0      # segment reduction per row
C_ROW_SORT = 8.0       # full-sort per row (n log n folded into the constant)
C_ROW_JOIN = 4.0       # sort+searchsorted join per row
C_KERNEL_LAUNCH = 64.0  # fixed per kernel launch
C_PROBE = 24.0         # one binary-search probe pair (per component)
C_TOMBSTONE = 0.05     # per anti-matter key: one probe pair in a batched
#                        searchsorted (visibility masks / shadow subtraction)

DEFAULT_EQ_SELECTIVITY = 0.1
DEFAULT_RANGE_SELECTIVITY = 0.33
_F32_EXACT = 1 << 24   # ints in [-2^24, 2^24] are exact in float32

# Read-amplification thresholds (the mutation follow-up): a query over a fed
# dataset pays one access-path probe per component plus one batched probe per
# retained tombstone. When either grows past these bounds the per-query tax
# exceeds what one compaction would amortize — explain() says so.
READ_AMP_COMPONENTS = 6        # components probed per query
READ_AMP_TOMBSTONE_FRAC = 0.25  # tombstones / visible rows

# Write-stall early warning: the ingest path hard-stalls writers at
# ~2× max_runs resident components (Feed.stall_runs). The planner sees the
# same component count through its probe charge, so it can warn *before*
# the cap: stall pressure = components probed / STALL_COMPONENT_CAP, with a
# note once pressure crosses STALL_WARN_FRAC.
STALL_COMPONENT_CAP = 2 * READ_AMP_COMPONENTS
STALL_WARN_FRAC = 0.75


def _conjunct_selectivity(c: Expr, stats: TableStats) -> float:
    """Deterministic textbook selectivity from stats alone (literal values
    are runtime params — the executable must not depend on them)."""
    if isinstance(c, IsIn):
        l = c.children[0]
        if not isinstance(l, Col):
            return 1.0
        k = len(c.values)
        cs = stats.column(l.name)
        if cs is not None and cs.distinct:
            return min(k / max(cs.distinct, 1), 1.0)
        return min(k * DEFAULT_EQ_SELECTIVITY, 1.0)
    if not isinstance(c, Compare):
        return 1.0
    l, r = c.children
    if not (isinstance(l, Col) and isinstance(r, Lit)):
        return 1.0
    cs = stats.column(l.name)
    if c.op == "==":
        if cs is not None and cs.distinct:
            return 1.0 / max(cs.distinct, 1)
        return DEFAULT_EQ_SELECTIVITY
    if c.op == "!=":
        return 1.0 - (_conjunct_selectivity(Compare("==", l, r), stats))
    return DEFAULT_RANGE_SELECTIVITY


def _filter_selectivity(pred: Optional[Expr], stats: TableStats) -> float:
    if pred is None:
        return 1.0
    sel = 1.0
    for c in _split_conjuncts(pred):
        sel *= _conjunct_selectivity(c, stats)
    return sel


# -- bind-time zone-map pruning ----------------------------------------------


def _prefix_xform(v):
    """Bind-time transform for string constraints routed through a
    ``__pfx_<col>`` lane: the big-endian pack of the literal's first
    PREFIX_BYTES encoded bytes. Order-preserving over the space-padded
    encoding, so span tests against prefix-lane zone maps are conservative-
    correct for ==/IN (a prefix miss proves the full string cannot match).
    Non-string values return None — the constraint then simply doesn't
    apply (literal rebinding may swap a string for an int)."""
    if not isinstance(v, str):
        return None
    return int(pack_prefix(encode_strings([v]))[0])


@dataclasses.dataclass(frozen=True)
class _Constraint:
    """One ``col <op> lit`` conjunct constraining a union component. ``ref``
    resolves the literal at bind time: ("raw", i) reads the i-th literal of
    the raw plan, ("const", v) is a plan constant. Op "in" carries a
    ("many", (ref, ...)) set — it excludes only when EVERY member misses.
    ``xform`` (prefix-lane twins) maps each resolved value into the lane's
    integer domain before the interval tests."""

    column: str
    op: str
    ref: tuple
    xform: object = None

    def value(self, raw_values: list):
        kind, v = self.ref
        if kind == "many":
            vals = tuple(raw_values[i] if k == "raw" else i for k, i in v)
            if self.xform is not None:
                vals = tuple(self.xform(x) for x in vals)
                if any(x is None for x in vals):
                    return None
            return vals
        out = raw_values[v] if kind == "raw" else v
        return self.xform(out) if self.xform is not None else out

    def excludes(self, span: tuple, v) -> bool:
        """True when the component's zone span proves zero matching rows."""
        lo, hi = span
        if self.op == "==":
            return v < lo or v > hi
        if self.op == "in":
            return all(x < lo or x > hi for x in v)
        if self.op == ">=":
            return hi < v
        if self.op == ">":
            return hi <= v
        if self.op == "<=":
            return lo > v
        if self.op == "<":
            return lo >= v
        return False

    def block_keep(self, spans: np.ndarray, v) -> np.ndarray:
        """Vectorized per-block form of (not excludes): ``spans`` is the
        (n_blocks, 2) [lo, hi] zone-map array; returns the boolean keep mask.
        Empty blocks carry the [max, min] sentinel and fail every test."""
        lo, hi = spans[:, 0], spans[:, 1]
        if self.op == "==":
            return (lo <= v) & (v <= hi)
        if self.op == "in":
            keep = np.zeros(spans.shape[0], bool)
            for x in v:
                keep |= (lo <= x) & (x <= hi)
            return keep
        if self.op == ">=":
            return hi >= v
        if self.op == ">":
            return hi > v
        if self.op == "<=":
            return lo <= v
        if self.op == "<":
            return lo < v
        return np.ones(spans.shape[0], bool)

    def bound_repr(self, v) -> tuple:
        if self.op == "in":
            return (min(v), max(v)) if v else ("∅", "∅")
        return {"==": (v, v), ">=": (v, "+∞"), ">": (f">{v}", "+∞"),
                "<=": ("-∞", v), "<": ("-∞", f"<{v}")}[self.op]


@dataclasses.dataclass
class _CompDesc:
    address: str
    rows: int
    spans: dict[str, tuple]
    constraints: list[_Constraint]
    prunable: bool
    tombstones: int = 0  # anti-matter the component retains even when its
    #                      matter is pruned (key-visibility reasoning: a span
    #                      miss proves zero visible MATTER, never zero
    #                      annihilation into older components)


@dataclasses.dataclass
class _UnionDesc:
    ordinal: int
    comps: list[_CompDesc]


@dataclasses.dataclass
class _ScanDesc:
    """Block-skip opportunity for one Scan site: its component's per-block
    zone maps plus the provenance-proven ``col <op> lit`` conjuncts applied
    above it. The second level of the pruning hierarchy — run-level pruning
    drops whole components, this refines what survives down to blocks."""

    ordinal: int                 # scan ordinal (walk order over the opt plan)
    address: str
    n_blocks: int
    zone_block: int
    spans: dict                  # column -> (n_blocks, 2) zone array
    constraints: list[_Constraint]
    n_shards: int = 1            # mesh row partitions the layout was built for
    rows_per_shard: int = 0


class PruneDecisions:
    """Bind-time pruning outcome: per union ordinal, the surviving component
    indices and the zone-map rationale for each dropped run; per scan
    ordinal, the surviving block-id list of the intra-component refinement.
    ``signature`` keys the Session's third cache level — block lists are in
    it because they are static plan structure (kernel grids / gather slices
    bake them in)."""

    def __init__(self, by_union: dict[int, tuple[tuple, tuple]],
                 blocks: Optional[dict] = None):
        self.by_union = by_union
        self.blocks = blocks or {}
        self.signature = (
            tuple(sorted((k, tuple(surv))
                         for k, (surv, _) in by_union.items())),
            tuple(sorted(self.blocks.items())))

    def surviving(self, ordinal: int, n: int) -> tuple:
        if ordinal not in self.by_union:
            return tuple(range(n))
        return self.by_union[ordinal][0]

    def pruned(self, ordinal: int) -> tuple:
        if ordinal not in self.by_union:
            return ()
        return self.by_union[ordinal][1]

    def block_ids(self, scan_ordinal: int) -> Optional[tuple]:
        return self.blocks.get(scan_ordinal)


NO_PRUNE = PruneDecisions({})


def _numeric(v) -> bool:
    """Bind-time type gate for the interval tests: a scalar number, or (op
    "in") a non-empty tuple of numbers. A rebound literal of any other type
    (or an xform that refused it) silently opts the constraint out."""
    if isinstance(v, tuple):
        return len(v) > 0 and all(_numeric(x) for x in v)
    return isinstance(v, (int, float, np.integer, np.floating))


class Pruner:
    """Extracted once per (optimized plan, stats epoch); ``decide`` is the
    cheap per-execution pass (pure interval arithmetic on python scalars,
    plus one O(n_blocks) vector test per constrained scan)."""

    def __init__(self, unions: list[_UnionDesc],
                 scans: Optional[list[_ScanDesc]] = None):
        self.unions = unions
        self.scans = scans or []

    @property
    def has_prunable(self) -> bool:
        return any(c.prunable and c.constraints for u in self.unions
                   for c in u.comps)

    def decide(self, raw_values: list,
               block_skip: bool = True) -> PruneDecisions:
        by_union: dict[int, tuple[tuple, tuple]] = {}
        for u in self.unions:
            surviving: list[int] = []
            pruned: list[PH.PrunedComponent] = []
            for i, comp in enumerate(u.comps):
                record = None
                if comp.prunable:
                    for con in comp.constraints:
                        span = comp.spans.get(con.column)
                        if span is None:
                            continue
                        v = con.value(raw_values)
                        if v is None or not _numeric(v):
                            continue
                        if con.excludes(span, v):
                            record = PH.PrunedComponent(
                                address=comp.address, column=con.column,
                                span=span, bound=con.bound_repr(v),
                                rows=comp.rows, tombstones=comp.tombstones)
                            break
                if record is None:
                    surviving.append(i)
                else:
                    pruned.append(record)
            if not surviving:
                # keep the first component: the merged identity result
                # (count 0 / ±inf extremes) must still be computed on-device,
                # bit-identical to the unpruned all-empty execution.
                surviving = [0]
                pruned = [r for r in pruned if r.address != u.comps[0].address]
            by_union[u.ordinal] = (tuple(surviving), tuple(pruned))
        blocks: dict[int, tuple] = {}
        if block_skip:
            for d in self.scans:
                keep = np.ones(d.n_blocks, bool)
                applied = False
                for con in d.constraints:
                    spans = d.spans.get(con.column)
                    if spans is None:
                        continue
                    v = con.value(raw_values)
                    if v is None or not _numeric(v):
                        continue
                    applied = True
                    keep &= con.block_keep(spans, v)
                if not applied or keep.all():
                    continue
                ids = tuple(int(b) for b in np.nonzero(keep)[0])
                # keep at least one block: a zero-size kernel grid never
                # initializes its accumulator, and downstream static shapes
                # need >= 1 row. An extra surviving block never changes the
                # result — its rows simply fail the predicate.
                blocks[d.ordinal] = ids if ids else (0,)
        return PruneDecisions(by_union, blocks)


def _origin_column(node: P.Plan, name: str) -> Optional[str]:
    """Resolve a stream column name at ``node``'s output down to the STORED
    column it reads, following pure ``Col`` Project rebindings. None when the
    name is computed (UDF/arith) or shadowed — a predicate on such a column
    must never be matched against catalog spans by name (``df["k"] =
    df["v"]`` rebinds the name k to v's values; k's stored span is a lie)."""
    from repro.core.window import Window

    if isinstance(node, P.Scan):
        return name
    if isinstance(node, P.Project):
        for n, e in node.outputs:
            if n == name:
                if isinstance(e, Col):
                    return _origin_column(node.children[0], e.name)
                return None
        return None
    if isinstance(node, Window) and name == node.out_name:
        return None  # computed analytic column shadows any stored namesake
    if len(node.children) == 1:  # filter/limit/sort/window pass through
        return _origin_column(node.children[0], name)
    return None


def _identity_project(node: P.Plan) -> bool:
    """True for the narrow Projects column pruning inserts: every output is
    the same-named stored column (no renames, no computed expressions) — the
    only Project shape access-path planning may safely look through."""
    return isinstance(node, P.Project) and all(
        isinstance(e, Col) and e.name == n for n, e in node.outputs)


def _union_ordinals(opt: P.Plan) -> dict[int, int]:
    """Union nodes numbered in walk order — build_pruner and plan_physical
    must agree on the numbering."""
    out: dict[int, int] = {}
    for node in P.walk(opt):
        if isinstance(node, (P.UnionRuns, P.UnionScalar)):
            out[id(node)] = len(out)
    return out


def _scan_ordinals(opt: P.Plan) -> dict[int, int]:
    """Scan nodes numbered in walk order — the block-skip decisions are
    keyed by these, and build_pruner / plan_physical walk the same plan
    object so the numbering agrees."""
    out: dict[int, int] = {}
    for node in P.walk(opt):
        if isinstance(node, P.Scan):
            out[id(node)] = len(out)
    return out


def _scan_constraints(opt: P.Plan, lit_ref) -> dict[int, list[_Constraint]]:
    """Provenance-proven ``col <op> lit`` conjuncts per Scan site: a
    Filter/FilterCount contributes its conjuncts to the Scan it reaches
    through ROW-WISE nodes only (more Filters, Projects — renames resolved
    by ``_origin_column``; a rebound name never constrains the stored
    column). Anything positional between the filter and the scan (Limit,
    TopK, Sort+Limit, Window, a union, a join) breaks the chain: those
    operators consume rows by position, so pruning rows the *later* filter
    would drop could change which rows they emit."""
    out: dict[int, list[_Constraint]] = {}
    for node in P.walk(opt):
        pred = getattr(node, "predicate", None)
        if not isinstance(node, (P.Filter, P.FilterCount)) or pred is None:
            continue
        cur = node.children[0]
        while isinstance(cur, (P.Filter, P.Project)):
            cur = cur.children[0]
        if not isinstance(cur, P.Scan):
            continue
        scan = cur
        for c in _split_conjuncts(pred):
            if isinstance(c, IsIn):
                l = c.children[0]
                if isinstance(l, Col) and c.values \
                        and all(isinstance(v, Lit) for v in c.values):
                    origin = _origin_column(node.children[0], l.name)
                    if origin is not None:
                        out.setdefault(id(scan), []).append(_Constraint(
                            origin, "in",
                            ("many", tuple(lit_ref(v) for v in c.values))))
                continue
            if not isinstance(c, Compare):
                continue
            l, r = c.children
            if not (isinstance(l, Col) and isinstance(r, Lit)) \
                    or c.op not in ("==", ">=", ">", "<=", "<"):
                continue
            origin = _origin_column(node.children[0], l.name)
            if origin is not None:
                out.setdefault(id(scan), []).append(
                    _Constraint(origin, c.op, lit_ref(r)))
    return out


def _expand_string_constraints(cons, stats: TableStats) -> list[_Constraint]:
    """String ==/IN conjuncts prune through the ``__pfx_<col>`` lane: emit a
    twin constraint on the lane with the prefix-pack bind-time transform.
    Component-independent by construction (the pack is a pure function of
    the literal), unlike dict ids, which are per-component — so prefix lanes
    are the ONLY string pruning route here."""
    out = list(cons)
    for c in cons:
        if c.op not in ("==", "in") or c.xform is not None:
            continue
        cs = stats.column(c.column)
        if cs is None or not cs.is_string:
            continue
        lane = prefix_lane_name(c.column)
        if stats.column(lane) is None:
            continue
        out.append(dataclasses.replace(c, column=lane, xform=_prefix_xform))
    return out


def build_pruner(opt: P.Plan, catalog: Catalog, raw_lits: list,
                 n_shards: int = 1) -> Pruner:
    """Walk the optimized plan's LSM unions and describe every component's
    prune opportunity: its zone spans plus the ``col <op> lit`` conjuncts
    (from the pushed-down per-component filters) that bound it. A second
    pass describes every constrained Scan's *block-level* opportunity (the
    per-ZONE_BLOCK zone maps harvested at load/flush time) — including
    scans of plain, non-fed datasets, which have no run to prune but whole
    kernel tiles to skip.

    ``n_shards`` is the session mesh's row-partition count: a scan's block
    zones are usable only when harvested for the SAME layout (flat block ids
    address per-shard local tiles, so a mismatched layout would skip the
    wrong rows). Components harvested before a mesh change simply opt out of
    block skipping until re-harvested — run-level pruning is unaffected."""
    raw_index = {id(l): i for i, l in enumerate(raw_lits)}

    def lit_ref(lit: Lit) -> tuple:
        src = lit
        while id(src) not in raw_index and getattr(src, "source", None) is not None:
            src = src.source
        if id(src) in raw_index:
            return ("raw", raw_index[id(src)])
        return ("const", lit.value)

    per_scan = _scan_constraints(opt, lit_ref)
    unions: list[_UnionDesc] = []
    ordinals = _union_ordinals(opt)
    for node in P.walk(opt):
        if not isinstance(node, (P.UnionRuns, P.UnionScalar)):
            continue
        comps: list[_CompDesc] = []
        for child in node.children:
            scans = [n for n in P.walk(child) if isinstance(n, P.Scan)]
            if len(scans) != 1:
                comps.append(_CompDesc("?", 0, {}, [], prunable=False))
                continue
            scan = scans[0]
            try:
                stats = harvest(catalog.get(scan.dataverse, scan.dataset))
            except KeyError:
                comps.append(_CompDesc("?", 0, {}, [], prunable=False))
                continue
            spans = {name: cs.span for name, cs in stats.columns.items()
                     if cs.span is not None and not cs.is_string}
            cons_all = _expand_string_constraints(
                per_scan.get(id(scan), ()), stats)
            constraints = [c for c in cons_all if c.column in spans]
            comps.append(_CompDesc(stats.address, stats.rows, spans,
                                   constraints, prunable=True,
                                   tombstones=stats.tombstones))
        unions.append(_UnionDesc(ordinals[id(node)], comps))
    scan_descs: list[_ScanDesc] = []
    scan_ords = _scan_ordinals(opt)
    for node in P.walk(opt):
        if not isinstance(node, P.Scan):
            continue
        cons = per_scan.get(id(node))
        if not cons:
            continue
        try:
            stats = harvest(catalog.get(node.dataverse, node.dataset))
        except KeyError:
            continue
        bz = stats.block_zones
        if bz is None or bz.n_blocks <= 1:
            continue  # a single block can never be skipped
        if bz.n_shards != max(n_shards, 1):
            continue  # zone layout predates the mesh: ids would be wrong
        cons = _expand_string_constraints(cons, stats)
        usable = [c for c in cons if c.column in bz.spans]
        if usable:
            scan_descs.append(_ScanDesc(scan_ords[id(node)], stats.address,
                                        bz.n_blocks, bz.block, dict(bz.spans),
                                        usable, bz.n_shards,
                                        bz.rows_per_shard))
    return Pruner(unions, scan_descs)


# -- the planner -------------------------------------------------------------


class _PlannerCtx:
    def __init__(self, catalog: Catalog, mode: str, decisions: PruneDecisions,
                 enable_index: bool):
        self.catalog = catalog
        self.mode = mode
        self.decisions = decisions
        self.enable_index = enable_index
        self.ordinals: dict[int, int] = {}
        self.scan_ordinals: dict[int, int] = {}

    def stats(self, dataverse: str, dataset: str) -> Optional[TableStats]:
        try:
            return harvest(self.catalog.get(dataverse, dataset))
        except KeyError:
            return None

    def scan_blocks(self, scan: P.Plan) -> Optional[tuple]:
        """Surviving block ids of the bind-time block zone-map test for this
        Scan site (None = no skipping)."""
        ordinal = self.scan_ordinals.get(id(scan))
        if ordinal is None:
            return None
        return self.decisions.block_ids(ordinal)

    @property
    def kernels(self) -> bool:
        return self.mode == "kernel"


def plan_physical(opt: P.Plan, catalog: Catalog, *, mode: str = "gspmd",
                  decisions: PruneDecisions = NO_PRUNE,
                  enable_index: bool = True) -> PH.PhysOp:
    """Logical (optimized) plan → costed physical plan. ``decisions`` is the
    bind-time pruning outcome; the returned plan reads only surviving
    components, and only their surviving blocks."""
    ctx = _PlannerCtx(catalog, mode, decisions, enable_index)
    ctx.ordinals = _union_ordinals(opt)
    ctx.scan_ordinals = _scan_ordinals(opt)
    return _plan_terminal(opt, ctx)


# -- stream planning ---------------------------------------------------------


def _scan_stats(ctx: _PlannerCtx, node) -> Optional[TableStats]:
    return ctx.stats(node.dataverse, node.dataset)


def _component_shadow(ctx: _PlannerCtx, dataverse: str, dataset: str):
    """Anti-matter shadowing info for one LSM component: the primary key the
    visibility probes compare on, the strictly-newer components that hold
    tombstones (their anti sets must subtract from this component), and the
    total tombstone count (for costing). Newest-wins is an ORDER property:
    base < run0 < run1 < …, and only newer anti-matter annihilates."""
    base_name = dataset.split("@")[0]
    try:
        comps = ctx.catalog.components(dataverse, base_name)
    except KeyError:
        return None, (), 0
    primary = comps[0].primary_index
    if primary is None or len(comps) == 1:
        return (primary.column if primary is not None else None), (), 0
    # locate this component by its stable address IN the bound manifest's
    # order — uids are creation-ordered, not positional, so "newer than"
    # is a position property of the pinned component tuple
    names = [c.name for c in comps]
    try:
        ordinal = names.index(dataset) if "@" in dataset else 0
    except ValueError:  # address not served by this manifest
        return primary.column, (), 0
    sources: list[tuple[str, str]] = []
    total = 0
    for r in comps[ordinal + 1:]:
        if r.anti_rows:
            sources.append((dataverse, r.name))
            total += r.anti_rows
    return primary.column, tuple(sources), total


def _plan_scan(node: P.Scan, ctx: _PlannerCtx) -> PH.PhysOp:
    stats = _scan_stats(ctx, node)
    ds = ctx.catalog.get(node.dataverse, node.dataset)
    key_col, shadow, n_anti = _component_shadow(ctx, node.dataverse,
                                                node.dataset)
    out = PH.TableScan(node.dataverse, node.dataset, open_cast=not ds.closed,
                       key_col=key_col if shadow else None,
                       shadow_sources=shadow)
    if stats is not None:
        out.est_rows = stats.rows
        out.rows_touched = stats.padded_rows
        out.cost = stats.padded_rows * C_ROW_SCAN + n_anti * C_TOMBSTONE
        bz = stats.block_zones
        blocks = ctx.scan_blocks(node)
        if bz is not None:
            out.set_blocks(blocks, bz.block, bz.n_blocks,
                           n_shards=bz.n_shards,
                           rows_per_shard=bz.rows_per_shard)
        if blocks is not None and bz is not None:
            # discount the scan by the surviving fraction: the lowering
            # streams only these blocks (skipped blocks provably hold no
            # rows passing the conjuncts the list was derived from).
            frac = len(blocks) / bz.n_blocks
            out.rows_touched = min(stats.padded_rows,
                                   len(blocks) * bz.block)
            out.est_rows = max(stats.rows * frac, 1)
            out.cost = out.rows_touched * C_ROW_SCAN + n_anti * C_TOMBSTONE
            out.note = out.block_note()
    if shadow:
        note = (f"newest-wins: {n_anti} tombstone(s) in "
                f"{len(shadow)} newer component(s) subtract from this "
                f"scan's mask")
        out.note = (out.note + " — " if out.note else "") + note
    return out


def _plan_filter(node: P.Filter, ctx: _PlannerCtx) -> PH.PhysOp:
    """Stream filter: an ``IndexProbe`` access path when an indexed column is
    range-bound (remaining conjuncts stay residual), generic mask otherwise.
    Both stream every physical row — the probe's value is the tighter
    cardinality estimate it gives operators above (and the count path)."""
    inner = node.children[0]
    proj = None
    if _identity_project(inner) and isinstance(inner.children[0], P.Scan):
        # look through the narrow Project column pruning inserted (identity
        # outputs only — a renaming Project would change what names mean)
        proj, inner = inner, inner.children[0]
    if ctx.enable_index and isinstance(inner, P.Scan):
        stats = _scan_stats(ctx, inner)
        if stats is not None:
            conjuncts = _split_conjuncts(node.predicate)
            for colname, cs in stats.columns.items():
                if cs.index is None:
                    continue
                found = _range_bounds(conjuncts, colname)
                if found is None:
                    continue
                lo, hi, residual = found
                res_expr = None
                for r in residual:
                    from repro.core.expr import BoolOp
                    res_expr = r if res_expr is None else BoolOp("AND", res_expr, r)
                ds = ctx.catalog.get(inner.dataverse, inner.dataset)
                key_col, shadow, n_anti = _component_shadow(
                    ctx, inner.dataverse, inner.dataset)
                probe = PH.IndexProbe(inner.dataverse, inner.dataset, colname,
                                      lo, hi, res_expr, open_cast=not ds.closed,
                                      key_col=key_col if shadow else None,
                                      shadow_sources=shadow)
                probe.est_rows = max(
                    stats.rows * _filter_selectivity(node.predicate, stats), 1)
                probe.rows_touched = stats.padded_rows
                probe.cost = stats.padded_rows * C_ROW_SCAN \
                    + n_anti * C_TOMBSTONE
                probe.note = f"index {cs.index}:{colname} bounds the stream"
                bz = stats.block_zones
                blocks = ctx.scan_blocks(inner)
                if bz is not None:
                    probe.set_blocks(blocks, bz.block, bz.n_blocks,
                                     n_shards=bz.n_shards,
                                     rows_per_shard=bz.rows_per_shard)
                if blocks is not None and bz is not None:
                    # literal-aware refinement: the bind-time zone test
                    # already intersected the predicate's literals with the
                    # per-block spans, so the surviving-block fraction is a
                    # tighter (and signature-stable — block lists are in the
                    # prune signature) selectivity than the stats default.
                    frac = len(blocks) / bz.n_blocks
                    probe.rows_touched = min(stats.padded_rows,
                                             len(blocks) * bz.block)
                    probe.est_rows = max(min(probe.est_rows,
                                             stats.rows * frac), 1)
                    probe.cost = probe.rows_touched * C_ROW_SCAN \
                        + n_anti * C_TOMBSTONE
                    probe.note += " — " + probe.block_note()
                if shadow:
                    probe.note += (f" — {n_anti} newer tombstone(s) subtract "
                                   f"from the mask")
                if proj is None:
                    return probe
                # mask-then-project ≡ project-then-mask for identity outputs
                out = PH.ProjectCols(probe, proj.outputs)
                out.est_rows = probe.est_rows
                out.cost = probe.est_rows * 0.1 * len(proj.outputs)
                return out
    child = _plan_stream(node.children[0], ctx)
    out = PH.FullScanFilter(child, node.predicate)
    stats0 = _leaf_stats(child, ctx)
    sel = _filter_selectivity(node.predicate, stats0) if stats0 else 0.5
    out.est_rows = max(child.est_rows * sel, 1)
    out.rows_touched = child.est_rows
    out.cost = child.est_rows * 0.2
    return out


def _leaf_stats(phys: PH.PhysOp, ctx: _PlannerCtx) -> Optional[TableStats]:
    for n in PH.walk(phys):
        key = getattr(n, "source_key", None)
        if key is not None:
            return ctx.stats(*key)
    return None


def _plan_stream(node: P.Plan, ctx: _PlannerCtx) -> PH.PhysOp:
    from repro.core.window import Window

    if isinstance(node, P.Scan):
        return _plan_scan(node, ctx)

    if isinstance(node, P.Filter):
        return _plan_filter(node, ctx)

    if isinstance(node, P.Project):
        child = _plan_stream(node.children[0], ctx)
        out = PH.ProjectCols(child, node.outputs)
        out.est_rows = child.est_rows
        out.cost = child.est_rows * 0.1 * len(node.outputs)
        return out

    if isinstance(node, P.Limit):
        child = _plan_stream(node.children[0], ctx)
        out = PH.LimitRows(child, node.n)
        out.est_rows = min(node.n, child.est_rows or node.n)
        out.cost = child.est_rows * 0.1
        return out

    if isinstance(node, P.TopK):
        child = _plan_stream(node.children[0], ctx)
        out = PH.TopKSelect(child, node.key, node.k, node.ascending,
                            kernel=ctx.kernels)
        out.est_rows = min(node.k, child.est_rows or node.k)
        out.cost = child.est_rows * (C_ROW_KERNEL if ctx.kernels else C_ROW_SCAN)
        if ctx.kernels:
            out.cost += C_KERNEL_LAUNCH
            out.note = "block_topk kernel selection"
        return out

    if isinstance(node, P.Sort):
        child = _plan_stream(node.children[0], ctx)
        out = PH.SortRows(child, node.key, node.ascending)
        out.est_rows = child.est_rows
        out.cost = child.est_rows * C_ROW_SORT
        return out

    if isinstance(node, Window):
        child = _plan_stream(node.children[0], ctx)
        out = PH.WindowEval(child, node)
        out.est_rows = child.est_rows
        out.cost = child.est_rows * C_ROW_SORT
        return out

    if isinstance(node, P.UnionRuns):
        return _plan_union_runs(node, ctx)

    if isinstance(node, P.GroupAgg):
        return _plan_groupagg(node, ctx)

    if isinstance(node, P.Join):
        _check_join_materializable(node, ctx)
        left = _plan_stream(node.children[0], ctx)
        right = _plan_stream(node.children[1], ctx)
        out = PH.JoinGather(left, right, node.left_on, node.right_on)
        out.est_rows = left.est_rows
        out.cost = (left.est_rows + right.est_rows) * C_ROW_JOIN
        return out

    raise NotImplementedError(f"no physical plan for {type(node).__name__}")


def _charge_read_amp(ctx: _PlannerCtx, out: PH.PhysOp, kids: list) -> None:
    """The read-amplification cost term (mutation follow-up): every query
    over a fed dataset pays one access-path probe per surviving component
    plus one batched searchsorted probe per resident tombstone. The per-
    component per-tombstone charges already live on the scans; this charges
    the *union-level* probing tax and flags when a compaction would pay for
    itself within a handful of queries."""
    probes = 0
    tombstones = visible = 0
    for k in kids:
        st = _leaf_stats(k, ctx)
        if st is None:
            continue
        probes += 1
        tombstones += st.tombstones
        visible += st.rows
    tombstones += sum(p.tombstones for p in getattr(out, "pruned", ()))
    out.cost += probes * C_PROBE
    out.stall_pressure = probes / STALL_COMPONENT_CAP
    tel.set_gauge("planner.stall_pressure", out.stall_pressure)
    amp = probes > READ_AMP_COMPONENTS or (
        visible > 0 and tombstones / visible > READ_AMP_TOMBSTONE_FRAC)
    if amp:
        out.compaction_recommended = True
        note = (f"read amplification: {probes} component probe(s), "
                f"{tombstones} tombstone(s) subtract per query — "
                f"compaction recommended")
        out.note = (out.note + " — " if out.note else "") + note
    if out.stall_pressure >= STALL_WARN_FRAC:
        out.stall_imminent = True
        note = (f"stall imminent: {probes}/{STALL_COMPONENT_CAP} components "
                f"toward the write-stall cap "
                f"(pressure {out.stall_pressure:.2f})")
        out.note = (out.note + " — " if out.note else "") + note


def _plan_union_runs(node: P.UnionRuns, ctx: _PlannerCtx) -> PH.PhysOp:
    ordinal = ctx.ordinals.get(id(node), -1)
    surviving = ctx.decisions.surviving(ordinal, len(node.children))
    pruned = ctx.decisions.pruned(ordinal)
    kids = [_plan_stream(node.children[i], ctx) for i in surviving]
    out = PH.PrunedUnionRuns(kids, pruned)
    out.est_rows = sum(k.est_rows for k in kids)
    out.cost = out.est_rows * 0.05
    if pruned:
        out.note = (f"zone maps pruned {len(pruned)}/{len(node.children)} "
                    f"components ({sum(p.rows for p in pruned):,} rows skipped)")
    _charge_read_amp(ctx, out, kids)
    return out


# -- join guards (moved from the compiler: they are *planning* decisions) ----


def _check_join_materializable(node: P.Join, ctx: _PlannerCtx) -> None:
    """Materializing joins require unique build keys (static shapes: each
    probe row gathers ≤1 match). A fed build side contributes base + runs, so
    every component must be internally unique AND the component key ranges
    pairwise disjoint — proven from catalog stats or refused."""
    scans = [l for l in P.walk(node.children[1]) if isinstance(l, P.Scan)]
    if not scans:
        return
    first = scans[0].dataset.split("@")[0]
    comps = [l for l in scans if l.dataverse == scans[0].dataverse
             and l.dataset.split("@")[0] == first]
    ranges = []
    for leaf in comps:
        stats = _scan_stats(ctx, leaf)
        cs = stats.column(node.right_on) if stats is not None else None
        if cs is None:
            continue
        if cs.distinct is not None and cs.distinct < stats.rows:
            raise NotImplementedError(
                f"materializing join on non-unique key "
                f"{node.right_on!r} (distinct={cs.distinct} < "
                f"rows={stats.rows}); COUNT over such joins is "
                "supported (join-count path)")
        if cs.lo is not None:
            ranges.append((cs.lo, cs.hi))
    if len(comps) > 1:
        if len(ranges) < len(comps):
            raise NotImplementedError(
                f"materializing join against a fed dataset needs "
                f"key bounds on {node.right_on!r} to prove the LSM "
                "components disjoint")
        for i, (lo_a, hi_a) in enumerate(ranges):
            for lo_b, hi_b in ranges[i + 1:]:
                if lo_a <= hi_b and lo_b <= hi_a:
                    raise NotImplementedError(
                        f"materializing join key {node.right_on!r} "
                        "may repeat across LSM components "
                        f"(overlapping bounds); compact first or "
                        "use COUNT (join-count path)")


def _join_key_int32_safe(side: P.Plan, col: str, ctx: _PlannerCtx) -> bool:
    """True when stats prove the join key casts to int32 losslessly (the
    merge_join kernel's tile dtype). Every leaf carrying the column must
    pass — an LSM run can extend the base's domain."""
    i32 = np.iinfo(np.int32)
    metas: list[ColumnStats] = []
    for leaf in P.walk(side):
        if isinstance(leaf, P.Scan):
            stats = _scan_stats(ctx, leaf)
            cs = stats.column(col) if stats is not None else None
            if cs is not None:
                metas.append(cs)
    if not metas:
        return False
    for m in metas:
        if m.is_string or not np.issubdtype(m.dtype, np.integer):
            return False
        if m.lo is None or m.hi is None or m.lo < i32.min or m.hi > i32.max:
            return False
    return True


# -- terminal planning -------------------------------------------------------


def _plan_terminal(node: P.Plan, ctx: _PlannerCtx) -> PH.PhysOp:
    if isinstance(node, P.UnionScalar):
        ordinal = ctx.ordinals.get(id(node), -1)
        surviving = ctx.decisions.surviving(ordinal, len(node.children))
        pruned = ctx.decisions.pruned(ordinal)
        kids = [_plan_terminal(node.children[i], ctx) for i in surviving]
        out = PH.MergeScalars(kids, node.merges, pruned)
        out.est_rows = 1
        out.cost = len(kids) * 0.5
        if pruned:
            out.note = (f"zone maps pruned {len(pruned)}/{len(node.children)} "
                        f"components "
                        f"({sum(p.rows for p in pruned):,} rows skipped)")
        _charge_read_amp(ctx, out, kids)
        return out

    if isinstance(node, P.FilterCount):
        return _plan_count(node, ctx)

    if isinstance(node, P.JoinCount):
        return _plan_join_count(node.children[0], node.children[1],
                                node.left_on, node.right_on, ctx)

    if isinstance(node, P.Agg):
        # COUNT over a Join must use the duplicate-correct join-count path
        # even when the optimizer was disabled (semantics ≠ optimization).
        if len(node.aggs) == 1 and node.aggs[0].op == "count" \
                and isinstance(node.children[0], P.Join):
            j = node.children[0]
            return _plan_join_count(j.children[0], j.children[1],
                                    j.left_on, j.right_on, ctx)
        child = _plan_stream(node.children[0], ctx)
        out = PH.ScalarAgg(child, node.aggs)
        out.est_rows = 1
        out.cost = child.est_rows * 0.1 * len(node.aggs)
        return out

    if isinstance(node, P.GroupAgg):
        return _plan_groupagg(node, ctx)

    return _plan_stream(node, ctx)


def _plan_count(node: P.FilterCount, ctx: _PlannerCtx) -> PH.PhysOp:
    """The flagship costed decision: COUNT(pred) over one component picks the
    cheapest valid access path instead of the old rewrite-rule priority."""
    child = node.children[0]
    pred = node.predicate
    # index/kernel candidates may only look through IDENTITY Projects (the
    # narrow ones column pruning inserts): a renaming Project changes what
    # predicate names mean, and a candidate reading stored columns by those
    # names would count the wrong data — renames stay on the mask path.
    inner = child.children[0] if _identity_project(child) else child

    candidates: list[PH.PhysOp] = []
    if isinstance(inner, P.Scan) and pred is not None:
        stats = _scan_stats(ctx, inner)
        if stats is not None:
            conjuncts = _split_conjuncts(pred)
            sel = _filter_selectivity(pred, stats)
            key_col, shadow, n_anti = _component_shadow(
                ctx, inner.dataverse, inner.dataset)
            if ctx.enable_index:
                for colname, cs in stats.columns.items():
                    if cs.index is None:
                        continue
                    found = _range_bounds(conjuncts, colname)
                    if found is None:
                        continue
                    lo, hi, residual = found
                    if residual:
                        continue  # residual conjuncts: not index-only
                    if shadow and colname != key_col:
                        # newer anti-matter shadows rows of this component by
                        # PRIMARY key; a secondary index alone cannot tell
                        # which of its matching entries died — only the
                        # primary index supports index-only subtraction. The
                        # mask/kernel candidates below stay valid.
                        continue
                    cand: PH.PhysOp = PH.IndexOnlyCount(
                        inner.dataverse, inner.dataset, colname, lo, hi)
                    cand.est_rows = max(stats.rows * sel, 1)
                    cand.rows_touched = cand.est_rows
                    cand.cost = C_PROBE + math.log2(max(stats.padded_rows, 2))
                    cand.note = f"index-only: sorted {cs.index} index on {colname}"
                    if shadow:
                        sub = PH.ShadowProbeCount(inner.dataverse,
                                                  inner.dataset, colname,
                                                  lo, hi, shadow)
                        sub.est_rows = min(n_anti, cand.est_rows)
                        sub.cost = C_PROBE + n_anti * C_TOMBSTONE
                        sub.note = (f"{n_anti} tombstone(s) from "
                                    f"{len(shadow)} newer component(s) probe "
                                    f"the primary index")
                        wrapped = PH.SubtractScalars(cand, sub)
                        wrapped.est_rows = cand.est_rows
                        wrapped.cost = 0.5
                        wrapped.note = ("anti-matter subtraction: count = "
                                        "index-only matches − matches newer "
                                        "tombstones shadow")
                        cand = wrapped
                    candidates.append(cand)
            if ctx.kernels:
                krc = _try_kernel_range_count(inner, pred, stats, ctx,
                                              key_col if shadow else None,
                                              shadow)
                if krc is not None:
                    krc.est_rows = max(stats.rows * sel, 1)
                    krc.rows_touched = stats.padded_rows
                    notes = [krc.note] if krc.note else []
                    if krc.block_ids is not None:
                        # the kernel grid visits only surviving blocks: the
                        # launch cost scales with blocks scanned, not total.
                        krc.rows_touched = min(
                            stats.padded_rows,
                            len(krc.block_ids) * krc.zone_block)
                        krc.est_rows = max(
                            krc.est_rows * len(krc.block_ids)
                            / max(krc.blocks_total, 1), 1)
                        notes.append(krc.block_note())
                    krc.cost = C_KERNEL_LAUNCH \
                        + krc.rows_touched * C_ROW_KERNEL \
                        + n_anti * C_TOMBSTONE
                    if shadow:
                        notes.append(f"matter mask folds {n_anti} newer "
                                     f"tombstone(s) into one kernel row")
                    krc.note = " — ".join(notes)
                    candidates.append(krc)
                kic = _try_kernel_isin_count(inner, pred, stats, ctx,
                                             key_col if shadow else None,
                                             shadow)
                if kic is not None:
                    for kid in kic.children:
                        rt = stats.padded_rows
                        if kid.block_ids is not None:
                            rt = min(stats.padded_rows,
                                     len(kid.block_ids) * kid.zone_block)
                        kid.rows_touched = rt
                        kid.est_rows = max(
                            stats.rows * sel / len(kic.children), 1)
                        kid.cost = C_KERNEL_LAUNCH + rt * C_ROW_KERNEL \
                            + n_anti * C_TOMBSTONE
                    kic.est_rows = max(stats.rows * sel, 1)
                    kic.cost = 0.5 * len(kic.children)
                    candidates.append(kic)

    generic = PH.MaskCount(_plan_stream(child, ctx), pred)
    gstats = _leaf_stats(generic, ctx)
    gsel = _filter_selectivity(pred, gstats) if gstats is not None else 1.0
    generic.est_rows = max((gstats.rows if gstats else 0) * gsel, 0)
    generic.rows_touched = generic.children[0].est_rows
    generic.cost = generic.children[0].est_rows * 0.05
    candidates.append(generic)

    best = min(candidates, key=lambda c: c.total_cost())
    if len(candidates) > 1:
        alts = "; ".join(f"{type(c).__name__} cost={c.total_cost():,.0f}"
                         for c in candidates if c is not best)
        best.note = (best.note + " — " if best.note else "") + \
            f"chosen over {alts}"
    return best


def _dict_lane_stats(stats: TableStats, col: str) -> Optional[ColumnStats]:
    """The ``__dict_<col>`` lane's stats when the component dictionary-
    encodes ``col`` AND the lane passes the filter_count int32 proof
    (ids are 0..G-1, so the proof only fails on an empty dictionary)."""
    cs = stats.column(col)
    if cs is None or not cs.is_string or cs.dict_values is None:
        return None
    lcs = stats.column(dict_lane_name(col))
    if lcs is None or not np.issubdtype(lcs.dtype, np.integer) \
            or lcs.lo is None or lcs.hi is None \
            or lcs.lo < _RANGE_MIN or lcs.hi > _RANGE_MAX:
        return None
    return lcs


def _dict_eq_binders(values: tuple):
    """lo/hi bind-time transforms for ``col == lit`` on the dict-id lane:
    a present literal binds both bounds to its id; an absent one binds the
    empty range [1, 0] — the kernel then counts zero rows, exactly what the
    full-width comparison would. Literals are canonicalized to stored form
    first (ascii, width-truncated, padding stripped) so e.g. a
    trailing-space literal binds to the same id its encoded row matches."""
    pos = {v: i for i, v in enumerate(values)}

    def lo(v):
        return pos.get(canon_string(v), 1)

    def hi(v):
        return pos.get(canon_string(v), 0)

    return lo, hi


def _isin_binders(pos: dict, j: int):
    """lo/hi transforms for member ``j`` of an IN list. Each binder sees ALL
    sibling values, so a duplicate of an earlier member (or an absent value)
    binds the empty range — per-member counts stay disjoint and their sum
    never double-counts. Members are compared in canonical stored form, so
    two spellings that encode to the same row count as duplicates."""
    def lo(*vals):
        v = canon_string(vals[j])
        return 1 if v in map(canon_string, vals[:j]) or v not in pos \
            else pos[v]

    def hi(*vals):
        v = canon_string(vals[j])
        return 0 if v in map(canon_string, vals[:j]) or v not in pos \
            else pos[v]

    return lo, hi


def _try_kernel_range_count(scan: P.Scan, pred: Expr, stats: TableStats,
                            ctx: _PlannerCtx,
                            key_col: Optional[str] = None,
                            shadow_sources: tuple = ()
                            ) -> Optional[PH.KernelRangeCount]:
    """COUNT whose predicate fully decomposes into ``Col {==,>=,<=} Lit``
    conjuncts on int32-provable integer columns → filter_count kernel.
    String equality on a dictionary-encoded column joins the fast path as
    an ordinary int conjunct on the ``__dict_<col>`` id lane (the literal
    binds to its sorted-dictionary id). Partial matches never fuse
    (graceful fallback to the mask path)."""
    cols: list[str] = []
    los: list[Expr] = []
    his: list[Expr] = []
    notes: list[str] = []
    for c in _split_conjuncts(pred):
        if not isinstance(c, Compare):
            return None
        l, r = c.children
        if not (isinstance(l, Col) and isinstance(r, Lit)):
            return None
        cs = stats.column(l.name)
        if cs is None:
            return None
        if cs.is_string:
            if c.op != "==" or not isinstance(r.value, str) \
                    or _dict_lane_stats(stats, l.name) is None:
                return None
            blo, bhi = _dict_eq_binders(cs.dict_values)
            lo = Lit(blo(r.value))
            lo.binder, lo.sources = blo, (r,)
            hi = Lit(bhi(r.value))
            hi.binder, hi.sources = bhi, (r,)
            i = blo(r.value)
            notes.append(
                f"dict lane {dict_lane_name(l.name)}: {l.name} == "
                f"{r.value!r} → id "
                f"{i if i <= bhi(r.value) else '∅'}/{len(cs.dict_values)}")
            cols.append(dict_lane_name(l.name))
            los.append(lo)
            his.append(hi)
            continue
        if not np.issubdtype(cs.dtype, np.integer):
            return None
        # the kernel evaluates on int32 tiles: column bounds must prove the
        # cast lossless, or wider-int values wrap and counts corrupt.
        if cs.lo is None or cs.hi is None \
                or cs.lo < _RANGE_MIN or cs.hi > _RANGE_MAX:
            return None
        if not isinstance(r.value, (int, np.integer)):
            return None
        if c.op == "==":
            # NEVER alias one Lit as both bounds: a point and a range plan
            # share a physical fingerprint (literal values excluded), so the
            # executable's two param slots must map to two distinct Lit
            # objects or a cache hit cross-binds them.
            lo, hi = r, Lit(r.value, source=r)
        elif c.op == ">=":
            lo, hi = r, Lit(_RANGE_MAX)
        elif c.op == "<=":
            lo, hi = Lit(_RANGE_MIN), r
        else:  # strict bounds / != : conservative, stay on the mask path
            return None
        cols.append(l.name)
        los.append(lo)
        his.append(hi)
    ds = ctx.catalog.get(scan.dataverse, scan.dataset)
    has_valid = "__valid__" in ds.table.columns
    out = PH.KernelRangeCount(scan.dataverse, scan.dataset, cols, los, his,
                              has_valid, key_col=key_col,
                              shadow_sources=shadow_sources)
    if notes:
        out.note = "; ".join(notes)
    bz = stats.block_zones
    if bz is not None:
        out.set_blocks(ctx.scan_blocks(scan), bz.block, bz.n_blocks,
                       n_shards=bz.n_shards, rows_per_shard=bz.rows_per_shard)
    return out


def _try_kernel_isin_count(scan: P.Scan, pred: Expr, stats: TableStats,
                           ctx: _PlannerCtx,
                           key_col: Optional[str] = None,
                           shadow_sources: tuple = ()
                           ) -> Optional[PH.MergeScalars]:
    """COUNT(col IN [...]) on a dictionary-encoded string column → one
    filter_count launch per member on the ``__dict_<col>`` id lane, partial
    counts summed. Dict ids partition rows, so the sum never double-counts;
    duplicate or absent members bind the empty range and contribute zero."""
    conjuncts = _split_conjuncts(pred)
    if len(conjuncts) != 1 or not isinstance(conjuncts[0], IsIn):
        return None
    e = conjuncts[0]
    l = e.children[0]
    vals = e.values
    if not (isinstance(l, Col) and vals
            and all(isinstance(v, Lit) and isinstance(v.value, str)
                    for v in vals)):
        return None
    cs = stats.column(l.name)
    if _dict_lane_stats(stats, l.name) is None:
        return None
    lane = dict_lane_name(l.name)
    ds = ctx.catalog.get(scan.dataverse, scan.dataset)
    has_valid = "__valid__" in ds.table.columns
    pos = {v: i for i, v in enumerate(cs.dict_values)}
    sources = tuple(vals)
    cur = tuple(v.value for v in vals)
    bz = stats.block_zones
    lane_spans = np.asarray(bz.span_of(lane)) if bz is not None else None
    sblocks = ctx.scan_blocks(scan) if bz is not None else None
    kids: list[PH.PhysOp] = []
    for j in range(len(vals)):
        blo, bhi = _isin_binders(pos, j)
        mlo, mhi = blo(*cur), bhi(*cur)
        lo = Lit(mlo)
        lo.binder, lo.sources = blo, sources
        hi = Lit(mhi)
        hi.binder, hi.sources = bhi, sources
        kid = PH.KernelRangeCount(scan.dataverse, scan.dataset, [lane],
                                  [lo], [hi], has_valid, key_col=key_col,
                                  shadow_sources=shadow_sources)
        if bz is not None:
            # per-member refinement: this launch only visits blocks whose
            # dict-id zone span contains ITS member's id (a duplicate or
            # absent member binds the empty range — nothing survives, the
            # min-one-block guard keeps the grid non-empty). Block lists
            # are in the prune signature, so a re-bind with different
            # literals replans rather than reusing a stale grid.
            cands = sblocks if sblocks is not None else range(bz.n_blocks)
            keep = None
            if lane_spans is not None:
                keep = tuple(b for b in cands
                             if lane_spans[b, 0] <= mhi
                             and mlo <= lane_spans[b, 1]) or (0,)
            elif sblocks is not None:
                keep = tuple(sblocks)
            kid.set_blocks(keep, bz.block, bz.n_blocks,
                           n_shards=bz.n_shards,
                           rows_per_shard=bz.rows_per_shard)
        kids.append(kid)
    out = PH.MergeScalars(kids, [("count", "sum")], ())
    ids = [pos.get(v) for v in cur]
    out.note = (f"dict lane {lane}: {l.name} IN {list(cur)!r} → ids "
                f"{ids} ({len(kids)} filter_count launch(es), partials "
                f"summed)")
    return out


def _plan_join_count(lnode: P.Plan, rnode: P.Plan, left_on: str, right_on: str,
                     ctx: _PlannerCtx) -> PH.PhysOp:
    left = _plan_stream(lnode, ctx)
    right = _plan_stream(rnode, ctx)
    presorted_key = None
    if isinstance(rnode, P.Scan):
        stats = _scan_stats(ctx, rnode)
        if stats is not None and stats.index_on(right_on) is not None:
            presorted_key = (rnode.dataverse, rnode.dataset)
    kernel = ctx.kernels and _join_key_int32_safe(lnode, left_on, ctx) \
        and _join_key_int32_safe(rnode, right_on, ctx)
    out = PH.JoinCountOp(left, right, left_on, right_on,
                         presorted_key=presorted_key, kernel=kernel)
    n = left.est_rows + right.est_rows
    out.est_rows = 1
    out.cost = C_KERNEL_LAUNCH + n * C_ROW_KERNEL if kernel else n * C_ROW_JOIN
    if kernel:
        out.note = "int32-safety proven from stats: merge_join kernel"
    return out


# -- group-by planning -------------------------------------------------------


def _group_domain(phys_child: PH.PhysOp, key: str, ctx: _PlannerCtx):
    """Resolve (lo, num_groups) for the bounded-domain group-by from the
    *surviving* physical leaves. Bounds merge across the LSM components of
    the FIRST dataset family that carries them; leaves of other datasets (a
    join build side with a same-named column) never widen the domain."""
    lo = hi = family = None
    for leaf in PH.walk(phys_child):
        skey = getattr(leaf, "source_key", None)
        if skey is None:
            continue
        stats = ctx.stats(*skey)
        cs = stats.column(key) if stats is not None else None
        if cs is None or cs.lo is None or cs.hi is None:
            continue
        fam = (skey[0], skey[1].split("@")[0])
        if family is None:
            family = fam
        elif fam != family:
            continue
        lo = cs.lo if lo is None else min(lo, cs.lo)
        hi = cs.hi if hi is None else max(hi, cs.hi)
    if lo is not None:
        return int(lo), int(hi - lo + 1)
    raise ValueError(
        f"group key {key!r} has no domain statistics; bounded-domain group-by "
        "requires catalog lo/hi (Wisconsin columns carry them)")


def _trace_col(node: P.Plan, col: str, ctx: _PlannerCtx) -> Optional[ColumnStats]:
    """Resolve the ColumnStats a stream column name originates from, following
    Project renames and join name-resolution; None when provenance cannot be
    established (computed expressions, suffixed join collisions)."""
    from repro.core.window import Window

    if isinstance(node, Window) and col == node.out_name:
        return None  # computed analytic column, no catalog bounds
    if isinstance(node, P.Scan):
        stats = _scan_stats(ctx, node)
        return stats.column(col) if stats is not None else None
    if isinstance(node, P.Project):
        for name, e in node.outputs:
            if name == col:
                if isinstance(e, Col):
                    return _trace_col(node.children[0], e.name, ctx)
                return None
        return None
    if isinstance(node, P.UnionRuns):
        # every component must prove the column; the union's bound is the
        # envelope of the per-component bounds (runs may extend the domain).
        metas = [_trace_col(c, col, ctx) for c in node.children]
        if any(m is None or m.lo is None or m.hi is None for m in metas):
            return None
        return ColumnStats(metas[0].dtype,
                           min(m.lo for m in metas), max(m.hi for m in metas),
                           sum(m.distinct or 0 for m in metas) or None,
                           any(m.is_string for m in metas), False)
    if isinstance(node, P.Join):
        # join_materialize: the left side wins a bare name; right-only names
        # pass through; a collision suffixes the right column (untraceable by
        # its stream name, so it resolves to None here).
        left_meta = _trace_col(node.children[0], col, ctx)
        if left_meta is not None:
            return left_meta
        return _trace_col(node.children[1], col, ctx)
    if len(node.children) == 1:  # filter/limit/sort/window pass columns through
        return _trace_col(node.children[0], col, ctx)
    return None


def _kernel_groupagg_exact(node: P.GroupAgg, ctx: _PlannerCtx, aggs) -> bool:
    """The segment_agg kernel computes in float32 — bit-identical to the
    generic path only when every per-group result is an exactly-representable
    integer: counts need n < 2^24; sum/mean need integer value columns whose
    stats bounds prove n * max|value| < 2^24; max/min only need the values
    representable. Provenance is traced to the origin table (conservative:
    the UNPRUNED component set bounds n)."""
    leaf_stats = [_scan_stats(ctx, l) for l in P.walk(node)
                  if isinstance(l, P.Scan)]
    leaf_stats = [s for s in leaf_stats if s is not None]
    if not leaf_stats:
        return False
    n = sum(s.padded_rows for s in leaf_stats)
    if n >= _F32_EXACT:
        return False
    for _, op, col in aggs:
        if op == "count":
            continue
        m = _trace_col(node.children[0], col, ctx)
        if m is None or m.is_string or not np.issubdtype(m.dtype, np.integer):
            return False
        if m.lo is None or m.hi is None:
            return False
        maxabs = max(abs(int(m.lo)), abs(int(m.hi)))
        bound = maxabs if op in ("max", "min") else n * maxabs
        if bound >= _F32_EXACT:
            return False
    return True


def _string_group_setup(node: P.GroupAgg, child: PH.PhysOp, key: str,
                        ctx: _PlannerCtx):
    """String group-by over dictionary-encoded components: build the UNION
    dictionary U (byte-lex sorted — ASCII str-sort over the space-padded
    encoding) and wrap every physical component in a ``DictRemapCols`` that
    rewrites its local dict ids into positions in U *below* the union
    concat. The group-by then runs over the int domain [0, |U|) on the
    existing segment-reduce/segment_agg machinery; ``key_values`` decodes
    surviving ids back to strings at the result boundary. None when the key
    isn't a stored dictionary-encoded string column on every component."""
    top = node.children[0]
    origins = {_origin_column(c, key) for c in top.children} \
        if isinstance(top, P.UnionRuns) else {_origin_column(top, key)}
    if origins != {key}:
        return None  # renamed/computed key: lane names would not line up
    comps = list(child.children) if isinstance(child, PH.PrunedUnionRuns) \
        else [child]
    dicts: list[tuple] = []
    family = None
    for c in comps:
        skey = None
        for leaf in PH.walk(c):
            skey = getattr(leaf, "source_key", None)
            if skey is not None:
                break
        if skey is None:
            return None
        stats = ctx.stats(*skey)
        cs = stats.column(key) if stats is not None else None
        if cs is None or not cs.is_string or cs.dict_values is None:
            return None
        fam = (skey[0], skey[1].split("@")[0])
        if family is None:
            family = fam
        elif fam != family:
            return None
        dicts.append(tuple(cs.dict_values))
    union: set = set()
    for d in dicts:
        union.update(d)
    if not union:
        return None  # no live string anywhere: stay on the generic raise
    U = sorted(union)
    upos = {v: i for i, v in enumerate(U)}
    lane = dict_lane_name(key)
    wrapped: list[PH.PhysOp] = []
    for c, d in zip(comps, dicts):
        w = PH.DictRemapCols(c, key, lane, tuple(upos[v] for v in d))
        w.est_rows = c.est_rows
        w.cost = c.est_rows * 0.05
        wrapped.append(w)
    return wrapped, tuple(U)


def _plan_groupagg(node: P.GroupAgg, ctx: _PlannerCtx) -> PH.PhysOp:
    assert len(node.keys) == 1, "single-key group-by (paper expressions 4/8)"
    key = node.keys[0]
    child = _plan_stream(node.children[0], ctx)
    key_values = None
    setup = _string_group_setup(node, child, key, ctx)
    if setup is not None:
        wrapped, key_values = setup
        if isinstance(child, PH.PrunedUnionRuns):
            child.children = tuple(wrapped)  # remap BELOW the concat
        else:
            child = wrapped[0]
        lo, num_groups = 0, len(key_values)
    else:
        lo, num_groups = _group_domain(child, key, ctx)
    aggs = [(s.out_name, s.op, s.column) for s in node.aggs]

    if ctx.kernels \
            and all(op in ("count", "sum", "mean", "max", "min")
                    for _, op, _ in aggs) \
            and _kernel_groupagg_exact(node, ctx, aggs):
        comps = list(child.children) if isinstance(child, PH.PrunedUnionRuns) \
            else [child]
        out = PH.KernelSegmentAgg(comps, key, lo, num_groups, node.aggs,
                                  key_values=key_values)
        if isinstance(child, PH.PrunedUnionRuns):
            out.pruned = child.pruned
            out.note = child.note
        # hoist each component's surviving-block list off its TableScan into
        # the segment_agg grid itself: the stream then feeds full-length
        # columns (no gather copy) and the kernel's index_map skips pruned
        # tiles — rows in skipped blocks are already masked out by the
        # filter the list was derived from.
        comp_blocks: list = []
        skipped = total = 0
        for c in comps:
            scans = [s for s in PH.walk(c) if isinstance(s, PH.TableScan)
                     and s.block_ids is not None]
            if len(scans) == 1:
                s = scans[0]
                comp_blocks.append(
                    (s.block_ids, s.zone_block) + s.shard_layout())
                skipped += s.blocks_total - len(s.block_ids)
                total += s.blocks_total
                s.block_ids = None  # the kernel grid skips, not the stream
            else:
                comp_blocks.append(None)
        out.comp_blocks = tuple(comp_blocks)
        out.est_rows = num_groups
        out.cost = sum(c.est_rows for c in comps) * C_ROW_KERNEL \
            + C_KERNEL_LAUNCH * len(comps)
        if skipped:
            out.note = (out.note + " — " if out.note else "") + \
                (f"zone maps: {total - skipped}/{total} block(s) in the "
                 f"segment_agg grid(s), {skipped} skipped")
        out.note = (out.note + " — " if out.note else "") + \
            "f32 exactness proven from stats: segment_agg kernel"
        return out

    out = PH.GroupAggGeneric(child, key, lo, num_groups, node.aggs,
                             key_values=key_values)
    out.est_rows = num_groups
    out.cost = child.est_rows * C_ROW_GROUP + num_groups
    return out
