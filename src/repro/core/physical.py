"""Physical query plans — what the cost-based planner hands the compiler.

The logical optimizer (core/optimizer.py) only rewrites *what* to compute;
every *how* decision — index probe vs. full scan vs. fused Pallas kernel,
which LSM runs to read at all — lives in a physical operator chosen by the
planner (core/physical_planner.py) from catalog statistics (core/stats.py).

Each node carries its cost annotations:

  * ``est_rows`` — estimated rows the operator emits,
  * ``rows_touched`` — physical rows it reads (what the cost model charges),
  * ``cost`` — the operator's own cost units,
  * ``note`` — the planner's rationale (alternatives considered, pruning).

``fingerprint()`` keys the compiled-executable dedup cache: two logical
plans that the planner maps to the same physical shape (a point ``==`` and a
range ``>=``/``<=`` over the same access path) share one executable —
literal values stay runtime parameters exactly like the logical layer.
``format_plan()`` renders the tree ``explain()`` shows, including per-node
costs and the zone-span rationale for every pruned run.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.expr import Expr


@dataclasses.dataclass(frozen=True)
class PrunedComponent:
    """One LSM component the planner dropped at bind time, with the zone-map
    rationale (recorded for explain; the compiled plan never reads it).

    Pruning is mutation-safe because it reasons per key-visibility: only the
    component's *matter* contribution is dropped (zone spans cover matter
    only, and a span miss proves zero visible matching rows). Its anti-matter
    — which annihilates *into* older components — is never pruned: surviving
    scans keep the pruned run's tombstone set among their shadow sources, so
    the subtraction still happens. ``tombstones`` records that retention for
    the explain rationale."""

    address: str
    column: str
    span: tuple          # the run's zone span [lo, hi]
    bound: tuple         # the predicate's effective [lo, hi] at bind time
    rows: int            # live rows the pruned run holds
    tombstones: int = 0  # anti-matter records the run keeps contributing

    def describe(self) -> str:
        out = (f"{self.address} PRUNED: zone span {self.column}∈"
               f"[{self.span[0]}, {self.span[1]}] misses predicate "
               f"[{self.bound[0]}, {self.bound[1]}] ({self.rows} rows skipped)")
        if self.tombstones:
            out += (f"; {self.tombstones} anti-matter record(s) RETAINED — "
                    f"they still subtract from older components")
        return out


class PhysOp:
    """Base physical operator. ``children`` are other PhysOps; cost fields
    are filled by the planner."""

    children: tuple["PhysOp", ...] = ()
    est_rows: float = 0.0
    rows_touched: float = 0.0
    cost: float = 0.0
    note: str = ""
    # Write-stall early warning (set by the planner's read-amp charge):
    # component probes / write-stall component cap, and whether it crossed
    # the warn fraction. 0.0 everywhere on un-fed plans.
    stall_pressure: float = 0.0
    stall_imminent: bool = False

    def exprs(self) -> list[Expr]:
        return []

    def fingerprint(self) -> str:
        raise NotImplementedError

    def label(self) -> str:
        return type(self).__name__

    def total_cost(self) -> float:
        return self.cost + sum(c.total_cost() for c in self.children)


def walk(node: PhysOp):
    yield node
    for c in node.children:
        yield from walk(c)


def all_exprs(node: PhysOp) -> list[Expr]:
    out: list[Expr] = []
    for n in walk(node):
        out.extend(n.exprs())
    return out


def scan_leaves(node: PhysOp) -> list[tuple[str, str]]:
    """Dataset keys the physical plan actually reads (pruned runs excluded —
    the executable must never gather a dropped component)."""
    keys: list[tuple[str, str]] = []
    for n in walk(node):
        key = getattr(n, "source_key", None)
        if key is not None and key not in keys:
            keys.append(key)
    return keys


def anti_leaves(node: PhysOp) -> list[tuple[str, str]]:
    """Components whose anti-matter key sets the plan subtracts with. A
    matter-pruned run can still appear here: its tombstones annihilate into
    surviving older components, so its anti array must be gathered even
    though its table is not."""
    keys: list[tuple[str, str]] = []
    for n in walk(node):
        for key in getattr(n, "shadow_sources", ()):
            if key not in keys:
                keys.append(key)
    return keys


def _shadow_fp(shadow_sources) -> str:
    return "|".join(f"{dv}.{name}" for dv, name in shadow_sources)


def _blocks_fp(block_ids) -> str:
    # Surviving-block lists are STATIC plan structure (baked into the gather
    # slices / kernel grid), so they must participate in the executable-dedup
    # fingerprint — two bindings with different surviving blocks can never
    # share a compiled program.
    return "all" if block_ids is None else ",".join(map(str, block_ids))


class _BlockSkip:
    """Mixin state for operators that can skip zone-map-pruned blocks.

    ``block_ids`` is the static ascending tuple of surviving block indices
    (None = scan everything); ``zone_block`` the block size in rows;
    ``blocks_total`` the component's physical block count (0 when the
    component has no block zone maps). ``blocks_scanned`` reports the blocks
    the operator actually reads — it can differ from ``len(block_ids)``
    only when a parent hoisted the list into its own kernel grid
    (KernelSegmentAgg).

    On a sharded mesh the ids live in the per-shard layout (flat id
    ``s * blocks_per_shard + j`` = shard ``s``'s local block ``j`` —
    stats.BlockZones): ``n_shards``/``blocks_per_shard``/``rows_per_shard``
    carry that layout to the lowering, which re-bases the flat list into
    per-shard local grids/gathers. ``n_shards == 1`` is the global layout."""

    block_ids: Optional[tuple] = None
    zone_block: int = 0
    blocks_total: int = 0
    blocks_scanned: int = 0
    n_shards: int = 1
    blocks_per_shard: int = 0
    rows_per_shard: int = 0

    def set_blocks(self, block_ids, zone_block: int, total: int,
                   n_shards: int = 1, rows_per_shard: int = 0) -> None:
        self.block_ids = tuple(block_ids) if block_ids is not None else None
        self.zone_block = int(zone_block)
        self.blocks_total = int(total)
        self.blocks_scanned = total if block_ids is None else len(block_ids)
        self.n_shards = max(int(n_shards), 1)
        self.blocks_per_shard = self.blocks_total // self.n_shards
        self.rows_per_shard = int(rows_per_shard)

    def shard_layout(self) -> tuple:
        """(n_shards, blocks_per_shard, rows_per_shard) — what the lowering
        needs to slice a flat surviving-block list per shard."""
        return (self.n_shards, self.blocks_per_shard, self.rows_per_shard)

    def block_note(self) -> str:
        skipped = self.blocks_total - self.blocks_scanned
        out = (f"zone maps: {self.blocks_scanned}/{self.blocks_total} "
               f"block(s) scanned, {skipped} skipped")
        if self.n_shards > 1 and self.block_ids is not None:
            bp = max(self.blocks_per_shard, 1)
            per = [0] * self.n_shards
            for b in self.block_ids:
                per[min(b // bp, self.n_shards - 1)] += 1
            out += (f" ({self.n_shards} shards, per-shard "
                    f"{'/'.join(map(str, per))} of {bp})")
        return out


# -- stream operators (produce (env, mask)) ---------------------------------


class TableScan(PhysOp, _BlockSkip):
    """Full component scan. ``shadow_sources`` are the newer LSM components
    whose anti-matter annihilates into this one: the lowering subtracts the
    shadowed rows from the stream mask (a sorted-probe per source on the
    ``key_col`` primary key), so every operator above sees only visible
    matter — in all three execution modes.

    With ``block_ids`` set (bind-time block zone-map test) the lowering
    streams only the surviving row blocks — sound because the planner only
    sets the list when every conjunct it derives from is applied above this
    scan, so skipped blocks provably contribute no passing rows."""

    def __init__(self, dataverse: str, dataset: str, open_cast: bool = False,
                 key_col: Optional[str] = None,
                 shadow_sources: tuple = ()):
        self.dataverse, self.dataset, self.open_cast = dataverse, dataset, open_cast
        self.key_col = key_col
        self.shadow_sources = tuple(shadow_sources)

    @property
    def source_key(self):
        return (self.dataverse, self.dataset)

    def fingerprint(self):
        return (f"p:scan({self.dataverse}.{self.dataset},{int(self.open_cast)},"
                f"{self.key_col},{_shadow_fp(self.shadow_sources)},"
                f"blk:{_blocks_fp(self.block_ids)})")

    def label(self):
        out = f"TableScan {self.dataverse}.{self.dataset}" + \
            (" [open: cast-per-access]" if self.open_cast else "")
        if self.blocks_total and self.blocks_scanned < self.blocks_total:
            out += f" [blocks {self.blocks_scanned}/{self.blocks_total}]"
        if self.shadow_sources:
            out += (f" ⊖ anti-matter of {len(self.shadow_sources)} newer "
                    f"component(s)")
        return out


class IndexProbe(PhysOp, _BlockSkip):
    """Streaming access path via an indexed column's range predicate: the
    bound conjuncts become the index mask, the rest stay residual. Shadow
    sources subtract exactly like :class:`TableScan`.

    With ``block_ids`` set, the lowering gathers only the surviving row
    blocks before the probe (the same static-slice gather as TableScan) —
    the sorted-index mask then tests a fraction of the physical rows instead
    of streaming all of them."""

    def __init__(self, dataverse: str, dataset: str, index_col: str,
                 lo: Optional[Expr], hi: Optional[Expr],
                 residual: Optional[Expr] = None, open_cast: bool = False,
                 key_col: Optional[str] = None,
                 shadow_sources: tuple = ()):
        self.dataverse, self.dataset, self.index_col = dataverse, dataset, index_col
        self.lo, self.hi, self.residual = lo, hi, residual
        self.open_cast = open_cast
        self.key_col = key_col
        self.shadow_sources = tuple(shadow_sources)

    @property
    def source_key(self):
        return (self.dataverse, self.dataset)

    def exprs(self):
        return [e for e in (self.lo, self.hi, self.residual) if e is not None]

    def fingerprint(self):
        lo = self.lo.fingerprint() if self.lo else "-inf"
        hi = self.hi.fingerprint() if self.hi else "+inf"
        res = self.residual.fingerprint() if self.residual else ""
        return (f"p:ixprobe({self.dataverse}.{self.dataset},{self.index_col},"
                f"{lo},{hi},{res},{int(self.open_cast)},{self.key_col},"
                f"{_shadow_fp(self.shadow_sources)},"
                f"blk:{_blocks_fp(self.block_ids)})")

    def label(self):
        bounds = f"{self.index_col} ∈ [{'-∞' if self.lo is None else '?'}, " \
                 f"{'+∞' if self.hi is None else '?'}]"
        res = " +residual" if self.residual is not None else ""
        out = f"IndexProbe {self.dataverse}.{self.dataset} ({bounds}{res})"
        if self.blocks_total and self.blocks_scanned < self.blocks_total:
            out += f" [blocks {self.blocks_scanned}/{self.blocks_total}]"
        if self.shadow_sources:
            out += (f" ⊖ anti-matter of {len(self.shadow_sources)} newer "
                    f"component(s)")
        return out


class FullScanFilter(PhysOp):
    def __init__(self, child: PhysOp, predicate: Expr):
        self.children, self.predicate = (child,), predicate

    def exprs(self):
        return [self.predicate]

    def fingerprint(self):
        return f"p:filter({self.predicate.fingerprint()},{self.children[0].fingerprint()})"

    def label(self):
        return f"FullScanFilter ({self.predicate.to_sql()})"


class ProjectCols(PhysOp):
    def __init__(self, child: PhysOp, outputs: Sequence[tuple[str, Expr]]):
        self.children, self.outputs = (child,), tuple(outputs)

    def exprs(self):
        return [e for _, e in self.outputs]

    def fingerprint(self):
        items = ",".join(f"{n}:{e.fingerprint()}" for n, e in self.outputs)
        return f"p:project([{items}],{self.children[0].fingerprint()})"

    def label(self):
        return f"Project [{', '.join(n for n, _ in self.outputs)}]"


class LimitRows(PhysOp):
    def __init__(self, child: PhysOp, n: int):
        self.children, self.n = (child,), int(n)

    def fingerprint(self):
        return f"p:limit({self.n},{self.children[0].fingerprint()})"

    def label(self):
        return f"Limit {self.n}"


class TopKSelect(PhysOp):
    """Sort+limit fused; ``kernel`` selects the block_topk Pallas selection
    primitive instead of lax.top_k (a planner decision, not a mode branch)."""

    def __init__(self, child: PhysOp, key: str, k: int, ascending: bool,
                 kernel: bool = False):
        self.children = (child,)
        self.key, self.k, self.ascending, self.kernel = key, int(k), ascending, kernel

    def fingerprint(self):
        return (f"p:topk({self.key},{self.k},{self.ascending},"
                f"{int(self.kernel)},{self.children[0].fingerprint()})")

    def label(self):
        how = "pallas block_topk" if self.kernel else "lax.top_k"
        d = "asc" if self.ascending else "desc"
        return f"TopK {self.key} {d} k={self.k} [{how}]"


class SortRows(PhysOp):
    def __init__(self, child: PhysOp, key: str, ascending: bool):
        self.children, self.key, self.ascending = (child,), key, ascending

    def fingerprint(self):
        return f"p:sort({self.key},{self.ascending},{self.children[0].fingerprint()})"

    def label(self):
        return f"Sort {self.key} {'asc' if self.ascending else 'desc'}"


class WindowEval(PhysOp):
    def __init__(self, child: PhysOp, window):
        self.children, self.window = (child,), window

    def fingerprint(self):
        return f"p:window({self.window.fingerprint()},{self.children[0].fingerprint()})"

    def label(self):
        return f"Window {self.window.func}(order by {self.window.order_by})"


class JoinGather(PhysOp):
    """Materializing inner equi-join (unique build keys, proven from stats
    by the planner): probe rows gather their single match."""

    def __init__(self, left: PhysOp, right: PhysOp, left_on: str, right_on: str):
        self.children = (left, right)
        self.left_on, self.right_on = left_on, right_on

    def fingerprint(self):
        return (f"p:joingather({self.left_on}={self.right_on},"
                f"{self.children[0].fingerprint()},{self.children[1].fingerprint()})")

    def label(self):
        return f"JoinGather {self.left_on} = {self.right_on}"


class PrunedUnionRuns(PhysOp):
    """Base ∪ surviving runs of a fed dataset. ``pruned`` records the runs
    the bind-time zone-span test dropped; the executable only ever reads the
    surviving children."""

    def __init__(self, children: Sequence[PhysOp],
                 pruned: Sequence[PrunedComponent] = ()):
        self.children = tuple(children)
        self.pruned = tuple(pruned)

    def fingerprint(self):
        inner = ",".join(c.fingerprint() for c in self.children)
        return f"p:unionruns({inner})"

    def label(self):
        return (f"UnionRuns [{len(self.children)} components, "
                f"{len(self.pruned)} pruned]")


# -- grouped operators -------------------------------------------------------


def _keyvals_fp(key_values) -> str:
    # The decoded-key dictionary is static plan structure (baked into the
    # id → string gather), so it participates in the executable-dedup
    # fingerprint like surviving-block lists do.
    return "-" if key_values is None else "|".join(map(str, key_values))


class DictRemapCols(PhysOp):
    """Per-component dictionary-id remap for a string group-by key: replaces
    ``key`` in the stream env with this component's ``__dict_<key>`` lane
    mapped through ``remap`` (component-local id → position in the union
    dictionary). Runs BELOW the union concat, so by the time components
    merge, every row speaks the same global id space — the same remap a
    compaction applies when it rebuilds lanes over merged rows."""

    def __init__(self, child: PhysOp, key: str, lane: str, remap):
        self.children = (child,)
        self.key, self.lane = key, lane
        self.remap = tuple(int(r) for r in remap)

    def fingerprint(self):
        r = ",".join(map(str, self.remap))
        return (f"p:dictremap({self.key},{self.lane},[{r}],"
                f"{self.children[0].fingerprint()})")

    def label(self):
        return (f"DictRemap {self.key} via {self.lane} "
                f"[{len(self.remap)} local ids → union dictionary]")


class GroupAggGeneric(PhysOp):
    """Bounded-domain group-by via segment reductions (gspmd/shard_map
    lowering; the domain [lo, lo+num_groups) comes from planner stats).

    ``key_values`` (string group-by): the union dictionary — surviving group
    ids decode back to encoded strings at the result boundary."""

    def __init__(self, child: PhysOp, key: str, lo: int, num_groups: int, aggs,
                 key_values=None):
        self.children = (child,)
        self.key, self.lo, self.num_groups = key, int(lo), int(num_groups)
        self.aggs = tuple(aggs)
        self.key_values = tuple(key_values) if key_values is not None else None

    def fingerprint(self):
        a = ",".join(s.fingerprint() for s in self.aggs)
        return (f"p:groupagg({self.key},{self.lo},{self.num_groups},[{a}],"
                f"kv:{_keyvals_fp(self.key_values)},"
                f"{self.children[0].fingerprint()})")

    def label(self):
        out = (f"GroupAgg {self.key} G={self.num_groups} "
               f"[{', '.join(s.op for s in self.aggs)}] [segment-reduce]")
        if self.key_values is not None:
            out += " [string key: union dictionary]"
        return out


class KernelSegmentAgg(PhysOp):
    """Group-by lowered onto the segment_agg Pallas kernel: one fused
    one-hot-matmul launch per component for the sum family (+1 per extreme
    family), partials merged with +/max/min. Children are the per-LSM-
    component streams. Chosen only under a static f32-exactness proof.

    ``comp_blocks[i]`` is the i-th component's surviving-block list
    (zone-block units; None = all blocks), HOISTED off that component's
    TableScan by the planner so the segment_agg grid itself skips pruned
    tiles instead of the stream gathering a compacted copy first.

    ``key_values`` (string group-by): the union dictionary — surviving group
    ids decode back to encoded strings at the result boundary."""

    comp_blocks: tuple = ()

    def __init__(self, comps: Sequence[PhysOp], key: str, lo: int,
                 num_groups: int, aggs, key_values=None):
        self.children = tuple(comps)
        self.key, self.lo, self.num_groups = key, int(lo), int(num_groups)
        self.aggs = tuple(aggs)
        self.key_values = tuple(key_values) if key_values is not None else None

    def fingerprint(self):
        a = ",".join(s.fingerprint() for s in self.aggs)
        inner = ",".join(c.fingerprint() for c in self.children)
        blk = ";".join(_blocks_fp(b) for b in self.comp_blocks) \
            if self.comp_blocks else "all"
        return (f"p:ksegagg({self.key},{self.lo},{self.num_groups},[{a}],"
                f"blk:{blk},kv:{_keyvals_fp(self.key_values)},{inner})")

    def label(self):
        out = (f"KernelSegmentAgg {self.key} G={self.num_groups} "
               f"[{', '.join(s.op for s in self.aggs)}] "
               f"[{len(self.children)} segment_agg launch group(s)]")
        if self.key_values is not None:
            out += " [string key: union dictionary]"
        return out


# -- scalar terminals --------------------------------------------------------


class MaskCount(PhysOp):
    """Generic COUNT: stream the child, reduce the mask (full scan)."""

    def __init__(self, child: PhysOp, predicate: Optional[Expr]):
        self.children, self.predicate = (child,), predicate

    def exprs(self):
        return [self.predicate] if self.predicate is not None else []

    def fingerprint(self):
        p = self.predicate.fingerprint() if self.predicate else "true"
        return f"p:maskcount({p},{self.children[0].fingerprint()})"

    def label(self):
        p = f" ({self.predicate.to_sql()})" if self.predicate is not None else ""
        return f"MaskCount{p} [full scan]"


class IndexOnlyCount(PhysOp):
    """COUNT answered from the sorted index alone: two binary searches per
    shard + merge — never touches the base columns (the paper's index-only
    query)."""

    def __init__(self, dataverse: str, dataset: str, index_col: str,
                 lo: Optional[Expr], hi: Optional[Expr]):
        self.dataverse, self.dataset, self.index_col = dataverse, dataset, index_col
        self.lo, self.hi = lo, hi

    @property
    def source_key(self):
        return (self.dataverse, self.dataset)

    def exprs(self):
        return [e for e in (self.lo, self.hi) if e is not None]

    def fingerprint(self):
        lo = self.lo.fingerprint() if self.lo else "-inf"
        hi = self.hi.fingerprint() if self.hi else "+inf"
        return f"p:ixcount({self.dataverse}.{self.dataset},{self.index_col},{lo},{hi})"

    def label(self):
        return (f"IndexOnlyCount {self.dataverse}.{self.dataset} "
                f"on {self.index_col} [binary search]")


class ShadowProbeCount(PhysOp):
    """The subtrahend of anti-matter subtraction on the index-only path:
    COUNT of this component's matter rows with primary key ∈ [lo, hi] that
    newer components' anti-matter shadows. Still index-only — the unioned
    (deduplicated) anti keys probe the component's sorted primary index,
    two binary searches per tombstone, never touching base columns."""

    def __init__(self, dataverse: str, dataset: str, index_col: str,
                 lo: Optional[Expr], hi: Optional[Expr],
                 shadow_sources: tuple):
        self.dataverse, self.dataset, self.index_col = dataverse, dataset, index_col
        self.lo, self.hi = lo, hi
        self.shadow_sources = tuple(shadow_sources)

    @property
    def source_key(self):
        return (self.dataverse, self.dataset)

    def exprs(self):
        return [e for e in (self.lo, self.hi) if e is not None]

    def fingerprint(self):
        lo = self.lo.fingerprint() if self.lo else "-inf"
        hi = self.hi.fingerprint() if self.hi else "+inf"
        return (f"p:shadowprobe({self.dataverse}.{self.dataset},"
                f"{self.index_col},{lo},{hi},"
                f"{_shadow_fp(self.shadow_sources)})")

    def label(self):
        return (f"ShadowProbeCount {self.dataverse}.{self.dataset} "
                f"on {self.index_col} [{len(self.shadow_sources)} anti "
                f"set(s), binary search]")


class SubtractScalars(PhysOp):
    """Anti-matter subtraction at the scalar merge: result = minuend −
    subtrahend per output (sum-merged outputs only — counts and sums; an
    extremum is never subtractable and takes the mask path instead). This
    is what keeps a component's index-only access path valid after newer
    components deleted/upserted into it."""

    def __init__(self, child: PhysOp, shadow: PhysOp,
                 names: Sequence[str] = ("count",)):
        self.children = (child, shadow)
        self.names = tuple(names)

    def fingerprint(self):
        return (f"p:subtract([{','.join(self.names)}],"
                f"{self.children[0].fingerprint()},"
                f"{self.children[1].fingerprint()})")

    def label(self):
        return f"SubtractScalars [{', '.join(self.names)}] [anti-matter]"


class KernelRangeCount(PhysOp, _BlockSkip):
    """COUNT of conjunctive inclusive ranges over integer columns lowered
    onto the filter_count Pallas kernel: one (k, n) tile pass, bounds as a
    (k, 2) runtime operand, no mask column in HBM. With shadow sources the
    matter/visibility mask folds in as ONE extra kernel row with bounds
    (1, 1) — the kernel itself performs the subtract-at-merge.

    ``block_ids`` drives the kernel grid through surviving blocks only
    (scalar-prefetched index_map): grid size = surviving blocks, skipped
    tiles are never fetched, and the count stays bit-identical because a
    skipped block's zone span proves no row satisfies the conjuncts."""

    def __init__(self, dataverse: str, dataset: str, cols: Sequence[str],
                 los: Sequence[Expr], his: Sequence[Expr], has_valid: bool,
                 key_col: Optional[str] = None,
                 shadow_sources: tuple = ()):
        self.dataverse, self.dataset = dataverse, dataset
        self.cols = tuple(cols)
        self.los, self.his = tuple(los), tuple(his)
        self.has_valid = has_valid
        self.key_col = key_col
        self.shadow_sources = tuple(shadow_sources)

    @property
    def source_key(self):
        return (self.dataverse, self.dataset)

    def exprs(self):
        out: list[Expr] = []
        for lo, hi in zip(self.los, self.his):
            out.extend((lo, hi))
        return out

    def fingerprint(self):
        return (f"p:krangecount({self.dataverse}.{self.dataset},"
                f"[{','.join(self.cols)}],{int(self.has_valid)},"
                f"{self.key_col},{_shadow_fp(self.shadow_sources)},"
                f"blk:{_blocks_fp(self.block_ids)})")

    def label(self):
        out = (f"KernelRangeCount {self.dataverse}.{self.dataset} "
               f"[{', '.join(self.cols)}] [filter_count kernel]")
        if self.blocks_total and self.blocks_scanned < self.blocks_total:
            out += f" [blocks {self.blocks_scanned}/{self.blocks_total}]"
        if self.shadow_sources:
            out += " [matter-mask row folded]"
        return out


class ScalarAgg(PhysOp):
    def __init__(self, child: PhysOp, aggs):
        self.children, self.aggs = (child,), tuple(aggs)

    def fingerprint(self):
        a = ",".join(s.fingerprint() for s in self.aggs)
        return f"p:scalaragg([{a}],{self.children[0].fingerprint()})"

    def label(self):
        return f"ScalarAgg [{', '.join(s.op for s in self.aggs)}]"


class JoinCountOp(PhysOp):
    """Fused join+count. ``kernel`` lowers onto merge_join_count (int32-safe
    proof required); ``presorted`` reuses the build side's sorted index."""

    def __init__(self, left: PhysOp, right: PhysOp, left_on: str, right_on: str,
                 presorted_key: Optional[tuple] = None, kernel: bool = False):
        self.children = (left, right)
        self.left_on, self.right_on = left_on, right_on
        self.presorted_key = presorted_key  # (dataverse, dataset) of sorted build
        self.kernel = kernel

    @property
    def presorted(self) -> bool:
        return self.presorted_key is not None

    def fingerprint(self):
        return (f"p:joincount({self.left_on}={self.right_on},"
                f"{self.presorted_key},{int(self.kernel)},"
                f"{self.children[0].fingerprint()},{self.children[1].fingerprint()})")

    def label(self):
        how = "merge_join kernel" if self.kernel else "sort+searchsorted"
        pre = ", presorted build" if self.presorted else ""
        return f"JoinCount {self.left_on} = {self.right_on} [{how}{pre}]"


class MergeScalars(PhysOp):
    """Merge of per-LSM-component scalar programs (+/max/min per output) —
    the cross-component psum analogue. ``pruned`` records runs the zone-span
    test excluded at bind time."""

    def __init__(self, children: Sequence[PhysOp],
                 merges: Sequence[tuple[str, str]],
                 pruned: Sequence[PrunedComponent] = ()):
        self.children = tuple(children)
        self.merges = tuple(merges)
        self.pruned = tuple(pruned)

    def fingerprint(self):
        m = ",".join(f"{n}:{op}" for n, op in self.merges)
        inner = ",".join(c.fingerprint() for c in self.children)
        return f"p:mergescalars([{m}],{inner})"

    def label(self):
        ops = ", ".join(f"{n}:{op}" for n, op in self.merges)
        return (f"MergeScalars [{ops}] [{len(self.children)} components, "
                f"{len(self.pruned)} pruned]")


class PointLookup(PhysOp):
    """Primary-key point lookup — the one access path that bypasses query
    compilation entirely: per-component host binary searches over the
    clustered key copy, walked newest → oldest so anti-matter resolves
    without any subtraction arithmetic (the first component owning the key
    decides: fresh matter wins, a tombstone kills every older occurrence).
    Components whose key zone span misses the probe are skipped without a
    search. On a sharded mesh each probe is routed to the owning row
    partition(s) via the per-shard key zone spans (``shards`` is the mesh's
    partition count, ``shard_probes`` the shard windows actually searched).
    Rendered by ``explain`` like every other physical operator."""

    def __init__(self, dataverse: str, dataset: str, key_col: str,
                 components: int, probed: int, skipped: int,
                 found_in: Optional[str] = None,
                 tombstoned_by: Optional[str] = None,
                 shards: int = 1, shard_probes: int = 0):
        self.dataverse, self.dataset, self.key_col = dataverse, dataset, key_col
        self.components = components
        self.probed, self.skipped = probed, skipped
        self.found_in = found_in
        self.tombstoned_by = tombstoned_by
        self.shards = shards
        self.shard_probes = shard_probes

    def fingerprint(self):
        return (f"p:pointlookup({self.dataverse}.{self.dataset},"
                f"{self.key_col})")

    def label(self):
        out = (f"PointLookup {self.dataverse}.{self.dataset} on "
               f"{self.key_col} [newest-wins, {self.probed} of "
               f"{self.components} component(s) probed, "
               f"{self.skipped} span-skipped]")
        if self.shards > 1:
            out += (f" [shard-routed: {self.shard_probes} of "
                    f"{self.probed * self.shards} shard window(s) searched]")
        return out


# -- explain rendering --------------------------------------------------------


def _fmt_ms(seconds: float) -> str:
    return f"{seconds * 1e3:.2f}ms"


def format_plan(root: PhysOp, analyze: Optional[dict] = None) -> str:
    """The ``explain()`` rendering: one line per operator with cost
    estimates, nested tree structure, planner rationale, and a pruning line
    per excluded LSM run.

    With ``analyze`` (the per-node measurement dict ``profile_physical``
    returns, keyed by ``id(node)``), each operator line also shows the
    *measured* self/total wall time and the actual row count beside the
    estimates — estimate-vs-actual drift on one line."""
    measures = (analyze or {}).get("nodes", {})
    lines: list[str] = []

    def emit(node: PhysOp, prefix: str, is_last: bool, is_root: bool):
        branch = "" if is_root else ("└─ " if is_last else "├─ ")
        meta = f"cost={node.cost:,.0f} rows≈{node.est_rows:,.0f}"
        if node.rows_touched and node.rows_touched != node.est_rows:
            meta += f" touched={node.rows_touched:,.0f}"
        m = measures.get(id(node))
        if m is not None:
            meta += (f" | self={_fmt_ms(m['self_seconds'])} "
                     f"total={_fmt_ms(m['total_seconds'])} "
                     f"rows={m['rows']:,}")
        lines.append(f"{prefix}{branch}{node.label()}  [{meta}]")
        child_prefix = prefix if is_root else prefix + ("   " if is_last else "│  ")
        if node.note:
            lines.append(f"{child_prefix}· {node.note}")
        pruned = getattr(node, "pruned", ())
        items: list = list(node.children) + list(pruned)
        for i, item in enumerate(items):
            last = i == len(items) - 1
            if isinstance(item, PrunedComponent):
                mark = "└─ " if last else "├─ "
                lines.append(f"{child_prefix}{mark}✂ {item.describe()}")
            else:
                emit(item, child_prefix, last, False)

    emit(root, "", True, True)
    lines.append(f"total estimated cost: {root.total_cost():,.0f}")
    if analyze is not None:
        rm = measures.get(id(root))
        if rm is not None:
            lines.append(f"measured wall time (per-operator, unjitted): "
                         f"{_fmt_ms(rm['total_seconds'])}")
        if analyze.get("jit_seconds") is not None:
            lines.append(f"jitted end-to-end: "
                         f"{_fmt_ms(analyze['jit_seconds'])}")
    return "\n".join(lines)


def prune_report(root: PhysOp) -> dict:
    """Aggregate pruning metrics over a physical plan (benchmarks / CI smoke
    read this): component counts, physical rows touched vs. skipped, and the
    intra-component block tally of the second pruning level."""
    components = pruned = 0
    rows_pruned = tombstones_retained = 0
    blocks_total = blocks_scanned = 0
    shards = 1
    shard_probes = 0
    compaction_recommended = False
    stall_pressure = 0.0
    stall_imminent = False
    for node in walk(root):
        shards = max(shards, getattr(node, "shards", 1),
                     getattr(node, "n_shards", 1))
        shard_probes += getattr(node, "shard_probes", 0)
        if getattr(node, "compaction_recommended", False):
            compaction_recommended = True
        stall_pressure = max(stall_pressure,
                             getattr(node, "stall_pressure", 0.0))
        if getattr(node, "stall_imminent", False):
            stall_imminent = True
        bt = getattr(node, "blocks_total", 0)
        if bt:
            blocks_total += bt
            blocks_scanned += getattr(node, "blocks_scanned", bt)
        p = getattr(node, "pruned", None)
        if p is None:
            continue
        components += len(node.children) + len(p)
        pruned += len(p)
        rows_pruned += sum(pc.rows for pc in p)
        tombstones_retained += sum(pc.tombstones for pc in p)
    rows_touched = sum(int(n.rows_touched) for n in walk(root)
                       if getattr(n, "source_key", None) is not None)
    return {"components": components, "pruned": pruned,
            "rows_pruned": rows_pruned, "rows_touched": rows_touched,
            "tombstones_retained": tombstones_retained,
            "blocks_total": blocks_total, "blocks_scanned": blocks_scanned,
            "blocks_skipped": blocks_total - blocks_scanned,
            "shards": shards, "shard_probes": shard_probes,
            "compaction_recommended": compaction_recommended,
            "stall_pressure": stall_pressure,
            "stall_imminent": stall_imminent,
            "total_cost": root.total_cost()}
