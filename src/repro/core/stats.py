"""Unified statistics layer — the planner's single source of truth.

AsterixDB's cost-based rewrites read dataset/index statistics from the
metadata node; the analogue here is a uniform harvest over every storage
component the engine owns:

  * **base datasets** — per-column lo/hi/distinct collected at load
    (``session._collect_stats``), index inventory, live row counts;
  * **LSM runs**      — the same shape per device-resident flush: each run's
    column ``[lo, hi]`` is its *zone span* (the envelope of the per-block
    zone maps built at flush time), which is what run-level pruning tests
    predicate ranges against;
  * **materialized views** — group counts and key domain of the
    incrementally-maintained state.

Every harvest is O(metadata): nothing touches device arrays. (The per-block
``BlockZones`` referenced by a harvest are computed O(rows) ONCE at
load/flush/compaction time and merely handed through here.) The catalog
carries a ``stats_epoch`` bumped on any event that changes what statistics
describe (DDL, feed flush, compaction) — compiled plans are keyed by the
epoch, so a stale executable can never read a dropped LSM component.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional

import numpy as np

from repro.core.catalog import INTERNAL_COLUMNS, Catalog, Dataset

# Zone-map block granularity: one zone block per filter_count kernel tile —
# literally the kernel's BLOCK, imported so the equality is structural
# (kernels/ops.py re-exports it for the kernel-side grid expansion).
from repro.kernels.filter_count import BLOCK as ZONE_BLOCK_ROWS


def mesh_shards(mesh, data_axes=None) -> int:
    """Row-partition count of a session mesh: the product of the data-axis
    extents (every data-parallel sharding spec row-shards over them). 1 for
    meshless sessions — the zone-map layout then degenerates to global."""
    if mesh is None:
        return 1
    if data_axes:
        return int(np.prod([mesh.shape[a] for a in data_axes]))
    return int(mesh.devices.size)


@dataclasses.dataclass(frozen=True)
class BlockZones:
    """Intra-component zone maps: per-``ZONE_BLOCK_ROWS`` [min, max] of each
    numeric column over the component's physical row layout (matter only;
    float NaNs count as dead rows). Harvested once at load / flush /
    compaction; the bind-time block-skip test intersects bound predicate
    intervals with these spans to compact the kernel grid down to surviving
    blocks.

    The layout is shard-aware: blocks are laid out per mesh row-partition
    (flat block ``s * blocks_per_shard + j`` is shard ``s``'s LOCAL block
    ``j`` — ``rows_per_shard`` rows per chunk, trailing partial blocks
    sentinel-padded), so per-shard kernel grids and gathers address local
    tiles directly. ``n_shards == 1`` is the original global layout."""

    block: int
    n_blocks: int
    spans: Mapping[str, "object"]  # column -> (n_blocks, 2) ndarray
    n_shards: int = 1
    rows_per_shard: int = 0        # 0 = whole table (unsharded)

    @property
    def blocks_per_shard(self) -> int:
        return self.n_blocks // max(self.n_shards, 1)

    def span_of(self, column: str):
        return self.spans.get(column)

    def shard_lists(self, block_ids) -> list[list[int]]:
        """Split a flat surviving-block-id tuple into per-shard LOCAL id
        lists (flat id ``s * blocks_per_shard + j`` -> shard ``s``, local
        ``j``). Flat ids arrive sorted, so each local list stays sorted."""
        bp = self.blocks_per_shard
        out: list[list[int]] = [[] for _ in range(max(self.n_shards, 1))]
        for b in block_ids:
            out[b // bp].append(b % bp)
        return out


def harvest_block_zones(table, n_shards: int = 1) -> Optional[BlockZones]:
    """Compute a table's per-block zone maps (None when no numeric column
    exists or the table is empty), laid out over ``n_shards`` row
    partitions. O(rows) at load/flush time — never at query time."""
    from repro.engine.table import compute_block_zones

    n = len(table)
    if n_shards <= 1 or (n and n % n_shards):
        n_shards = 1
    spans = compute_block_zones(table, ZONE_BLOCK_ROWS, n_shards)
    if not spans:
        return None
    nb = int(next(iter(spans.values())).shape[0])
    return BlockZones(ZONE_BLOCK_ROWS, nb, spans, n_shards,
                      n // max(n_shards, 1))


@dataclasses.dataclass(frozen=True)
class ColumnStats:
    """Per-column statistics (the ColumnMeta view the planner consumes).

    ``lo``/``hi`` bound the live value domain — for an LSM run this is the
    run's zone span; ``index`` is the kind of index covering the column
    ("primary"/"secondary") or None."""

    dtype: np.dtype
    lo: Optional[float] = None
    hi: Optional[float] = None
    distinct: Optional[int] = None
    is_string: bool = False
    sorted_ascending: bool = False
    index: Optional[str] = None
    # dictionary-encoded string column: this component's sorted value
    # dictionary (byte-lex order; position == ``__dict_<col>`` lane id).
    # Presence is what lets the planner bind a string literal to an int id.
    dict_values: Optional[tuple] = None

    @property
    def bounded(self) -> bool:
        return self.lo is not None and self.hi is not None

    @property
    def span(self) -> Optional[tuple[float, float]]:
        return (self.lo, self.hi) if self.bounded else None


@dataclasses.dataclass(frozen=True)
class TableStats:
    """Statistics for one storage component (base table, LSM run, or view).

    ``rows`` counts *visible* rows — matter minus what newer components'
    anti-matter annihilated; ``padded_rows`` is the physical (block-padded,
    shard-padded) length every full-scan operator actually touches —
    the quantity the cost model charges for. ``tombstones`` counts the
    anti-matter records this component carries (they subtract from older
    components at query time); ``shadowed`` counts this component's own
    matter newer anti-matter annihilated (already discounted from
    ``rows``)."""

    address: str                 # "dataverse.name" (runs: "dv.name@run<i>")
    rows: int
    padded_rows: int
    columns: Mapping[str, ColumnStats]
    kind: str = "dataset"        # dataset | run | view
    tombstones: int = 0
    shadowed: int = 0
    block_zones: Optional[BlockZones] = None  # intra-component zone maps

    def column(self, name: str) -> Optional[ColumnStats]:
        return self.columns.get(name)

    def span(self, name: str) -> Optional[tuple[float, float]]:
        c = self.columns.get(name)
        return c.span if c is not None else None

    def index_on(self, name: str) -> Optional[str]:
        c = self.columns.get(name)
        return c.index if c is not None else None

    @property
    def is_run(self) -> bool:
        return self.kind == "run"


def harvest(ds: Dataset) -> TableStats:
    """Uniform stats harvest for a base dataset or an LSM run."""
    cols: dict[str, ColumnStats] = {}
    for name, meta in ds.table.meta.items():
        if name in INTERNAL_COLUMNS:
            continue
        ix = ds.index_on(name)
        cols[name] = ColumnStats(
            dtype=np.dtype(meta.dtype), lo=meta.lo, hi=meta.hi,
            distinct=meta.distinct, is_string=meta.is_string,
            sorted_ascending=meta.sorted_ascending,
            index=ix.kind if ix is not None else None,
            dict_values=getattr(meta, "dict_values", None))
    return TableStats(address=f"{ds.dataverse}.{ds.name}",
                      rows=ds.num_live_rows,
                      padded_rows=len(ds.table),
                      columns=cols,
                      kind="run" if "@" in ds.name else "dataset",
                      tombstones=ds.anti_rows,
                      shadowed=ds.annihilated_rows,
                      block_zones=ds.block_zones)


def component_stats(catalog: Catalog, dataverse: str, name: str) -> TableStats:
    """Stats for a component address — resolves "<name>@run<i>" like the
    catalog does, so planner code never special-cases LSM components."""
    return harvest(catalog.get(dataverse, name))


def view_stats(view) -> TableStats:
    """Stats harvest for an incrementally-maintained MaterializedView: live
    group count and the key domain of the dense state."""
    counts = getattr(view, "_counts", None)
    if counts is None:
        return TableStats(address=f"{view.dataverse}.{view.name}", rows=0,
                          padded_rows=0, columns={}, kind="view")
    live = int((counts > 0).sum())
    g = int(counts.shape[0])
    key_dtype = np.dtype(view._key_dtype) if view._key_dtype is not None \
        else np.dtype(np.int64)
    cols = {view.key: ColumnStats(dtype=key_dtype, lo=view.lo,
                                  hi=view.lo + g - 1, distinct=live,
                                  sorted_ascending=True)}
    return TableStats(address=f"{view.dataverse}.{view.name}", rows=live,
                      padded_rows=g, columns=cols, kind="view")
