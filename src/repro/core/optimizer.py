"""Rule-based *logical* optimizer — the rewrite half of AsterixDB's
rule+cost compiler. (The cost half — index probe vs. full scan vs. Pallas
kernel, zone-map run pruning — lives in core/physical_planner.py: this
module decides *what* to compute, never *how*.)

Rules (each is a bottom-up rewrite; applied to fixpoint):
  1. ``fuse_filters``        — Filter(Filter(x, a), b)        -> Filter(x, a AND b)
  2. ``fuse_projects``       — Project(Project(x))            -> Project(x) (inline)
  3. ``pushdown_limit``      — Limit(Project(x), n)           -> Project(Limit(x, n))
                               Limit(Sort(x), n)              -> TopK(x, n)
     (this is the paper's lazy-eval win on expressions 5/10: the UDF/upper
      runs on n rows, not the dataset)
  4. ``fuse_agg``            — Agg[count*](Filter(x, p))      -> FilterCount(x, p)
                               Agg[count*](Join(l, r))        -> JoinCount(l, r)
  5. ``union_pushdown``      — distribute row-wise operators and scalar
     aggregates through an LSM union (per-component access paths).
  6. ``prune_columns``       — insert narrow Projects above Scans so only
     referenced columns are ever touched (columnar projection pushdown).

Every rewrite preserves the plan's SQL++ semantics; property tests in
``tests/test_property.py`` check optimized == unoptimized results on random
plans and data.
"""
from __future__ import annotations

import numpy as np

from repro.core import plan as P
from repro.core.catalog import Catalog
from repro.core.expr import BoolOp, Col, Compare, Expr, Lit

# Sentinel bounds for one-sided ranges; the filter_count kernel operates on
# int32 column tiles, so the sentinels are the int32 domain edges. (Shared
# with the physical planner's kernel-range-count candidate construction.)
_RANGE_MIN = int(np.iinfo(np.int32).min)
_RANGE_MAX = int(np.iinfo(np.int32).max)


def optimize(root: P.Plan, catalog: Catalog | None = None, *,
             enable_pushdown: bool = True, **_compat) -> P.Plan:
    """Logical rewrites only. ``**_compat`` swallows the historical
    ``enable_index``/``enable_kernel_fusion`` flags: access-path choice is
    the physical planner's job now (Session forwards those knobs there)."""
    prev_fp = None
    node = root
    if catalog is not None:
        # NOT an optimization: a Scan of a fed dataset MUST see base ∪ runs
        # (LSM read semantics), so the expansion runs regardless of flags.
        node = _expand_feeds(node, catalog)
    for _ in range(12):  # fixpoint with a safety bound
        if enable_pushdown:
            node = _rewrite(node, _fuse_filters)
            node = _rewrite(node, _pushdown_limit)
            node = _rewrite(node, _fuse_agg)
            node = _rewrite(node, _union_pushdown)
        fp = node.fingerprint()
        if fp == prev_fp:
            break
        prev_fp = fp
    if enable_pushdown and catalog is not None:
        node = _prune_columns(node, catalog)
    return _uniquify(node, set())


def _uniquify(node: P.Plan, seen: set[int]) -> P.Plan:
    """Make the optimized plan a proper TREE. User plans are DAGs: derived
    frames share the base frame's Scan object, and a self-join shares whole
    subtrees — but the physical planner keys per-occurrence state (scan
    ordinals, per-scan pruning constraints) by object identity, so a node
    reachable twice would alias two branches' predicates onto one scan.
    Clone every re-encountered node (copy-on-write; Expr objects stay
    shared — literal slots are bound by Expr identity on purpose)."""
    import copy

    clone = copy.copy(node) if id(node) in seen else node
    seen.add(id(clone))
    kids = tuple(_uniquify(c, seen) for c in clone.children)
    if kids != tuple(clone.children):
        if clone is node:  # never mutate a node the raw plan still owns
            clone = copy.copy(node)
            seen.add(id(clone))
        clone.children = kids
    return clone


def _expand_feeds(node: P.Plan, catalog: Catalog) -> P.Plan:
    """Single top-down pass replacing every Scan of a dataset that has LSM
    runs with UnionRuns(Scan(base), Scan(run_0), ...). Component Scans keep
    the plain dataset name for the base (it resolves to the base table only;
    runs live beside it) and each run's stable "<name>@run<uid>" address, so
    fingerprints change whenever the run set does. ``catalog`` may be a
    pinned Snapshot — the component set then reflects exactly the bound
    manifest."""
    if isinstance(node, P.Scan):
        if "@" in node.dataset:
            return node
        try:
            comps = catalog.components(node.dataverse, node.dataset)
        except KeyError:
            return node
        runs = comps[1:]
        if not runs:
            return node
        plans: list[P.Plan] = [node]
        plans += [P.Scan(r.name, node.dataverse) for r in runs]
        return P.UnionRuns(plans)
    kids = tuple(_expand_feeds(c, catalog) for c in node.children)
    return _with_children(node, kids) if kids != node.children else node


def _rewrite(node: P.Plan, rule) -> P.Plan:
    new_children = tuple(_rewrite(c, rule) for c in node.children)
    if new_children != node.children:
        node = _with_children(node, new_children)
    out = rule(node)
    return out if out is not None else node


def _with_children(node: P.Plan, children: tuple[P.Plan, ...]) -> P.Plan:
    import copy

    clone = copy.copy(node)
    clone.children = children
    return clone


# -- rules -------------------------------------------------------------------


def _fuse_filters(node: P.Plan):
    if isinstance(node, P.Filter) and isinstance(node.children[0], P.Filter):
        inner = node.children[0]
        return P.Filter(inner.children[0], BoolOp("AND", inner.predicate, node.predicate))
    return None


def _pushdown_limit(node: P.Plan):
    if not isinstance(node, P.Limit):
        return None
    child = node.children[0]
    if isinstance(child, P.Project):
        # row-wise projection commutes with LIMIT: run UDFs on n rows only.
        return P.Project(P.Limit(child.children[0], node.n), child.outputs)
    if isinstance(child, P.Sort):
        return P.TopK(child.children[0], child.key, node.n, child.ascending)
    if isinstance(child, P.Limit):
        return P.Limit(child.children[0], min(node.n, child.n))
    return None


def _fuse_agg(node: P.Plan):
    if not isinstance(node, P.Agg):
        return None
    if len(node.aggs) == 1 and node.aggs[0].op == "count" and node.aggs[0].column is None:
        child = node.children[0]
        if isinstance(child, P.Filter):
            return P.FilterCount(child.children[0], child.predicate)
        if isinstance(child, P.Join):
            return P.JoinCount(child.children[0], child.children[1],
                               child.left_on, child.right_on)
        if isinstance(child, P.Scan):
            return P.FilterCount(child, None)
    return None


def _union_pushdown(node: P.Plan):
    """Distribute row-wise operators and scalar aggregates through an LSM
    union so each component keeps its own access path (per-run index probes,
    per-run fused kernels). Sharing the predicate/output Expr objects across
    components is safe: literal slots are assigned by object identity, so
    every occurrence reads the same runtime param."""
    child = node.children[0] if node.children else None
    if not isinstance(child, P.UnionRuns):
        return None
    if isinstance(node, P.Filter):
        return P.UnionRuns([P.Filter(c, node.predicate) for c in child.children])
    if isinstance(node, P.Project):
        return P.UnionRuns([P.Project(c, node.outputs) for c in child.children])
    if isinstance(node, P.FilterCount):
        return P.UnionScalar(
            [P.FilterCount(c, node.predicate) for c in child.children],
            [("count", "sum")])
    if isinstance(node, P.Agg) and all(
            s.op in ("count", "sum", "max", "min") for s in node.aggs):
        merges = [(s.out_name, "sum" if s.op in ("count", "sum") else s.op)
                  for s in node.aggs]
        return P.UnionScalar([P.Agg(c, node.aggs) for c in child.children], merges)
    # Agg with mean, GroupAgg, Sort/TopK/Limit/Join: stay above the union —
    # the compiler's concat lowering (or per-component GroupAgg partials in
    # kernel mode) handles them.
    return None


def _split_conjuncts(e: Expr) -> list[Expr]:
    if isinstance(e, BoolOp) and e.op == "AND":
        return _split_conjuncts(e.children[0]) + _split_conjuncts(e.children[1])
    return [e]


def _range_bounds(conjuncts: list[Expr], column: str):
    """Extract (lo, hi, residual_conjuncts) for ``column`` from conjuncts of
    the form Col <cmp> Lit. Returns None if no usable bound exists."""
    lo = hi = None
    residual: list[Expr] = []
    for c in conjuncts:
        used = False
        if isinstance(c, Compare):
            l, r = c.children
            if isinstance(l, Col) and l.name == column and isinstance(r, Lit):
                if c.op == "==":
                    # NEVER alias one Lit as both bounds: a point scan and a
                    # range scan share a fingerprint (literal values are
                    # excluded), so the compiled executable's two param slots
                    # must map to two distinct Lit objects or a plan-cache
                    # hit cross-binds them (found by hypothesis).
                    lo, hi = r, Lit(r.value, source=r)
                    used = True
                elif c.op in (">=",):
                    lo = r
                    used = True
                elif c.op in ("<=",):
                    hi = r
                    used = True
                # strict bounds handled conservatively as residual predicates
        if not used:
            residual.append(c)
    if lo is None and hi is None:
        return None
    return lo, hi, residual


# -- projection pushdown ------------------------------------------------------


def _prune_columns(node: P.Plan, catalog: Catalog, needed: set[str] | None = None) -> P.Plan:
    """Top-down pass: compute the columns each subtree must produce and wrap
    Scans in narrow Projects. ``needed=None`` means "all columns"."""
    if isinstance(node, P.Scan):
        if needed is None:
            return node
        from repro.core.catalog import INTERNAL_COLUMNS
        from repro.engine.table import dict_lane_name, is_lane_column

        ds = catalog.get(node.dataverse, node.dataset)
        names = ds.table.column_names()
        cols = [c for c in names
                if c in needed and c not in INTERNAL_COLUMNS
                and not is_lane_column(c)]
        if set(cols) >= set(n for n in names if n not in INTERNAL_COLUMNS
                            and not is_lane_column(n)):
            return node
        # keep the selected string columns' dict lanes riding along: the
        # kernel group-by remap (DictRemapCols) reads them from the env.
        lanes = [dict_lane_name(c) for c in cols
                 if dict_lane_name(c) in names]
        return P.Project(node, [(c, Col(c)) for c in cols + lanes])

    if isinstance(node, P.Project):
        child_needed = set()
        for _, e in node.outputs:
            child_needed |= e.columns()
        kids = (_prune_columns(node.children[0], catalog, child_needed),)
        return _with_children(node, kids)

    if isinstance(node, (P.Filter, P.FilterCount)):
        child_needed = None
        if needed is not None:
            child_needed = set(needed)
            for e in node.exprs():
                child_needed |= e.columns()
        kids = (_prune_columns(node.children[0], catalog, child_needed),)
        return _with_children(node, kids)

    if isinstance(node, (P.Agg, P.GroupAgg, P.TopK, P.Sort)):
        child_needed = node.required_columns() if isinstance(node, (P.Agg, P.GroupAgg)) else None
        if isinstance(node, (P.TopK, P.Sort)):
            child_needed = None if needed is None else (set(needed) | node.required_columns())
        kids = (_prune_columns(node.children[0], catalog, child_needed),)
        return _with_children(node, kids)

    if isinstance(node, P.UnionRuns):
        # components share one schema: the same requirement applies to each
        kids = tuple(_prune_columns(c, catalog, needed) for c in node.children)
        return _with_children(node, kids)

    if isinstance(node, (P.Join, P.JoinCount)):
        ln: set[str] | None
        rn: set[str] | None
        if isinstance(node, P.JoinCount):
            ln, rn = {node.left_on}, {node.right_on}
        else:
            ln = None if needed is None else set(needed) | {node.left_on}
            rn = None if needed is None else set(needed) | {node.right_on}
        kids = (
            _prune_columns(node.children[0], catalog, ln),
            _prune_columns(node.children[1], catalog, rn),
        )
        return _with_children(node, kids)

    kids = tuple(_prune_columns(c, catalog, None) for c in node.children)
    return _with_children(node, kids) if kids != node.children else node
