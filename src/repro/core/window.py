"""Window functions — the paper's §VI future-work item, implemented.

``Window`` is a plan node computing per-row analytic functions over an
optional bounded-domain partition and a sort order:

    row_number()           ROW_NUMBER() OVER (PARTITION BY p ORDER BY o)
    rank()                 RANK()        (ties share rank)
    cumsum(col)            SUM(col)      with UNBOUNDED PRECEDING frame
    moving_avg(col, k)     AVG(col)      over a k-row trailing frame

TPU-native execution (static shapes, no per-group loops): one argsort by
(partition, order) composite key, segment boundaries via searchsorted,
vectorized prefix ops, inverse-permute back to storage order — rows keep
their original positions (Pandas alignment semantics).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import plan as P

WINDOW_FUNCS = ("row_number", "rank", "cumsum", "moving_avg")


class Window(P.Plan):
    """Appends one window column to the child's output."""

    def __init__(self, child: P.Plan, out_name: str, func: str,
                 order_by: str, partition_by: Optional[str] = None,
                 value_col: Optional[str] = None, frame: int = 0,
                 ascending: bool = True):
        assert func in WINDOW_FUNCS, func
        self.children = (child,)
        self.out_name, self.func = out_name, func
        self.order_by, self.partition_by = order_by, partition_by
        self.value_col, self.frame, self.ascending = value_col, frame, ascending

    def fingerprint(self):
        return (f"window({self.out_name},{self.func},{self.order_by},"
                f"{self.partition_by},{self.value_col},{self.frame},"
                f"{self.ascending},{self.children[0].fingerprint()})")

    def required_columns(self):
        cols = {self.order_by}
        if self.partition_by:
            cols.add(self.partition_by)
        if self.value_col:
            cols.add(self.value_col)
        return cols

    def to_sql(self):
        over = []
        if self.partition_by:
            over.append(f"PARTITION BY t.{self.partition_by}")
        over.append(f"ORDER BY t.{self.order_by}"
                    f"{'' if self.ascending else ' DESC'}")
        if self.func == "row_number":
            fn = "ROW_NUMBER()"
        elif self.func == "rank":
            fn = "RANK()"
        elif self.func == "cumsum":
            fn = f"SUM(t.{self.value_col})"
            over.append("ROWS UNBOUNDED PRECEDING")
        else:
            fn = f"AVG(t.{self.value_col})"
            over.append(f"ROWS {self.frame - 1} PRECEDING")
        return (f"SELECT t.*, {fn} OVER ({' '.join(over)}) AS {self.out_name} "
                f"FROM ({self.children[0].to_sql()}) t")


def execute_window(env: dict, mask: jax.Array, node: Window) -> tuple[dict, jax.Array]:
    """Vectorized window evaluation (storage-order aligned)."""
    n = mask.shape[0]
    order_col = env[node.order_by]
    okey = order_col.astype(jnp.float32)
    if not node.ascending:
        okey = -okey
    # dead rows sort to the end; composite (partition, order) sort key
    big = jnp.float32(3e38)
    okey = jnp.where(mask, okey, big)
    if node.partition_by is not None:
        pcol = env[node.partition_by].astype(jnp.float32)
        pkey = jnp.where(mask, pcol, big)
        # lexicographic via two stable sorts: order first, then partition
        perm = jnp.argsort(okey, stable=True)
        perm = perm[jnp.argsort(pkey[perm], stable=True)]
        part_sorted = pkey[perm]
        starts_mask = jnp.concatenate(
            [jnp.ones((1,), jnp.bool_), part_sorted[1:] != part_sorted[:-1]])
    else:
        perm = jnp.argsort(okey, stable=True)
        starts_mask = jnp.zeros((n,), jnp.bool_).at[0].set(True)

    pos = jnp.arange(n)
    # index of each row's partition start, in sorted coordinates
    start_idx = jax.lax.cummax(jnp.where(starts_mask, pos, 0))

    if node.func in ("row_number", "rank"):
        rn = pos - start_idx + 1
        if node.func == "rank":
            ok_sorted = okey[perm]
            new_val = jnp.concatenate(
                [jnp.ones((1,), jnp.bool_), ok_sorted[1:] != ok_sorted[:-1]])
            new_val = new_val | starts_mask
            rank_anchor = jax.lax.cummax(jnp.where(new_val, pos, 0))
            rn = rank_anchor - start_idx + 1
        out_sorted = rn.astype(jnp.int32)
    elif node.func == "cumsum":
        v = jnp.where(mask, env[node.value_col], 0)[perm].astype(jnp.float32)
        cs = jnp.cumsum(v)
        seg_base = jax.lax.cummax(jnp.where(starts_mask, cs - v, -jnp.inf))
        out_sorted = cs - seg_base
    else:  # moving_avg over trailing `frame` rows within the partition
        k = max(int(node.frame), 1)
        v = jnp.where(mask, env[node.value_col], 0)[perm].astype(jnp.float32)
        cs = jnp.concatenate([jnp.zeros((1,)), jnp.cumsum(v)])
        lo = jnp.maximum(pos - k + 1, start_idx)
        wsum = cs[pos + 1] - cs[lo]
        out_sorted = wsum / jnp.maximum(pos - lo + 1, 1)

    out = jnp.zeros((n,), out_sorted.dtype).at[perm].set(out_sorted)
    new_env = dict(env)
    new_env[node.out_name] = out
    return new_env, mask
