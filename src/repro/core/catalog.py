"""The catalog: datasets, datatypes, indexes — AsterixDB's metadata node.

A ``Dataset`` owns a row-sharded :class:`~repro.engine.table.Table` plus any
indexes. ``closed`` datasets have a declared schema (typed dense columns);
``open`` datasets simulate schema-on-read: values are stored widened
(float64/boxed) and every access pays a cast — this models the paper's
open-vs-closed datatype cost difference ("AFrame" vs "AFrame Schema").
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.engine.table import ColumnMeta, Table


@dataclasses.dataclass
class IndexInfo:
    name: str
    column: str
    kind: str  # "primary" (clustered: table sorted by column) | "secondary"
    # secondary index payload: sorted keys + row ids + per-block zone maps,
    # each row-sharded like the base table.
    sorted_keys: Optional[object] = None
    row_ids: Optional[object] = None


@dataclasses.dataclass
class Dataset:
    name: str
    dataverse: str
    table: Table
    closed: bool = True  # closed datatype == schema provided
    indexes: dict[str, IndexInfo] = dataclasses.field(default_factory=dict)

    def index_on(self, column: str) -> Optional[IndexInfo]:
        for ix in self.indexes.values():
            if ix.column == column:
                return ix
        return None

    @property
    def primary_index(self) -> Optional[IndexInfo]:
        for ix in self.indexes.values():
            if ix.kind == "primary":
                return ix
        return None


class Catalog:
    def __init__(self):
        self._datasets: dict[tuple[str, str], Dataset] = {}

    def register(self, ds: Dataset) -> Dataset:
        self._datasets[(ds.dataverse, ds.name)] = ds
        return ds

    def get(self, dataverse: str, name: str) -> Dataset:
        key = (dataverse, name)
        if key not in self._datasets:
            raise KeyError(f"unknown dataset {dataverse}.{name}")
        return self._datasets[key]

    def drop(self, dataverse: str, name: str) -> None:
        self._datasets.pop((dataverse, name), None)

    def names(self) -> list[str]:
        return [f"{dv}.{n}" for dv, n in self._datasets]


def open_widen(table: Table) -> Table:
    """Simulate an *open* datatype: numeric columns stored as float64 with a
    per-access cast cost; schema-on-read (paper's open ADM datatype)."""
    cols = {}
    meta = {}
    for name, col in table.columns.items():
        m = table.meta[name]
        if col.ndim == 1 and jnp.issubdtype(col.dtype, jnp.integer) and name != "__valid__":
            cols[name] = col.astype(jnp.float32)
            meta[name] = ColumnMeta(np.dtype(np.float32), m.lo, m.hi, m.distinct,
                                    m.is_string, m.sorted_ascending)
        else:
            cols[name] = col
            meta[name] = m
    return Table(cols, meta, table.num_rows)
