"""The catalog: datasets, datatypes, indexes — AsterixDB's metadata node.

A ``Dataset`` owns a row-sharded :class:`~repro.engine.table.Table` plus any
indexes. ``closed`` datasets have a declared schema (typed dense columns);
``open`` datasets simulate schema-on-read: values are stored widened
(float64/boxed) and every access pays a cast — this models the paper's
open-vs-closed datatype cost difference ("AFrame" vs "AFrame Schema").
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.engine.table import ColumnMeta, Table

# Engine-internal per-row columns that must never surface in query envs,
# schemas, or statistics: the padding/validity mask and the anti-matter
# (tombstone) flag mutated runs carry. One authoritative tuple — every
# "skip internal columns" site references it.
INTERNAL_COLUMNS = ("__valid__", "__antimatter__")


@dataclasses.dataclass
class IndexInfo:
    name: str
    column: str
    kind: str  # "primary" (clustered: table sorted by column) | "secondary"
    # secondary index payload: sorted keys + row ids + per-block zone maps,
    # each row-sharded like the base table.
    sorted_keys: Optional[object] = None
    row_ids: Optional[object] = None
    # per-ZONE_BLOCK min/max of sorted_keys (index order), built in the same
    # fused program as the sort. The run-level envelope (= the column's lo/hi
    # stats) drives query-time zone-map RUN pruning in the physical planner.
    # Intra-component BLOCK skipping uses Dataset.block_zones instead — zone
    # maps over the *storage* order, which is what the filter kernel streams.
    zone_min: Optional[object] = None
    zone_max: Optional[object] = None


@dataclasses.dataclass
class Dataset:
    name: str
    dataverse: str
    table: Table
    closed: bool = True  # closed datatype == schema provided
    # First-class, always-present index inventory (never getattr-defaulted):
    # planner and compiler read it through core/stats.py TableStats — the one
    # source of truth for access-path availability.
    indexes: dict[str, IndexInfo] = dataclasses.field(default_factory=dict)
    # LSM components (engine/lsm.py): each run is itself a Dataset holding a
    # device-resident flush (padded + sharded, own indexes/zone maps). Runs
    # are addressed as "<name>@run<i>" and never appear in catalog.names();
    # queries over a fed dataset execute as base ∪ runs (UnionRuns plan node)
    # until compaction folds them back into ``table``.
    runs: list["Dataset"] = dataclasses.field(default_factory=list)
    live_rows: Optional[int] = None  # matter-row count (None -> len(table))
    # -- anti-matter (delete/upsert) bookkeeping ----------------------------
    # A mutated run carries tombstones: its table holds anti-matter rows
    # (``__antimatter__`` True, ``__valid__`` False — invisible to every
    # matter path) and ``anti_keys_arr`` is the same key set as a sorted
    # device array for query-time visibility probes. ``annihilated_*`` track
    # THIS component's matter shadowed by strictly-newer components' anti-
    # matter (maintained at flush time, O(tombstones·log n)); the stats
    # layer discounts them so cost estimates and compaction triggers see
    # visible rows, not raw storage.
    anti_rows: int = 0                       # tombstones this component holds
    anti_keys_arr: Optional[object] = None   # sorted device array of anti keys
    host_anti_keys: Optional[object] = None  # host copy of the same (point
    #                                          lookups probe it without a
    #                                          device->host transfer)
    annihilated_rows: int = 0                # own matter shadowed by newer anti
    annihilated_keys: set = dataclasses.field(default_factory=set)
    host_keys: Optional[object] = None       # host copy of the sorted matter
    #                                          primary keys (clustered order)
    level: int = 0                           # LSM level (leveled compaction)
    # Intra-component zone maps (core/stats.py BlockZones): per-ZONE_BLOCK
    # [min, max] of every integer column over the stored row layout,
    # harvested at load (session.create_dataset/persist) and flush/compaction
    # (lsm.make_run). The run-level envelope lives in the column stats; these
    # per-block values feed kernel-grid block skipping.
    block_zones: Optional[object] = None

    @property
    def num_live_rows(self) -> int:
        """Visible matter rows: physical matter minus rows newer anti-matter
        has annihilated."""
        matter = self.live_rows if self.live_rows is not None else len(self.table)
        return max(matter - self.annihilated_rows, 0)

    def index_on(self, column: str) -> Optional[IndexInfo]:
        for ix in self.indexes.values():
            if ix.column == column:
                return ix
        return None

    @property
    def primary_index(self) -> Optional[IndexInfo]:
        for ix in self.indexes.values():
            if ix.kind == "primary":
                return ix
        return None


class Catalog:
    def __init__(self):
        self._datasets: dict[tuple[str, str], Dataset] = {}
        # Monotone statistics epoch: bumped on every event that changes what
        # the catalog statistics describe (DDL, feed flush, compaction).
        # Compiled plans are keyed by the epoch (Session's plan cache), so a
        # stale executable can never read a dropped LSM component.
        self.stats_epoch: int = 0

    def bump_stats_epoch(self) -> int:
        self.stats_epoch += 1
        return self.stats_epoch

    def register(self, ds: Dataset) -> Dataset:
        self._datasets[(ds.dataverse, ds.name)] = ds
        self.bump_stats_epoch()
        return ds

    def get(self, dataverse: str, name: str) -> Dataset:
        if "@" in name:  # LSM component address: "<dataset>@run<i>"
            base_name, _, comp = name.partition("@")
            ds = self.get(dataverse, base_name)
            if comp.startswith("run"):
                i = int(comp[3:])
                if i < len(ds.runs):
                    return ds.runs[i]
            raise KeyError(f"unknown LSM component {dataverse}.{name}")
        key = (dataverse, name)
        if key not in self._datasets:
            raise KeyError(f"unknown dataset {dataverse}.{name}")
        return self._datasets[key]

    def drop(self, dataverse: str, name: str) -> None:
        if self._datasets.pop((dataverse, name), None) is not None:
            self.bump_stats_epoch()

    def names(self) -> list[str]:
        return [f"{dv}.{n}" for dv, n in self._datasets]


def open_widen(table: Table) -> Table:
    """Simulate an *open* datatype: numeric columns stored as float64 with a
    per-access cast cost; schema-on-read (paper's open ADM datatype)."""
    cols = {}
    meta = {}
    for name, col in table.columns.items():
        m = table.meta[name]
        if col.ndim == 1 and jnp.issubdtype(col.dtype, jnp.integer) and name != "__valid__":
            cols[name] = col.astype(jnp.float32)
            meta[name] = ColumnMeta(np.dtype(np.float32), m.lo, m.hi, m.distinct,
                                    m.is_string, m.sorted_ascending)
        else:
            cols[name] = col
            meta[name] = m
    return Table(cols, meta, table.num_rows)
