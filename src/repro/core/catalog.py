"""The catalog: datasets, datatypes, indexes — AsterixDB's metadata node.

A ``Dataset`` owns a row-sharded :class:`~repro.engine.table.Table` plus any
indexes. ``closed`` datasets have a declared schema (typed dense columns);
``open`` datasets simulate schema-on-read: values are stored widened
(float32 for numeric lanes) and every access pays a cast — this models the
paper's open-vs-closed datatype cost difference ("AFrame" vs "AFrame
Schema").

Concurrency model (snapshot-isolated serving):

  * every dataset's component set — the base table plus its LSM runs — is
    described by an immutable, **LSN-stamped** :class:`Manifest`. Mutating
    the component set (feed flush, leveled merge, full compaction) never
    edits a manifest in place: the writer builds fresh components off the
    hot path and **publishes** a new manifest under the catalog lock, then
    the old manifest is **retired**. The swap is a single reference
    assignment — readers either see the old set or the new set, never a
    half-merged one (AsterixDB's LSM discipline; gnitz's LSN-only
    atomicity).
  * readers never take the writer path: :meth:`Catalog.snapshot` captures
    the current manifest of every dataset (O(datasets) metadata, no device
    work) and **pins** them. A query plans, compiles, and executes entirely
    against its pinned :class:`Snapshot`, so a concurrent flush/compaction
    can never change what a bound plan reads. Retired manifests stay alive
    while pinned (publish-then-retire); release drops the pin.
  * component addresses are **stable ids**: a run is ``"<ds>@run<uid>"``
    where ``uid`` is a per-dataset monotone counter assigned at flush time
    and never reused — a compaction that folds neighbours does not shift
    the address of a surviving run (list positions did; uids don't).
"""
from __future__ import annotations

import dataclasses
import threading
import weakref
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.engine.table import ColumnMeta, Table, is_lane_column
from repro.runtime import telemetry as tel

# Engine-internal per-row columns that must never surface in query envs,
# schemas, or statistics: the padding/validity mask and the anti-matter
# (tombstone) flag mutated runs carry. One authoritative tuple — every
# "skip internal columns" site references it.
INTERNAL_COLUMNS = ("__valid__", "__antimatter__")


@dataclasses.dataclass
class IndexInfo:
    name: str
    column: str
    kind: str  # "primary" (clustered: table sorted by column) | "secondary"
    # secondary index payload: sorted keys + row ids + per-block zone maps,
    # each row-sharded like the base table.
    sorted_keys: Optional[object] = None
    row_ids: Optional[object] = None
    # per-ZONE_BLOCK min/max of sorted_keys (index order), built in the same
    # fused program as the sort. The run-level envelope (= the column's lo/hi
    # stats) drives query-time zone-map RUN pruning in the physical planner.
    # Intra-component BLOCK skipping uses Dataset.block_zones instead — zone
    # maps over the *storage* order, which is what the filter kernel streams.
    zone_min: Optional[object] = None
    zone_max: Optional[object] = None


@dataclasses.dataclass(eq=False)  # identity semantics: components are
#                                   compared/looked-up by object identity
#                                   (manifest CAS validation), never by value
class Dataset:
    name: str
    dataverse: str
    table: Table
    closed: bool = True  # closed datatype == schema provided
    # First-class, always-present index inventory (never getattr-defaulted):
    # planner and compiler read it through core/stats.py TableStats — the one
    # source of truth for access-path availability. The *inventory* (which
    # columns, which kinds) is hard metadata; the payloads (sorted keys, row
    # ids, zone arrays) are SOFT state, rebuildable from the table columns
    # (engine/lsm.py recover()).
    indexes: dict[str, IndexInfo] = dataclasses.field(default_factory=dict)
    live_rows: Optional[int] = None  # matter-row count (None -> len(table))
    # -- anti-matter (delete/upsert) bookkeeping ----------------------------
    # A mutated run carries tombstones: its table holds anti-matter rows
    # (``__antimatter__`` True, ``__valid__`` False — invisible to every
    # matter path) and ``anti_keys_arr`` is the same key set as a sorted
    # device array for query-time visibility probes. ``annihilated_*`` track
    # THIS component's matter shadowed by strictly-newer components' anti-
    # matter (maintained at flush time, O(tombstones·log n)); the stats
    # layer discounts them so cost estimates and compaction triggers see
    # visible rows, not raw storage. All of it is soft state: query-time
    # visibility always derives from the bound manifest's anti arrays, and
    # recover() replays the bookkeeping from the hard rows.
    anti_rows: int = 0                       # tombstones this component holds
    anti_keys_arr: Optional[object] = None   # sorted device array of anti keys
    host_anti_keys: Optional[object] = None  # host copy of the same (point
    #                                          lookups probe it without a
    #                                          device->host transfer)
    annihilated_rows: int = 0                # own matter shadowed by newer anti
    annihilated_keys: set = dataclasses.field(default_factory=set)
    host_keys: Optional[object] = None       # host copy of the sorted matter
    #                                          primary keys (clustered order)
    level: int = 0                           # LSM level (leveled compaction)
    # Intra-component zone maps (core/stats.py BlockZones): per-ZONE_BLOCK
    # [min, max] of every integer column over the stored row layout,
    # harvested at load (session.create_dataset/persist) and flush/compaction
    # (lsm.make_run). The run-level envelope lives in the column stats; these
    # per-block values feed kernel-grid block skipping.
    block_zones: Optional[object] = None
    # Stable component id: runs get a per-dataset monotone uid at flush time
    # (never reused for the dataset's lifetime) and are addressed as
    # "<name>@run<uid>"; -1 for base datasets.
    uid: int = -1
    # The current manifest for a *registered base* dataset (None for run
    # components). Swapped atomically by Catalog.publish — never mutated.
    manifest: Optional["Manifest"] = None
    # True for components whose device buffers the ENGINE built and owns
    # exclusively (flush-built runs, compaction-built bases): only these are
    # eagerly device-deleted by the retired-manifest reclamation sweep. A
    # user-loaded base may share its arrays with the caller's Table, so it
    # is left to ordinary Python GC.
    engine_owned: bool = False
    # Durable-segment filename (runtime/durable.py) once this component's
    # hard state is on disk; None while memory-only. Set by
    # DurableStore.write_component (idempotence marker) and at cold-start
    # mount (so a re-publish never rewrites an existing segment).
    seg_name: Optional[str] = None
    # True while this component's SOFT state (index payloads, zone maps,
    # host key copies, anti arrays, annihilation bookkeeping) has not been
    # rebuilt since a cold-start mount — lsm.ensure_soft clears it lazily at
    # first bind instead of paying every index build at Session.open.
    soft_stale: bool = False

    @property
    def runs(self) -> list["Dataset"]:
        """The dataset's CURRENT LSM components (live manifest view).

        Read-only: the returned list is a copy — mutating it changes
        nothing. Writers publish a new manifest (``Catalog.publish``);
        readers bind a pinned ``Snapshot`` instead of this property."""
        if self.manifest is None:
            return []
        return list(self.manifest.runs)

    @property
    def num_live_rows(self) -> int:
        """Visible matter rows: physical matter minus rows newer anti-matter
        has annihilated."""
        matter = self.live_rows if self.live_rows is not None else len(self.table)
        return max(matter - self.annihilated_rows, 0)

    def index_on(self, column: str) -> Optional[IndexInfo]:
        for ix in self.indexes.values():
            if ix.column == column:
                return ix
        return None

    @property
    def primary_index(self) -> Optional[IndexInfo]:
        for ix in self.indexes.values():
            if ix.kind == "primary":
                return ix
        return None


@dataclasses.dataclass
class Manifest:
    """One immutable, LSN-stamped description of a dataset's component set:
    the base plus the ordered run list (oldest → newest; newest-wins
    visibility is this order). ``lsn`` is the catalog-global log sequence
    number of the publish that created it — strictly monotone, so manifests
    totally order and the plan cache can key on it.

    A manifest is never edited after publish. ``retired`` flips (under the
    catalog lock) when a newer manifest supersedes it; ``pins`` counts live
    snapshots still bound to it — a retired-but-pinned manifest keeps its
    components reachable for exactly the readers that bound it
    (publish-then-retire)."""

    lsn: int
    base: Dataset
    runs: tuple = ()
    retired: bool = False
    pins: int = 0

    @property
    def components(self) -> tuple:
        """(base, run_0, ..., run_n) — oldest to newest."""
        return (self.base,) + tuple(self.runs)


def component_nbytes(ds: Dataset) -> int:
    """Device bytes one LSM component holds resident: table columns, index
    payloads, and the sorted anti-key array. Metadata-only (sums ``nbytes``
    over the arrays — no device work), so the GC-visibility sweep can run on
    every publish/release."""
    total = 0
    for col in ds.table.columns.values():
        total += int(getattr(col, "nbytes", 0) or 0)
    if ds.anti_keys_arr is not None:
        total += int(getattr(ds.anti_keys_arr, "nbytes", 0) or 0)
    for ix in ds.indexes.values():
        for arr in (ix.sorted_keys, ix.row_ids, ix.zone_min, ix.zone_max):
            if arr is not None:
                total += int(getattr(arr, "nbytes", 0) or 0)
    return total


def _delete_component_buffers(ds: Dataset) -> None:
    """Eagerly free one component's device buffers (table columns, anti-key
    array, index payloads). Host-side copies (``host_keys``,
    ``host_anti_keys``, annihilation sets) are left alone — they are cheap
    and point lookups on OTHER components never read a retired one."""
    import jax

    arrays = list(ds.table.columns.values())
    if ds.anti_keys_arr is not None:
        arrays.append(ds.anti_keys_arr)
    for ix in ds.indexes.values():
        for arr in (ix.sorted_keys, ix.row_ids, ix.zone_min, ix.zone_max):
            if arr is not None:
                arrays.append(arr)
    for a in arrays:
        if isinstance(a, jax.Array) and not a.is_deleted():
            a.delete()


def _resolve_run(manifest: Manifest, dataverse: str, base_name: str,
                 comp: str) -> Dataset:
    """Resolve a stable-id component address suffix ("run<uid>") against one
    manifest. Raises KeyError for malformed suffixes, unknown uids, and
    retired (compacted-away) components alike — the address names a
    component that this manifest does not serve."""
    if comp.startswith("run"):
        try:
            uid = int(comp[3:])
        except ValueError:
            raise KeyError(
                f"malformed LSM component address {dataverse}.{base_name}"
                f"@{comp}: expected '@run<uid>'") from None
        for r in manifest.runs:
            if r.uid == uid:
                return r
    raise KeyError(f"unknown LSM component {dataverse}.{base_name}@{comp}")


class Snapshot:
    """An immutable, pinned view of the catalog at one LSN: every dataset's
    manifest as of :meth:`Catalog.snapshot`. Duck-types the *read* surface
    of the catalog (``get`` / ``components`` / ``manifest`` / ``names`` /
    ``stats_epoch``), so the optimizer, pruner, physical planner, compiler,
    and ``CompiledQuery.gather_tables`` bind against pinned components
    without knowing they hold a snapshot — a concurrent flush or background
    compaction can never change what a bound plan reads.

    Pins are released with :meth:`release` (or the context-manager exit);
    until then every captured manifest — retired or not — keeps its
    components alive."""

    def __init__(self, catalog: "Catalog", manifests: dict,
                 stats_epoch: int, lsn: int):
        self._catalog = catalog
        self._manifests = manifests  # (dataverse, name) -> Manifest
        self.stats_epoch = stats_epoch
        self.lsn = lsn
        self._released = False

    def manifest(self, dataverse: str, name: str) -> Manifest:
        key = (dataverse, name)
        if key not in self._manifests:
            raise KeyError(f"unknown dataset {dataverse}.{name}")
        return self._manifests[key]

    def components(self, dataverse: str, name: str) -> tuple:
        return self.manifest(dataverse, name).components

    def get(self, dataverse: str, name: str) -> Dataset:
        if "@" in name:  # stable component address: "<dataset>@run<uid>"
            base_name, _, comp = name.partition("@")
            return _resolve_run(self.manifest(dataverse, base_name),
                                dataverse, base_name, comp)
        return self.manifest(dataverse, name).base

    def names(self) -> list[str]:
        return [f"{dv}.{n}" for dv, n in self._manifests]

    def release(self) -> None:
        if self._released:
            return
        self._released = True
        with self._catalog._lock:
            for m in self._manifests.values():
                m.pins -= 1
        # reclaim + refresh the GC-visibility gauges only when something is
        # actually retired — the common query path (nothing to do) stays free
        if self._catalog._retired:
            self._catalog._reclaim()
            self._catalog.gc_stats()

    def __enter__(self) -> "Snapshot":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class Catalog:
    def __init__(self):
        self._datasets: dict[tuple[str, str], Dataset] = {}
        # Monotone statistics epoch: bumped on every event that changes what
        # the catalog statistics describe (DDL, feed flush, compaction).
        # Compiled plans are keyed by (epoch, LSN) — the Session's plan
        # cache — so a stale executable can never read a retired component.
        self.stats_epoch: int = 0
        # Catalog-global log sequence number: bumped by every manifest
        # publish. The single point of atomicity for storage state — a
        # reader's snapshot is "everything at LSN <= n".
        self.lsn: int = 0
        # One lock serializes writers (manifest publishes, DDL) and makes
        # snapshot capture consistent. Readers hold it only for the
        # O(datasets) metadata capture — never across planning or execution,
        # so no query ever blocks on a running compaction.
        self._lock = threading.RLock()
        self._run_uids: dict[tuple[str, str], int] = {}
        # Retired manifests still alive (weakly held): a retired-but-pinned
        # manifest keeps superseded components device-resident for exactly
        # its readers — the GC-visibility sweep (gc_stats) walks this set to
        # report how many bytes long-lived snapshots are retaining. Weak
        # references on purpose: once the last snapshot releases, the
        # manifest (and its exclusive components) free normally and the
        # series drops back to zero — tracking must not itself retain.
        self._retired: "weakref.WeakValueDictionary[int, Manifest]" = \
            weakref.WeakValueDictionary()
        # Durable storage attachment (runtime/durable.py DurableStore).
        # None for memory-only catalogs — every durability hook below is a
        # no-op then. When set, publish() gains a durable-commit step and
        # _reclaim() also unlinks dead components' segment files.
        self.store = None
        # Datasets with soft-stale components (cold-start mounts awaiting
        # their first bind): O(1) membership test on the query hot path —
        # lsm.ensure_soft rebuilds and removes under the catalog lock.
        self.stale: set[tuple[str, str]] = set()

    def attach_store(self, store) -> None:
        """Attach the durable store. One store per catalog: sessions that
        share a catalog share its storage directory too."""
        with self._lock:
            if self.store is not None and self.store is not store:
                raise RuntimeError(
                    "catalog already has a durable store attached")
            self.store = store

    @property
    def lock(self) -> threading.RLock:
        return self._lock

    def bump_stats_epoch(self) -> int:
        with self._lock:
            self.stats_epoch += 1
            return self.stats_epoch

    def next_run_uid(self, dataverse: str, name: str) -> int:
        """Allocate the next stable run uid for a dataset. Uids are per
        dataset, monotone, and never reused — a full compaction resets the
        run list but not the counter, so a stale address can never alias a
        different, newer run."""
        with self._lock:
            key = (dataverse, name)
            uid = self._run_uids.get(key, 0)
            self._run_uids[key] = uid + 1
            return uid

    def register(self, ds: Dataset) -> Dataset:
        """DDL entry point: register a fresh base dataset under an initial
        one-component manifest."""
        return self.publish(ds.dataverse, ds.name, ds, ())

    def publish(self, dataverse: str, name: str, base: Dataset,
                runs) -> Manifest:
        """Atomically swap a dataset's manifest (publish-then-retire): stamp
        the next LSN, install the new manifest, retire the old one. The old
        manifest object is untouched beyond the ``retired`` flag — snapshots
        that pinned it keep reading exactly the component set they bound."""
        with self._lock:
            key = (dataverse, name)
            old = self._datasets.get(key)
            # capture before the swap: flushes republish the SAME base
            # Dataset object, so old.manifest is unreachable afterwards
            old_manifest = old.manifest if old is not None else None
            self.lsn += 1
            m = Manifest(self.lsn, base, tuple(runs))
            base.manifest = m
            self._datasets[key] = base
            if old_manifest is not None and old_manifest is not m:
                old_manifest.retired = True
                self._retired[id(old_manifest)] = old_manifest
            self.bump_stats_epoch()
            tel.inc("catalog.publishes_total")
            if old_manifest is not None and old_manifest is not m:
                tel.inc("catalog.manifests_retired_total")
            if self.store is not None:
                # The durable-commit step of the swap: segments for the new
                # components (heavy tensor writes happen off-lock in the
                # flush/compaction builders; this persists only what is
                # still missing — fresh DDL bases; mounted republishes are
                # no-ops), then the manifest generation via write-temp →
                # fsync → atomic rename. A crash before the rename leaves
                # the previous generation + the WAL tail authoritative.
                self.store.commit(dataverse, name, m)
            self._reclaim()
            self.gc_stats()
            return m

    def manifest(self, dataverse: str, name: str) -> Manifest:
        key = (dataverse, name)
        if key not in self._datasets:
            raise KeyError(f"unknown dataset {dataverse}.{name}")
        return self._datasets[key].manifest

    def components(self, dataverse: str, name: str) -> tuple:
        """(base, *runs) of the dataset's CURRENT manifest. Readers that
        need a stable view across multiple calls use snapshot() instead."""
        return self.manifest(dataverse, name).components

    def snapshot(self) -> Snapshot:
        """Capture and pin the current manifest of every dataset — the
        read-side entry point of snapshot isolation. O(datasets), metadata
        only; the caller releases the snapshot when its bound plan is done."""
        with self._lock:
            manifests = {k: ds.manifest for k, ds in self._datasets.items()}
            for m in manifests.values():
                m.pins += 1
            return Snapshot(self, manifests, self.stats_epoch, self.lsn)

    def get(self, dataverse: str, name: str) -> Dataset:
        if "@" in name:  # stable component address: "<dataset>@run<uid>"
            base_name, _, comp = name.partition("@")
            return _resolve_run(self.manifest(dataverse, base_name),
                                dataverse, base_name, comp)
        key = (dataverse, name)
        if key not in self._datasets:
            raise KeyError(f"unknown dataset {dataverse}.{name}")
        return self._datasets[key]

    def drop(self, dataverse: str, name: str) -> None:
        with self._lock:
            ds = self._datasets.pop((dataverse, name), None)
            self.stale.discard((dataverse, name))
            if ds is not None:
                if ds.manifest is not None:
                    ds.manifest.retired = True
                    self._retired[id(ds.manifest)] = ds.manifest
                    tel.inc("catalog.manifests_retired_total")
                if self.store is not None:
                    self.store.drop_dataset(dataverse, name)
                self.bump_stats_epoch()
                self._reclaim()
                self.gc_stats()

    def _reclaim(self) -> None:
        """Active retired-manifest reclamation (the second half of the PR 6
        follow-up — gc_stats is the visibility half): delete the device
        buffers of components reachable ONLY through retired, UNPINNED
        manifests, and drop those manifests from the retired set. Runs on
        every publish/drop/snapshot-release, so
        ``catalog.retired_component_bytes`` falls back to ~0 as soon as the
        last reader releases — no reliance on the Python GC ever collecting
        the weakly-held manifest objects. Protected components (present in
        a current manifest, or in ANY still-pinned retired manifest) are
        never touched; byte counts are captured before deletion."""
        with self._lock:
            protected: set[int] = set()
            for ds in self._datasets.values():
                if ds.manifest is not None:
                    for comp in ds.manifest.components:
                        protected.add(id(comp))
            for m in list(self._retired.values()):
                if m.pins > 0:
                    for comp in m.components:
                        protected.add(id(comp))
            comps_freed = bytes_freed = 0
            dead_segs: list[tuple[str, str, str]] = []
            for mid, m in list(self._retired.items()):
                if m.pins > 0:
                    continue
                for comp in m.components:
                    if id(comp) in protected:
                        continue
                    protected.add(id(comp))  # shared across retired: once
                    if comp.seg_name is not None:
                        dead_segs.append((comp.dataverse,
                                          comp.name.partition("@")[0],
                                          comp.seg_name))
                    if not comp.engine_owned:
                        continue  # may share buffers with a caller's Table
                    bytes_freed += component_nbytes(comp)
                    comps_freed += 1
                    _delete_component_buffers(comp)
                self._retired.pop(mid, None)
        if self.store is not None:
            # retired-component GC, durable half: unlink segment files no
            # kept manifest generation references anymore (the store skips
            # segments a kept generation or an in-flight build still needs)
            for dv, name, seg in dead_segs:
                self.store.maybe_unlink(dv, name, seg)
        if comps_freed:
            tel.inc("catalog.reclaimed_components_total", comps_freed)
            tel.inc("catalog.reclaimed_bytes_total", bytes_freed)

    def gc_stats(self) -> dict:
        """The PR 6 GC-visibility follow-up, measured: walk the still-alive
        retired manifests and report what they retain — manifest counts
        (pinned vs merely awaiting collection) and the device bytes of
        components reachable ONLY through them (a component also present in
        a current manifest is not leaked, it is just shared). Updates the
        ``catalog.*`` gauges; called on every publish/drop/snapshot-release
        and callable directly."""
        with self._lock:
            current: set[int] = set()
            pinned_current = 0
            for ds in self._datasets.values():
                if ds.manifest is None:
                    continue
                if ds.manifest.pins > 0:
                    pinned_current += 1
                for comp in ds.manifest.components:
                    current.add(id(comp))
            retired = retired_pinned = 0
            leaked: dict[int, Dataset] = {}
            for m in list(self._retired.values()):
                retired += 1
                if m.pins > 0:
                    retired_pinned += 1
                for comp in m.components:
                    if id(comp) not in current:
                        leaked[id(comp)] = comp
            retained = sum(component_nbytes(c) for c in leaked.values())
        out = {"manifests_retired": retired,
               "manifests_retired_pinned": retired_pinned,
               "manifests_pinned": pinned_current + retired_pinned,
               "retired_components": len(leaked),
               "retired_component_bytes": retained}
        for k, v in out.items():
            tel.set_gauge(f"catalog.{k}", v)
        return out

    def names(self) -> list[str]:
        return [f"{dv}.{n}" for dv, n in self._datasets]


def open_widen(table: Table) -> Table:
    """Simulate an *open* datatype: integer columns stored as float32 with a
    per-access cast cost; schema-on-read (the paper's open ADM datatype).
    float32 — not a wider float — is deliberate: it is the TPU-native lane
    dtype, and the cost being modelled is the cast itself, not extra
    precision (tests/test_manifest.py pins the dtype)."""
    cols = {}
    meta = {}
    for name, col in table.columns.items():
        m = table.meta[name]
        # derived string lanes stay integer even in an open dataset: they are
        # engine internals (dict ids feed int32 kernels, prefixes feed zone
        # maps), not user values paying the schema-on-read cast.
        if col.ndim == 1 and jnp.issubdtype(col.dtype, jnp.integer) \
                and name != "__valid__" and not is_lane_column(name):
            cols[name] = col.astype(jnp.float32)
            meta[name] = ColumnMeta(np.dtype(np.float32), m.lo, m.hi, m.distinct,
                                    m.is_string, m.sorted_ascending)
        else:
            cols[name] = col
            meta[name] = m
    return Table(cols, meta, table.num_rows)
