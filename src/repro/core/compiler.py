"""Physical-plan compiler: costed physical plan → one jitted SPMD program.

The AsterixDB analogue of "ship the SQL++ string, get an optimized Hyracks
job": the physical plan (core/physical.py, chosen by the cost-based planner
in core/physical_planner.py) lowers to a closed JAX function over (dataset
columns, literal params) and jits once per *physical* fingerprint — literal
values are runtime params, so randomized predicates reuse the executable
(the prepared-statement effect the paper gets from AsterixDB's plan cache).

The three execution modes are **lowering strategies**, not branches inside
operator lowerings:

  * ``gspmd``     — :class:`LoweringStrategy`: plain jnp ops; under jit XLA
    GSPMD inserts collectives (the paper-faithful baseline).
  * ``shard_map`` — :class:`ShardMapStrategy`: relational operators from
    engine/distributed.py with hand-placed minimal collectives.
  * ``kernel``    — same two strategies; what makes kernel mode different is
    the *planner* emitting kernel physical operators (KernelRangeCount,
    KernelSegmentAgg, kernel JoinCount, block-topk selection), which every
    strategy knows how to launch (locally or composed via shard_map).

Each ``_lower_*`` function handles exactly one physical operator and calls
only ``ctx.strategy`` primitives — there is no ``ctx.mode`` branching inside
lowerings.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import physical as PH
from repro.core.catalog import INTERNAL_COLUMNS, Catalog
from repro.core.expr import collect_params, param_values
from repro.engine import physical
from repro.runtime import telemetry as tel


# -- lowering strategies ------------------------------------------------------


class LoweringStrategy:
    """Single-program lowering: plain jnp over (possibly sharded) arrays —
    under jit, XLA GSPMD inserts any needed collectives."""

    def __init__(self, kernel_backend: Optional[str] = None,
                 kernel_interpret: Optional[bool] = None):
        self.kernel_backend = kernel_backend
        # None = auto-detect per kernels/ops: compiled Pallas on TPU,
        # interpret mode elsewhere; a Session(kernel_interpret=...) override
        # forces one or the other (debugging / TPU bring-up).
        self.kernel_interpret = kernel_interpret

    def count(self, mask):
        return jnp.sum(mask, dtype=jnp.int32)

    def agg(self, env, mask, op, column):
        return physical.agg_scalar(env, mask, op, column)

    def limit(self, env, mask, n):
        return physical.limit(env, mask, n)

    def topk(self, env, mask, key, k, ascending, select):
        return physical.topk(env, mask, key, k, ascending, select=select)

    def group_agg(self, env, mask, key, lo, num_groups, aggs):
        return physical.group_agg(env, mask, key, lo, num_groups, aggs)

    def kernel_group_agg(self, gid, values, num_groups, n, op,
                         block_ids: Optional[tuple] = None,
                         shard_blocks=None):
        from repro.kernels import ops
        assert shard_blocks is None, \
            "per-shard grids need the shard_map strategy"
        return ops.segment_agg(values, gid, num_groups, n, op=op,
                               backend=self.kernel_backend,
                               block_ids=block_ids,
                               interpret=self.kernel_interpret)

    def kernel_filter_count(self, mat, bounds,
                            block_ids: Optional[tuple] = None,
                            shard_blocks=None):
        from repro.kernels import ops
        assert shard_blocks is None, \
            "per-shard grids need the shard_map strategy"
        return ops.filter_count(mat, bounds, mat.shape[1],
                                backend=self.kernel_backend,
                                block_ids=block_ids,
                                interpret=self.kernel_interpret)

    def index_count(self, ix_keys, valid, lo, hi):
        from repro.engine.index import index_count_local
        nv = jnp.sum(valid, dtype=jnp.int32)
        return index_count_local(ix_keys, nv, lo, hi)

    def shadow_count(self, ix_keys, valid, anti_keys, lo, hi):
        from repro.engine.index import shadow_count_local
        nv = jnp.sum(valid, dtype=jnp.int32)
        return shadow_count_local(ix_keys, nv, anti_keys, lo, hi)

    def join_count(self, lkey, lmask, rkey, rmask, presorted):
        if presorted:
            # index order: valid keys ascending, padding at +inf tail
            n_r = jnp.sum(rmask, dtype=jnp.int32)
            lo = jnp.searchsorted(rkey, lkey, side="left")
            hi = jnp.searchsorted(rkey, lkey, side="right")
            hi = jnp.minimum(hi, n_r)
            cnt = jnp.where(lmask, jnp.maximum(hi - lo, 0), 0)
            return jnp.sum(cnt, dtype=jnp.int32)
        return physical.join_count(lkey, lmask, rkey, rmask)

    def kernel_join_count(self, lkey, lmask, rkey, rmask, presorted):
        from repro.kernels import ops
        ls = ops.sort_join_keys(lkey, lmask)
        rs = ops.sort_join_keys(rkey, rmask, presorted=presorted)
        nl = jnp.sum(lmask, dtype=jnp.int32)
        nr = jnp.sum(rmask, dtype=jnp.int32)
        cnt = ops.merge_join_count(ls, rs, nl, nr,
                                   backend=self.kernel_backend)
        return cnt.astype(jnp.int32)


class ShardMapStrategy(LoweringStrategy):
    """Hand-placed minimal collectives: each relational primitive runs
    per-shard inside shard_map with an explicit psum/pmax/gather merge
    (engine/distributed.py)."""

    def __init__(self, mesh, data_axes, kernel_backend: Optional[str] = None,
                 kernel_interpret: Optional[bool] = None):
        super().__init__(kernel_backend, kernel_interpret)
        self.mesh, self.data_axes = mesh, data_axes

    def count(self, mask):
        from repro.engine import distributed as D
        return D.dist_count(self.mesh, self.data_axes, mask)

    def agg(self, env, mask, op, column):
        from repro.engine import distributed as D
        if op == "count":
            return D.dist_count(self.mesh, self.data_axes, mask)
        return D.dist_agg(self.mesh, self.data_axes, op, env[column], mask)

    def limit(self, env, mask, n):
        from repro.engine import distributed as D
        return D.dist_limit(self.mesh, self.data_axes, env, mask, n)

    def topk(self, env, mask, key, k, ascending, select):
        from repro.engine import distributed as D
        return D.dist_topk(self.mesh, self.data_axes, env, mask, key, k,
                           ascending, select=select)

    def group_agg(self, env, mask, key, lo, num_groups, aggs):
        from repro.engine import distributed as D
        value_cols = {c: env[c] for _, _, c in aggs if c}
        out, gmask = D.dist_group_agg(self.mesh, self.data_axes, env[key],
                                      mask, lo, num_groups, aggs, value_cols)
        out[key] = out.pop("__key__")
        return out, gmask

    def kernel_group_agg(self, gid, values, num_groups, n, op,
                         block_ids: Optional[tuple] = None,
                         shard_blocks=None):
        from repro.engine import distributed as D
        return D.dist_kernel_group_agg(self.mesh, self.data_axes, gid, values,
                                       num_groups, op=op,
                                       backend=self.kernel_backend,
                                       block_ids=block_ids,
                                       shard_blocks=shard_blocks,
                                       interpret=self.kernel_interpret)

    def kernel_filter_count(self, mat, bounds,
                            block_ids: Optional[tuple] = None,
                            shard_blocks=None):
        from repro.engine import distributed as D
        return D.dist_kernel_filter_count(self.mesh, self.data_axes, mat,
                                          bounds, backend=self.kernel_backend,
                                          block_ids=block_ids,
                                          shard_blocks=shard_blocks,
                                          interpret=self.kernel_interpret)

    def index_count(self, ix_keys, valid, lo, hi):
        from repro.engine import distributed as D
        return D.dist_index_count(self.mesh, self.data_axes, ix_keys, valid,
                                  lo, hi)

    def shadow_count(self, ix_keys, valid, anti_keys, lo, hi):
        from repro.engine import distributed as D
        return D.dist_shadow_count(self.mesh, self.data_axes, ix_keys, valid,
                                   anti_keys, lo, hi)

    def join_count(self, lkey, lmask, rkey, rmask, presorted):
        from repro.engine import distributed as D
        return D.dist_join_count(self.mesh, self.data_axes, lkey, lmask,
                                 rkey, rmask, presorted_right=presorted)

    def kernel_join_count(self, lkey, lmask, rkey, rmask, presorted):
        from repro.engine import distributed as D
        return D.dist_kernel_join_count(self.mesh, self.data_axes, lkey,
                                        lmask, rkey, rmask,
                                        presorted_right=presorted,
                                        backend=self.kernel_backend)


def make_strategy(ctx: "ExecContext") -> LoweringStrategy:
    """The ONLY place execution mode is consulted at lowering time: pick the
    collective-placement strategy. Operator choice already happened in the
    planner."""
    if ctx.mode in ("shard_map", "kernel") and ctx.mesh is not None:
        return ShardMapStrategy(ctx.mesh, ctx.data_axes, ctx.kernel_backend,
                                ctx.kernel_interpret)
    return LoweringStrategy(ctx.kernel_backend, ctx.kernel_interpret)


@dataclasses.dataclass
class ExecContext:
    catalog: Catalog
    mesh: Any = None            # jax Mesh when distributed
    data_axes: tuple = ("data",)
    mode: str = "gspmd"         # gspmd | shard_map | kernel
    kernel_backend: Optional[str] = None  # kernels/ops dispatch: None|xla|pallas
    kernel_interpret: Optional[bool] = None  # None = auto (TPU compiled)
    strategy: Optional[LoweringStrategy] = None

    def __post_init__(self):
        if self.strategy is None:
            self.strategy = make_strategy(self)


@dataclasses.dataclass
class CompiledQuery:
    plan: Any                   # the optimized *logical* plan (provenance)
    physical: PH.PhysOp         # the costed physical plan that was lowered
    fingerprint: str            # physical fingerprint (executable dedup key)
    kind: str                   # scalar | table | grouped
    fn: Callable                # jitted: (tables, params) -> result
    leaf_keys: list             # dataset keys feeding `tables` (pruned runs excluded)
    lits: list                  # literal slots (physical plan order)
    raw_fn: Callable = None     # unjitted build (jaxpr inspection in tests)
    anti_keys: list = dataclasses.field(default_factory=list)
    #                             components whose sorted anti-key arrays the
    #                             plan subtracts with (may include runs whose
    #                             MATTER was zone-pruned — their tombstones
    #                             still annihilate into older components)

    def gather_tables(self, catalog: Catalog) -> dict:
        tables = {}
        for key in self.leaf_keys:
            ds = catalog.get(*key)
            tables[f"{key[0]}.{key[1]}"] = dict(ds.table.columns)
            for ix in ds.indexes.values():
                if ix.sorted_keys is not None:
                    tables[f"{key[0]}.{key[1]}"][f"__ix_{ix.column}__"] = ix.sorted_keys
                    tables[f"{key[0]}.{key[1]}"][f"__ixid_{ix.column}__"] = ix.row_ids
        for key in self.anti_keys:
            ds = catalog.get(*key)
            tables[f"anti:{key[0]}.{key[1]}"] = ds.anti_keys_arr
        return tables

    def run(self, catalog: Catalog, lits=None, params=None):
        """``params``: pre-bound literal values in slot order (the Session's
        plan cache computes them via its literal binding). ``lits``: literal
        slots from the *current* plan instance — on a plan-cache hit the
        executable is reused but the fresh literal values must be bound
        (same fingerprint ⇒ same slot order)."""
        if params is None:
            params = param_values(lits if lits is not None else self.lits)
        return self.fn(self.gather_tables(catalog), params)


def compile_physical(logical, phys: PH.PhysOp, ctx: ExecContext) -> CompiledQuery:
    """Lower one physical plan into a jitted executable."""
    leaf_keys = PH.scan_leaves(phys)
    lits = collect_params(PH.all_exprs(phys))
    kind, build = _lower_terminal(phys, ctx)
    jitted = jax.jit(build)
    return CompiledQuery(logical, phys, phys.fingerprint(), kind, jitted,
                         leaf_keys, lits, raw_fn=build,
                         anti_keys=PH.anti_leaves(phys))


def compile_plan(opt_plan, ctx: ExecContext, *, enable_index: bool = True,
                 enable_prune: bool = True,
                 enable_block_skip: bool = True) -> CompiledQuery:
    """Convenience one-shot path (``Session.persist``, tests): cost-plan the
    optimized logical plan — pruning decided from its own literal values —
    then lower. The knobs mirror the Session's planner settings."""
    from repro.core.expr import ordered_lits
    from repro.core.physical_planner import (NO_PRUNE, build_pruner,
                                             plan_physical)
    from repro.core import plan as P

    raw_lits = ordered_lits(P.all_exprs(opt_plan))
    decisions = NO_PRUNE
    if enable_prune:
        from repro.core.stats import mesh_shards

        pruner = build_pruner(opt_plan, ctx.catalog, raw_lits,
                              n_shards=mesh_shards(ctx.mesh, ctx.data_axes))
        decisions = pruner.decide([l.value for l in raw_lits],
                                  block_skip=enable_block_skip)
    phys = plan_physical(opt_plan, ctx.catalog, mode=ctx.mode,
                         decisions=decisions, enable_index=enable_index)
    return compile_physical(opt_plan, phys, ctx)


def _result_rows(kind: str, out) -> int:
    """Actual row count of one lowered result: live mask sum for streams and
    groups, 1 for a scalar dict."""
    if kind in ("table", "grouped"):
        return int(np.asarray(out[1]).sum())
    return 1


def profile_physical(phys: PH.PhysOp, ctx: ExecContext, tables: dict,
                     params) -> dict:
    """Per-operator measurement for ``explain(analyze=True)``.

    The compiled executable is ONE fused jitted program — XLA gives no
    per-operator attribution — so profiling lowers each node's *subtree*
    standalone and executes it eagerly (unjitted, ``block_until_ready``
    synchronized). Self time = subtree total − Σ direct-child subtree
    totals, clamped at 0 (eager timing noise can invert tiny nodes). Row
    counts are exact: same lowering, same inputs as the jitted run.
    O(nodes · subtree cost) — fine at these plan sizes, and only paid when
    the user explicitly asks to analyze.

    Returns ``{"nodes": {id(node): {kind, total_seconds, self_seconds,
    rows}}}`` — the dict ``format_plan(root, analyze=...)`` renders."""
    nodes: dict[int, dict] = {}
    for node in PH.walk(phys):
        try:
            kind, build = _lower_terminal(node, ctx)
        except NotImplementedError:  # pragma: no cover - defensive
            continue
        with tel.span("profile.operator", op=type(node).__name__):
            t0 = time.perf_counter()
            out = jax.block_until_ready(build(tables, params))
            dt = time.perf_counter() - t0
        nodes[id(node)] = {"kind": kind, "total_seconds": dt,
                           "rows": _result_rows(kind, out)}
    for node in PH.walk(phys):
        m = nodes.get(id(node))
        if m is None:
            continue
        kids = sum(nodes[id(c)]["total_seconds"] for c in node.children
                   if id(c) in nodes)
        m["self_seconds"] = max(m["total_seconds"] - kids, 0.0)
    return {"nodes": nodes}


# -- streaming lowering -------------------------------------------------------


def _env_of(cols: dict, open_cast: bool):
    from repro.engine.table import is_lane_column

    env = {k: v for k, v in cols.items()
           if k not in INTERNAL_COLUMNS and not k.startswith("__ix")}
    if open_cast:  # schema-on-read: pay a widen/cast per access — but the
        # derived string lanes stay integer (dict-id remaps index with them)
        env = {k: (v.astype(jnp.float32) if jnp.issubdtype(v.dtype, jnp.integer)
                   and v.ndim == 1 and not is_lane_column(k) else v)
               for k, v in env.items()}
    mask = cols.get("__valid__",
                    jnp.ones((next(iter(env.values())).shape[0],), jnp.bool_))
    return env, mask


def _shadowed(tables: dict, keys, shadow_sources) -> "jax.Array":
    """True where a row's primary key appears in any newer component's
    sorted anti-key set — the newest-wins subtraction every matter stream
    applies. One batched binary search per tombstone set; mode-independent
    (the anti arrays are replicated, so gspmd/shard_map/kernel agree
    bit-for-bit)."""
    hit = None
    for dv, name in shadow_sources:
        ak = tables[f"anti:{dv}.{name}"]
        k = keys.astype(ak.dtype)
        pos = jnp.minimum(jnp.searchsorted(ak, k, side="left"),
                          ak.shape[0] - 1)
        h = ak[pos] == k
        hit = h if hit is None else (hit | h)
    return hit


def _block_gather(blocks: Optional[tuple], zone_block: int,
                  n_shards: int = 1, blocks_per_shard: int = 0,
                  rows_per_shard: int = 0, pad_multiple: int = 1):
    """Static-slice gather of the surviving row blocks (ascending ids keep
    the original row order). None = identity. Used by the generic stream
    path — the gspmd/shard_map analogue of driving the kernel grid through
    the block-id list.

    With ``n_shards > 1`` flat block ids address per-shard local tiles
    (``s * blocks_per_shard + j`` = shard ``s``'s local block ``j``); the
    slice is computed inside shard ``s``'s contiguous row chunk and a
    trailing partial block clips at the chunk boundary, so a gather never
    straddles shards. ``pad_multiple`` zero-pads the gathered length up to a
    multiple (shard_map operators split rows evenly over the mesh): pad rows
    carry a False mask (bool zero), so every mask-aware operator ignores
    them."""
    if blocks is None:
        return lambda col: col
    spans = []
    for b in blocks:
        if n_shards <= 1:
            spans.append((b * zone_block, (b + 1) * zone_block))
        else:
            s, j = divmod(b, blocks_per_shard)
            base = s * rows_per_shard
            spans.append((base + j * zone_block,
                          base + min((j + 1) * zone_block, rows_per_shard)))

    def sel(col):
        parts = [col[lo:hi] for lo, hi in spans]
        out = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)
        if pad_multiple > 1:
            pad = (-out.shape[0]) % pad_multiple
            if pad:
                out = jnp.pad(out, [(0, pad)] + [(0, 0)] * (out.ndim - 1))
        return out
    return sel


def _stream_pad(ctx: ExecContext) -> int:
    """Row-count multiple gathered streams must keep: shard_map operators
    split their inputs evenly over the mesh's data axes."""
    if isinstance(ctx.strategy, ShardMapStrategy):
        from repro.core.stats import mesh_shards
        return mesh_shards(ctx.mesh, ctx.data_axes)
    return 1


def _lower_stream(node: PH.PhysOp, ctx: ExecContext) -> Callable:
    """Returns fn(tables, params) -> (env, mask). Filters never compact
    (selection-vector execution; DESIGN.md §2)."""
    if isinstance(node, PH.TableScan):
        key = f"{node.dataverse}.{node.dataset}"
        open_cast = node.open_cast
        shadow, key_col = node.shadow_sources, node.key_col
        sel = _block_gather(node.block_ids, node.zone_block,
                            *node.shard_layout(), pad_multiple=_stream_pad(ctx))

        def fn(tables, params):
            env, mask = _env_of(tables[key], open_cast)
            env = {k: sel(v) for k, v in env.items()}
            mask = sel(mask)
            if shadow:
                mask = mask & ~_shadowed(tables, sel(tables[key][key_col]),
                                         shadow)
            return env, mask
        return fn

    if isinstance(node, PH.IndexProbe):
        key = f"{node.dataverse}.{node.dataset}"
        open_cast = node.open_cast
        shadow, key_col = node.shadow_sources, node.key_col
        # the probe inherits its Scan site's surviving-block list: rows in
        # skipped blocks provably fail the very conjuncts that bound the
        # probe, so gathering first shrinks what the range mask touches.
        sel = _block_gather(node.block_ids, node.zone_block,
                            *node.shard_layout(), pad_multiple=_stream_pad(ctx))

        def fn(tables, params):
            env, mask = _env_of(tables[key], open_cast)
            env = {k: sel(v) for k, v in env.items()}
            mask = sel(mask)
            if shadow:
                mask = mask & ~_shadowed(tables, sel(tables[key][key_col]),
                                         shadow)
            keys_col = env[node.index_col]
            lo = node.lo.evaluate(env, params) if node.lo is not None else None
            hi = node.hi.evaluate(env, params) if node.hi is not None else None
            mask = physical.index_range_mask(keys_col, mask, lo, hi)
            if node.residual is not None:
                mask = mask & node.residual.evaluate(env, params)
            return env, mask
        return fn

    if isinstance(node, PH.PrunedUnionRuns):
        kids = [_lower_stream(c, ctx) for c in node.children]
        if len(kids) == 1:
            return kids[0]

        def fn(tables, params):
            envs, masks = [], []
            for k in kids:
                e, m = k(tables, params)
                envs.append(e)
                masks.append(m)
            names = list(envs[0])
            env = {n: jnp.concatenate([e[n] for e in envs], axis=0)
                   for n in names}
            return env, jnp.concatenate(masks, axis=0)
        return fn

    if isinstance(node, PH.DictRemapCols):
        child = _lower_stream(node.children[0], ctx)
        key, lane = node.key, node.lane
        remap = np.asarray(node.remap, np.int32)

        def fn(tables, params):
            env, mask = child(tables, params)
            env = dict(env)
            lane_col = env.pop(lane)
            if remap.size == 0:
                # empty local dictionary: the component has no live string
                # rows, so every row is masked — any id is fine.
                env[key] = jnp.zeros_like(lane_col)
            else:
                # dead rows carry id -1: clamp to 0 — they map to SOME valid
                # union id, but their mask is False so they weigh nothing.
                env[key] = jnp.take(jnp.asarray(remap),
                                    jnp.maximum(lane_col, 0).astype(jnp.int32))
            return env, mask
        return fn

    if isinstance(node, PH.FullScanFilter):
        child = _lower_stream(node.children[0], ctx)

        def fn(tables, params):
            env, mask = child(tables, params)
            return env, mask & node.predicate.evaluate(env, params)
        return fn

    if isinstance(node, PH.ProjectCols):
        child = _lower_stream(node.children[0], ctx)
        outputs = node.outputs

        def fn(tables, params):
            env, mask = child(tables, params)
            return {name: e.evaluate(env, params) for name, e in outputs}, mask
        return fn

    if isinstance(node, PH.LimitRows):
        child = _lower_stream(node.children[0], ctx)

        def fn(tables, params):
            env, mask = child(tables, params)
            return ctx.strategy.limit(env, mask, node.n)
        return fn

    if isinstance(node, PH.TopKSelect):
        child = _lower_stream(node.children[0], ctx)
        # one lowering, parameterized by the selection primitive: the planner
        # swaps in the block_topk Pallas kernel, everything else is shared.
        select = physical.kernel_topk_select(ctx.kernel_backend) \
            if node.kernel else physical._select_topk

        def fn(tables, params):
            env, mask = child(tables, params)
            return ctx.strategy.topk(env, mask, node.key, node.k,
                                     node.ascending, select)
        return fn

    if isinstance(node, PH.SortRows):
        child = _lower_stream(node.children[0], ctx)

        def fn(tables, params):
            env, mask = child(tables, params)
            return physical.sort_full(env, mask, node.key, node.ascending)
        return fn

    if isinstance(node, PH.WindowEval):
        from repro.core.window import execute_window

        child = _lower_stream(node.children[0], ctx)

        def fn(tables, params):
            env, mask = child(tables, params)
            return execute_window(env, mask, node.window)
        return fn

    if isinstance(node, PH.JoinGather):
        # build-key uniqueness/disjointness was proven by the planner
        lchild = _lower_stream(node.children[0], ctx)
        rchild = _lower_stream(node.children[1], ctx)

        def fn(tables, params):
            lenv, lm = lchild(tables, params)
            renv, rm = rchild(tables, params)
            return physical.join_materialize(lenv, lm, renv, rm,
                                             node.left_on, node.right_on)
        return fn

    if isinstance(node, (PH.GroupAggGeneric, PH.KernelSegmentAgg)):
        return _lower_groupagg(node, ctx)

    raise NotImplementedError(f"stream lowering for {type(node).__name__}")


def _lower_groupagg(node, ctx: ExecContext) -> Callable:
    aggs = [(s.out_name, s.op, s.column) for s in node.aggs]
    if isinstance(node, PH.KernelSegmentAgg):
        comps = [_lower_stream(c, ctx) for c in node.children]
        inner = _lower_kernel_segment_agg(node, ctx, comps, aggs)
    else:
        child = _lower_stream(node.children[0], ctx)
        key, lo, num_groups = node.key, node.lo, node.num_groups

        def inner(tables, params):
            env, mask = child(tables, params)
            return ctx.strategy.group_agg(env, mask, key, lo, num_groups, aggs)

    key_values = getattr(node, "key_values", None)
    if key_values is None:
        return inner

    # string group-by: the machinery above grouped over union-dictionary ids
    # (DictRemapCols remapped each component below the concat). Decode the
    # surviving ids back to the encoded (G, 16) string rows at the result
    # boundary — identical in all three modes because every path returns the
    # group id itself as the key column.
    from repro.engine.table import encode_strings

    enc = np.asarray(encode_strings(list(key_values)))
    out_key = node.key

    def fn(tables, params):
        out, gmask = inner(tables, params)
        out = dict(out)
        out[out_key] = jnp.take(jnp.asarray(enc),
                                out[out_key].astype(jnp.int32), axis=0)
        return out, gmask
    return fn


def _lower_kernel_segment_agg(node: PH.KernelSegmentAgg, ctx: ExecContext,
                              comps: list, aggs: list) -> Callable:
    """One lowered stream per LSM component (a single entry for a plain
    dataset). Each component runs its own kernel launches — one fused
    one-hot-matmul for the sum family, one select-and-reduce per extreme
    family — and the (G, C) partials merge with +/max/min, exactly the merge
    a compaction-time recompute would produce. The planner proved f32
    exactness; count/sum/mean fuse into a single (BLOCK, C) value tile
    (col 0 counts, cols 1.. sum the value columns)."""
    key, lo, num_groups = node.key, node.lo, node.num_groups
    comp_blocks = node.comp_blocks or tuple(None for _ in comps)
    # resolve each component's hoisted block list ONCE at lowering time:
    # single-shard layouts keep the static zone-block tuple (the grid bakes
    # it in); multi-shard layouts expand to the per-shard (-1-padded)
    # kernel-block matrix each shard's launch scalar-prefetches.
    resolved: list[tuple] = []
    for blk in comp_blocks:
        if blk is None or blk[0] is None:
            resolved.append((None, None))
            continue
        ids, zb = blk[0], blk[1]
        nsh, bp, rps = (blk[2:5] if len(blk) >= 5 else (1, 0, 0))
        if nsh > 1:
            from repro.kernels import ops
            from repro.kernels.segment_agg import BLOCK as _SA_BLOCK
            resolved.append((None, ops.shard_block_arrays(
                ids, zb, _SA_BLOCK, nsh, bp, rps)))
        else:
            resolved.append((ids, None))
    vcols: list[str] = []   # distinct sum-family value columns, first-use order
    xcols: dict[str, list[str]] = {"max": [], "min": []}
    for _, op, col in aggs:
        if op in ("sum", "mean") and col not in vcols:
            vcols.append(col)
        elif op in ("max", "min") and col not in xcols[op]:
            xcols[op].append(col)

    def launch(gid, cols_f32, n, op, block_ids, shard_blocks):
        values = jnp.stack(cols_f32, axis=1)  # (n, C)
        return ctx.strategy.kernel_group_agg(gid, values, num_groups, n, op,
                                             block_ids=block_ids,
                                             shard_blocks=shard_blocks)

    def fn(tables, params):
        sums = maxs = mins = None
        key_dtype = val_dtypes = None
        for comp, (block_ids, shard_blocks) in zip(comps, resolved):
            env, mask = comp(tables, params)
            # block_ids/shard_blocks were hoisted off the component's
            # TableScan: the stream stays full-length and the segment_agg
            # grid itself skips pruned tiles (rows there are already masked
            # out by the filter the list came from).
            key_col = env[key]
            key_dtype = key_col.dtype
            val_dtypes = {c: env[c].dtype for _, _, c in aggs if c}
            # dead rows get gid -1: the kernel's live-check drops them, so an
            # arbitrary (non-prefix) mask needs no compaction.
            gid = jnp.where(mask, (key_col - lo).astype(jnp.int32), -1)
            n = mask.shape[0]
            tiles = [jnp.ones(mask.shape, jnp.float32)]
            tiles += [env[c].astype(jnp.float32) for c in vcols]
            part = launch(gid, tiles, n, "sum", block_ids, shard_blocks)
            sums = part if sums is None else sums + part
            if xcols["max"]:
                part = launch(gid, [env[c].astype(jnp.float32)
                                    for c in xcols["max"]], n, "max",
                              block_ids, shard_blocks)
                maxs = part if maxs is None else jnp.maximum(maxs, part)
            if xcols["min"]:
                part = launch(gid, [env[c].astype(jnp.float32)
                                    for c in xcols["min"]], n, "min",
                              block_ids, shard_blocks)
                mins = part if mins is None else jnp.minimum(mins, part)
        counts = sums[:, 0].astype(jnp.int32)
        out = {key: jnp.arange(lo, lo + num_groups, dtype=key_dtype)}
        for out_name, op, col in aggs:
            if op == "count":
                out[out_name] = counts
            elif op == "sum":
                out[out_name] = sums[:, 1 + vcols.index(col)].astype(val_dtypes[col])
            elif op == "mean":  # exact-integer f32 sum / count, as generic
                out[out_name] = sums[:, 1 + vcols.index(col)] / jnp.maximum(counts, 1)
            else:  # max/min: empty groups hold ±inf — pin before the int cast
                src = maxs if op == "max" else mins
                v = src[:, xcols[op].index(col)]
                out[out_name] = jnp.where(counts > 0, v, 0.0).astype(val_dtypes[col])
        return out, counts > 0
    return fn


# -- terminal lowering --------------------------------------------------------


def _lower_terminal(node: PH.PhysOp, ctx: ExecContext) -> tuple[str, Callable]:
    if isinstance(node, PH.MergeScalars):
        # per-LSM-component scalar programs (each with its own access path:
        # index-only count, fused range-count kernel, generic mask) merged
        # with +/max/min — the cross-component analogue of a psum. Pruned
        # runs were dropped by the planner: they never compile, gather, or
        # launch.
        subs = []
        for c in node.children:
            kind, build = _lower_terminal(c, ctx)
            assert kind == "scalar", f"MergeScalars over {kind} child"
            subs.append(build)
        merges = node.merges
        combine = {"sum": jnp.add, "max": jnp.maximum, "min": jnp.minimum}

        def fn(tables, params):
            outs = [s(tables, params) for s in subs]
            res = dict(outs[0])
            for o in outs[1:]:
                for name, op in merges:
                    res[name] = combine[op](res[name], o[name])
            return res
        return "scalar", fn

    if isinstance(node, PH.SubtractScalars):
        # anti-matter subtraction: visible = all matter − shadowed matter,
        # computed by two scalar programs over the same component.
        kind_a, minuend = _lower_terminal(node.children[0], ctx)
        kind_b, subtrahend = _lower_terminal(node.children[1], ctx)
        assert kind_a == kind_b == "scalar", (kind_a, kind_b)
        names = node.names

        def fn(tables, params):
            a = minuend(tables, params)
            b = subtrahend(tables, params)
            return {n: (a[n] - b[n]).astype(a[n].dtype)
                    if n in names and n in b else a[n] for n in a}
        return "scalar", fn

    if isinstance(node, PH.ShadowProbeCount):
        return "scalar", _lower_shadow_probe_count(node, ctx)

    if isinstance(node, PH.KernelRangeCount):
        return "scalar", _lower_kernel_range_count(node, ctx)

    if isinstance(node, PH.IndexOnlyCount):
        return "scalar", _lower_index_only_count(node, ctx)

    if isinstance(node, PH.MaskCount):
        child = _lower_stream(node.children[0], ctx)
        pred = node.predicate

        def fn(tables, params):
            env, mask = child(tables, params)
            if pred is not None:
                mask = mask & pred.evaluate(env, params)
            return {"count": ctx.strategy.count(mask)}
        return "scalar", fn

    if isinstance(node, PH.JoinCountOp):
        return "scalar", _lower_join_count(node, ctx)

    if isinstance(node, PH.ScalarAgg):
        child = _lower_stream(node.children[0], ctx)
        aggs = [(s.out_name, s.op, s.column) for s in node.aggs]

        def fn(tables, params):
            env, mask = child(tables, params)
            return {name: ctx.strategy.agg(env, mask, op, col)
                    for name, op, col in aggs}
        return "scalar", fn

    if isinstance(node, (PH.GroupAggGeneric, PH.KernelSegmentAgg)):
        return "grouped", _lower_groupagg(node, ctx)

    # table-producing terminals
    return "table", _lower_stream(node, ctx)


def _lower_kernel_range_count(node: PH.KernelRangeCount, ctx: ExecContext) -> Callable:
    """Lower onto the filter_count kernel: one (k, n) int32 tile of predicate
    columns + a (k, 2) runtime bounds operand. The column read bypasses the
    generic stream path so NO row mask is ever built outside the kernel —
    when the base table carries a ``__valid__`` padding column it folds in as
    one extra kernel row with bounds (1, 1). Newer components' anti-matter
    folds into the SAME row: the matter mask (valid ∧ not-shadowed) is the
    subtract-at-merge term, evaluated by the kernel itself. ``block_ids``
    (bind-time block zone-map survivors) drive the kernel grid: skipped
    tiles are never fetched."""
    key = f"{node.dataverse}.{node.dataset}"
    cols, los, his, has_valid = node.cols, node.los, node.his, node.has_valid
    shadow, key_col = node.shadow_sources, node.key_col
    block_ids = node.block_ids
    shard_blocks = None
    nsh, bp, rps = node.shard_layout()
    if block_ids is not None and nsh > 1:
        # multi-shard layout: expand the flat zone-block survivors into the
        # per-shard kernel-block matrix each shard scalar-prefetches.
        from repro.kernels import ops
        from repro.kernels.filter_count import BLOCK as _FC_BLOCK
        shard_blocks = ops.shard_block_arrays(block_ids, node.zone_block,
                                              _FC_BLOCK, nsh, bp, rps)
        block_ids = None

    def fn(tables, params):
        t = tables[key]
        rows = [t[c].astype(jnp.int32) for c in cols]
        lo_vals = [jnp.asarray(e.evaluate({}, params), jnp.int32) for e in los]
        hi_vals = [jnp.asarray(e.evaluate({}, params), jnp.int32) for e in his]
        if has_valid or shadow:
            n = rows[0].shape[0]
            matter = t["__valid__"] if has_valid \
                else jnp.ones((n,), jnp.bool_)
            if shadow:
                matter = matter & ~_shadowed(tables, t[key_col], shadow)
            rows.append(matter.astype(jnp.int32))
            lo_vals.append(jnp.int32(1))
            hi_vals.append(jnp.int32(1))
        mat = jnp.stack(rows)
        bounds = jnp.stack([jnp.stack(lo_vals), jnp.stack(hi_vals)], axis=1)
        cnt = ctx.strategy.kernel_filter_count(mat, bounds,
                                               block_ids=block_ids,
                                               shard_blocks=shard_blocks)
        return {"count": cnt.astype(jnp.int32)}
    return fn


def _lower_shadow_probe_count(node: PH.ShadowProbeCount, ctx: ExecContext) -> Callable:
    """The index-only subtrahend: the deduplicated union of the newer
    components' anti-key sets (a key may be tombstoned twice — a row must
    die exactly once), clipped to the predicate range, counts each
    tombstone's matter occurrences in this component's sorted primary index
    with two binary searches. The anti arrays are immutable for the life of
    the plan (the executable is stats-epoch keyed), so the sorted-unique
    union is computed ONCE here on the host and baked in as a constant —
    never re-sorted per query."""
    key = f"{node.dataverse}.{node.dataset}"
    ix_name = f"__ix_{node.index_col}__"
    anti_union = np.unique(np.concatenate(
        [np.asarray(ctx.catalog.get(dv, name).anti_keys_arr)
         for dv, name in node.shadow_sources]))

    def fn(tables, params):
        t = tables[key]
        ix_keys = t[ix_name]
        valid = t.get("__valid__", jnp.ones((ix_keys.shape[0],), jnp.bool_))
        anti = jnp.asarray(anti_union).astype(ix_keys.dtype)
        lo = node.lo.evaluate({}, params) if node.lo is not None else None
        hi = node.hi.evaluate({}, params) if node.hi is not None else None
        cnt = ctx.strategy.shadow_count(ix_keys, valid, anti, lo, hi)
        return {"count": cnt.astype(jnp.int32)}
    return fn


def _lower_index_only_count(node: PH.IndexOnlyCount, ctx: ExecContext) -> Callable:
    key = f"{node.dataverse}.{node.dataset}"

    def fn(tables, params):
        cols = tables[key]
        ix_keys = cols[f"__ix_{node.index_col}__"]
        valid = cols.get("__valid__",
                         jnp.ones((ix_keys.shape[0],), jnp.bool_))
        lo = node.lo.evaluate({}, params) if node.lo is not None else None
        hi = node.hi.evaluate({}, params) if node.hi is not None else None
        return {"count": ctx.strategy.index_count(ix_keys, valid, lo, hi)}
    return fn


def _lower_join_count(node: PH.JoinCountOp, ctx: ExecContext) -> Callable:
    lchild = _lower_stream(node.children[0], ctx)
    rchild = _lower_stream(node.children[1], ctx)
    left_on, right_on = node.left_on, node.right_on
    presorted = node.presorted
    if presorted:
        rkey_table = f"{node.presorted_key[0]}.{node.presorted_key[1]}"
        rkey_name = f"__ix_{right_on}__"

    join = ctx.strategy.kernel_join_count if node.kernel \
        else ctx.strategy.join_count

    def fn(tables, params):
        lenv, lm = lchild(tables, params)
        renv, rm = rchild(tables, params)
        rkey = tables[rkey_table][rkey_name] if presorted else renv[right_on]
        cnt = join(lenv[left_on], lm, rkey, rm, presorted)
        return {"count": cnt}
    return fn
