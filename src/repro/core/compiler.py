"""Plan compiler: optimized logical plan → one jitted SPMD program.

The AsterixDB analogue of "ship the SQL++ string, get an optimized Hyracks
job": the plan lowers to a closed JAX function over (dataset columns, literal
params) and jits once per plan *fingerprint* (literal values are runtime
params, so the benchmark's randomized predicates reuse the executable — the
prepared-statement effect the paper gets from AsterixDB's plan cache).

Three execution modes:
  * ``gspmd``     — plain jnp ops; under jit XLA GSPMD inserts collectives.
    This is the paper-faithful baseline ("let the optimizer/partitioner do
    it").
  * ``shard_map`` — the beyond-paper optimized mode: relational operators
    from engine/distributed.py with hand-placed minimal collectives.
  * ``kernel``    — fusable plan shapes lower onto the Pallas relational
    kernels (kernels/ops.py backend dispatch: compiled Pallas on TPU,
    interpret/XLA twins elsewhere). FusedRangeCount -> filter_count,
    GroupAgg -> segment_agg, JoinCount -> merge_join_count, TopK ->
    topk_merge; anything the kernels don't cover falls back to the
    gspmd/shard_map lowering of the same node.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as P
from repro.core.catalog import Catalog
from repro.core.expr import collect_params, param_values
from repro.engine import physical
from repro.engine.table import Table


@dataclasses.dataclass
class ExecContext:
    catalog: Catalog
    mesh: Any = None            # jax Mesh when distributed
    data_axes: tuple = ("data",)
    mode: str = "gspmd"         # gspmd | shard_map | kernel
    kernel_backend: Optional[str] = None  # kernels/ops dispatch: None|xla|pallas

    @property
    def distributed(self) -> bool:
        # kernel mode over a mesh composes via shard_map: each shard runs the
        # kernel locally, partials merge with the existing collectives.
        return self.mode in ("shard_map", "kernel") and self.mesh is not None

    @property
    def use_kernels(self) -> bool:
        return self.mode == "kernel"


@dataclasses.dataclass
class CompiledQuery:
    plan: P.Plan
    fingerprint: str
    kind: str                   # scalar | table | grouped
    fn: Callable                # jitted: (tables, params) -> result
    leaf_keys: list             # dataset keys feeding `tables`
    lits: list                  # literal slots (plan order)
    raw_fn: Callable = None     # unjitted build (jaxpr inspection in tests)

    def gather_tables(self, catalog: Catalog) -> dict:
        tables = {}
        for key in self.leaf_keys:
            ds = catalog.get(*key)
            tables[f"{key[0]}.{key[1]}"] = dict(ds.table.columns)
            for ixname, ix in getattr(ds, "indexes", {}).items():
                if getattr(ix, "sorted_keys", None) is not None:
                    tables[f"{key[0]}.{key[1]}"][f"__ix_{ix.column}__"] = ix.sorted_keys
                    tables[f"{key[0]}.{key[1]}"][f"__ixid_{ix.column}__"] = ix.row_ids
        return tables

    def run(self, catalog: Catalog, lits=None, params=None):
        """``params``: pre-bound literal values in slot order (the Session's
        plan cache computes them via its literal binding). ``lits``: literal
        slots from the *current* plan instance — on a plan-cache hit the
        executable is reused but the fresh literal values must be bound
        (same fingerprint ⇒ same slot order)."""
        if params is None:
            params = param_values(lits if lits is not None else self.lits)
        return self.fn(self.gather_tables(catalog), params)


def _scan_leaves(plan: P.Plan) -> list[tuple[str, str]]:
    keys = []
    for node in P.walk(plan):
        if isinstance(node, (P.Scan, P.IndexRangeScan)):
            k = (node.dataverse, node.dataset)
            if k not in keys:
                keys.append(k)
    return keys


def compile_plan(plan: P.Plan, ctx: ExecContext) -> CompiledQuery:
    leaf_keys = _scan_leaves(plan)
    lits = collect_params(P.all_exprs(plan))
    kind, build = _lower_terminal(plan, ctx)
    jitted = jax.jit(build)
    return CompiledQuery(plan, plan.fingerprint(), kind, jitted, leaf_keys, lits,
                         raw_fn=build)


# -- streaming lowering -------------------------------------------------------


def _lower_stream(node: P.Plan, ctx: ExecContext) -> Callable:
    """Returns fn(tables, params) -> (env, mask). Filters never compact
    (selection-vector execution; DESIGN.md §2)."""
    if isinstance(node, P.Scan):
        key = f"{node.dataverse}.{node.dataset}"
        ds = ctx.catalog.get(node.dataverse, node.dataset)
        open_cast = not ds.closed

        def fn(tables, params):
            cols = tables[key]
            env = {k: v for k, v in cols.items()
                   if k != "__valid__" and not k.startswith("__ix")}
            if open_cast:  # schema-on-read: pay a widen/cast per access
                env = {k: (v.astype(jnp.float32) if jnp.issubdtype(v.dtype, jnp.integer)
                           and v.ndim == 1 else v) for k, v in env.items()}
            mask = cols.get("__valid__",
                            jnp.ones((next(iter(env.values())).shape[0],), jnp.bool_))
            return env, mask
        return fn

    if isinstance(node, P.IndexRangeScan):
        key = f"{node.dataverse}.{node.dataset}"

        def fn(tables, params):
            cols = tables[key]
            env = {k: v for k, v in cols.items()
                   if k != "__valid__" and not k.startswith("__ix")}
            mask = cols.get("__valid__",
                            jnp.ones((next(iter(env.values())).shape[0],), jnp.bool_))
            keys_col = env[node.index_col]
            lo = node.lo.evaluate(env, params) if node.lo is not None else None
            hi = node.hi.evaluate(env, params) if node.hi is not None else None
            mask = physical.index_range_mask(keys_col, mask, lo, hi)
            if node.residual is not None:
                mask = mask & node.residual.evaluate(env, params)
            return env, mask
        return fn

    if isinstance(node, P.UnionRuns):
        kids = [_lower_stream(c, ctx) for c in node.children]

        def fn(tables, params):
            envs, masks = [], []
            for k in kids:
                e, m = k(tables, params)
                envs.append(e)
                masks.append(m)
            names = list(envs[0])
            env = {n: jnp.concatenate([e[n] for e in envs], axis=0)
                   for n in names}
            return env, jnp.concatenate(masks, axis=0)
        return fn

    if isinstance(node, P.Filter):
        child = _lower_stream(node.children[0], ctx)

        def fn(tables, params):
            env, mask = child(tables, params)
            return env, mask & node.predicate.evaluate(env, params)
        return fn

    if isinstance(node, P.Project):
        child = _lower_stream(node.children[0], ctx)
        outputs = node.outputs

        def fn(tables, params):
            env, mask = child(tables, params)
            return {name: e.evaluate(env, params) for name, e in outputs}, mask
        return fn

    if isinstance(node, P.Limit):
        child = _lower_stream(node.children[0], ctx)

        def fn(tables, params):
            env, mask = child(tables, params)
            if ctx.distributed:
                from repro.engine import distributed as D
                return D.dist_limit(ctx.mesh, ctx.data_axes, env, mask, node.n)
            return physical.limit(env, mask, node.n)
        return fn

    if isinstance(node, P.TopK):
        child = _lower_stream(node.children[0], ctx)
        # one lowering, parameterized by the selection primitive: kernel mode
        # swaps in the block_topk Pallas kernel, everything else is shared.
        select = physical.kernel_topk_select(ctx.kernel_backend) \
            if ctx.use_kernels else physical._select_topk

        def fn(tables, params):
            env, mask = child(tables, params)
            if ctx.distributed:
                from repro.engine import distributed as D
                return D.dist_topk(ctx.mesh, ctx.data_axes, env, mask,
                                   node.key, node.k, node.ascending,
                                   select=select)
            return physical.topk(env, mask, node.key, node.k, node.ascending,
                                 select=select)
        return fn

    if isinstance(node, P.Sort):
        child = _lower_stream(node.children[0], ctx)

        def fn(tables, params):
            env, mask = child(tables, params)
            return physical.sort_full(env, mask, node.key, node.ascending)
        return fn

    if isinstance(node, P.GroupAgg):
        return _lower_groupagg(node, ctx)

    from repro.core.window import Window, execute_window

    if isinstance(node, Window):
        child = _lower_stream(node.children[0], ctx)

        def fn(tables, params):
            env, mask = child(tables, params)
            return execute_window(env, mask, node)
        return fn

    if isinstance(node, P.Join):
        lchild = _lower_stream(node.children[0], ctx)
        rchild = _lower_stream(node.children[1], ctx)
        # materializing joins require unique build keys (static shapes:
        # each probe row gathers ≤1 match). Catch violations via stats; a
        # fed build side contributes base + runs, so every component must be
        # internally unique AND the component key ranges pairwise disjoint.
        scans = [l for l in P.walk(node.children[1]) if isinstance(l, P.Scan)]
        if scans:
            first = scans[0].dataset.split("@")[0]
            comps = [l for l in scans if l.dataverse == scans[0].dataverse
                     and l.dataset.split("@")[0] == first]
            ranges = []
            for leaf in comps:
                ds = ctx.catalog.get(leaf.dataverse, leaf.dataset)
                meta = ds.table.meta.get(node.right_on)
                if meta is None:
                    continue
                if meta.distinct is not None and meta.distinct < ds.num_live_rows:
                    raise NotImplementedError(
                        f"materializing join on non-unique key "
                        f"{node.right_on!r} (distinct={meta.distinct} < "
                        f"rows={ds.num_live_rows}); COUNT over such joins is "
                        "supported (join-count path)")
                if meta.lo is not None:
                    ranges.append((meta.lo, meta.hi))
            if len(comps) > 1:
                if len(ranges) < len(comps):
                    raise NotImplementedError(
                        f"materializing join against a fed dataset needs "
                        f"key bounds on {node.right_on!r} to prove the LSM "
                        "components disjoint")
                for i, (lo_a, hi_a) in enumerate(ranges):
                    for lo_b, hi_b in ranges[i + 1:]:
                        if lo_a <= hi_b and lo_b <= hi_a:
                            raise NotImplementedError(
                                f"materializing join key {node.right_on!r} "
                                "may repeat across LSM components "
                                f"(overlapping bounds); compact first or "
                                "use COUNT (join-count path)")

        def fn(tables, params):
            lenv, lm = lchild(tables, params)
            renv, rm = rchild(tables, params)
            return physical.join_materialize(lenv, lm, renv, rm,
                                             node.left_on, node.right_on)
        return fn

    raise NotImplementedError(f"stream lowering for {type(node).__name__}")


def _group_domain(node: P.GroupAgg, ctx: ExecContext):
    """Resolve (lo, num_groups) for the bounded-domain group-by from leaf
    dataset column statistics (the DBMS catalog stats analogue). Bounds merge
    across the LSM components (base + runs) of the FIRST dataset that carries
    them: a run whose delta extends the key domain widens the group table
    (extra all-zero groups are masked out at materialization, so widening
    never changes results). Leaves of OTHER datasets — a join's build side
    whose same-named column loses name resolution anyway — must not widen
    the domain (an unrelated huge-bounded column would explode G)."""
    key = node.keys[0]
    lo = hi = family = None
    for leaf in P.walk(node):
        if isinstance(leaf, P.Scan):
            ds = ctx.catalog.get(leaf.dataverse, leaf.dataset)
            meta = ds.table.meta.get(key)
            if meta is None or meta.lo is None or meta.hi is None:
                continue
            fam = (leaf.dataverse, leaf.dataset.split("@")[0])
            if family is None:
                family = fam
            elif fam != family:
                continue
            lo = meta.lo if lo is None else min(lo, meta.lo)
            hi = meta.hi if hi is None else max(hi, meta.hi)
    if lo is not None:
        return int(lo), int(hi - lo + 1)
    raise ValueError(
        f"group key {key!r} has no domain statistics; bounded-domain group-by "
        "requires catalog lo/hi (Wisconsin columns carry them)")


def _lower_groupagg(node: P.GroupAgg, ctx: ExecContext) -> Callable:
    assert len(node.keys) == 1, "single-key group-by (paper expressions 4/8)"
    key = node.keys[0]
    lo, num_groups = _group_domain(node, ctx)
    child_node = node.children[0]
    aggs = [(s.out_name, s.op, s.column) for s in node.aggs]

    # kernel mode: count/sum/mean all reduce to one segment-sum, so every
    # AggSpec fuses into a single (BLOCK, C) value tile — one one-hot-matmul
    # kernel launch per grid step (col 0 counts, cols 1.. sum the value
    # columns); max/min add one select-and-reduce launch each. The kernels
    # compute in f32 — fusion requires a static proof of exactness (catalog
    # bounds) or the generic native-dtype path keeps the
    # bit-identical-to-gspmd contract. Over an LSM union each component gets
    # its own kernel launches; partials merge with +/max/min (the same shape
    # a psum merge has across shards).
    if ctx.use_kernels \
            and all(op in ("count", "sum", "mean", "max", "min")
                    for _, op, _ in aggs) \
            and _kernel_groupagg_exact(node, ctx, aggs):
        if isinstance(child_node, P.UnionRuns):
            comps = [_lower_stream(c, ctx) for c in child_node.children]
        else:
            comps = [_lower_stream(child_node, ctx)]
        return _lower_groupagg_kernel(node, ctx, key, lo, num_groups, comps, aggs)

    child = _lower_stream(child_node, ctx)

    def fn(tables, params):
        env, mask = child(tables, params)
        if ctx.distributed:
            from repro.engine import distributed as D
            value_cols = {c: env[c] for _, _, c in aggs if c}
            out, gmask = D.dist_group_agg(ctx.mesh, ctx.data_axes, env[key], mask,
                                          lo, num_groups, aggs, value_cols)
            out[key] = out.pop("__key__")
            return out, gmask
        out, gmask = physical.group_agg(env, mask, key, lo, num_groups, aggs)
        return out, gmask
    return fn


_F32_EXACT = 1 << 24  # every int in [-2^24, 2^24] is exactly representable


def _kernel_groupagg_exact(node: P.GroupAgg, ctx: ExecContext, aggs: list) -> bool:
    """The segment_agg kernel computes in float32. That is bit-identical to
    the generic path only when every per-group result is an
    exactly-representable integer: counts need n < 2^24; sum/mean need an
    integer value column whose catalog bounds prove n * max|value| < 2^24;
    max/min only need the values themselves representable (|value| < 2^24 —
    no accumulation).

    The bound must come from the table the column ACTUALLY originates from:
    `_trace_col` follows Project renames, join name-resolution, and LSM
    unions down to leaves; untraceable provenance (computed expressions,
    suffixed join collisions) refuses fusion — refusal is always safe. n is
    the SUM of leaf row counts, an upper bound on any stream length (a union
    concatenates its components, joins emit the probe side's length,
    filters/limits only shrink)."""
    tables = [ctx.catalog.get(l.dataverse, l.dataset).table
              for l in P.walk(node) if isinstance(l, P.Scan)]
    if not tables:
        return False
    n = sum(len(t) for t in tables)
    if n >= _F32_EXACT:
        return False
    for _, op, col in aggs:
        if op == "count":
            continue
        m = _trace_col(node.children[0], col, ctx)
        if m is None or m.is_string or not np.issubdtype(m.dtype, np.integer):
            return False
        if m.lo is None or m.hi is None:
            return False
        maxabs = max(abs(int(m.lo)), abs(int(m.hi)))
        bound = maxabs if op in ("max", "min") else n * maxabs
        if bound >= _F32_EXACT:
            return False
    return True


def _trace_col(node: P.Plan, col: str, ctx: ExecContext):
    """Resolve the ColumnMeta a stream column name originates from, following
    Project renames and join name-resolution; None when provenance cannot be
    established (computed expressions, suffixed join collisions)."""
    from repro.core.expr import Col
    from repro.core.window import Window

    if isinstance(node, Window) and col == node.out_name:
        return None  # computed analytic column, no catalog bounds
    if isinstance(node, (P.Scan, P.IndexRangeScan)):
        t = ctx.catalog.get(node.dataverse, node.dataset).table
        return t.meta.get(col)
    if isinstance(node, P.Project):
        for name, e in node.outputs:
            if name == col:
                if isinstance(e, Col):
                    return _trace_col(node.children[0], e.name, ctx)
                return None
        return None
    if isinstance(node, P.UnionRuns):
        # every component must prove the column; the union's bound is the
        # envelope of the per-component bounds (runs may extend the domain).
        metas = [_trace_col(c, col, ctx) for c in node.children]
        if any(m is None or m.lo is None or m.hi is None for m in metas):
            return None
        from repro.engine.table import ColumnMeta
        return ColumnMeta(metas[0].dtype,
                          min(m.lo for m in metas), max(m.hi for m in metas),
                          sum(m.distinct or 0 for m in metas) or None,
                          any(m.is_string for m in metas), False)
    if isinstance(node, P.Join):
        # join_materialize: the left side wins a bare name; right-only names
        # pass through; a collision suffixes the right column (untraceable by
        # its stream name, so it resolves to None here).
        left_meta = _trace_col(node.children[0], col, ctx)
        if left_meta is not None:
            return left_meta
        return _trace_col(node.children[1], col, ctx)
    if len(node.children) == 1:  # filter/limit/sort/window pass columns through
        return _trace_col(node.children[0], col, ctx)
    return None


def _lower_groupagg_kernel(node: P.GroupAgg, ctx: ExecContext, key: str,
                           lo: int, num_groups: int, comps: list,
                           aggs: list) -> Callable:
    """``comps``: one lowered stream per LSM component (a single entry for a
    plain dataset). Each component runs its own kernel launches — one fused
    one-hot-matmul for the sum family, one select-and-reduce per extreme
    family — and the (G, C) partials merge with +/max/min, exactly the merge
    a compaction-time recompute would produce."""
    vcols: list[str] = []   # distinct sum-family value columns, first-use order
    xcols: dict[str, list[str]] = {"max": [], "min": []}
    for _, op, col in aggs:
        if op in ("sum", "mean") and col not in vcols:
            vcols.append(col)
        elif op in ("max", "min") and col not in xcols[op]:
            xcols[op].append(col)

    def launch(gid, cols_f32, n, op):
        values = jnp.stack(cols_f32, axis=1)  # (n, C)
        if ctx.distributed:
            from repro.engine import distributed as D
            return D.dist_kernel_group_agg(ctx.mesh, ctx.data_axes, gid, values,
                                           num_groups, op=op,
                                           backend=ctx.kernel_backend)
        from repro.kernels import ops
        return ops.segment_agg(values, gid, num_groups, n, op=op,
                               backend=ctx.kernel_backend)

    def fn(tables, params):
        sums = maxs = mins = None
        key_dtype = val_dtypes = None
        for comp in comps:
            env, mask = comp(tables, params)
            key_col = env[key]
            key_dtype = key_col.dtype
            val_dtypes = {c: env[c].dtype for _, _, c in aggs if c}
            # dead rows get gid -1: the kernel's live-check drops them, so an
            # arbitrary (non-prefix) mask needs no compaction.
            gid = jnp.where(mask, (key_col - lo).astype(jnp.int32), -1)
            n = mask.shape[0]
            tiles = [jnp.ones(mask.shape, jnp.float32)]
            tiles += [env[c].astype(jnp.float32) for c in vcols]
            part = launch(gid, tiles, n, "sum")
            sums = part if sums is None else sums + part
            if xcols["max"]:
                part = launch(gid, [env[c].astype(jnp.float32)
                                    for c in xcols["max"]], n, "max")
                maxs = part if maxs is None else jnp.maximum(maxs, part)
            if xcols["min"]:
                part = launch(gid, [env[c].astype(jnp.float32)
                                    for c in xcols["min"]], n, "min")
                mins = part if mins is None else jnp.minimum(mins, part)
        counts = sums[:, 0].astype(jnp.int32)
        out = {key: jnp.arange(lo, lo + num_groups, dtype=key_dtype)}
        for out_name, op, col in aggs:
            if op == "count":
                out[out_name] = counts
            elif op == "sum":
                out[out_name] = sums[:, 1 + vcols.index(col)].astype(val_dtypes[col])
            elif op == "mean":  # exact-integer f32 sum / count, as generic
                out[out_name] = sums[:, 1 + vcols.index(col)] / jnp.maximum(counts, 1)
            else:  # max/min: empty groups hold ±inf — pin before the int cast
                src = maxs if op == "max" else mins
                v = src[:, xcols[op].index(col)]
                out[out_name] = jnp.where(counts > 0, v, 0.0).astype(val_dtypes[col])
        return out, counts > 0
    return fn


# -- terminal lowering -----------------------------------------------------------


def _lower_terminal(plan: P.Plan, ctx: ExecContext) -> tuple[str, Callable]:
    if isinstance(plan, P.UnionScalar):
        # per-LSM-component scalar programs (each with its own access path:
        # index-only count, fused range-count kernel, generic mask) merged
        # with +/max/min — the cross-component analogue of a psum.
        subs = []
        for c in plan.children:
            kind, build = _lower_terminal(c, ctx)
            assert kind == "scalar", f"UnionScalar over {kind} child"
            subs.append(build)
        merges = plan.merges
        combine = {"sum": jnp.add, "max": jnp.maximum, "min": jnp.minimum}

        def fn(tables, params):
            outs = [s(tables, params) for s in subs]
            res = dict(outs[0])
            for o in outs[1:]:
                for name, op in merges:
                    res[name] = combine[op](res[name], o[name])
            return res
        return "scalar", fn

    if isinstance(plan, P.FusedRangeCount):
        return "scalar", _lower_fused_range_count(plan, ctx)

    if isinstance(plan, P.FilterCount):
        return "scalar", _lower_filter_count(plan, ctx)

    if isinstance(plan, P.JoinCount):
        return "scalar", _lower_join_count(plan, ctx)

    if isinstance(plan, P.Agg):
        # COUNT over a Join must use the duplicate-correct join-count path
        # even when the optimizer was disabled (semantics ≠ optimization).
        if len(plan.aggs) == 1 and plan.aggs[0].op == "count" \
                and isinstance(plan.children[0], P.Join):
            j = plan.children[0]
            return "scalar", _lower_join_count(
                P.JoinCount(j.children[0], j.children[1], j.left_on, j.right_on),
                ctx)
        child = _lower_stream(plan.children[0], ctx)
        aggs = [(s.out_name, s.op, s.column) for s in plan.aggs]

        def fn(tables, params):
            env, mask = child(tables, params)
            out = {}
            for name, op, col in aggs:
                if ctx.distributed and op != "count":
                    from repro.engine import distributed as D
                    out[name] = D.dist_agg(ctx.mesh, ctx.data_axes, op, env[col], mask)
                elif ctx.distributed:
                    from repro.engine import distributed as D
                    out[name] = D.dist_count(ctx.mesh, ctx.data_axes, mask)
                else:
                    out[name] = physical.agg_scalar(env, mask, op, col)
            return out
        return "scalar", fn

    if isinstance(plan, P.GroupAgg):
        return "grouped", _lower_groupagg(plan, ctx)

    # table-producing terminals
    stream = _lower_stream(plan, ctx)
    return "table", stream


def _lower_fused_range_count(plan: P.FusedRangeCount, ctx: ExecContext) -> Callable:
    """Lower onto the filter_count kernel: one (k, n) int32 tile of predicate
    columns + a (k, 2) runtime bounds operand. The column read bypasses the
    generic stream path so NO row mask is ever built outside the kernel —
    when the base table carries a ``__valid__`` padding column it folds in as
    one extra kernel row with bounds (1, 1)."""
    leaf = plan.children[0]
    if isinstance(leaf, P.Project):  # projection pushdown wraps the Scan
        leaf = leaf.children[0]
    assert isinstance(leaf, P.Scan), "FusedRangeCount lowers over a Scan leaf"
    key = f"{leaf.dataverse}.{leaf.dataset}"
    ds = ctx.catalog.get(leaf.dataverse, leaf.dataset)
    has_valid = "__valid__" in ds.table.columns
    cols, los, his = plan.cols, plan.los, plan.his

    def fn(tables, params):
        t = tables[key]
        rows = [t[c].astype(jnp.int32) for c in cols]
        lo_vals = [jnp.asarray(e.evaluate({}, params), jnp.int32) for e in los]
        hi_vals = [jnp.asarray(e.evaluate({}, params), jnp.int32) for e in his]
        if has_valid:
            rows.append(t["__valid__"].astype(jnp.int32))
            lo_vals.append(jnp.int32(1))
            hi_vals.append(jnp.int32(1))
        mat = jnp.stack(rows)
        bounds = jnp.stack([jnp.stack(lo_vals), jnp.stack(hi_vals)], axis=1)
        if ctx.distributed:
            from repro.engine import distributed as D
            cnt = D.dist_kernel_filter_count(ctx.mesh, ctx.data_axes, mat, bounds,
                                             backend=ctx.kernel_backend)
        else:
            from repro.kernels import ops
            cnt = ops.filter_count(mat, bounds, mat.shape[1],
                                   backend=ctx.kernel_backend)
        return {"count": cnt.astype(jnp.int32)}
    return fn


def _lower_filter_count(plan: P.FilterCount, ctx: ExecContext) -> Callable:
    child_node = plan.children[0]

    # index-only count: FilterCount(IndexRangeScan, residual-free)
    if isinstance(child_node, P.IndexRangeScan) and child_node.residual is None \
            and plan.predicate is None:
        node = child_node
        key = f"{node.dataverse}.{node.dataset}"

        def fn(tables, params):
            cols = tables[key]
            ix_keys = cols[f"__ix_{node.index_col}__"]
            valid = cols.get("__valid__",
                             jnp.ones((ix_keys.shape[0],), jnp.bool_))
            lo = node.lo.evaluate({}, params) if node.lo is not None else None
            hi = node.hi.evaluate({}, params) if node.hi is not None else None
            if ctx.distributed:
                from repro.engine import distributed as D
                return {"count": D.dist_index_count(ctx.mesh, ctx.data_axes,
                                                    ix_keys, valid, lo, hi)}
            from repro.engine.index import index_count_local
            nv = jnp.sum(valid, dtype=jnp.int32)
            return {"count": index_count_local(ix_keys, nv, lo, hi)}
        return fn

    child = _lower_stream(child_node, ctx)
    pred = plan.predicate

    def fn(tables, params):
        env, mask = child(tables, params)
        if pred is not None:
            mask = mask & pred.evaluate(env, params)
        if ctx.distributed:
            from repro.engine import distributed as D
            return {"count": D.dist_count(ctx.mesh, ctx.data_axes, mask)}
        return {"count": jnp.sum(mask, dtype=jnp.int32)}
    return fn


def _join_key_int32_safe(side: P.Plan, col: str, ctx: ExecContext) -> bool:
    """True when catalog bounds prove the join key column casts to int32
    losslessly (the merge_join kernel's tile dtype). Every leaf that carries
    the column must pass — an LSM run can extend the base's domain."""
    i32 = np.iinfo(np.int32)
    metas = []
    for leaf in P.walk(side):
        if isinstance(leaf, P.Scan):
            m = ctx.catalog.get(leaf.dataverse, leaf.dataset).table.meta.get(col)
            if m is not None:
                metas.append(m)
    if not metas:
        return False
    for m in metas:
        if m.is_string or not np.issubdtype(m.dtype, np.integer):
            return False
        if m.lo is None or m.hi is None or m.lo < i32.min or m.hi > i32.max:
            return False
    return True


def _lower_join_count(plan: P.JoinCount, ctx: ExecContext) -> Callable:
    lchild = _lower_stream(plan.children[0], ctx)
    rchild = _lower_stream(plan.children[1], ctx)
    left_on, right_on = plan.left_on, plan.right_on

    # presorted build side when the right leaf has an index on the join key
    presorted = False
    rleaf = plan.children[1]
    if isinstance(rleaf, P.Scan):
        ds = ctx.catalog.get(rleaf.dataverse, rleaf.dataset)
        presorted = ds.index_on(right_on) is not None
    rkey_name = f"__ix_{right_on}__" if presorted else right_on

    # the merge_join kernel works on int32 tiles: both key columns need
    # catalog bounds proving a lossless cast, else the generic native-dtype
    # path keeps the counts exact (wider-int values would wrap silently).
    if ctx.use_kernels and _join_key_int32_safe(plan.children[0], left_on, ctx) \
            and _join_key_int32_safe(plan.children[1], right_on, ctx):
        def fn(tables, params):
            lenv, lm = lchild(tables, params)
            renv, rm = rchild(tables, params)
            if presorted:
                rkey = tables[f"{rleaf.dataverse}.{rleaf.dataset}"][rkey_name]
            else:
                rkey = renv[right_on]
            if ctx.distributed:
                from repro.engine import distributed as D
                cnt = D.dist_kernel_join_count(ctx.mesh, ctx.data_axes,
                                               lenv[left_on], lm, rkey, rm,
                                               presorted_right=presorted,
                                               backend=ctx.kernel_backend)
                return {"count": cnt}
            from repro.kernels import ops
            ls = ops.sort_join_keys(lenv[left_on], lm)
            rs = ops.sort_join_keys(rkey, rm, presorted=presorted)
            nl = jnp.sum(lm, dtype=jnp.int32)
            nr = jnp.sum(rm, dtype=jnp.int32)
            cnt = ops.merge_join_count(ls, rs, nl, nr, backend=ctx.kernel_backend)
            return {"count": cnt.astype(jnp.int32)}
        return fn

    def fn(tables, params):
        lenv, lm = lchild(tables, params)
        renv, rm = rchild(tables, params)
        if presorted:
            rleaf_key = f"{rleaf.dataverse}.{rleaf.dataset}"
            rkey = tables[rleaf_key][rkey_name]
        else:
            rkey = renv[right_on]
        if ctx.distributed:
            from repro.engine import distributed as D
            return {"count": D.dist_join_count(ctx.mesh, ctx.data_axes,
                                               lenv[left_on], lm, rkey, rm,
                                               presorted_right=presorted)}
        if presorted:
            # index order: valid keys ascending, padding at +inf tail
            n_r = jnp.sum(rm, dtype=jnp.int32)
            lo = jnp.searchsorted(rkey, lenv[left_on], side="left")
            hi = jnp.searchsorted(rkey, lenv[left_on], side="right")
            hi = jnp.minimum(hi, n_r)
            cnt = jnp.where(lm, jnp.maximum(hi - lo, 0), 0)
            return {"count": jnp.sum(cnt, dtype=jnp.int32)}
        return {"count": physical.join_count(lenv[left_on], lm, rkey, rm)}
    return fn
