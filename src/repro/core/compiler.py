"""Plan compiler: optimized logical plan → one jitted SPMD program.

The AsterixDB analogue of "ship the SQL++ string, get an optimized Hyracks
job": the plan lowers to a closed JAX function over (dataset columns, literal
params) and jits once per plan *fingerprint* (literal values are runtime
params, so the benchmark's randomized predicates reuse the executable — the
prepared-statement effect the paper gets from AsterixDB's plan cache).

Two execution modes:
  * ``gspmd``     — plain jnp ops; under jit XLA GSPMD inserts collectives.
    This is the paper-faithful baseline ("let the optimizer/partitioner do
    it").
  * ``shard_map`` — the beyond-paper optimized mode: relational operators
    from engine/distributed.py with hand-placed minimal collectives.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as P
from repro.core.catalog import Catalog
from repro.core.expr import collect_params, param_values
from repro.engine import physical
from repro.engine.table import Table


@dataclasses.dataclass
class ExecContext:
    catalog: Catalog
    mesh: Any = None            # jax Mesh when distributed
    data_axes: tuple = ("data",)
    mode: str = "gspmd"         # gspmd | shard_map

    @property
    def distributed(self) -> bool:
        return self.mode == "shard_map" and self.mesh is not None


@dataclasses.dataclass
class CompiledQuery:
    plan: P.Plan
    fingerprint: str
    kind: str                   # scalar | table | grouped
    fn: Callable                # jitted: (tables, params) -> result
    leaf_keys: list             # dataset keys feeding `tables`
    lits: list                  # literal slots (plan order)

    def run(self, catalog: Catalog, lits=None):
        """``lits``: literal slots from the *current* plan instance — on a
        plan-cache hit the executable is reused but the fresh literal values
        must be bound (same fingerprint ⇒ same slot order)."""
        tables = {}
        for key in self.leaf_keys:
            ds = catalog.get(*key)
            tables[f"{key[0]}.{key[1]}"] = dict(ds.table.columns)
            for ixname, ix in getattr(ds, "indexes", {}).items():
                if getattr(ix, "sorted_keys", None) is not None:
                    tables[f"{key[0]}.{key[1]}"][f"__ix_{ix.column}__"] = ix.sorted_keys
                    tables[f"{key[0]}.{key[1]}"][f"__ixid_{ix.column}__"] = ix.row_ids
        params = param_values(lits if lits is not None else self.lits)
        return self.fn(tables, params)


def _scan_leaves(plan: P.Plan) -> list[tuple[str, str]]:
    keys = []
    for node in P.walk(plan):
        if isinstance(node, (P.Scan, P.IndexRangeScan)):
            k = (node.dataverse, node.dataset)
            if k not in keys:
                keys.append(k)
    return keys


def compile_plan(plan: P.Plan, ctx: ExecContext) -> CompiledQuery:
    leaf_keys = _scan_leaves(plan)
    lits = collect_params(P.all_exprs(plan))
    kind, build = _lower_terminal(plan, ctx)
    jitted = jax.jit(build)
    return CompiledQuery(plan, plan.fingerprint(), kind, jitted, leaf_keys, lits)


# -- streaming lowering -------------------------------------------------------


def _lower_stream(node: P.Plan, ctx: ExecContext) -> Callable:
    """Returns fn(tables, params) -> (env, mask). Filters never compact
    (selection-vector execution; DESIGN.md §2)."""
    if isinstance(node, P.Scan):
        key = f"{node.dataverse}.{node.dataset}"
        ds = ctx.catalog.get(node.dataverse, node.dataset)
        open_cast = not ds.closed

        def fn(tables, params):
            cols = tables[key]
            env = {k: v for k, v in cols.items()
                   if k != "__valid__" and not k.startswith("__ix")}
            if open_cast:  # schema-on-read: pay a widen/cast per access
                env = {k: (v.astype(jnp.float32) if jnp.issubdtype(v.dtype, jnp.integer)
                           and v.ndim == 1 else v) for k, v in env.items()}
            mask = cols.get("__valid__",
                            jnp.ones((next(iter(env.values())).shape[0],), jnp.bool_))
            return env, mask
        return fn

    if isinstance(node, P.IndexRangeScan):
        key = f"{node.dataverse}.{node.dataset}"

        def fn(tables, params):
            cols = tables[key]
            env = {k: v for k, v in cols.items()
                   if k != "__valid__" and not k.startswith("__ix")}
            mask = cols.get("__valid__",
                            jnp.ones((next(iter(env.values())).shape[0],), jnp.bool_))
            keys_col = env[node.index_col]
            lo = node.lo.evaluate(env, params) if node.lo is not None else None
            hi = node.hi.evaluate(env, params) if node.hi is not None else None
            mask = physical.index_range_mask(keys_col, mask, lo, hi)
            if node.residual is not None:
                mask = mask & node.residual.evaluate(env, params)
            return env, mask
        return fn

    if isinstance(node, P.Filter):
        child = _lower_stream(node.children[0], ctx)

        def fn(tables, params):
            env, mask = child(tables, params)
            return env, mask & node.predicate.evaluate(env, params)
        return fn

    if isinstance(node, P.Project):
        child = _lower_stream(node.children[0], ctx)
        outputs = node.outputs

        def fn(tables, params):
            env, mask = child(tables, params)
            return {name: e.evaluate(env, params) for name, e in outputs}, mask
        return fn

    if isinstance(node, P.Limit):
        child = _lower_stream(node.children[0], ctx)

        def fn(tables, params):
            env, mask = child(tables, params)
            if ctx.distributed:
                from repro.engine import distributed as D
                return D.dist_limit(ctx.mesh, ctx.data_axes, env, mask, node.n)
            return physical.limit(env, mask, node.n)
        return fn

    if isinstance(node, P.TopK):
        child = _lower_stream(node.children[0], ctx)

        def fn(tables, params):
            env, mask = child(tables, params)
            if ctx.distributed:
                from repro.engine import distributed as D
                return D.dist_topk(ctx.mesh, ctx.data_axes, env, mask,
                                   node.key, node.k, node.ascending)
            return physical.topk(env, mask, node.key, node.k, node.ascending)
        return fn

    if isinstance(node, P.Sort):
        child = _lower_stream(node.children[0], ctx)

        def fn(tables, params):
            env, mask = child(tables, params)
            return physical.sort_full(env, mask, node.key, node.ascending)
        return fn

    if isinstance(node, P.GroupAgg):
        return _lower_groupagg(node, ctx)

    from repro.core.window import Window, execute_window

    if isinstance(node, Window):
        child = _lower_stream(node.children[0], ctx)

        def fn(tables, params):
            env, mask = child(tables, params)
            return execute_window(env, mask, node)
        return fn

    if isinstance(node, P.Join):
        lchild = _lower_stream(node.children[0], ctx)
        rchild = _lower_stream(node.children[1], ctx)
        # materializing joins require unique build keys (static shapes:
        # each probe row gathers ≤1 match). Catch violations via stats.
        for leaf in P.walk(node.children[1]):
            if isinstance(leaf, P.Scan):
                ds = ctx.catalog.get(leaf.dataverse, leaf.dataset)
                meta = ds.table.meta.get(node.right_on)
                if meta is not None and meta.distinct is not None \
                        and meta.distinct < len(ds.table):
                    raise NotImplementedError(
                        f"materializing join on non-unique key "
                        f"{node.right_on!r} (distinct={meta.distinct} < "
                        f"rows={len(ds.table)}); COUNT over such joins is "
                        "supported (join-count path)")
                break

        def fn(tables, params):
            lenv, lm = lchild(tables, params)
            renv, rm = rchild(tables, params)
            return physical.join_materialize(lenv, lm, renv, rm,
                                             node.left_on, node.right_on)
        return fn

    raise NotImplementedError(f"stream lowering for {type(node).__name__}")


def _group_domain(node: P.GroupAgg, ctx: ExecContext):
    """Resolve (lo, num_groups) for the bounded-domain group-by from leaf
    dataset column statistics (the DBMS catalog stats analogue)."""
    key = node.keys[0]
    for leaf in P.walk(node):
        if isinstance(leaf, P.Scan):
            ds = ctx.catalog.get(leaf.dataverse, leaf.dataset)
            meta = ds.table.meta.get(key)
            if meta is not None and meta.lo is not None and meta.hi is not None:
                return int(meta.lo), int(meta.hi - meta.lo + 1)
    raise ValueError(
        f"group key {key!r} has no domain statistics; bounded-domain group-by "
        "requires catalog lo/hi (Wisconsin columns carry them)")


def _lower_groupagg(node: P.GroupAgg, ctx: ExecContext) -> Callable:
    assert len(node.keys) == 1, "single-key group-by (paper expressions 4/8)"
    key = node.keys[0]
    lo, num_groups = _group_domain(node, ctx)
    child = _lower_stream(node.children[0], ctx)
    aggs = [(s.out_name, s.op, s.column) for s in node.aggs]

    def fn(tables, params):
        env, mask = child(tables, params)
        if ctx.distributed:
            from repro.engine import distributed as D
            value_cols = {c: env[c] for _, _, c in aggs if c}
            out, gmask = D.dist_group_agg(ctx.mesh, ctx.data_axes, env[key], mask,
                                          lo, num_groups, aggs, value_cols)
            out[key] = out.pop("__key__")
            return out, gmask
        out, gmask = physical.group_agg(env, mask, key, lo, num_groups, aggs)
        return out, gmask
    return fn


# -- terminal lowering -----------------------------------------------------------


def _lower_terminal(plan: P.Plan, ctx: ExecContext) -> tuple[str, Callable]:
    if isinstance(plan, P.FilterCount):
        return "scalar", _lower_filter_count(plan, ctx)

    if isinstance(plan, P.JoinCount):
        return "scalar", _lower_join_count(plan, ctx)

    if isinstance(plan, P.Agg):
        # COUNT over a Join must use the duplicate-correct join-count path
        # even when the optimizer was disabled (semantics ≠ optimization).
        if len(plan.aggs) == 1 and plan.aggs[0].op == "count" \
                and isinstance(plan.children[0], P.Join):
            j = plan.children[0]
            return "scalar", _lower_join_count(
                P.JoinCount(j.children[0], j.children[1], j.left_on, j.right_on),
                ctx)
        child = _lower_stream(plan.children[0], ctx)
        aggs = [(s.out_name, s.op, s.column) for s in plan.aggs]

        def fn(tables, params):
            env, mask = child(tables, params)
            out = {}
            for name, op, col in aggs:
                if ctx.distributed and op != "count":
                    from repro.engine import distributed as D
                    out[name] = D.dist_agg(ctx.mesh, ctx.data_axes, op, env[col], mask)
                elif ctx.distributed:
                    from repro.engine import distributed as D
                    out[name] = D.dist_count(ctx.mesh, ctx.data_axes, mask)
                else:
                    out[name] = physical.agg_scalar(env, mask, op, col)
            return out
        return "scalar", fn

    if isinstance(plan, P.GroupAgg):
        return "grouped", _lower_groupagg(plan, ctx)

    # table-producing terminals
    stream = _lower_stream(plan, ctx)
    return "table", stream


def _lower_filter_count(plan: P.FilterCount, ctx: ExecContext) -> Callable:
    child_node = plan.children[0]

    # index-only count: FilterCount(IndexRangeScan, residual-free)
    if isinstance(child_node, P.IndexRangeScan) and child_node.residual is None \
            and plan.predicate is None:
        node = child_node
        key = f"{node.dataverse}.{node.dataset}"

        def fn(tables, params):
            cols = tables[key]
            ix_keys = cols[f"__ix_{node.index_col}__"]
            valid = cols.get("__valid__",
                             jnp.ones((ix_keys.shape[0],), jnp.bool_))
            lo = node.lo.evaluate({}, params) if node.lo is not None else None
            hi = node.hi.evaluate({}, params) if node.hi is not None else None
            if ctx.distributed:
                from repro.engine import distributed as D
                return {"count": D.dist_index_count(ctx.mesh, ctx.data_axes,
                                                    ix_keys, valid, lo, hi)}
            from repro.engine.index import index_count_local
            nv = jnp.sum(valid, dtype=jnp.int32)
            return {"count": index_count_local(ix_keys, nv, lo, hi)}
        return fn

    child = _lower_stream(child_node, ctx)
    pred = plan.predicate

    def fn(tables, params):
        env, mask = child(tables, params)
        if pred is not None:
            mask = mask & pred.evaluate(env, params)
        if ctx.distributed:
            from repro.engine import distributed as D
            return {"count": D.dist_count(ctx.mesh, ctx.data_axes, mask)}
        return {"count": jnp.sum(mask, dtype=jnp.int32)}
    return fn


def _lower_join_count(plan: P.JoinCount, ctx: ExecContext) -> Callable:
    lchild = _lower_stream(plan.children[0], ctx)
    rchild = _lower_stream(plan.children[1], ctx)
    left_on, right_on = plan.left_on, plan.right_on

    # presorted build side when the right leaf has an index on the join key
    presorted = False
    rleaf = plan.children[1]
    if isinstance(rleaf, P.Scan):
        ds = ctx.catalog.get(rleaf.dataverse, rleaf.dataset)
        presorted = ds.index_on(right_on) is not None
    rkey_name = f"__ix_{right_on}__" if presorted else right_on

    def fn(tables, params):
        lenv, lm = lchild(tables, params)
        renv, rm = rchild(tables, params)
        if presorted:
            rleaf_key = f"{rleaf.dataverse}.{rleaf.dataset}"
            rkey = tables[rleaf_key][rkey_name]
        else:
            rkey = renv[right_on]
        if ctx.distributed:
            from repro.engine import distributed as D
            return {"count": D.dist_join_count(ctx.mesh, ctx.data_axes,
                                               lenv[left_on], lm, rkey, rm,
                                               presorted_right=presorted)}
        if presorted:
            # index order: valid keys ascending, padding at +inf tail
            n_r = jnp.sum(rm, dtype=jnp.int32)
            lo = jnp.searchsorted(rkey, lenv[left_on], side="left")
            hi = jnp.searchsorted(rkey, lenv[left_on], side="right")
            hi = jnp.minimum(hi, n_r)
            cnt = jnp.where(lm, jnp.maximum(hi - lo, 0), 0)
            return {"count": jnp.sum(cnt, dtype=jnp.int32)}
        return {"count": physical.join_count(lenv[left_on], lm, rkey, rm)}
    return fn
