"""Group-by aggregation as one-hot matmul — the MXU segment reduction
(paper expressions 4/8; also the MoE combine primitive).

Per grid step: a (BLOCK,) tile of group ids becomes a (G, BLOCK) one-hot
matrix multiplied against the (BLOCK, C) value tile on the MXU, accumulating
(G, C) partial sums in the output block (revisited every step — Pallas keeps
it resident in VMEM). Bounded-domain keys (Wisconsin mod-columns, MoE expert
ids) make G small, so the one-hot GEMM beats scatter-adds on TPU, which has
no efficient random-access memory path.

``op`` selects the reduction: "sum" (the MXU matmul above) or "max"/"min"
(VPU select-and-reduce over the same one-hot tile — not sum-shaped, so no
matmul, but the same blocked revisit pattern keeps the (G, C) accumulator in
VMEM). max/min feed group extremes for the kernel execution mode and the
incrementally-maintained views of the streaming ingestion subsystem.

``block_ids`` drives the grid through only the listed blocks (zone-map
block skipping): the id list rides in as a scalar-prefetch operand feeding
the index_map, and the kernel reads the same ref to rebuild the ``n_valid``
base — skipped blocks hold no live rows for this launch's mask, so partials
are bit-identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 2048

_INIT = {"sum": 0.0, "max": -jnp.inf, "min": jnp.inf}


def _body(op, nvalid_ref, gid_ref, val_ref, out_ref, base):
    gids = gid_ref[0, :]  # (BLOCK,)
    vals = val_ref[...]   # (BLOCK, C)
    b = gids.shape[0]
    G = out_ref.shape[0]
    live = (base + jax.lax.broadcasted_iota(jnp.int32, (b,), 0)) < nvalid_ref[0, 0]
    live = live & (gids >= 0) & (gids < G)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (G, b), 0) == gids[None, :])
    if op == "sum":
        oh = onehot.astype(jnp.float32) * live[None, :].astype(jnp.float32)
        out_ref[...] += jax.lax.dot(oh, vals.astype(jnp.float32),
                                    preferred_element_type=jnp.float32)
    else:
        sel = (onehot & live[None, :])[:, :, None]  # (G, b, 1)
        cand = jnp.where(sel, vals[None, :, :].astype(jnp.float32), _INIT[op])
        if op == "max":
            out_ref[...] = jnp.maximum(out_ref[...], jnp.max(cand, axis=1))
        else:
            out_ref[...] = jnp.minimum(out_ref[...], jnp.min(cand, axis=1))


def _kernel(op, nvalid_ref, gid_ref, val_ref, out_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, _INIT[op])

    _body(op, nvalid_ref, gid_ref, val_ref, out_ref,
          step * gid_ref.shape[1])


def _kernel_ids(op, ids_ref, nvalid_ref, gid_ref, val_ref, out_ref):
    """Block-skipping variant: grid over surviving blocks only; the scalar-
    prefetched id list rebuilds the validity base per step."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, _INIT[op])

    _body(op, nvalid_ref, gid_ref, val_ref, out_ref,
          ids_ref[step] * gid_ref.shape[1])


def _kernel_ids_arr(op, ids_ref, nvalid_ref, gid_ref, val_ref, out_ref):
    """Runtime-id variant (per-shard grids under shard_map): the id list is
    a TRACED scalar-prefetch operand padded with ``-1`` sentinels — one
    compiled grid of the max surviving count serves every shard. Pad steps
    clamp to tile 0 in the index_map and are gated off here, so the partial
    aggregates stay bit-identical."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, _INIT[op])

    @pl.when(ids_ref[step] >= 0)
    def _run():
        _body(op, nvalid_ref, gid_ref, val_ref, out_ref,
              ids_ref[step] * gid_ref.shape[1])


@functools.partial(jax.jit,
                   static_argnames=("num_groups", "op", "block", "interpret",
                                    "block_ids"))
def segment_agg(values: jax.Array, gids: jax.Array, num_groups: int, n_valid,
                *, op: str = "sum", block: int = BLOCK,
                interpret: bool | None = None,
                block_ids: tuple | None = None,
                block_ids_arr: jax.Array | None = None) -> jax.Array:
    """values: (n, c) f32; gids: (n,) int32 -> (num_groups, c) per-group
    ``op``-reductions. Groups with no live member hold the identity
    (0 / -inf / +inf) — callers mask by count.

    ``interpret=None`` auto-detects: compiled Pallas on TPU, interpret mode
    elsewhere. ``block_ids`` (static tuple, units of ``block`` rows) makes
    the grid visit only the listed blocks — sound whenever every live row
    with gid ≥ 0 lives in a listed block. ``block_ids_arr`` is the TRACED
    (m,) int32 per-shard alternative, ``-1``-padded at the end (mutually
    exclusive with ``block_ids``)."""
    assert op in _INIT, op
    from repro.kernels.filter_count import _resolve_interpret
    interpret = _resolve_interpret(interpret)
    n, c = values.shape
    pad = (-n) % block
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        gids = jnp.pad(gids, (0, pad))
    nb = values.shape[0] // block
    args = [jnp.asarray(n_valid, jnp.int32).reshape(1, 1),
            gids.astype(jnp.int32).reshape(1, -1), values]
    if block_ids_arr is not None:
        assert block_ids is None, "block_ids and block_ids_arr are exclusive"
        ids = block_ids_arr.astype(jnp.int32)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(int(ids.shape[0]),),
            in_specs=[
                pl.BlockSpec((1, 1), lambda i, ids: (0, 0)),
                pl.BlockSpec((1, block),
                             lambda i, ids: (0, jnp.maximum(ids[i], 0))),
                pl.BlockSpec((block, c),
                             lambda i, ids: (jnp.maximum(ids[i], 0), 0)),
            ],
            out_specs=pl.BlockSpec((num_groups, c), lambda i, ids: (0, 0)),
        )
        return pl.pallas_call(
            functools.partial(_kernel_ids_arr, op),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((num_groups, c), jnp.float32),
            interpret=interpret,
        )(ids, *args)
    if block_ids is None:
        return pl.pallas_call(
            functools.partial(_kernel, op),
            grid=(nb,),
            in_specs=[
                pl.BlockSpec((1, 1), lambda i: (0, 0)),
                pl.BlockSpec((1, block), lambda i: (0, i)),
                pl.BlockSpec((block, c), lambda i: (i, 0)),
            ],
            out_specs=pl.BlockSpec((num_groups, c), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((num_groups, c), jnp.float32),
            interpret=interpret,
        )(*args)
    assert all(0 <= b < nb for b in block_ids), (block_ids, nb)
    # grid = surviving blocks; the scalar-prefetched id list feeds the
    # index_map, so pruned tiles are never fetched at all.
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(len(block_ids),),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, ids: (0, 0)),
            pl.BlockSpec((1, block), lambda i, ids: (0, ids[i])),
            pl.BlockSpec((block, c), lambda i, ids: (ids[i], 0)),
        ],
        out_specs=pl.BlockSpec((num_groups, c), lambda i, ids: (0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel_ids, op),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_groups, c), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(block_ids, jnp.int32), *args)
