"""Block sorted-merge join count (paper expression 12).

TPU-native replacement for hybrid-hash join: both key columns arrive sorted
(from a sorted index, or one engine sort). The grid walks (left-block ×
right-block) pairs; sortedness means only O(diagonal) pairs can overlap, so
each pair first checks its zone (block min/max) and skips the O(BL·BR)
equality popcount unless ranges intersect — block-granular merge join, brute
equality inside a block (a (BL, BR) VPU compare, duplicate-correct).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024


def _kernel(nl_ref, nr_ref, l_ref, r_ref, out_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        out_ref[0, 0] = jnp.int32(0)

    l = l_ref[0, :]  # (BL,) sorted ascending (global sort ⇒ block-sorted)
    r = r_ref[0, :]  # (BR,)
    bl, br = l.shape[0], r.shape[0]
    lm = (i * bl + jax.lax.broadcasted_iota(jnp.int32, (bl,), 0)) < nl_ref[0, 0]
    rm = (j * br + jax.lax.broadcasted_iota(jnp.int32, (br,), 0)) < nr_ref[0, 0]
    # zone check: block ranges must intersect (sorted ⇒ min/max at the ends)
    l_lo, l_hi = l[0], l[bl - 1]
    r_lo, r_hi = r[0], r[br - 1]
    overlap = (l_lo <= r_hi) & (r_lo <= l_hi)

    @pl.when(overlap)
    def _count():
        eq = (l[:, None] == r[None, :]) & lm[:, None] & rm[None, :]
        out_ref[0, 0] += jnp.sum(eq.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def merge_join_count(lkeys: jax.Array, rkeys: jax.Array, nl, nr,
                     *, block: int = BLOCK, interpret: bool = True) -> jax.Array:
    """lkeys/rkeys: sorted int32 (valid prefix of length nl/nr; +inf-style
    sentinel padding after). -> int32 join cardinality."""
    def padto(a):
        pad = (-a.shape[0]) % block
        if pad:
            a = jnp.pad(a, (0, pad), constant_values=jnp.iinfo(jnp.int32).max)
        return a

    l = padto(lkeys.astype(jnp.int32))
    r = padto(rkeys.astype(jnp.int32))
    out = pl.pallas_call(
        _kernel,
        grid=(l.shape[0] // block, r.shape[0] // block),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, block), lambda i, j: (0, i)),
            pl.BlockSpec((1, block), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.int32),
        interpret=interpret,
    )(jnp.asarray(nl, jnp.int32).reshape(1, 1),
      jnp.asarray(nr, jnp.int32).reshape(1, 1),
      l.reshape(1, -1), r.reshape(1, -1))
    return out[0, 0]
