"""Masked per-block top-k (paper expression 9: ORDER BY ... LIMIT k).

Distributed top-k never sorts the dataset: each block yields its k local
maxima (k rounds of max + mask-out on the VPU — k is tiny, LIMIT 5 in the
benchmark), the (n/BLOCK, k) candidates merge with one small host-side
top_k. The kernel emits (values, global row indices) per block; dead rows
enter as -inf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 4096
NEG = float("-inf")


def _kernel(nvalid_ref, scores_ref, mask_ref, vals_ref, idx_ref):
    step = pl.program_id(0)
    s = scores_ref[0, :].astype(jnp.float32)
    m = mask_ref[0, :]
    b = s.shape[0]
    base = step * b
    live = ((base + jax.lax.broadcasted_iota(jnp.int32, (b,), 0)) < nvalid_ref[0, 0])
    s = jnp.where(m & live, s, NEG)
    k = vals_ref.shape[1]
    for kk in range(k):  # k is static & small
        v = jnp.max(s)
        a = jnp.argmax(s).astype(jnp.int32)
        vals_ref[0, kk] = v
        idx_ref[0, kk] = base + a
        s = jnp.where(jax.lax.broadcasted_iota(jnp.int32, (b,), 0) == a, NEG, s)


@functools.partial(jax.jit, static_argnames=("k", "block", "interpret"))
def block_topk(scores: jax.Array, mask: jax.Array, n_valid, k: int,
               *, block: int = BLOCK, interpret: bool = True):
    """scores (n,), mask (n,) -> (values (nb, k), indices (nb, k))."""
    n = scores.shape[0]
    pad = (-n) % block
    if pad:
        scores = jnp.pad(scores, (0, pad))
        mask = jnp.pad(mask, (0, pad))
    nb = scores.shape[0] // block
    vals, idx = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_specs=[pl.BlockSpec((1, k), lambda i: (i, 0)),
                   pl.BlockSpec((1, k), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, k), jnp.float32),
                   jax.ShapeDtypeStruct((nb, k), jnp.int32)],
        interpret=interpret,
    )(jnp.asarray(n_valid, jnp.int32).reshape(1, 1),
      scores.astype(jnp.float32).reshape(1, -1), mask.reshape(1, -1))
    return vals, idx


def topk_merge(scores, mask, n_valid, k: int, *, block: int = BLOCK,
               interpret: bool = True):
    """Full top-k: block_topk + one small merge (the k×nb candidate set)."""
    vals, idx = block_topk(scores, mask, n_valid, k, block=block,
                           interpret=interpret)
    flat_v = vals.reshape(-1)
    flat_i = idx.reshape(-1)
    top_v, pos = jax.lax.top_k(flat_v, k)
    return top_v, flat_i[pos]
