"""Flash-decode: single-token attention against a long KV cache, Pallas TPU.

Grid (B, KV, S/BK): the sequential dim streams cache blocks through VMEM
with online-softmax state per (kv-head × G q-heads). Per-sequence valid
length masks dead cache slots (padded/unwritten); a production variant would
bound the KV walk with scalar-prefetched lengths — here every block is
visited and masked (noted; the masked blocks cost bandwidth only).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

DEFAULT_BK = 1024
NEG = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, scale: float, bk: int, nkb: int):
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)                  # (BK, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, BK)
    pos = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    s = jnp.where(pos < len_ref[0, 0], s, NEG)
    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=-1)
    v = v_ref[0, 0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[:, 0] = m_new

    @pl.when(jk == nkb - 1)
    def _emit():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def flash_decode(q, k, v, lengths, *, bk: int = DEFAULT_BK,
                 interpret: bool = True):
    """q: (B,H,D); k,v: (B,KV,S,D); lengths: (B,) -> (B,H,D)."""
    B, H, D = q.shape
    KV, S = k.shape[1], k.shape[2]
    G = H // KV
    bk = min(bk, S)
    assert S % bk == 0, (S, bk)
    nkb = S // bk
    scale = 1.0 / np.sqrt(D)
    qg = q.reshape(B, KV, G, D)

    kernel = functools.partial(_kernel, scale=scale, bk=bk, nkb=nkb)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, nkb),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, j: (b, 0)),
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        scratch_shapes=[
            _VMEM((G, D), jnp.float32) if _VMEM else None,
            _VMEM((G, 1), jnp.float32) if _VMEM else None,
            _VMEM((G, 1), jnp.float32) if _VMEM else None,
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32).reshape(B, 1), qg, k, v)
    return out.reshape(B, H, D)
