"""Public kernel ops: backend dispatch + the flash custom_vjp.

``flash_attention`` is the training-grade op: forward via the Pallas kernel
(TPU) or an XLA online-softmax twin (same math, used where Pallas cannot
compile — e.g. the CPU-hosted dry-run); EITHER way the custom_vjp saves only
(q, k, v, out, lse) and the backward *recomputes* probabilities blockwise —
no (Sq × Skv) probability tensor is ever stored. Swapping the models'
attention onto this op is §Perf iteration 1 (memory-roofline win).

Backend selection: ``backend="auto"`` uses Pallas-interpret on CPU (kernel
semantics validated everywhere) and compiled Pallas on TPU; "xla" forces the
jnp twin (what the dry-run lowers).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.decode_attention import flash_decode as _flash_decode_pallas
from repro.kernels.filter_count import filter_count as _filter_count
from repro.kernels.flash_attention import flash_mha_fwd as _flash_fwd_pallas
from repro.kernels.merge_join import merge_join_count as _merge_join
from repro.kernels.segment_agg import segment_agg as _segment_agg
from repro.kernels.topk_mask import topk_merge as _topk_merge
from repro.runtime import telemetry as tel

_DEFAULT_BACKEND = "xla"

# Trace-time dispatch counters: the kernel execution mode's tests assert the
# relational kernels are actually on the lowered path (one tick per trace,
# not per run — cached executables don't re-trace).
DISPATCH_COUNTS: dict[str, int] = {}


def reset_dispatch_counts() -> None:
    DISPATCH_COUNTS.clear()


def _tick(name: str, grid: Optional[int] = None,
          blocks_total: Optional[int] = None,
          backend: Optional[str] = None) -> None:
    """One tick per trace. Mirrors into the telemetry registry with the
    launch shape: which backend (pallas/xla), interpret vs compiled, and —
    for the block-skipping kernels — grid size vs the component's physical
    block count (scanned/skipped in kernel-block units)."""
    DISPATCH_COUNTS[name] = DISPATCH_COUNTS.get(name, 0) + 1
    pallas = _use_pallas(backend)
    tel.inc("kernel.launches_total", kernel=name,
            backend="pallas" if pallas else "xla",
            interpret=str(pallas and _interpret()).lower())
    if grid is not None:
        tel.inc("kernel.grid_blocks_total", grid, kernel=name)
        if blocks_total is not None:
            tel.inc("kernel.blocks_scanned_total", grid, kernel=name)
            tel.inc("kernel.blocks_skipped_total", blocks_total - grid,
                    kernel=name)


def set_default_backend(name: str) -> None:
    global _DEFAULT_BACKEND
    assert name in ("xla", "pallas")
    _DEFAULT_BACKEND = name


def _use_pallas(backend: Optional[str]) -> bool:
    b = backend or _DEFAULT_BACKEND
    return b == "pallas"


def _interpret() -> bool:
    from repro.kernels.filter_count import _resolve_interpret
    return _resolve_interpret(None)


# -- relational kernels ------------------------------------------------------------

# Zone-map block size the planner's block-skip lists are expressed in: the
# filter_count kernel's own tile. segment_agg's smaller BLOCK is bridged by
# _expand_block_ids below (one zone block = several kernel blocks).
from repro.kernels.filter_count import BLOCK as ZONE_BLOCK_ROWS


def _expand_block_ids(block_ids, zone_block: int, block: int,
                      n: int) -> tuple:
    """Re-express zone-block ids in units of a kernel's own (smaller or
    equal) block size, clipped to the kernel's padded block count."""
    if block_ids is None:
        return None
    assert zone_block % block == 0, (zone_block, block)
    r = zone_block // block
    nb = -(-n // block)
    out = tuple(j for b in block_ids
                for j in range(b * r, min((b + 1) * r, nb)))
    assert out, (block_ids, zone_block, block, n)  # layout mismatch otherwise
    return out


def shard_block_arrays(block_ids, zone_block: int, block: int, n_shards: int,
                       blocks_per_shard: int, rows_per_shard: int) -> np.ndarray:
    """Expand a flat shard-aware zone-block id tuple into the per-shard
    KERNEL-block id matrix the distributed wrappers scalar-prefetch: row
    ``s`` lists shard ``s``'s surviving local kernel-block ids (units of
    ``block`` rows over the shard's own chunk), ``-1``-padded at the END to
    the max surviving count (always >= 1 so the grid is non-empty — an
    all-``-1`` row is a shard with nothing to scan). The zone layout places
    flat block ``s * blocks_per_shard + j`` wholly inside shard ``s``, so
    the expansion never crosses a shard boundary."""
    assert zone_block % block == 0, (zone_block, block)
    r = zone_block // block
    nb_local = -(-rows_per_shard // block)
    per: list[list[int]] = [[] for _ in range(n_shards)]
    for b in block_ids:
        s, j = divmod(int(b), blocks_per_shard)
        per[s].extend(range(j * r, min((j + 1) * r, nb_local)))
    m = max(1, max(len(p) for p in per))
    out = np.full((n_shards, m), -1, np.int32)
    for s, p in enumerate(per):
        out[s, : len(p)] = p
    return out


def filter_count(cols, bounds, n_valid, backend: Optional[str] = None,
                 block_ids: Optional[tuple] = None,
                 block_ids_arr=None,
                 interpret: Optional[bool] = None):
    from repro.kernels.filter_count import BLOCK as _FC_BLOCK
    if block_ids_arr is not None:
        # per-shard runtime ids (already kernel-block units, -1-padded):
        # grid length is the padded list; true scanned/skipped telemetry is
        # accounted host-side by the distributed wrapper, not here.
        _tick("filter_count", grid=int(block_ids_arr.shape[0]),
              backend=backend)
        if _use_pallas(backend):
            return _filter_count(cols, bounds, n_valid,
                                 block_ids_arr=block_ids_arr,
                                 interpret=_interpret() if interpret is None
                                 else interpret)
        return ref.filter_count(cols, bounds, n_valid,
                                block_ids_arr=block_ids_arr, block=_FC_BLOCK)
    ids = _expand_block_ids(block_ids, ZONE_BLOCK_ROWS, _FC_BLOCK,
                            cols.shape[1])
    nb = -(-cols.shape[1] // _FC_BLOCK)
    _tick("filter_count", grid=len(ids) if ids is not None else nb,
          blocks_total=nb, backend=backend)
    if _use_pallas(backend):
        return _filter_count(cols, bounds, n_valid, block_ids=ids,
                             interpret=_interpret() if interpret is None
                             else interpret)
    return ref.filter_count(cols, bounds, n_valid, block_ids=ids,
                            block=_FC_BLOCK)


def segment_agg(values, gids, num_groups, n_valid, op: str = "sum",
                backend: Optional[str] = None,
                block_ids: Optional[tuple] = None,
                block_ids_arr=None,
                interpret: Optional[bool] = None):
    from repro.kernels.segment_agg import BLOCK as _SA_BLOCK
    if block_ids_arr is not None:
        _tick("segment_agg", grid=int(block_ids_arr.shape[0]),
              backend=backend)
        if _use_pallas(backend):
            return _segment_agg(values, gids, num_groups, n_valid, op=op,
                                block_ids_arr=block_ids_arr,
                                interpret=_interpret() if interpret is None
                                else interpret)
        return ref.segment_agg(values, gids, num_groups, n_valid, op,
                               block_ids_arr=block_ids_arr, block=_SA_BLOCK)
    ids = _expand_block_ids(block_ids, ZONE_BLOCK_ROWS, _SA_BLOCK,
                            values.shape[0])
    nb = -(-values.shape[0] // _SA_BLOCK)
    _tick("segment_agg", grid=len(ids) if ids is not None else nb,
          blocks_total=nb, backend=backend)
    if _use_pallas(backend):
        return _segment_agg(values, gids, num_groups, n_valid, op=op,
                            block_ids=ids,
                            interpret=_interpret() if interpret is None
                            else interpret)
    return ref.segment_agg(values, gids, num_groups, n_valid, op,
                           block_ids=ids, block=_SA_BLOCK)


def sort_join_keys(keys, mask, presorted: bool = False):
    """Prep one side for merge_join_count's sortedness contract: int32 keys,
    dead rows replaced by the +inf-style sentinel, ascending sort (skipped
    when the keys come from a sorted index). Shared by the single-device and
    shard-local kernel join paths."""
    if presorted:  # index order: valid ascending, sentinel tail
        return keys.astype(jnp.int32)
    sent = jnp.iinfo(jnp.int32).max
    return jnp.sort(jnp.where(mask, keys.astype(jnp.int32), sent))


def merge_join_count(lkeys, rkeys, nl, nr, backend: Optional[str] = None):
    """Equi-join cardinality over SORTED key columns (valid prefix of length
    nl/nr, +inf-style sentinel padding after). The XLA twin exploits the same
    sortedness contract via binary search — ref.merge_join_count's O(nl·nr)
    compare matrix is a test oracle, not an execution path."""
    _tick("merge_join_count", backend=backend)
    if _use_pallas(backend):
        return _merge_join(lkeys, rkeys, nl, nr, interpret=_interpret())
    lo = jnp.searchsorted(rkeys, lkeys, side="left")
    hi = jnp.minimum(jnp.searchsorted(rkeys, lkeys, side="right"), nr)
    lm = jnp.arange(lkeys.shape[0]) < nl
    return jnp.sum(jnp.where(lm, jnp.maximum(hi - lo, 0), 0), dtype=jnp.int32)


def topk(scores, mask, n_valid, k, backend: Optional[str] = None):
    """Masked top-k over the valid prefix: (values (k,), global indices (k,));
    identical tie-breaking (lowest index first) on both backends."""
    _tick("topk", backend=backend)
    if _use_pallas(backend):
        return _topk_merge(scores, mask, n_valid, k, interpret=_interpret())
    live = mask & (jnp.arange(scores.shape[0]) < n_valid)
    s = jnp.where(live, scores.astype(jnp.float32), -jnp.inf)
    vals, idx = jax.lax.top_k(s, k)
    return vals, idx.astype(jnp.int32)


# -- flash attention (training-grade custom_vjp) -------------------------------------


def _xla_flash_fwd(q, k, v, causal: bool, bq: int):
    """Online-softmax forward in plain jnp (scan over q blocks), emitting
    (out, lse) — identical contract to the Pallas kernel."""
    B, H, Sq, D = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / np.sqrt(D)
    bq = min(bq, Sq)
    nqb = Sq // bq
    rem = Sq - nqb * bq
    kg = k.astype(jnp.float32)
    vg = v.astype(jnp.float32)

    def one(qc, qpos):
        qq = qc.reshape(B, KV, G, -1, D).astype(jnp.float32) * scale
        s = jnp.einsum("bkgqd,bksd->bkgqs", qq, kg)
        if causal:
            m = qpos[:, None] >= jnp.arange(Skv)[None, :]
            s = jnp.where(m[None, None, None], s, -1e30)
        mx = jnp.max(s, axis=-1)
        p = jnp.exp(s - mx[..., None])
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bkgqs,bksd->bkgqd", p, vg) / jnp.maximum(l, 1e-30)[..., None]
        lse = mx + jnp.log(jnp.maximum(l, 1e-30))
        qlen = qq.shape[3]
        return o.reshape(B, H, qlen, D), lse.reshape(B, H, qlen)

    outs, lses = [], []
    if nqb:
        qs = q[:, :, : nqb * bq].reshape(B, H, nqb, bq, D).transpose(2, 0, 1, 3, 4)
        ps = jnp.arange(nqb * bq).reshape(nqb, bq)

        def body(_, xs):
            qc, pp = xs
            o, ls = one(qc.transpose(0, 1, 2, 3), pp)
            return None, (o, ls)

        _, (o_s, l_s) = jax.lax.scan(body, None, (qs, ps))
        outs.append(o_s.transpose(1, 2, 0, 3, 4).reshape(B, H, nqb * bq, D))
        lses.append(l_s.transpose(1, 2, 0, 3).reshape(B, H, nqb * bq))
    if rem:
        o, ls = one(q[:, :, nqb * bq:], jnp.arange(nqb * bq, Sq))
        outs.append(o)
        lses.append(ls)
    out = jnp.concatenate(outs, axis=2) if len(outs) > 1 else outs[0]
    lse = jnp.concatenate(lses, axis=2) if len(lses) > 1 else lses[0]
    return out.astype(q.dtype), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True, bq: int = 512,
                    backend: str = "xla"):
    """GQA attention, O(S) residuals. q: (B,H,Sq,D); k,v: (B,KV,Skv,D)."""
    out, _ = _flash_fwd_dispatch(q, k, v, causal, bq, backend)
    return out


def _flash_fwd_dispatch(q, k, v, causal, bq, backend):
    if backend == "pallas":
        return _flash_fwd_pallas(q, k, v, causal=causal, bq=min(bq, q.shape[2]),
                                 interpret=_interpret())
    return _xla_flash_fwd(q, k, v, causal, bq)


def _flash_fwd_rule(q, k, v, causal, bq, backend):
    out, lse = _flash_fwd_dispatch(q, k, v, causal, bq, backend)
    return out, (q, k, v, out, lse)


def _flash_bwd_rule(causal, bq, backend, res, do):
    """Recompute-probabilities backward, blocked over q chunks (no (Sq×Skv)
    residual). Standard flash equations with the saved lse."""
    q, k, v, out, lse = res
    B, H, Sq, D = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / np.sqrt(D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    bq_ = min(bq, Sq)
    nqb = Sq // bq_
    rem = Sq - nqb * bq_

    def chunk_grads(qc, oc, dc, lc, qpos):
        qf = qc.reshape(B, KV, G, -1, D).astype(jnp.float32)
        of = oc.reshape(B, KV, G, -1, D).astype(jnp.float32)
        df = dc.reshape(B, KV, G, -1, D).astype(jnp.float32)
        lf = lc.reshape(B, KV, G, -1)
        s = jnp.einsum("bkgqd,bksd->bkgqs", qf * scale, kf)
        if causal:
            m = qpos[:, None] >= jnp.arange(Skv)[None, :]
            s = jnp.where(m[None, None, None], s, -1e30)
        p = jnp.exp(s - lf[..., None])  # exact probs from lse
        dp = jnp.einsum("bkgqd,bksd->bkgqs", df, vf)
        delta = jnp.sum(df * of, axis=-1)  # (B,KV,G,q)
        ds = p * (dp - delta[..., None])
        dqc = jnp.einsum("bkgqs,bksd->bkgqd", ds, kf) * scale
        dkc = jnp.einsum("bkgqs,bkgqd->bksd", ds, qf) * scale
        dvc = jnp.einsum("bkgqs,bkgqd->bksd", p, df)
        return dqc.reshape(B, H, -1, D), dkc, dvc

    dq_parts = []
    dk = jnp.zeros((B, KV, Skv, D), jnp.float32)
    dv = jnp.zeros((B, KV, Skv, D), jnp.float32)
    if nqb:
        def split4(a):
            return a[:, :, : nqb * bq_].reshape(B, H, nqb, bq_, D).transpose(2, 0, 1, 3, 4)

        qs = split4(q)
        os_ = split4(out)
        dos = split4(do)
        ls = lse[:, :, : nqb * bq_].reshape(B, H, nqb, bq_).transpose(2, 0, 1, 3)
        ps = jnp.arange(nqb * bq_).reshape(nqb, bq_)

        def body(carry, xs):
            dk_, dv_ = carry
            qc, oc, dc, lc, pp = xs
            dqc, dkc, dvc = chunk_grads(qc, oc, dc, lc, pp)
            return (dk_ + dkc, dv_ + dvc), dqc

        (dk, dv), dq_s = jax.lax.scan(body, (dk, dv), (qs, os_, dos, ls, ps))
        dq_parts.append(dq_s.transpose(1, 2, 0, 3, 4).reshape(B, H, nqb * bq_, D))
    if rem:
        dqc, dkc, dvc = chunk_grads(q[:, :, nqb * bq_:], out[:, :, nqb * bq_:],
                                    do[:, :, nqb * bq_:], lse[:, :, nqb * bq_:],
                                    jnp.arange(nqb * bq_, Sq))
        dk = dk + dkc
        dv = dv + dvc
        dq_parts.append(dqc)
    dq = jnp.concatenate(dq_parts, axis=2) if len(dq_parts) > 1 else dq_parts[0]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def flash_decode(q, k, v, lengths, backend: Optional[str] = None):
    """Single-token decode attention. q: (B,H,D); k,v: (B,KV,S,D)."""
    if _use_pallas(backend):
        return _flash_decode_pallas(q, k, v, lengths, interpret=_interpret())
    return ref.decode_attention(q, k, v, lengths)
