"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Each function is the semantic specification; kernels/<name>.py must match it
for all shapes/dtypes the tests sweep (interpret=True on CPU, compiled on
real TPUs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _block_select(n: int, block: int, block_ids) -> np.ndarray:
    """Original row indices of the listed blocks (static: block_ids is a
    Python tuple) — the XLA twins' analogue of driving the Pallas grid
    through surviving blocks only."""
    return np.concatenate([np.arange(b * block, min((b + 1) * block, n))
                           for b in block_ids])


def _arr_select(n: int, block: int, ids: jax.Array):
    """Traced analogue of ``_block_select`` for runtime (-1-padded) id
    lists: returns (gather positions clamped into [0, n), original row
    positions, live mask). Pad ids (< 0) clamp to block 0 and come back
    dead — the twin of the Pallas grid's gated no-op steps."""
    pos = (jnp.maximum(ids, 0)[:, None] * block
           + jnp.arange(block)[None, :]).reshape(-1)
    live = jnp.repeat(ids >= 0, block)
    return jnp.minimum(pos, n - 1), pos, live


def filter_count(cols: jax.Array, bounds: jax.Array, n_valid,
                 block_ids=None, block: int = 4096,
                 block_ids_arr=None) -> jax.Array:
    """cols: (k, n) int32; bounds: (k, 2) int32 [lo, hi] inclusive.
    Count of rows i < n_valid with AND_k (lo_k <= cols[k, i] <= hi_k).
    ``block_ids`` restricts the pass to the listed row blocks (zone-map
    block skipping); the original row index still gates ``n_valid``.
    ``block_ids_arr`` is the traced -1-padded per-shard alternative."""
    k, n = cols.shape
    if block_ids_arr is not None:
        sel, pos, live = _arr_select(n, block,
                                     jnp.asarray(block_ids_arr, jnp.int32))
        cols = cols[:, sel]
        m = live & (pos < n_valid)
    elif block_ids is not None:
        sel = _block_select(n, block, block_ids)
        cols = cols[:, sel]
        m = jnp.asarray(sel) < n_valid
    else:
        m = jnp.arange(n) < n_valid
    ok = jnp.all((cols >= bounds[:, :1]) & (cols <= bounds[:, 1:2]), axis=0)
    return jnp.sum(ok & m, dtype=jnp.int32)


def segment_agg(values: jax.Array, gids: jax.Array, num_groups: int,
                n_valid, op: str = "sum",
                block_ids=None, block: int = 2048,
                block_ids_arr=None) -> jax.Array:
    """values: (n, c) f32; gids: (n,) int32. Per-group column ``op``-reductions
    (G, c); empty groups hold the identity (0 / -inf / +inf). ``block_ids``
    restricts the reduction to the listed row blocks (``block_ids_arr``:
    the traced -1-padded per-shard form)."""
    n = values.shape[0]
    if block_ids_arr is not None:
        sel, pos, live = _arr_select(n, block,
                                     jnp.asarray(block_ids_arr, jnp.int32))
        values = values[sel]
        gids = gids[sel]
        idx = jnp.where(live & (pos < n), pos, n)  # dead rows fail n_valid
        n = int(sel.shape[0])
    elif block_ids is not None:
        sel = _block_select(n, block, block_ids)
        values = values[sel]
        gids = gids[sel]
        idx = jnp.asarray(sel)
        n = len(sel)
    else:
        idx = jnp.arange(n)
    m = (idx < n_valid) & (gids >= 0) & (gids < num_groups)
    safe = jnp.where(m, gids, num_groups)
    if op == "sum":
        v = jnp.where(m[:, None], values, 0.0)
        return jax.ops.segment_sum(v, safe, num_segments=num_groups + 1)[:num_groups]
    ident = -jnp.inf if op == "max" else jnp.inf
    seg = jax.ops.segment_max if op == "max" else jax.ops.segment_min
    v = jnp.where(m[:, None], values.astype(jnp.float32), ident)
    out = seg(v, safe, num_segments=num_groups + 1)[:num_groups]
    # segment_max/min leave untouched segments at the dtype min/max; pin the
    # identity so the contract matches the Pallas kernel exactly.
    counts = jax.ops.segment_sum(m.astype(jnp.int32), safe,
                                 num_segments=num_groups + 1)[:num_groups]
    return jnp.where((counts > 0)[:, None], out, ident)


def merge_join_count(lkeys: jax.Array, rkeys: jax.Array, nl, nr) -> jax.Array:
    """Sorted equi-join cardinality: Σ_{i<nl, j<nr} [lkeys_i == rkeys_j]."""
    lm = jnp.arange(lkeys.shape[0]) < nl
    rm = jnp.arange(rkeys.shape[0]) < nr
    eq = (lkeys[:, None] == rkeys[None, :]) & lm[:, None] & rm[None, :]
    return jnp.sum(eq, dtype=jnp.int32)


def block_topk(scores: jax.Array, mask: jax.Array, k: int, block: int):
    """Per-block top-k: scores (n,) split into n/block blocks; returns
    (values (nb, k), global indices (nb, k)); masked-out -> -inf."""
    n = scores.shape[0]
    nb = n // block
    s = jnp.where(mask, scores.astype(jnp.float32), -jnp.inf).reshape(nb, block)
    v, i = jax.lax.top_k(s, k)
    return v, i + (jnp.arange(nb) * block)[:, None]


def mha(q, k, v, *, causal: bool = True, scale=None, pos_offset: int = 0):
    """GQA attention oracle. q: (B,H,Sq,D); k,v: (B,KV,Skv,D). fp32 softmax."""
    B, H, Sq, D = q.shape
    KV = k.shape[1]
    G = H // KV
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    qg = q.reshape(B, KV, G, Sq, D)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        Skv = k.shape[2]
        qpos = jnp.arange(Sq) + pos_offset
        mask = qpos[:, None] >= jnp.arange(Skv)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, D).astype(q.dtype)


def decode_attention(q, k, v, lengths):
    """Flash-decode oracle. q: (B,H,D); k,v: (B,KV,S,D); lengths: (B,) valid
    cache length per sequence. Returns (B,H,D)."""
    B, H, D = q.shape
    KV, S = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, D)
    s = jnp.einsum("bkgd,bksd->bkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(D)
    m = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(m[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)
