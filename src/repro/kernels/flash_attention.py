"""Causal GQA flash attention (prefill/train forward), Pallas TPU.

Grid (B, H, Sq/BQ, Skv/BK): the innermost (sequential) dim walks KV blocks
with the classic online-softmax state (m, l, acc) living in VMEM scratch;
out-of-causal-range KV blocks are skipped via ``pl.when``; the normalized
tile and its logsumexp are written when the last in-range KV block retires.
lse is emitted because the custom_vjp backward (kernels/ops.py) recomputes
probabilities from (q, k, v, lse) instead of materializing them — the whole
point vs. the XLA path (EXPERIMENTS.md §Perf iteration 1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

DEFAULT_BQ = 512
DEFAULT_BK = 512
NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref,
            *, scale: float, causal: bool, bq: int, bk: int, nkb: int):
    iq = pl.program_id(2)
    jk = pl.program_id(3)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    last = jnp.minimum(((iq + 1) * bq - 1) // bk, nkb - 1) if causal else nkb - 1
    in_range = (jk * bk <= (iq + 1) * bq - 1) if causal else True

    @pl.when(in_range)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)              # (BK, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=-1)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[:, 0] = m_new

    @pl.when(jk == last)
    def _emit():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = m_ref[:, 0] + jnp.log(l)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_mha_fwd(q, k, v, *, causal: bool = True, bq: int = DEFAULT_BQ,
                  bk: int = DEFAULT_BK, interpret: bool = True):
    """q: (B,H,Sq,D); k,v: (B,KV,Skv,D) -> (out (B,H,Sq,D), lse (B,H,Sq))."""
    B, H, Sq, D = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    nqb, nkb = Sq // bq, Skv // bk
    scale = 1.0 / np.sqrt(D)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               bq=bq, bk=bk, nkb=nkb)
    scratch = [
        _VMEM((bq, D), jnp.float32) if _VMEM else None,
        _VMEM((bq, 1), jnp.float32) if _VMEM else None,
        _VMEM((bq, 1), jnp.float32) if _VMEM else None,
    ]
    out, lse = pl.pallas_call(
        kernel,
        grid=(B, H, nqb, nkb),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j, g=G: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j, g=G: (b, h // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
            jax.ShapeDtypeStruct((B, H, Sq), jnp.float32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)
    return out, lse
