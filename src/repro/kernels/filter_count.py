"""Fused multi-predicate filter + count (paper expressions 1/3/11).

One pass over k conjunct columns: each grid step loads a (k, BLOCK) tile
into VMEM, evaluates the ANDed range predicates on the VPU, and accumulates
a popcount into a (1,1) SMEM-style accumulator. Predicate *constants* arrive
as a (k, 2) operand so randomized benchmark literals reuse the compiled
kernel. This is the engine's answer to "SELECT COUNT(*) WHERE ..." — no
intermediate mask column ever touches HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 4096


def _kernel(bounds_ref, nvalid_ref, cols_ref, out_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[0, 0] = jnp.int32(0)

    cols = cols_ref[...]  # (k, BLOCK) int32
    k, b = cols.shape
    base = step * b
    idx = base + jax.lax.broadcasted_iota(jnp.int32, (1, b), 1)
    ok = idx < nvalid_ref[0, 0]
    lo = bounds_ref[:, 0][:, None]
    hi = bounds_ref[:, 1][:, None]
    ok = ok & jnp.all((cols >= lo) & (cols <= hi), axis=0, keepdims=True)
    out_ref[0, 0] += jnp.sum(ok.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def filter_count(cols: jax.Array, bounds: jax.Array, n_valid,
                 *, block: int = BLOCK, interpret: bool = True) -> jax.Array:
    """cols: (k, n) int32; bounds: (k, 2); n_valid scalar. -> int32 count."""
    k, n = cols.shape
    pad = (-n) % block
    if pad:
        cols = jnp.pad(cols, ((0, 0), (0, pad)))
    nb = cols.shape[1] // block
    out = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((k, 2), lambda i: (0, 0)),          # bounds: resident
            pl.BlockSpec((1, 1), lambda i: (0, 0)),          # n_valid scalar
            pl.BlockSpec((k, block), lambda i: (0, i)),      # column tile
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),    # accumulator
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.int32),
        interpret=interpret,
    )(bounds.astype(jnp.int32), jnp.asarray(n_valid, jnp.int32).reshape(1, 1),
      cols.astype(jnp.int32))
    return out[0, 0]
