"""Fused multi-predicate filter + count (paper expressions 1/3/11).

One pass over k conjunct columns: each grid step loads a (k, BLOCK) tile
into VMEM, evaluates the ANDed range predicates on the VPU, and accumulates
a popcount into a (1,1) SMEM-style accumulator. Predicate *constants* arrive
as a (k, 2) operand so randomized benchmark literals reuse the compiled
kernel. This is the engine's answer to "SELECT COUNT(*) WHERE ..." — no
intermediate mask column ever touches HBM.

**Block skipping**: ``block_ids`` (a static tuple of surviving block
indices, produced by the planner's bind-time zone-map test) drives the grid
through the ``index_map`` — the id list rides in as a scalar-prefetch
operand (``PrefetchScalarGridSpec``), the grid size is the number of
*surviving* blocks, not the total, and the index_map fetches each step's
physical tile by id, so pruned tiles are never DMA'd out of HBM. The kernel
reads the same scalar ref to rebuild the row-index base for the ``n_valid``
edge check, keeping results bit-identical to the unskipped launch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 4096


def _body(bounds_ref, nvalid_ref, cols_ref, out_ref, base):
    """Shared predicate/accumulate body; ``base`` is the first physical row
    index of this step's tile."""
    cols = cols_ref[...]  # (k, BLOCK) int32
    k, b = cols.shape
    idx = base + jax.lax.broadcasted_iota(jnp.int32, (1, b), 1)
    ok = idx < nvalid_ref[0, 0]
    lo = bounds_ref[:, 0][:, None]
    hi = bounds_ref[:, 1][:, None]
    ok = ok & jnp.all((cols >= lo) & (cols <= hi), axis=0, keepdims=True)
    out_ref[0, 0] += jnp.sum(ok.astype(jnp.int32))


def _kernel(bounds_ref, nvalid_ref, cols_ref, out_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[0, 0] = jnp.int32(0)

    _body(bounds_ref, nvalid_ref, cols_ref, out_ref,
          step * cols_ref.shape[1])


def _kernel_ids(ids_ref, bounds_ref, nvalid_ref, cols_ref, out_ref):
    """Block-skipping variant: the grid enumerates surviving blocks; the
    scalar-prefetched id list yields each step's physical block id so the
    validity base is exact."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[0, 0] = jnp.int32(0)

    _body(bounds_ref, nvalid_ref, cols_ref, out_ref,
          ids_ref[step] * cols_ref.shape[1])


def _kernel_ids_arr(ids_ref, bounds_ref, nvalid_ref, cols_ref, out_ref):
    """Runtime-id variant (per-shard grids under shard_map): the id list is
    a TRACED scalar-prefetch operand padded with ``-1`` sentinels up to a
    common length, so every shard shares one compiled grid while scanning a
    different surviving set. The index_map clamps pad ids to tile 0 (some
    tile must be addressed); the body is gated off for them, so a pad step
    contributes nothing and the count stays bit-identical."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[0, 0] = jnp.int32(0)

    @pl.when(ids_ref[step] >= 0)
    def _run():
        _body(bounds_ref, nvalid_ref, cols_ref, out_ref,
              ids_ref[step] * cols_ref.shape[1])


def _resolve_interpret(interpret):
    # None = auto: compiled Pallas on real TPUs, interpret mode elsewhere
    # (the kernels' semantics are validated everywhere, compiled where the
    # hardware exists).
    return jax.default_backend() != "tpu" if interpret is None else interpret


@functools.partial(jax.jit,
                   static_argnames=("block", "interpret", "block_ids"))
def filter_count(cols: jax.Array, bounds: jax.Array, n_valid,
                 *, block: int = BLOCK, interpret: bool | None = None,
                 block_ids: tuple | None = None,
                 block_ids_arr: jax.Array | None = None) -> jax.Array:
    """cols: (k, n) int32; bounds: (k, 2); n_valid scalar. -> int32 count.

    ``block_ids``: optional static tuple of surviving block indices (units
    of ``block`` rows over the unpadded layout); the grid visits only those
    tiles. Skipped blocks provably contain no matching rows, so the count
    is bit-identical to the full launch.

    ``block_ids_arr``: TRACED (m,) int32 alternative, padded with ``-1``
    sentinels at the END — the per-shard form: under shard_map every shard
    binds its own local id list of a common padded length, so one compiled
    grid serves all shards. Mutually exclusive with ``block_ids``."""
    interpret = _resolve_interpret(interpret)
    k, n = cols.shape
    pad = (-n) % block
    if pad:
        cols = jnp.pad(cols, ((0, 0), (0, pad)))
    nb = cols.shape[1] // block
    args = [bounds.astype(jnp.int32),
            jnp.asarray(n_valid, jnp.int32).reshape(1, 1),
            cols.astype(jnp.int32)]
    if block_ids_arr is not None:
        assert block_ids is None, "block_ids and block_ids_arr are exclusive"
        ids = block_ids_arr.astype(jnp.int32)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(int(ids.shape[0]),),
            in_specs=[
                pl.BlockSpec((k, 2), lambda i, ids: (0, 0)),
                pl.BlockSpec((1, 1), lambda i, ids: (0, 0)),
                pl.BlockSpec((k, block),
                             lambda i, ids: (0, jnp.maximum(ids[i], 0))),
            ],
            out_specs=pl.BlockSpec((1, 1), lambda i, ids: (0, 0)),
        )
        out = pl.pallas_call(
            _kernel_ids_arr,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((1, 1), jnp.int32),
            interpret=interpret,
        )(ids, *args)
        return out[0, 0]
    if block_ids is None:
        out = pl.pallas_call(
            _kernel,
            grid=(nb,),
            in_specs=[
                pl.BlockSpec((k, 2), lambda i: (0, 0)),      # bounds: resident
                pl.BlockSpec((1, 1), lambda i: (0, 0)),      # n_valid scalar
                pl.BlockSpec((k, block), lambda i: (0, i)),  # column tile
            ],
            out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),  # accumulator
            out_shape=jax.ShapeDtypeStruct((1, 1), jnp.int32),
            interpret=interpret,
        )(*args)
        return out[0, 0]
    assert all(0 <= b < nb for b in block_ids), (block_ids, nb)
    # grid = surviving blocks; the scalar-prefetched id list feeds the
    # index_map, so pruned tiles are never fetched at all.
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(len(block_ids),),
        in_specs=[
            pl.BlockSpec((k, 2), lambda i, ids: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, ids: (0, 0)),
            pl.BlockSpec((k, block), lambda i, ids: (0, ids[i])),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, ids: (0, 0)),
    )
    out = pl.pallas_call(
        _kernel_ids,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.int32),
        interpret=interpret,
    )(jnp.asarray(block_ids, jnp.int32), *args)
    return out[0, 0]
