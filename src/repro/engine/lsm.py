"""LSM storage for streaming ingestion — AsterixDB's feed path on the
JAX/Pallas engine (paper §III-A).

AsterixDB feeds append to LSM components with online index maintenance; the
device-resident analogue here:

  * a **flush** turns the host buffer into a *run*: a block-padded (and
    mesh-sharded) columnar Table with its own sorted secondary indexes and
    zone maps, registered beside the base table. Flush cost is O(batch),
    never O(base).
  * **mutations** follow AsterixDB's anti-matter design (paper §III, live
    ingestion): a delete/upsert buffers an *anti-matter* record; the flushed
    run's table carries a per-row matter/anti-matter flag plus the primary
    key (anti rows are ``__valid__`` False, so every matter path ignores
    them), and a sorted anti-key array rides along for query-time visibility
    probes. An anti-matter record *annihilates* all matter with its key in
    strictly older components — newest component wins; an upsert is an
    anti-matter record plus fresh matter in the same run.
  * queries over a fed dataset execute as **base ∪ runs** (the ``UnionRuns``
    plan node): per-component index probes / kernel launches, one final
    merge — results are identical to querying the compacted dataset,
    including after upserts/deletes (the planner subtracts each component's
    contribution that newer anti-matter shadows).
  * **compaction** is deferred until a size-ratio policy fires, then folds
    every component into the base with a key-ordered newest-component-wins
    merge — annihilated matter and all tombstones are dropped (the only
    O(base) step, amortized over many flushes). The leveled policy variant
    instead merges same-level run groups into the next level, keeping every
    merge O(level), and full-compacts only on the size-ratio trigger.
  * **materialized views** (``Session.create_view``) are group-by aggregates
    maintained *incrementally*: each flush runs only the delta batch through
    the ``segment_agg`` path and merges partial aggregates — the paper's
    live-dashboard scenario. The f32 kernel path is gated by the same
    exactness reasoning the kernel execution mode uses; batches that cannot
    be proven exact fall back to native-dtype host reduction. Deletes and
    upserts feed *retraction* deltas: counts/sums take negative deltas; a
    retracted group max/min that touches the current extremum triggers an
    exact host recompute of the affected groups.
"""
from __future__ import annotations

import copy
import dataclasses
import threading
import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import plan as P
from repro.core.catalog import INTERNAL_COLUMNS, Dataset, Manifest, open_widen
from repro.engine.table import (ColumnMeta, Table, is_lane_column,
                                pad_to_block)
from repro.runtime import telemetry as tel
from repro.runtime.fault import StorageFault

RUN_BLOCK = 1024      # runs are padded to this row multiple
_F32_EXACT = 1 << 24  # every int in [-2^24, 2^24] is exactly representable


class ManifestConflict(RuntimeError):
    """A merge built off one manifest lost the CAS at publish time: a
    concurrent publish (flush or another merge) invalidated the component
    segment it planned against. The built components are discarded; the
    caller replans against the current manifest and retries."""


def _fault(session, point: str) -> None:
    """Consult the session's storage FaultPlan (runtime/fault.py) at one
    named crash point; raises StorageFault on a scheduled arrival."""
    plan = getattr(session, "fault_plan", None)
    if plan is not None:
        plan.check(point)


class _ManifestView:
    """A Dataset proxy bound to one captured manifest: ``runs`` is the
    pinned run list, every other attribute delegates to the base. Compaction
    policies plan against this view, so their decision and the CAS-validated
    merge both reference the same component set even while writers keep
    publishing."""

    def __init__(self, base: Dataset, manifest: Manifest):
        self._base = base
        self.runs = list(manifest.runs)

    def __getattr__(self, item):
        return getattr(self._base, item)


@dataclasses.dataclass(frozen=True)
class CompactionPolicy:
    """Deferred-compaction trigger (AsterixDB's size-ratio merge policy
    analogue): compact when the accumulated run burden — visible matter plus
    tombstones plus base rows the tombstones annihilated — reaches
    ``size_ratio`` × visible base rows, or when more than ``max_runs``
    components pile up. ``size_ratio=0`` degenerates to compact-every-flush
    (the benchmark baseline)."""

    size_ratio: float = 1.0
    max_runs: int = 8

    def plan(self, ds: Dataset) -> list[tuple]:
        """Compaction actions to run after a flush: ``("full",)`` merges
        every component into the base."""
        return [("full",)] if should_compact(ds, self) else []


@dataclasses.dataclass(frozen=True)
class LeveledCompactionPolicy(CompactionPolicy):
    """Leveled/tiered variant (the ROADMAP's planner-visible cost trade):
    flushes land in level 0; when a level accumulates ``fanin`` runs they
    merge into ONE run at the next level (an O(level) merge that drops
    annihilated matter early), so read amplification stays
    ~``levels × fanin`` instead of growing with every flush. The inherited
    size-ratio trigger still forces the full O(base) fold — with
    ``size_ratio=0`` the policy degenerates to compact-every-flush exactly
    like the tiered default."""

    level0_runs: int = 4    # runs tolerated at level 0 before a level merge
    level_ratio: int = 4    # fanin of every level above 0

    def fanin(self, level: int) -> int:
        return max(self.level0_runs if level == 0 else self.level_ratio, 2)

    def plan(self, ds: Dataset) -> list[tuple]:
        if should_compact(ds, self):
            return [("full",)]
        by_level: dict[int, list[int]] = {}
        for i, r in enumerate(ds.runs):
            by_level.setdefault(r.level, []).append(i)
        for level in sorted(by_level):
            idxs = by_level[level]
            if len(idxs) >= self.fanin(level):
                # same-level runs are contiguous by construction (levels are
                # non-increasing along the run list)
                return [("merge", idxs[0], idxs[-1] + 1, level + 1)]
        return []


def should_compact(ds: Dataset, policy: CompactionPolicy) -> bool:
    if not ds.runs:
        return False
    if len(ds.runs) > policy.max_runs:
        return True
    # Run burden discounts annihilated rows from the visible term but charges
    # the tombstones themselves and every component's shadowed matter (base
    # AND runs): all of it is storage a compaction would reclaim.
    burden = sum(r.num_live_rows + r.anti_rows + r.annihilated_rows
                 for r in ds.runs)
    burden += ds.annihilated_rows
    return burden >= policy.size_ratio * max(ds.num_live_rows, 1)


# -- runs -------------------------------------------------------------------


def make_run(session, base: Dataset, table: Table,
             anti_keys: Optional[np.ndarray] = None) -> Dataset:
    """Build one device-resident run from a flush batch: stats → (optional)
    open-widen → sort by the base's primary → append anti-matter rows →
    block-pad (+shard) → per-run sorted secondary indexes with zone maps.
    O(batch) throughout.

    ``anti_keys`` are the primary keys this run's anti-matter annihilates in
    older components. They materialize twice: as table rows flagged
    ``__antimatter__`` (``__valid__`` False — no matter path ever sees them)
    and as the sorted ``anti_keys_arr`` device array query-time visibility
    probes search. Column stats/zone spans are harvested from matter only."""
    from repro.engine.session import _collect_stats

    t0 = time.perf_counter()
    live = table.num_rows
    # `like` hint: a run's dict-lane presence follows the base table's, so
    # the column set stays uniform across every component in the union.
    table = _collect_stats(table, like=base.table.meta)
    if not base.closed:
        table = open_widen(table)
    primary = base.primary_index
    host_keys = None
    if primary is not None:
        order = np.argsort(np.asarray(table.columns[primary.column]),
                           kind="stable")
        cols = {k: np.asarray(v)[order] for k, v in table.columns.items()}
        meta = dict(table.meta)
        meta[primary.column] = dataclasses.replace(meta[primary.column],
                                                   sorted_ascending=True)
        table = Table(cols, meta, table.num_rows)
        host_keys = np.asarray(table.columns[primary.column])
    anti_sorted = None
    n_anti = 0 if anti_keys is None else len(anti_keys)
    if n_anti:
        key_col = primary.column
        kdt = np.asarray(table.columns[key_col]).dtype
        anti_sorted = np.sort(np.asarray(anti_keys).astype(kdt))
        table = _append_anti_rows(table, key_col, anti_sorted)
    table = pad_to_block(table, RUN_BLOCK)
    if session.mesh is not None:
        table = table.shard(session.mesh, session.data_axes)
    from repro.core.stats import harvest_block_zones, mesh_shards
    # stable component id: a per-dataset monotone uid, never reused — the
    # run keeps this address for life, compactions around it notwithstanding
    uid = session.catalog.next_run_uid(base.dataverse, base.name)
    run = Dataset(name=f"{base.name}@run{uid}", uid=uid,
                  dataverse=base.dataverse, table=table, closed=base.closed,
                  engine_owned=True,  # flush-built: safe to device-delete
                  live_rows=live, anti_rows=n_anti,
                  anti_keys_arr=None if anti_sorted is None
                  else jnp.asarray(anti_sorted),
                  host_anti_keys=anti_sorted,
                  host_keys=host_keys,
                  # intra-run zone maps, harvested in the same flush pass
                  # that builds the sorted indexes (matter rows only: anti
                  # rows and block padding never widen a span). Sharded
                  # sessions harvest the per-shard layout so block lists
                  # re-base to each row partition.
                  block_zones=harvest_block_zones(
                      table, mesh_shards(session.mesh, session.data_axes)))
    if primary is not None:
        run.indexes["primary"] = session._build_index(table, primary.column,
                                                      "primary")
    for ix in base.indexes.values():
        if ix.kind == "secondary":
            run.indexes[f"ix_{ix.column}"] = session._build_index(
                table, ix.column, "secondary")
    ds_label = f"{base.dataverse}.{base.name}"
    tel.inc("lsm.runs_built_total", dataset=ds_label)
    tel.observe("lsm.run_build_seconds", time.perf_counter() - t0,
                dataset=ds_label)
    tel.observe("lsm.run_build_rows", live, dataset=ds_label)
    return run


def _append_anti_rows(table: Table, key_col: str,
                      anti_sorted: np.ndarray) -> Table:
    """Anti-matter rows ride after the matter prefix: key column carries the
    annihilated key, every other column is zero, ``__antimatter__`` True and
    ``__valid__`` False (invisible to matter paths and index builds)."""
    m = table.num_rows
    t = len(anti_sorted)
    cols: dict[str, np.ndarray] = {}
    for k, v in table.columns.items():
        a = np.asarray(v)
        if k == key_col:
            pad = anti_sorted
        elif a.ndim == 2:
            pad = np.zeros((t, a.shape[1]), a.dtype)
        else:
            pad = np.zeros(t, a.dtype)
        cols[k] = np.concatenate([a, pad], axis=0)
    cols["__antimatter__"] = np.concatenate(
        [np.zeros(m, bool), np.ones(t, bool)])
    cols["__valid__"] = np.concatenate([np.ones(m, bool), np.zeros(t, bool)])
    meta = dict(table.meta)  # matter-only stats survive the append
    return Table(cols, meta, m + t)


def register_run(session, base: Dataset, run: Dataset) -> Optional[dict]:
    """Publish the run: one atomic manifest swap under the catalog lock
    (publish-then-retire — the swap bumps the LSN and statistics epoch, so
    every level of the Session plan cache, keyed on (epoch, LSN), rebinds
    and a cached executable for the old component set becomes unreachable).
    Snapshots pinned on the old manifest keep reading exactly the old
    component set.

    The publish happens FIRST, then the soft-state bookkeeping: when the
    run carries anti-matter, every older component's annihilation
    bookkeeping updates (O(tombstones · log component) host searches over
    the clustered key copies); when a materialized view is registered over
    the dataset, the newly annihilated rows are also gathered and returned
    for its retraction — without a view the gather is skipped entirely. A
    crash between publish and bookkeeping (the "post-swap" fault point)
    leaves the manifest committed and only soft state stale — recover()
    replays the bookkeeping from the hard rows."""
    cat = session.catalog
    if cat.store is not None:
        # persist the run's segment OFF the catalog lock (the heavy tensor
        # write); publish's durable-commit step below only links it. The
        # store's in-flight tracking protects it from GC until then.
        cat.store.write_component(base.dataverse, base.name, run)
    with cat.lock:
        # re-read the CURRENT manifest: the base the caller fetched may have
        # been swapped by a concurrent background compaction since
        cur = cat.manifest(base.dataverse, base.name)
        older = cur.components
        _fault(session, "pre-swap")
        cat.publish(base.dataverse, base.name, cur.base,
                    tuple(cur.runs) + (run,))
        _fault(session, "post-swap")
        retracted = None
        if run.anti_rows:
            gather = any((v.dataverse, v.dataset) == (base.dataverse, base.name)
                         for v in getattr(session, "views", {}).values())
            retracted = _annihilate_older(older, run, gather=gather)
    return retracted


def _annihilate_older(older, run: Dataset,
                      gather: bool = True) -> Optional[dict]:
    """Apply one new run's anti-key set to the strictly older components
    ``older``: count (and, with ``gather``, collect) the matter rows it
    newly shadows. A key a previous tombstone already covered is skipped —
    its matter was discounted then, so nothing double-subtracts. Callers
    hold the catalog lock: the bookkeeping sets this mutates are read (and
    copied) under the same lock by merges and stats."""
    anti_set = set(np.asarray(run.anti_keys_arr).tolist())
    gathered: list[dict[str, np.ndarray]] = []
    for comp in older:
        new = anti_set - comp.annihilated_keys
        if not new or comp.host_keys is None or not len(comp.host_keys):
            continue
        ak = np.sort(np.fromiter(new, dtype=comp.host_keys.dtype,
                                 count=len(new)))
        lo = np.searchsorted(comp.host_keys, ak, side="left")
        hi = np.searchsorted(comp.host_keys, ak, side="right")
        occ = hi - lo
        total = int(occ.sum())
        if not total:
            continue
        # record only keys that actually hit matter: a duplicate tombstone
        # for a miss re-probes later and finds 0 again (nothing can double-
        # discount), and the visibility masks stay proportional to rows
        # killed, not tombstones issued.
        comp.annihilated_keys |= set(ak[occ > 0].tolist())
        comp.annihilated_rows += total
        if not gather:
            continue
        # the matter prefix is clustered by the primary key, so index-space
        # positions ARE table row positions: gather the dying rows (device
        # gather of `total` rows) for view retraction.
        idx = np.concatenate([np.arange(l, h) for l, h in zip(lo, hi)
                              if h > l])
        gathered.append({k: np.asarray(v[jnp.asarray(idx)])
                         for k, v in comp.table.columns.items()
                         if k not in INTERNAL_COLUMNS
                         and not k.startswith("__ix")
                         and not is_lane_column(k)})
    if not gathered:
        return None
    names = list(gathered[0])
    return {k: np.concatenate([g[k] for g in gathered], axis=0)
            for k in names}


def host_visible_mask(comp: Dataset, key_col: Optional[str],
                      annihilated: Optional[set] = None) -> np.ndarray:
    """Host-side visibility of one component's physical rows: valid matter
    (anti rows and padding are ``__valid__`` False) minus rows newer
    components' anti-matter annihilated. ``annihilated`` overrides the
    component's live kill-set with a copy captured under the catalog lock —
    merges pass it so a concurrent flush mutating the live set mid-build
    cannot race the mask (the flushed tombstones are reconciled at swap
    time instead)."""
    mask = np.asarray(comp.table.valid).copy()
    anti = comp.table.columns.get("__antimatter__")
    if anti is not None:
        mask &= ~np.asarray(anti)
    kill_set = comp.annihilated_keys if annihilated is None else annihilated
    if kill_set and key_col is not None:
        keys = np.asarray(comp.table.columns[key_col])
        kill = np.fromiter(kill_set, dtype=keys.dtype, count=len(kill_set))
        mask &= ~np.isin(keys, kill)
    return mask


def _visible_columns(comp: Dataset, key_col: Optional[str],
                     annihilated: Optional[set] = None) -> dict[str, np.ndarray]:
    mask = host_visible_mask(comp, key_col, annihilated)
    # per-component dict lanes are dropped: merged/compacted outputs rebuild
    # coherent lanes through _collect_stats (the merge-on-compaction remap).
    return {k: np.asarray(v)[mask] for k, v in comp.table.columns.items()
            if k not in INTERNAL_COLUMNS and not is_lane_column(k)}


def _merge_meta(metas: list[ColumnMeta], total_rows: int) -> ColumnMeta:
    base = metas[0]
    lo = hi = distinct = None
    bounded = all(m.lo is not None and m.hi is not None for m in metas)
    if bounded:
        lo = min(m.lo for m in metas)
        hi = max(m.hi for m in metas)
    if all(m.distinct is not None for m in metas):
        # summing per-component distincts is only a TRUE distinct count when
        # the components cannot share values (pairwise-disjoint ranges) —
        # otherwise it saturates at the row count and would falsely certify
        # a duplicated key as unique to the materializing-join guard. With
        # possible overlap only max(component distinct) is provable.
        spans = sorted((m.lo, m.hi) for m in metas) if bounded else []
        disjoint = bool(spans) and all(
            spans[i][1] < spans[i + 1][0] for i in range(len(spans) - 1))
        if len(metas) == 1 or disjoint:
            distinct = min(sum(m.distinct for m in metas), total_rows)
        else:
            distinct = max(m.distinct for m in metas)
    return ColumnMeta(base.dtype, lo, hi, distinct, base.is_string, False)


def compact(session, ds: Dataset, manifest: Optional[Manifest] = None) -> Dataset:
    """Fold base ∪ runs into a fresh base with a key-ordered newest-
    component-wins merge: each component contributes only the matter no
    newer component's anti-matter annihilated (upserted rows survive once,
    deleted rows not at all), all tombstones drop — nothing older remains
    for them to annihilate — and the primary re-sort restores the clustered
    key order. One host merge, one re-shard, one index rebuild. Component
    stats merge so the catalog bounds stay truthful for the new key/value
    domains the runs introduced.

    Concurrency: the merge plans against ``manifest`` (default: the current
    one), builds the new base entirely OFF the catalog lock, and commits
    with a CAS-validated atomic swap — if a concurrent publish changed the
    base or reordered the merged segment, raises :class:`ManifestConflict`
    (nothing published; the caller replans and retries). Runs flushed while
    the merge was building survive the swap untouched and their anti keys
    are reconciled against the fresh base at swap time."""
    cat = session.catalog
    dv, name = ds.dataverse, ds.name
    ensure_soft(session, dv, name)  # kill-sets/host keys must be live
    t0 = time.perf_counter()
    tel.inc("lsm.compaction.attempts_total", kind="full")
    with cat.lock:
        m0 = manifest if manifest is not None else cat.manifest(dv, name)
        comps = m0.components
        # copy the kill-sets under the lock: a concurrent flush mutates the
        # live sets, and the swap-time reconciliation below covers exactly
        # the tombstones that land after this point
        kills = [set(c.annihilated_keys) for c in comps]
    key_col = m0.base.primary_index.column \
        if m0.base.primary_index is not None else None
    parts = [_visible_columns(c, key_col, kills[i])
             for i, c in enumerate(comps)]
    names = list(parts[0])
    merged = {k: np.concatenate([p[k] for p in parts], axis=0) for k in names}
    total = len(next(iter(merged.values()))) if names else 0
    metas = [c.table.meta for c in comps]
    meta = {k: _merge_meta([mm[k] for mm in metas], total) for k in names}
    secondary = [ix.column for ix in m0.base.indexes.values()
                 if ix.kind == "secondary"]
    _fault(session, "mid-merge")
    new_base = session._build_dataset(name, Table(merged, meta), dataverse=dv,
                                      closed=m0.base.closed,
                                      indexes=secondary, primary=key_col,
                                      stats_like=m0.base.table.meta)
    # compaction-built buffers are engine-exclusive (merged copies), unlike a
    # user-loaded base whose arrays may be shared with the caller's Table
    new_base.engine_owned = True
    if cat.store is not None:
        cat.store.write_component(dv, name, new_base)  # off-lock, pre-CAS
    try:
        with cat.lock:
            cur = cat.manifest(dv, name)
            if cur.base is not m0.base \
                    or tuple(cur.runs[:len(m0.runs)]) != tuple(m0.runs):
                tel.inc("lsm.compaction.conflicts_total", kind="full")
                raise ManifestConflict(
                    f"{dv}.{name}: component set changed under a full "
                    f"compaction (planned at lsn {m0.lsn}, now {cur.lsn})")
            newer = cur.runs[len(m0.runs):]  # flushed while the merge built
            _fault(session, "pre-swap")
            cat.publish(dv, name, new_base, newer)
            _fault(session, "post-swap")
            # reconcile: the surviving newer runs' tombstones still shadow
            # matter now living in the fresh base — replay their bookkeeping
            for r in newer:
                if r.anti_rows:
                    _annihilate_older((new_base,), r, gather=False)
    except ManifestConflict:
        if cat.store is not None:  # orphan segment: never committed
            cat.store.discard_component(dv, name, new_base)
        raise
    tel.inc("lsm.compactions_total", kind="full")
    tel.observe("lsm.compaction_seconds", time.perf_counter() - t0,
                kind="full")
    return new_base


def merge_runs(session, ds: Dataset, start: int, end: int, level: int,
               manifest: Optional[Manifest] = None) -> Dataset:
    """Leveled-compaction step: fold the contiguous run segment
    ``runs[start:end]`` of ``manifest`` (default: the current one) into ONE
    run at ``level`` — O(segment), never touching the base. Newest-wins
    inside the segment is already encoded in each member's annihilation
    bookkeeping (a member's matter shadowed by any newer component — inside
    or outside the segment — is dropped here), and the merged run keeps the
    union of member anti-key sets: older components still need them to
    subtract at query time.

    Concurrency mirrors :func:`compact`: build off-lock against kill-set
    copies, CAS-validate that the member segment is still intact (by
    component identity), publish one new manifest with the merged run in
    the segment's slot — its stable uid is fresh; surviving neighbours keep
    their addresses. Anti keys of runs flushed mid-build reconcile against
    the merged run at swap time."""
    cat = session.catalog
    dv, name = ds.dataverse, ds.name
    ensure_soft(session, dv, name)  # kill-sets/host keys must be live
    t0 = time.perf_counter()
    tel.inc("lsm.compaction.attempts_total", kind="level")
    with cat.lock:
        m0 = manifest if manifest is not None else cat.manifest(dv, name)
        members = tuple(m0.runs[start:end])
        kills = [set(m.annihilated_keys) for m in members]
    key_col = m0.base.primary_index.column \
        if m0.base.primary_index is not None else None
    parts = [_visible_columns(c, key_col, kills[i])
             for i, c in enumerate(members)]
    names = list(parts[0])
    merged_cols = {k: np.concatenate([p[k] for p in parts], axis=0)
                   for k in names}
    anti_parts = [np.asarray(m.anti_keys_arr) for m in members
                  if m.anti_rows]
    anti_union = np.unique(np.concatenate(anti_parts)) if anti_parts else None
    _fault(session, "mid-merge")
    run = make_run(session, m0.base, Table(merged_cols), anti_keys=anti_union)
    run.level = level
    if cat.store is not None:
        cat.store.write_component(dv, name, run)  # off-lock, pre-CAS
    try:
        with cat.lock:
            cur = cat.manifest(dv, name)
            if cur.base is not m0.base:
                tel.inc("lsm.compaction.conflicts_total", kind="level")
                raise ManifestConflict(
                    f"{dv}.{name}: base swapped under a level merge "
                    f"(planned at lsn {m0.lsn}, now {cur.lsn})")
            try:
                s = cur.runs.index(members[0])  # identity: Dataset eq is
                #                                 id-based
            except ValueError:
                s = -1
            if s < 0 or tuple(cur.runs[s:s + len(members)]) != members:
                tel.inc("lsm.compaction.conflicts_total", kind="level")
                raise ManifestConflict(
                    f"{dv}.{name}: merged run segment no longer contiguous "
                    f"(planned at lsn {m0.lsn}, now {cur.lsn})")
            tail = cur.runs[s + len(members):]
            # matter annihilated by newer-than-segment components known at
            # build time was dropped above; tombstones that landed mid-build
            # replay here (occurrence-counted, so stats stay truthful)
            for newer in tail:
                if newer.anti_rows:
                    _annihilate_older((run,), newer, gather=False)
            _fault(session, "pre-swap")
            cat.publish(dv, name, cur.base, cur.runs[:s] + (run,) + tail)
            _fault(session, "post-swap")
    except ManifestConflict:
        if cat.store is not None:  # orphan segment: never committed
            cat.store.discard_component(dv, name, run)
        raise
    tel.inc("lsm.compactions_total", kind="level")
    tel.observe("lsm.compaction_seconds", time.perf_counter() - t0,
                kind="level")
    return run


# -- background compaction ---------------------------------------------------


class BackgroundCompactor:
    """Runs the compaction policies (size-ratio, leveled, read-amplification
    — the same triggers the synchronous path uses) on a worker thread, off
    the ingest hot path. Writers call :meth:`notify` after each flush; the
    worker drains notified datasets to policy quiescence.

    Every merge builds fresh components entirely OFF the catalog lock and
    commits with one CAS-validated atomic manifest swap, so:

      * readers never block — a query's snapshot capture takes the lock for
        O(datasets) metadata only, and a running merge holds the lock only
        for the swap itself;
      * a concurrent flush that invalidates the planned segment raises
        :class:`ManifestConflict` — the worker replans against the current
        manifest and retries with exponential backoff, bounded by
        ``max_retries`` consecutive failures per dataset;
      * an injected :class:`~repro.runtime.fault.StorageFault` aborts the
        attempt identically: hard state is untouched (the swap never
        happened, or happened atomically), so the retry rebuilds from
        intact components.

    Writers needing backpressure (Feed's write stall) call
    :meth:`wait_below`, which sleeps on the worker's progress condition
    until the dataset's run count drops under the cap.

    The pending queue is sharded **per dataverse**: each dataverse gets its
    own worker thread (created lazily at first notify), so one tenant's
    long O(base) merge can never starve another tenant's compaction —
    multi-tenant isolation at the compaction layer. Workers share one
    condition variable; ``wait_idle``/``close`` span all of them."""

    def __init__(self, session, policy: Optional[CompactionPolicy] = None,
                 max_retries: int = 5, backoff_s: float = 0.002):
        self.session = session
        self.policy = policy if policy is not None else CompactionPolicy()
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.stats = {"level_merges": 0, "compactions": 0, "conflicts": 0,
                      "retries": 0, "faults": 0, "giveups": 0, "errors": 0}
        for k in self.stats:  # seed the mirrored registry series
            tel.inc(f"lsm.compactor.{k}_total", 0)
        self._cv = threading.Condition()
        # per-dataverse pending shards and their (lazily created) workers
        self._pending: dict[str, set[tuple[str, str]]] = {}
        self._inflight: dict[str, int] = {}
        self._threads: dict[str, threading.Thread] = {}
        self._stop = False

    # -- control -----------------------------------------------------------

    def notify(self, dataverse: str, name: str) -> None:
        """Mark a dataset dirty (a flush just published); returns at once.
        The notification lands on the dataset's dataverse shard, spawning
        that shard's worker on first use."""
        with self._cv:
            if self._stop:
                return
            self._pending.setdefault(dataverse, set()).add((dataverse, name))
            if dataverse not in self._threads:
                t = threading.Thread(
                    target=self._worker, args=(dataverse,), daemon=True,
                    name=f"lsm-compactor-{dataverse}")
                self._threads[dataverse] = t
                t.start()
                tel.set_gauge("lsm.compactor.workers", len(self._threads))
            self._cv.notify_all()

    def wait_idle(self, timeout: float = 30.0) -> bool:
        """Block until every dataverse worker has drained its notifications
        (tests and benchmarks use this as a barrier). True if all went idle
        in time."""
        deadline = time.perf_counter() + timeout
        with self._cv:
            while any(self._pending.values()) or any(self._inflight.values()):
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    return False
                self._cv.wait(min(remaining, 0.05))
        return True

    def wait_below(self, dataverse: str, name: str, cap: int,
                   timeout: float) -> float:
        """Write-stall backpressure: block until the dataset's run count
        drops below ``cap`` (or timeout). Returns seconds stalled."""
        t0 = time.perf_counter()
        with self._cv:
            while not self._stop:
                try:
                    n = len(self.session.catalog.manifest(dataverse, name).runs)
                except KeyError:
                    break
                if n < cap:
                    break
                remaining = timeout - (time.perf_counter() - t0)
                if remaining <= 0:
                    break
                self._cv.wait(min(remaining, 0.05))
        return time.perf_counter() - t0

    def close(self) -> None:
        with self._cv:
            self._stop = True
            self._cv.notify_all()
            threads = list(self._threads.values())
        for t in threads:
            t.join(timeout=30.0)

    def __enter__(self) -> "BackgroundCompactor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- workers (one per dataverse) ---------------------------------------

    def _worker(self, dataverse: str) -> None:
        while True:
            with self._cv:
                while not self._pending.get(dataverse) and not self._stop:
                    self._cv.wait()
                if self._stop:
                    return
                key = self._pending[dataverse].pop()
                self._inflight[dataverse] = \
                    self._inflight.get(dataverse, 0) + 1
            try:
                self._drain(key)
            finally:
                with self._cv:
                    self._inflight[dataverse] -= 1
                    self._cv.notify_all()

    def _drain(self, key: tuple[str, str]) -> None:
        """Run the policy to quiescence for one dataset: each iteration
        replans against the CURRENT manifest (a lost CAS or injected fault
        backs off and replans; merges may cascade across levels)."""
        cat = self.session.catalog
        failures = 0
        delay = self.backoff_s
        while not self._stop:
            try:
                base = cat.get(*key)
            except KeyError:
                return  # dataset dropped
            m = base.manifest
            actions = self.policy.plan(_ManifestView(base, m))
            if not actions:
                return
            act = actions[0]
            try:
                if act[0] == "full":
                    compact(self.session, base, manifest=m)
                    self._bump("compactions")
                else:
                    _, s, e, level = act
                    merge_runs(self.session, base, s, e, level, manifest=m)
                    self._bump("level_merges")
                failures = 0
                delay = self.backoff_s
            except ManifestConflict:
                self._bump("conflicts")
                failures += 1
            except StorageFault:
                self._bump("faults")
                failures += 1
            except Exception:  # pragma: no cover - defensive: keep serving
                self._bump("errors")
                return
            finally:
                with self._cv:
                    self._cv.notify_all()  # progress signal for stalled writers
            if failures:
                if failures > self.max_retries:
                    self._bump("giveups")
                    return  # dataset stays serveable, just under-compacted
                self._bump("retries")
                time.sleep(delay)
                delay *= 2

    def _bump(self, key: str) -> None:
        """One compactor event: the local dict (the back-compat ``stats``
        surface tests read) and its registry mirror move together."""
        self.stats[key] += 1
        tel.inc(f"lsm.compactor.{key}_total")


# -- crash recovery: rebuild soft state from hard state -----------------------


def recover(session, dataverse: str, name: str, lazy: bool = False) -> None:
    """Crash recovery: rebuild every component's SOFT state from its HARD
    state — the split the fault-injection tests assert.

    Hard state (survives an injected crash at any fault point): each
    component's columnar table — matter rows, anti-matter rows with the
    ``__antimatter__`` flag and the key column, the ``__valid__`` mask —
    plus the manifest itself (swapped atomically: after a crash it is
    either the old or the new one, never half of each) and the index
    INVENTORY (which columns, which kinds).

    Soft state (rebuilt here): index payloads (sorted keys / row ids / zone
    arrays), block zone maps, host-side clustered-key and anti-key copies,
    the annihilation bookkeeping (replayed newest-wins in manifest order),
    and materialized-view partials (reseeded from visible rows).

    With ``lazy`` the rebuild is only MARKED: each component flips
    ``soft_stale`` and the dataset joins ``catalog.stale``; the first bind
    (query, point lookup, flush, compaction, view seed) pays the rebuild
    via :func:`ensure_soft`. Cold start over a large catalog is then
    dominated by manifest load + WAL replay, not index builds."""
    cat = session.catalog
    if lazy:
        with cat.lock:
            m = cat.manifest(dataverse, name)
            for comp in m.components:
                comp.soft_stale = True
            cat.stale.add((dataverse, name))
        return
    with cat.lock:
        m = cat.manifest(dataverse, name)
    for comp in m.components:
        _rebuild_soft(session, comp)
        comp.soft_stale = False
    with cat.lock:
        for i, run in enumerate(m.runs):
            if run.anti_rows:
                _annihilate_older((m.base,) + tuple(m.runs[:i]), run,
                                  gather=False)
        cat.stale.discard((dataverse, name))
        cat.bump_stats_epoch()
    session.reseed_views(dataverse, name)


def ensure_soft(session, dataverse: str, name: str) -> None:
    """First-bind hook of the lazy rebuild: if the dataset carries
    soft-stale components (cold-start mounts), rebuild their soft state now
    — indexes, zone maps, host key copies, anti arrays — and replay the
    annihilation bookkeeping newest-wins across the whole component chain.
    O(1) when nothing is stale (one set-membership probe), so every bind
    site calls it unconditionally."""
    cat = session.catalog
    if (dataverse, name) not in cat.stale:
        return
    with cat.lock:
        if (dataverse, name) not in cat.stale:
            return  # another binder won the race
        try:
            m = cat.manifest(dataverse, name)
        except KeyError:
            cat.stale.discard((dataverse, name))
            return
        t0 = time.perf_counter()
        for comp in m.components:
            if comp.soft_stale:
                _rebuild_soft(session, comp)
                comp.soft_stale = False
        # annihilation bookkeeping is cross-component: replay the full
        # chain in manifest order (idempotent for freshly-zeroed sets)
        for i, run in enumerate(m.runs):
            if run.anti_rows:
                _annihilate_older((m.base,) + tuple(m.runs[:i]), run,
                                  gather=False)
        cat.stale.discard((dataverse, name))
        cat.bump_stats_epoch()
    tel.inc("storage.lazy_rebuilds_total")
    tel.observe("storage.lazy_rebuild_seconds", time.perf_counter() - t0)


def _rebuild_soft(session, comp: Dataset) -> None:
    """Rebuild one component's soft state from its table columns: the same
    passes create_dataset/make_run run at build time, so the rebuilt state
    is bit-identical to the pre-crash state."""
    from repro.core.stats import harvest_block_zones, mesh_shards

    t = comp.table
    valid = np.asarray(t.valid)
    anti_col = t.columns.get("__antimatter__")
    anti_mask = np.asarray(anti_col) if anti_col is not None \
        else np.zeros(t.num_rows, bool)
    comp.live_rows = int(valid.sum())
    comp.annihilated_rows = 0
    comp.annihilated_keys = set()
    primary_col = None
    for ix in comp.indexes.values():
        if ix.kind == "primary":
            primary_col = ix.column
    comp.anti_rows = int(anti_mask.sum())
    if comp.anti_rows and primary_col is not None:
        anti_sorted = np.sort(np.asarray(t.columns[primary_col])[anti_mask])
        comp.anti_keys_arr = jnp.asarray(anti_sorted)
        comp.host_anti_keys = anti_sorted
    else:
        comp.anti_keys_arr = None
        comp.host_anti_keys = None
    if primary_col is not None:
        # matter prefix is clustered: masking preserves the sorted order
        comp.host_keys = np.asarray(t.columns[primary_col])[valid]
    comp.block_zones = harvest_block_zones(
        t, mesh_shards(session.mesh, session.data_axes))
    for key, ix in list(comp.indexes.items()):
        comp.indexes[key] = session._build_index(t, ix.column, ix.kind)


# -- incrementally-maintained materialized views ----------------------------

_VIEW_OPS = ("count", "sum", "mean", "max", "min")


class MaterializedView:
    """A continuously-maintained group-by aggregate over a fed dataset (the
    paper's live Twitter dashboard). State is dense per-group partials over a
    dynamically-widening key domain; each flush applies only the delta batch.
    ``result()`` matches a from-scratch group-by query bit-for-bit for
    integer columns (sums tracked in int64/float64, means divided in f32
    exactly like the query path)."""

    def __init__(self, name: str, dataverse: str, dataset: str, key: str,
                 aggs, predicate=None):
        for s in aggs:
            if s.op not in _VIEW_OPS:
                raise ValueError(f"view aggregate {s.op!r} not in {_VIEW_OPS}")
        self.name = name
        self.dataverse, self.dataset = dataverse, dataset
        self.key = key
        self.aggs = list(aggs)
        self.predicate = None
        if predicate is not None:
            self.predicate = copy.deepcopy(predicate)
            for lit in self.predicate.literals():
                lit.slot = None  # evaluate un-parameterized on delta batches
        self._sum_cols = []
        self._max_cols, self._min_cols = [], []
        for s in self.aggs:
            if s.op in ("sum", "mean") and s.column not in self._sum_cols:
                self._sum_cols.append(s.column)
            elif s.op == "max" and s.column not in self._max_cols:
                self._max_cols.append(s.column)
            elif s.op == "min" and s.column not in self._min_cols:
                self._min_cols.append(s.column)
        self.lo: Optional[int] = None
        self._counts: Optional[np.ndarray] = None
        self._sums: dict[str, np.ndarray] = {}
        self._maxs: dict[str, np.ndarray] = {}
        self._mins: dict[str, np.ndarray] = {}
        self._key_dtype = None
        self._dtypes: dict[str, np.dtype] = {}
        self.stats = {"refreshes": 0, "rows_applied": 0,
                      "kernel_batches": 0, "exact_fallback_batches": 0,
                      "retractions": 0, "rows_retracted": 0,
                      "extremum_recomputes": 0}

    @classmethod
    def from_plan(cls, name: str, plan: P.Plan) -> "MaterializedView":
        """Accepts GroupAgg(keys=[k], aggs) over Scan or Filter(Scan)."""
        if not isinstance(plan, P.GroupAgg) or len(plan.keys) != 1:
            raise ValueError(
                "create_view needs a single-key group-by aggregate "
                "(df.groupby(key).agg(...)-shaped plan)")
        child = plan.children[0]
        predicate = None
        if isinstance(child, P.Filter):
            predicate = child.predicate
            child = child.children[0]
        if not isinstance(child, P.Scan) or "@" in child.dataset:
            raise ValueError(
                "create_view supports GroupAgg over a (optionally filtered) "
                "dataset scan")
        return cls(name, child.dataverse, child.dataset, plan.keys[0],
                   list(plan.aggs), predicate)

    # -- state ------------------------------------------------------------

    def reset(self) -> None:
        """Drop the materialized partials (view state is SOFT state):
        recovery reseeds from the dataset's visible rows, exactly like
        create_view's initial seed."""
        self.lo = None
        self._counts = None
        self._sums, self._maxs, self._mins = {}, {}, {}
        self._key_dtype = None
        self._dtypes = {}

    def _ensure_domain(self, klo: int, khi: int) -> None:
        if self._counts is None:
            self.lo = klo
            g = khi - klo + 1
            self._counts = np.zeros(g, np.int64)
            self._sums = {c: np.zeros(g, np.float64) for c in self._sum_cols}
            self._maxs = {c: np.full(g, -np.inf) for c in self._max_cols}
            self._mins = {c: np.full(g, np.inf) for c in self._min_cols}
            return
        g = self._counts.shape[0]
        new_lo = min(self.lo, klo)
        new_hi = max(self.lo + g - 1, khi)
        if new_lo == self.lo and new_hi == self.lo + g - 1:
            return
        left, right = self.lo - new_lo, new_hi - (self.lo + g - 1)

        def grow(a, fill):
            return np.pad(a, (left, right), constant_values=fill)

        self._counts = grow(self._counts, 0)
        self._sums = {c: grow(a, 0.0) for c, a in self._sums.items()}
        self._maxs = {c: grow(a, -np.inf) for c, a in self._maxs.items()}
        self._mins = {c: grow(a, np.inf) for c, a in self._mins.items()}
        self.lo = new_lo

    def _delta_exact_for_kernel(self, n: int, cols: dict[str, np.ndarray],
                                live: np.ndarray) -> bool:
        """Same exactness reasoning as the kernel execution mode's group-agg
        gate, but against the *actual* delta batch: f32 partials are
        bit-exact when every per-group count/sum/extreme stays an integer
        below 2^24."""
        if n >= _F32_EXACT:
            return False
        for c in self._sum_cols + self._max_cols + self._min_cols:
            a = cols[c]
            if not np.issubdtype(a.dtype, np.integer):
                return False
            vals = a[live]
            maxabs = int(np.abs(vals).max()) if vals.size else 0
            bound = n * maxabs if c in self._sum_cols else maxabs
            if bound >= _F32_EXACT:
                return False
        return True

    def apply_delta(self, cols: dict[str, np.ndarray],
                    valid: Optional[np.ndarray] = None) -> None:
        n = len(next(iter(cols.values())))
        self.stats["refreshes"] += 1
        if n == 0:
            return
        live = np.ones(n, bool) if valid is None else np.asarray(valid, bool).copy()
        if self.predicate is not None:
            env = {k: jnp.asarray(v) for k, v in cols.items()}
            live &= np.asarray(self.predicate.evaluate(env, []), bool)
        if not live.any():
            return
        keys = np.asarray(cols[self.key])
        self._key_dtype = keys.dtype
        for c in self._sum_cols + self._max_cols + self._min_cols:
            self._dtypes[c] = np.asarray(cols[c]).dtype
        kl = keys[live]
        self._ensure_domain(int(kl.min()), int(kl.max()))
        g = self._counts.shape[0]
        gid = np.where(live, keys.astype(np.int64) - self.lo, -1).astype(np.int32)
        self.stats["rows_applied"] += int(live.sum())
        if self._delta_exact_for_kernel(n, cols, live):
            self._apply_kernel(cols, gid, g, n)
        else:
            self._apply_exact(cols, gid, live, g)

    def _apply_kernel(self, cols, gid, g, n) -> None:
        """Delta partials via the segment_agg kernel path (one fused sum
        launch + one launch per extreme family), merged into int64/float64
        state — the same launch shapes a flush-sized GroupAgg would run."""
        from repro.kernels import ops as kops

        self.stats["kernel_batches"] += 1
        gid_j = jnp.asarray(gid)
        tiles = [jnp.ones(n, jnp.float32)]
        tiles += [jnp.asarray(cols[c]).astype(jnp.float32) for c in self._sum_cols]
        part = np.asarray(kops.segment_agg(jnp.stack(tiles, axis=1), gid_j, g, n))
        self._counts += part[:, 0].astype(np.int64)
        for i, c in enumerate(self._sum_cols):
            self._sums[c] += part[:, 1 + i].astype(np.float64)
        if self._max_cols:
            vals = jnp.stack([jnp.asarray(cols[c]).astype(jnp.float32)
                              for c in self._max_cols], axis=1)
            part = np.asarray(kops.segment_agg(vals, gid_j, g, n, op="max"))
            for i, c in enumerate(self._max_cols):
                np.maximum(self._maxs[c], part[:, i].astype(np.float64),
                           out=self._maxs[c])
        if self._min_cols:
            vals = jnp.stack([jnp.asarray(cols[c]).astype(jnp.float32)
                              for c in self._min_cols], axis=1)
            part = np.asarray(kops.segment_agg(vals, gid_j, g, n, op="min"))
            for i, c in enumerate(self._min_cols):
                np.minimum(self._mins[c], part[:, i].astype(np.float64),
                           out=self._mins[c])

    def apply_retraction(self, cols: dict[str, np.ndarray],
                         recompute=None) -> None:
        """Retract rows previously applied (their OLD values — the matter a
        flush's anti-matter just annihilated). Counts and sums take exact
        negative deltas (int64/float64 state); means follow for free. A
        retracted group max/min is *not* subtractable: when a retracted
        value touches the stored extremum, ``recompute(op, column, keys)``
        — the exact host fallback the Session provides, scanning the
        dataset's current visible rows — repairs exactly the affected
        groups. Groups whose count hits zero reset to identity so future
        inserts re-aggregate from scratch."""
        n = len(next(iter(cols.values()))) if cols else 0
        if n == 0 or self._counts is None:
            return
        self.stats["retractions"] += 1
        live = np.ones(n, bool)
        if self.predicate is not None:
            env = {k: jnp.asarray(v) for k, v in cols.items()}
            live &= np.asarray(self.predicate.evaluate(env, []), bool)
        if not live.any():
            return
        keys = np.asarray(cols[self.key])
        kl = keys[live]
        self._ensure_domain(int(kl.min()), int(kl.max()))
        g = self._counts.shape[0]
        ix = (kl.astype(np.int64) - self.lo).astype(np.int64)
        self.stats["rows_retracted"] += int(live.sum())
        self._counts -= np.bincount(ix, minlength=g).astype(np.int64)
        for c in self._sum_cols:
            vals = np.asarray(cols[c])[live].astype(np.float64)
            self._sums[c] -= np.bincount(ix, weights=vals, minlength=g)
        emptied = self._counts <= 0
        for c, op, state in [(c, "max", self._maxs) for c in self._max_cols] \
                + [(c, "min", self._mins) for c in self._min_cols]:
            vals = np.asarray(cols[c])[live].astype(np.float64)
            # groups where a retracted value ties the stored extremum: the
            # extremum may have just left the group — recompute those exactly
            hit = np.zeros(g, bool)
            touched = vals >= state[c][ix] if op == "max" else vals <= state[c][ix]
            hit[ix[touched]] = True
            hit &= ~emptied  # empty groups just reset below
            if hit.any():
                if recompute is None:
                    raise ValueError(
                        f"view {self.name!r}: retraction touched a group "
                        f"{op} and no exact recompute fallback is available")
                self.stats["extremum_recomputes"] += 1
                group_keys = (self.lo + np.nonzero(hit)[0]).astype(np.int64)
                state[c][hit] = recompute(op, c, group_keys)
            state[c][emptied] = -np.inf if op == "max" else np.inf
        for c in self._sum_cols:
            self._sums[c][emptied] = 0.0
        self._counts[emptied] = 0

    def _apply_exact(self, cols, gid, live, g) -> None:
        """Native-dtype host fallback when f32 exactness cannot be proven
        (float columns, huge batches): bincount sums in float64 (exact to
        2^53) + ufunc.at extremes."""
        self.stats["exact_fallback_batches"] += 1
        ix = gid[live]
        self._counts += np.bincount(ix, minlength=g).astype(np.int64)
        for c in self._sum_cols:
            vals = np.asarray(cols[c])[live].astype(np.float64)
            self._sums[c] += np.bincount(ix, weights=vals, minlength=g)
        for c in self._max_cols:
            np.maximum.at(self._maxs[c], ix, np.asarray(cols[c])[live])
        for c in self._min_cols:
            np.minimum.at(self._mins[c], ix, np.asarray(cols[c])[live])

    def result(self) -> dict[str, np.ndarray]:
        """The materialized group table (groups with at least one row), in
        the same dtypes the equivalent group-by query returns."""
        if self._counts is None:
            return {self.key: np.array([], dtype=np.int64),
                    **{s.out_name: np.array([]) for s in self.aggs}}
        live = self._counts > 0
        g = self._counts.shape[0]
        out = {self.key: (self.lo + np.arange(g))[live].astype(self._key_dtype)}
        counts = self._counts[live]
        for s in self.aggs:
            if s.op == "count":
                out[s.out_name] = counts.astype(np.int32)
            elif s.op == "sum":
                out[s.out_name] = self._sums[s.column][live].astype(
                    self._dtypes[s.column])
            elif s.op == "mean":  # f32 sum / f32 count, as the query path
                out[s.out_name] = (self._sums[s.column][live].astype(np.float32)
                                   / counts.astype(np.float32))
            elif s.op == "max":
                out[s.out_name] = self._maxs[s.column][live].astype(
                    self._dtypes[s.column])
            else:
                out[s.out_name] = self._mins[s.column][live].astype(
                    self._dtypes[s.column])
        return out
