"""LSM storage for streaming ingestion — AsterixDB's feed path on the
JAX/Pallas engine (paper §III-A).

AsterixDB feeds append to LSM components with online index maintenance; the
device-resident analogue here:

  * a **flush** turns the host buffer into a *run*: a block-padded (and
    mesh-sharded) columnar Table with its own sorted secondary indexes and
    zone maps, registered beside the base table. Flush cost is O(batch),
    never O(base).
  * queries over a fed dataset execute as **base ∪ runs** (the ``UnionRuns``
    plan node): per-component index probes / kernel launches, one final
    merge — results are identical to querying the compacted dataset.
  * **compaction** is deferred until a size-ratio policy fires, then merges
    every component into the base with a single re-shard + re-sort + index
    rebuild (the only O(base) step, amortized over many flushes).
  * **materialized views** (``Session.create_view``) are group-by aggregates
    maintained *incrementally*: each flush runs only the delta batch through
    the ``segment_agg`` path and merges partial aggregates — the paper's
    live-dashboard scenario. The f32 kernel path is gated by the same
    exactness reasoning the kernel execution mode uses; batches that cannot
    be proven exact fall back to native-dtype host reduction.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import plan as P
from repro.core.catalog import Dataset, open_widen
from repro.engine.table import ColumnMeta, Table, pad_to_block

RUN_BLOCK = 1024      # runs are padded to this row multiple
_F32_EXACT = 1 << 24  # every int in [-2^24, 2^24] is exactly representable


@dataclasses.dataclass(frozen=True)
class CompactionPolicy:
    """Deferred-compaction trigger (AsterixDB's size-ratio merge policy
    analogue): compact when accumulated run rows reach ``size_ratio`` × base
    rows, or when more than ``max_runs`` components pile up. ``size_ratio=0``
    degenerates to compact-every-flush (the benchmark baseline)."""

    size_ratio: float = 1.0
    max_runs: int = 8


def should_compact(ds: Dataset, policy: CompactionPolicy) -> bool:
    if not ds.runs:
        return False
    if len(ds.runs) > policy.max_runs:
        return True
    run_rows = sum(r.num_live_rows for r in ds.runs)
    return run_rows >= policy.size_ratio * max(ds.num_live_rows, 1)


# -- runs -------------------------------------------------------------------


def make_run(session, base: Dataset, table: Table) -> Dataset:
    """Build one device-resident run from a flush batch: stats → (optional)
    open-widen → sort by the base's primary → block-pad (+shard) → per-run
    sorted secondary indexes with zone maps. O(batch) throughout."""
    from repro.engine.session import _collect_stats

    live = table.num_rows
    table = _collect_stats(table)
    if not base.closed:
        table = open_widen(table)
    primary = base.primary_index
    if primary is not None:
        order = np.argsort(np.asarray(table.columns[primary.column]),
                           kind="stable")
        cols = {k: np.asarray(v)[order] for k, v in table.columns.items()}
        meta = dict(table.meta)
        m = meta[primary.column]
        meta[primary.column] = ColumnMeta(m.dtype, m.lo, m.hi, m.distinct,
                                          m.is_string, True)
        table = Table(cols, meta, table.num_rows)
    table = pad_to_block(table, RUN_BLOCK)
    if session.mesh is not None:
        table = table.shard(session.mesh, session.data_axes)
    run = Dataset(name=f"{base.name}@run{len(base.runs)}",
                  dataverse=base.dataverse, table=table, closed=base.closed,
                  live_rows=live)
    if primary is not None:
        run.indexes["primary"] = session._build_index(table, primary.column,
                                                      "primary")
    for ix in base.indexes.values():
        if ix.kind == "secondary":
            run.indexes[f"ix_{ix.column}"] = session._build_index(
                table, ix.column, "secondary")
    return run


def register_run(session, base: Dataset, run: Dataset) -> None:
    """Attach the run and bump the catalog's statistics epoch: the LSM
    component set is baked into optimized plans (UnionRuns fans out per
    component) and every level of the Session plan cache is keyed by the
    epoch, so cached executables for the old component set become
    unreachable — queries rebind against base ∪ runs including this one."""
    base.runs.append(run)
    session.catalog.bump_stats_epoch()


def _valid_columns(table: Table) -> dict[str, np.ndarray]:
    valid = np.asarray(table.valid)
    return {k: np.asarray(v)[valid] for k, v in table.columns.items()
            if k != "__valid__"}


def _merge_meta(metas: list[ColumnMeta], total_rows: int) -> ColumnMeta:
    base = metas[0]
    lo = hi = distinct = None
    bounded = all(m.lo is not None and m.hi is not None for m in metas)
    if bounded:
        lo = min(m.lo for m in metas)
        hi = max(m.hi for m in metas)
    if all(m.distinct is not None for m in metas):
        # summing per-component distincts is only a TRUE distinct count when
        # the components cannot share values (pairwise-disjoint ranges) —
        # otherwise it saturates at the row count and would falsely certify
        # a duplicated key as unique to the materializing-join guard. With
        # possible overlap only max(component distinct) is provable.
        spans = sorted((m.lo, m.hi) for m in metas) if bounded else []
        disjoint = bool(spans) and all(
            spans[i][1] < spans[i + 1][0] for i in range(len(spans) - 1))
        if len(metas) == 1 or disjoint:
            distinct = min(sum(m.distinct for m in metas), total_rows)
        else:
            distinct = max(m.distinct for m in metas)
    return ColumnMeta(base.dtype, lo, hi, distinct, base.is_string, False)


def compact(session, ds: Dataset) -> Dataset:
    """Fold base ∪ runs into a fresh base: one host merge, one re-shard, one
    re-sort, one index rebuild — instead of doing all of that per flush.
    Component stats merge so the catalog bounds stay truthful for the new
    key/value domains the runs introduced."""
    parts = [_valid_columns(ds.table)] + [_valid_columns(r.table) for r in ds.runs]
    names = list(parts[0])
    merged = {k: np.concatenate([p[k] for p in parts], axis=0) for k in names}
    total = len(next(iter(merged.values()))) if names else 0
    metas = [ds.table.meta] + [r.table.meta for r in ds.runs]
    meta = {k: _merge_meta([mm[k] for mm in metas], total) for k in names}
    secondary = [ix.column for ix in ds.indexes.values() if ix.kind == "secondary"]
    primary = ds.primary_index.column if ds.primary_index is not None else None
    return session.create_dataset(ds.name, Table(merged, meta),
                                  dataverse=ds.dataverse, closed=ds.closed,
                                  indexes=secondary, primary=primary)


# -- incrementally-maintained materialized views ----------------------------

_VIEW_OPS = ("count", "sum", "mean", "max", "min")


class MaterializedView:
    """A continuously-maintained group-by aggregate over a fed dataset (the
    paper's live Twitter dashboard). State is dense per-group partials over a
    dynamically-widening key domain; each flush applies only the delta batch.
    ``result()`` matches a from-scratch group-by query bit-for-bit for
    integer columns (sums tracked in int64/float64, means divided in f32
    exactly like the query path)."""

    def __init__(self, name: str, dataverse: str, dataset: str, key: str,
                 aggs, predicate=None):
        for s in aggs:
            if s.op not in _VIEW_OPS:
                raise ValueError(f"view aggregate {s.op!r} not in {_VIEW_OPS}")
        self.name = name
        self.dataverse, self.dataset = dataverse, dataset
        self.key = key
        self.aggs = list(aggs)
        self.predicate = None
        if predicate is not None:
            self.predicate = copy.deepcopy(predicate)
            for lit in self.predicate.literals():
                lit.slot = None  # evaluate un-parameterized on delta batches
        self._sum_cols = []
        self._max_cols, self._min_cols = [], []
        for s in self.aggs:
            if s.op in ("sum", "mean") and s.column not in self._sum_cols:
                self._sum_cols.append(s.column)
            elif s.op == "max" and s.column not in self._max_cols:
                self._max_cols.append(s.column)
            elif s.op == "min" and s.column not in self._min_cols:
                self._min_cols.append(s.column)
        self.lo: Optional[int] = None
        self._counts: Optional[np.ndarray] = None
        self._sums: dict[str, np.ndarray] = {}
        self._maxs: dict[str, np.ndarray] = {}
        self._mins: dict[str, np.ndarray] = {}
        self._key_dtype = None
        self._dtypes: dict[str, np.dtype] = {}
        self.stats = {"refreshes": 0, "rows_applied": 0,
                      "kernel_batches": 0, "exact_fallback_batches": 0}

    @classmethod
    def from_plan(cls, name: str, plan: P.Plan) -> "MaterializedView":
        """Accepts GroupAgg(keys=[k], aggs) over Scan or Filter(Scan)."""
        if not isinstance(plan, P.GroupAgg) or len(plan.keys) != 1:
            raise ValueError(
                "create_view needs a single-key group-by aggregate "
                "(df.groupby(key).agg(...)-shaped plan)")
        child = plan.children[0]
        predicate = None
        if isinstance(child, P.Filter):
            predicate = child.predicate
            child = child.children[0]
        if not isinstance(child, P.Scan) or "@" in child.dataset:
            raise ValueError(
                "create_view supports GroupAgg over a (optionally filtered) "
                "dataset scan")
        return cls(name, child.dataverse, child.dataset, plan.keys[0],
                   list(plan.aggs), predicate)

    # -- state ------------------------------------------------------------

    def _ensure_domain(self, klo: int, khi: int) -> None:
        if self._counts is None:
            self.lo = klo
            g = khi - klo + 1
            self._counts = np.zeros(g, np.int64)
            self._sums = {c: np.zeros(g, np.float64) for c in self._sum_cols}
            self._maxs = {c: np.full(g, -np.inf) for c in self._max_cols}
            self._mins = {c: np.full(g, np.inf) for c in self._min_cols}
            return
        g = self._counts.shape[0]
        new_lo = min(self.lo, klo)
        new_hi = max(self.lo + g - 1, khi)
        if new_lo == self.lo and new_hi == self.lo + g - 1:
            return
        left, right = self.lo - new_lo, new_hi - (self.lo + g - 1)

        def grow(a, fill):
            return np.pad(a, (left, right), constant_values=fill)

        self._counts = grow(self._counts, 0)
        self._sums = {c: grow(a, 0.0) for c, a in self._sums.items()}
        self._maxs = {c: grow(a, -np.inf) for c, a in self._maxs.items()}
        self._mins = {c: grow(a, np.inf) for c, a in self._mins.items()}
        self.lo = new_lo

    def _delta_exact_for_kernel(self, n: int, cols: dict[str, np.ndarray],
                                live: np.ndarray) -> bool:
        """Same exactness reasoning as the kernel execution mode's group-agg
        gate, but against the *actual* delta batch: f32 partials are
        bit-exact when every per-group count/sum/extreme stays an integer
        below 2^24."""
        if n >= _F32_EXACT:
            return False
        for c in self._sum_cols + self._max_cols + self._min_cols:
            a = cols[c]
            if not np.issubdtype(a.dtype, np.integer):
                return False
            vals = a[live]
            maxabs = int(np.abs(vals).max()) if vals.size else 0
            bound = n * maxabs if c in self._sum_cols else maxabs
            if bound >= _F32_EXACT:
                return False
        return True

    def apply_delta(self, cols: dict[str, np.ndarray],
                    valid: Optional[np.ndarray] = None) -> None:
        n = len(next(iter(cols.values())))
        self.stats["refreshes"] += 1
        if n == 0:
            return
        live = np.ones(n, bool) if valid is None else np.asarray(valid, bool).copy()
        if self.predicate is not None:
            env = {k: jnp.asarray(v) for k, v in cols.items()}
            live &= np.asarray(self.predicate.evaluate(env, []), bool)
        if not live.any():
            return
        keys = np.asarray(cols[self.key])
        self._key_dtype = keys.dtype
        for c in self._sum_cols + self._max_cols + self._min_cols:
            self._dtypes[c] = np.asarray(cols[c]).dtype
        kl = keys[live]
        self._ensure_domain(int(kl.min()), int(kl.max()))
        g = self._counts.shape[0]
        gid = np.where(live, keys.astype(np.int64) - self.lo, -1).astype(np.int32)
        self.stats["rows_applied"] += int(live.sum())
        if self._delta_exact_for_kernel(n, cols, live):
            self._apply_kernel(cols, gid, g, n)
        else:
            self._apply_exact(cols, gid, live, g)

    def _apply_kernel(self, cols, gid, g, n) -> None:
        """Delta partials via the segment_agg kernel path (one fused sum
        launch + one launch per extreme family), merged into int64/float64
        state — the same launch shapes a flush-sized GroupAgg would run."""
        from repro.kernels import ops as kops

        self.stats["kernel_batches"] += 1
        gid_j = jnp.asarray(gid)
        tiles = [jnp.ones(n, jnp.float32)]
        tiles += [jnp.asarray(cols[c]).astype(jnp.float32) for c in self._sum_cols]
        part = np.asarray(kops.segment_agg(jnp.stack(tiles, axis=1), gid_j, g, n))
        self._counts += part[:, 0].astype(np.int64)
        for i, c in enumerate(self._sum_cols):
            self._sums[c] += part[:, 1 + i].astype(np.float64)
        if self._max_cols:
            vals = jnp.stack([jnp.asarray(cols[c]).astype(jnp.float32)
                              for c in self._max_cols], axis=1)
            part = np.asarray(kops.segment_agg(vals, gid_j, g, n, op="max"))
            for i, c in enumerate(self._max_cols):
                np.maximum(self._maxs[c], part[:, i].astype(np.float64),
                           out=self._maxs[c])
        if self._min_cols:
            vals = jnp.stack([jnp.asarray(cols[c]).astype(jnp.float32)
                              for c in self._min_cols], axis=1)
            part = np.asarray(kops.segment_agg(vals, gid_j, g, n, op="min"))
            for i, c in enumerate(self._min_cols):
                np.minimum(self._mins[c], part[:, i].astype(np.float64),
                           out=self._mins[c])

    def _apply_exact(self, cols, gid, live, g) -> None:
        """Native-dtype host fallback when f32 exactness cannot be proven
        (float columns, huge batches): bincount sums in float64 (exact to
        2^53) + ufunc.at extremes."""
        self.stats["exact_fallback_batches"] += 1
        ix = gid[live]
        self._counts += np.bincount(ix, minlength=g).astype(np.int64)
        for c in self._sum_cols:
            vals = np.asarray(cols[c])[live].astype(np.float64)
            self._sums[c] += np.bincount(ix, weights=vals, minlength=g)
        for c in self._max_cols:
            np.maximum.at(self._maxs[c], ix, np.asarray(cols[c])[live])
        for c in self._min_cols:
            np.minimum.at(self._mins[c], ix, np.asarray(cols[c])[live])

    def result(self) -> dict[str, np.ndarray]:
        """The materialized group table (groups with at least one row), in
        the same dtypes the equivalent group-by query returns."""
        if self._counts is None:
            return {self.key: np.array([], dtype=np.int64),
                    **{s.out_name: np.array([]) for s in self.aggs}}
        live = self._counts > 0
        g = self._counts.shape[0]
        out = {self.key: (self.lo + np.arange(g))[live].astype(self._key_dtype)}
        counts = self._counts[live]
        for s in self.aggs:
            if s.op == "count":
                out[s.out_name] = counts.astype(np.int32)
            elif s.op == "sum":
                out[s.out_name] = self._sums[s.column][live].astype(
                    self._dtypes[s.column])
            elif s.op == "mean":  # f32 sum / f32 count, as the query path
                out[s.out_name] = (self._sums[s.column][live].astype(np.float32)
                                   / counts.astype(np.float32))
            elif s.op == "max":
                out[s.out_name] = self._maxs[s.column][live].astype(
                    self._dtypes[s.column])
            else:
                out[s.out_name] = self._mins[s.column][live].astype(
                    self._dtypes[s.column])
        return out
