"""Session: the client's connection to the engine (the paper's AsterixDB
REST endpoint analogue). Owns the catalog, the mesh, the executable cache,
and the timing hooks the DataFrame benchmark reads (creation time vs
expression time, paper §IV-D).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections.abc import Mapping
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core import plan as P
from repro.core.catalog import (INTERNAL_COLUMNS, Catalog, Dataset, IndexInfo,
                                open_widen)
from repro.core.compiler import (CompiledQuery, ExecContext, compile_physical,
                                 compile_plan)
from repro.core.optimizer import optimize
from repro.core.physical_planner import build_pruner, plan_physical
from repro.engine.table import Table
from repro.runtime import telemetry as tel

try:
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

from jax.sharding import PartitionSpec as PS


# Monotone per-process session ids: the `sid` label that keeps each
# session's series separate inside the process-wide registry.
_SESSION_IDS = itertools.count()


class _StatsView(Mapping):
    """``Session.stats`` as a read-only view over the telemetry registry.

    Same keys and values as the old seeded dict (``dict(sess.stats)`` and
    ``sess.stats["hits"]`` behave identically), but the counters live in ONE
    place — the registry — instead of being double-booked. ``hits`` sums the
    variant- and executable-level plan-cache hits (the two sites the old
    counter incremented at); entry-level hits are a separate, new series.
    ``point_lookups`` is seeded like every other key — the old dict left it
    unseeded and read it with ``.get``."""

    _KEYS = ("compiles", "hits", "optimizes", "plans",
             "pruned_components", "point_lookups")

    def __init__(self, sid: str):
        self._sid = sid

    def _value(self, key: str):
        if key == "hits":
            return (tel.counter_value("session.plan_cache.hits_total",
                                      level="variant", sid=self._sid)
                    + tel.counter_value("session.plan_cache.hits_total",
                                        level="executable", sid=self._sid))
        return tel.counter_value(f"session.{key}_total", sid=self._sid)

    def __getitem__(self, key: str):
        if key not in self._KEYS:
            raise KeyError(key)
        return self._value(key)

    def __iter__(self):
        return iter(self._KEYS)

    def __len__(self) -> int:
        return len(self._KEYS)

    def __repr__(self) -> str:
        return repr({k: self._value(k) for k in self._KEYS})


class _TimingsView(Mapping):
    """``Session.timings`` as a read-only view over the registry's last-*
    gauges. Fixed key set — the old dict grew one ``create:<dv>.<name>``
    key per dataset forever; per-dataset timing now lives in the
    ``session.create_dataset_seconds`` histogram series instead."""

    _GAUGES = {
        "last_execute": "session.last_execute_seconds",
        "last_point_lookup": "session.last_point_lookup_seconds",
        "last_create": "session.last_create_seconds",
        "last_view_recompute": "session.last_view_recompute_seconds",
    }

    def __init__(self, sid: str):
        self._sid = sid

    def __getitem__(self, key: str):
        name = self._GAUGES.get(key)
        v = tel.gauge_value(name, sid=self._sid) if name else None
        if v is None:
            raise KeyError(key)
        return v

    def __iter__(self):
        for key, name in self._GAUGES.items():
            if tel.gauge_value(name, sid=self._sid) is not None:
                yield key

    def __len__(self) -> int:
        return sum(1 for _ in self)

    def __repr__(self) -> str:
        return repr({k: self[k] for k in self})


@dataclasses.dataclass
class _PlanEntry:
    """One raw-fingerprint plan-cache entry, valid for a single
    (statistics epoch, manifest LSN) pair — a publish bumps both, so a
    stale entry can never resolve a retired component. ``variants`` is the
    third cache level: prune signature → (executable, literal binding)."""

    epoch: int
    lsn: int
    opt: P.Plan                  # optimized logical plan
    opt_fp: str
    raw_lits0: list              # the entry-creation call's literals (binding anchors)
    pruner: "object"             # physical_planner.Pruner
    variants: dict = dataclasses.field(default_factory=dict)


class Session:
    def __init__(self, mesh: Optional[Mesh] = None, mode: str = "auto",
                 data_axes: tuple[str, ...] = ("data",),
                 enable_index: bool = True, enable_pushdown: bool = True,
                 enable_prune: bool = True, enable_block_skip: bool = True,
                 kernel_backend: Optional[str] = None,
                 kernel_interpret: Optional[bool] = None,
                 catalog: Optional[Catalog] = None,
                 fault_plan: Optional[object] = None,
                 storage: Optional[object] = None):
        """mode: 'auto' (shard_map when a mesh is given), 'gspmd',
        'shard_map', or 'kernel' (the cost-based planner lowers fusable plan
        shapes onto the Pallas relational kernels; anything uncovered falls
        back to the gspmd / shard_map lowering).

        ``enable_prune`` turns bind-time zone-map run pruning on/off (off is
        only useful for benchmarking the pruning win); ``enable_block_skip``
        does the same for the intra-component block level (the surviving
        blocks of a predicate-constrained scan). Block skipping is fully
        shard-aware: zone maps are harvested per mesh row partition, the
        bind-time survivor list is re-based into per-shard local lists, and
        each shard's kernel grid / gather scans only its own survivors.

        ``kernel_backend`` feeds the kernels/ops dispatch: 'pallas' forces
        the Pallas kernels (interpret mode off-TPU), 'xla' the jnp twins;
        None picks pallas on TPU and the ops default elsewhere.
        ``kernel_interpret`` overrides the Pallas interpret auto-detection
        (None = compiled on TPU, interpret elsewhere).

        ``catalog`` shares another session's catalog (concurrent serving:
        reader sessions bind snapshots of a writer session's datasets; each
        session keeps its own plan caches). ``fault_plan`` arms the storage
        fault points (runtime/fault.py FaultPlan) for crash-consistency
        tests.

        ``storage`` attaches a durable store (runtime/durable.py): a
        DurableStore instance or a path to open one at. Every manifest
        publish then gains a durable-commit step (checksummed component
        segments + an atomically-renamed manifest generation) and feeds
        write an fsynced WAL — see ``Session.open`` for cold-start
        recovery of such a directory."""
        self.catalog = catalog if catalog is not None else Catalog()
        self.fault_plan = fault_plan
        self.storage = None
        if storage is not None:
            from repro.engine import lsm
            from repro.runtime.durable import DurableStore

            store = storage if isinstance(storage, DurableStore) \
                else DurableStore(storage)
            # the store's crash points consult THIS session's FaultPlan —
            # one fault source for in-memory and I/O points alike
            store._fault = lambda point: lsm._fault(self, point)
            self.catalog.attach_store(store)
            self.storage = store
        self.recovery_report: Optional[dict] = None
        self.mesh = mesh
        if mode == "auto":
            mode = "shard_map" if mesh is not None and mesh.devices.size > 1 else "gspmd"
        if mode == "local":  # historical alias for the single-program lowering
            mode = "gspmd"
        if mode not in ("gspmd", "shard_map", "kernel"):
            raise ValueError(f"unknown mode {mode!r}: "
                             "expected auto | gspmd | shard_map | kernel")
        if kernel_backend not in (None, "xla", "pallas"):
            raise ValueError(f"unknown kernel_backend {kernel_backend!r}: "
                             "expected None | xla | pallas")
        self.mode = mode
        if kernel_backend is None and mode == "kernel" \
                and jax.default_backend() == "tpu":
            kernel_backend = "pallas"
        self.kernel_backend = kernel_backend
        self.kernel_interpret = kernel_interpret
        self.data_axes = data_axes
        self.enable_index = enable_index
        self.enable_pushdown = enable_pushdown
        self.enable_prune = enable_prune
        self.enable_block_skip = enable_block_skip
        # Three-level plan cache:
        #   1. raw (pre-optimization) fingerprint → _PlanEntry, valid for one
        #      stats epoch: repeated query shapes skip the optimizer and the
        #      pruner *build* entirely;
        #   2. per entry, (stats_epoch, prune signature) → (executable,
        #      literal binding): randomized literals that keep the same
        #      surviving-run set rebind into the cached executable; literals
        #      that change which runs the zone maps prune rebuild only the
        #      physical plan (the optimizer output is reused);
        #   3. (physical fingerprint, epoch) → executable dedup across
        #      logical shapes (a point == and a range >=/<= predicate still
        #      share one compiled program, exactly like the old two-level
        #      cache).
        # Epoch keying is the invalidation mechanism: any flush / compaction
        # / DDL bumps catalog.stats_epoch, so a stale executable (which bakes
        # in shapes, access paths, and the LSM component set) can never run
        # against a changed catalog — a dropped run is unreachable.
        self._plans: dict[str, _PlanEntry] = {}
        self._compiled: dict[tuple, CompiledQuery] = {}
        # stats/timings are back-compat VIEWS over the registry, keyed by
        # this session's `sid` label. Counters are seeded here so every
        # series exists (and reads 0) before the first query.
        self.sid = str(next(_SESSION_IDS))
        for key in _StatsView._KEYS:
            if key == "hits":
                for level in ("entry", "variant", "executable"):
                    tel.inc("session.plan_cache.hits_total", 0,
                            level=level, sid=self.sid)
            else:
                tel.inc(f"session.{key}_total", 0, sid=self.sid)
        self.stats = _StatsView(self.sid)
        self.timings = _TimingsView(self.sid)
        # incrementally-maintained materialized views (engine/lsm.py),
        # refreshed from each feed flush's delta batch.
        self.views: dict[str, "object"] = {}

    # -- durable cold start --------------------------------------------------

    @classmethod
    def open(cls, path, lazy: bool = True, **kwargs) -> "Session":
        """Cold-start crash recovery: open a durable storage directory
        (``Session(storage=...).``'s on-disk layout) and reconstruct the
        catalog —

          1. load each dataset's newest checksum-valid manifest generation
             (a corrupt manifest or segment is quarantined and the previous
             generation serves instead — ``storage.corruption_total``);
          2. mount the component segments back onto the session's mesh and
             republish them (the catalog LSN resumes past the recovered
             high-water mark, run uids past the highest mounted uid);
          3. mark soft state for lazy rebuild-at-first-bind (``lazy=False``
             rebuilds indexes/zone maps eagerly, PR 6's ``recover``);
          4. replay the WAL tail — acked batches whose covering flush never
             committed — through the normal flush path, in order, skipping
             batches at or below the manifest's ``wal_upto`` (idempotence
             when the crash hit between commit and truncate).

        Returns the session with ``recovery_report`` populated. Raises
        ``StorageLockError`` if a live process holds the directory."""
        from repro.engine import ingest, lsm
        from repro.runtime.durable import DurableStore

        t0 = time.perf_counter()
        store = path if isinstance(path, DurableStore) else DurableStore(path)
        corrupt0 = tel.counter_value("storage.corruption_total") or 0
        sess = cls(storage=store, **kwargs)
        cat = sess.catalog
        report: dict = {"datasets": {}, "seconds": 0.0,
                        "corruption_events": 0, "wal_replayed_batches": 0}
        try:
            loads = []
            for dv, name in store.list_datasets():
                loads.append((dv, name) + store.load_dataset(dv, name))
            # restore the LSN high-water mark BEFORE any publish, so every
            # mounted generation commits with a strictly newer LSN than
            # anything already on disk
            with cat.lock:
                for dv, name, record, _, _ in loads:
                    cat.lsn = max(cat.lsn, int(record["lsn"]))
            for dv, name, record, segments, ds_report in loads:
                base = _mount_component(
                    sess, dv, record["base"]["seg"],
                    *segments[record["base"]["seg"]])
                runs = tuple(
                    _mount_component(sess, dv, r["seg"], *segments[r["seg"]])
                    for r in record["runs"])
                with cat.lock:
                    key = (dv, name)
                    max_uid = max((r.uid for r in runs), default=-1)
                    cat._run_uids[key] = max(cat._run_uids.get(key, 0),
                                             max_uid + 1)
                    cat.publish(dv, name, base, runs)
                lsm.recover(sess, dv, name, lazy=lazy)
                tail = store.wal_tail(dv, name)
                replayed = 0
                if tail:
                    # the replay feed IS the normal ingest path: validate,
                    # buffer, flush, publish — only WAL re-appends are off
                    lsm.ensure_soft(sess, dv, name)
                    feed = ingest.Feed(
                        sess, name, dv, flush_rows=1 << 62,
                        policy=lsm.CompactionPolicy(
                            size_ratio=float("inf"), max_runs=1 << 30))
                    feed._replay = True
                    for seq, kind, payload in tail:
                        lsm._fault(sess, "mid-replay")
                        if kind == "push":
                            feed.push(payload)
                        elif kind == "upsert":
                            feed.upsert(payload)
                        else:
                            feed.delete(payload["__keys__"])
                        replayed += 1
                    feed.flush()
                    tel.inc("storage.wal_replayed_batches_total", replayed)
                report["wal_replayed_batches"] += replayed
                report["datasets"][f"{dv}.{name}"] = {
                    "lsn": int(record["lsn"]),
                    "components": 1 + len(runs),
                    "wal_replayed_batches": replayed,
                    "manifest_fallbacks": ds_report["fallbacks"],
                    "quarantined": ds_report["quarantined"],
                }
        except BaseException:
            store.close()
            raise
        report["seconds"] = time.perf_counter() - t0
        report["corruption_events"] = int(
            (tel.counter_value("storage.corruption_total") or 0) - corrupt0)
        tel.observe("storage.recovery_seconds", report["seconds"])
        sess.recovery_report = report
        return sess

    def close(self) -> None:
        """Release the durable store (directory lock + WAL handles). A
        memory-only session is a no-op. Crash tests call this to simulate
        process death before reopening the same directory."""
        if self.storage is not None:
            self.storage.close()

    def _ensure_bound(self, plan: P.Plan) -> None:
        """Lazy-rebuild hook on the query path: before binding, rebuild the
        soft state of any scanned dataset still stale from a cold-start
        mount. O(1) when the catalog has no stale datasets — the common
        case costs one set check."""
        if not self.catalog.stale:
            return
        from repro.engine import lsm

        for node in P.walk(plan):
            if isinstance(node, P.Scan):
                lsm.ensure_soft(self, node.dataverse,
                                node.dataset.partition("@")[0])

    # -- DDL ----------------------------------------------------------------

    def create_dataset(self, name: str, table: Table, dataverse: str = "Default",
                       closed: bool = True, indexes: Sequence[str] = (),
                       primary: Optional[str] = None) -> Dataset:
        """Register (and shard) a dataset; optionally build indexes.

        ``primary`` sorts the stored table by that column (clustered);
        ``indexes`` build secondary sorted indexes per shard."""
        t0 = time.perf_counter()
        with tel.span("session.create_dataset", sid=self.sid,
                      dataset=f"{dataverse}.{name}"):
            ds = self._build_dataset(name, table, dataverse=dataverse,
                                     closed=closed, indexes=indexes,
                                     primary=primary)
            self.catalog.register(ds)
            self._invalidate_plans()
        tel.set_gauge("session.last_create_seconds",
                      time.perf_counter() - t0, sid=self.sid)
        return ds

    def _build_dataset(self, name: str, table: Table, dataverse: str = "Default",
                       closed: bool = True, indexes: Sequence[str] = (),
                       primary: Optional[str] = None,
                       stats_like: Optional[Mapping] = None) -> Dataset:
        """Build (stats → widen → cluster → shard → index) WITHOUT touching
        the catalog: background compaction builds replacement bases off the
        hot path and publishes them separately with one atomic manifest
        swap. ``stats_like`` (compaction: the retiring base's meta) keeps
        the string dict-lane decision sticky so runs flushed mid-merge stay
        column-uniform with the replacement base."""
        table = _collect_stats(table, like=stats_like)  # DBMS-style stats on load
        if not closed:
            table = open_widen(table)
        host_keys = None
        if primary is not None:
            order = np.argsort(np.asarray(table.columns[primary]), kind="stable")
            cols = {k: np.asarray(v)[order] for k, v in table.columns.items()}
            meta = dict(table.meta)
            meta[primary] = dataclasses.replace(meta[primary],
                                                sorted_ascending=True)
            table = Table(cols, meta, table.num_rows)
            # host copy of the clustered key order: anti-matter annihilation
            # bookkeeping (engine/lsm.py) binary-searches it at flush time
            host_keys = np.asarray(table.columns[primary])
        if self.mesh is not None:
            table = table.shard(self.mesh, self.data_axes)
        from repro.core.stats import harvest_block_zones
        ds = Dataset(name=name, dataverse=dataverse, table=table, closed=closed,
                     host_keys=host_keys,
                     # per-shard zone layout: sharded meshes get block lists
                     # local to each row partition (stats.BlockZones)
                     block_zones=harvest_block_zones(table, self.n_shards))
        if primary is not None:
            ds.indexes["primary"] = self._build_index(table, primary, "primary")
        for col in indexes:
            ds.indexes[f"ix_{col}"] = self._build_index(table, col, "secondary")
        return ds

    def _invalidate_plans(self) -> None:
        """Free cached plans eagerly. Correctness never depends on this call:
        every cache level is keyed by ``catalog.stats_epoch`` (bumped on DDL,
        feed flush, and compaction), so stale entries are unreachable — this
        just reclaims the memory."""
        self._plans.clear()
        self._compiled.clear()

    def _build_index(self, table: Table, column: str, kind: str) -> IndexInfo:
        sk, rid, zmin, zmax = _index_builder(self.mesh, self.data_axes)(
            table.columns[column], table.valid)
        return IndexInfo(name=f"{kind}:{column}", column=column, kind=kind,
                         sorted_keys=sk, row_ids=rid,
                         zone_min=zmin, zone_max=zmax)

    # -- materialized views (continuous queries over fed datasets) ----------

    def create_view(self, name: str, frame_or_plan) -> "object":
        """Register a continuously-maintained group-by aggregate (the
        paper's live-dashboard scenario): ``frame_or_plan`` is an AFrame (or
        its plan) of shape ``groupby(key).agg(...)`` over a — optionally
        filtered — dataset scan. The view is seeded from the dataset's
        current contents (base ∪ runs) and from then on refreshed
        *incrementally* from each feed flush's delta batch."""
        from repro.engine.lsm import MaterializedView

        plan = getattr(frame_or_plan, "_plan", frame_or_plan)
        view = MaterializedView.from_plan(name, plan)
        from repro.engine import lsm
        lsm.ensure_soft(self, view.dataverse, view.dataset)
        with self.catalog.snapshot() as snap:
            self._seed_view(view, snap.components(view.dataverse,
                                                  view.dataset))
        self.views[name] = view
        return view

    def _seed_view(self, view, comps) -> None:
        """Seed (or reseed) one view from a pinned component tuple."""
        from repro.engine.lsm import host_visible_mask
        from repro.engine.table import is_lane_column

        base = comps[0]
        key_col = base.primary_index.column \
            if base.primary_index is not None else None
        for comp in comps:
            cols = {k: np.asarray(v) for k, v in comp.table.columns.items()
                    if k not in INTERNAL_COLUMNS and not is_lane_column(k)}
            # seed from VISIBLE rows only: anti rows are __valid__ False, and
            # matter newer components already annihilated must not count
            view.apply_delta(cols, host_visible_mask(comp, key_col))

    def reseed_views(self, dataverse: str, dataset: str) -> None:
        """Rebuild every view over the dataset from scratch (crash recovery:
        view partials are soft state — lsm.recover calls this after the
        component-level rebuild)."""
        targets = [v for v in self.views.values()
                   if (v.dataverse, v.dataset) == (dataverse, dataset)]
        if not targets:
            return
        with self.catalog.snapshot() as snap:
            comps = snap.components(dataverse, dataset)
            for view in targets:
                view.reset()
                self._seed_view(view, comps)

    def read_view(self, name: str) -> dict:
        """The materialized result — no query execution, dashboard-latency."""
        return self.views[name].result()

    def drop_view(self, name: str) -> None:
        self.views.pop(name, None)

    def refresh_views(self, dataverse: str, dataset: str,
                      delta_cols: dict, retracted: Optional[dict] = None) -> None:
        """Apply one flushed delta batch to every view over the dataset
        (called by Feed.flush). ``retracted`` carries the OLD rows this
        flush's anti-matter annihilated: counts/sums take exact negative
        deltas; a retracted group extremum falls back to the exact host
        recompute over the dataset's current visible rows."""
        for view in self.views.values():
            if (view.dataverse, view.dataset) == (dataverse, dataset):
                view.apply_delta(delta_cols)
                if retracted is not None:
                    view.apply_retraction(retracted,
                                          recompute=self._view_recompute(view))

    def _view_recompute(self, view):
        """The exact extremum-repair fallback: host-scan the dataset's
        visible rows (base ∪ runs, newest-wins masks applied) and recompute
        ``op(column)`` for exactly the affected groups. O(dataset) — but it
        runs only when a retraction removed a group's current max/min, the
        one delta that is fundamentally not incremental."""
        from repro.engine.lsm import host_visible_mask

        def recompute(op: str, column: str, group_keys: np.ndarray) -> np.ndarray:
            import jax.numpy as jnp

            t0 = time.perf_counter()
            tel.inc("session.view_recomputes_total", sid=self.sid,
                    view=getattr(view, "name", "?"))
            with self.catalog.snapshot() as snap:
                comps = snap.components(view.dataverse, view.dataset)
                ds = comps[0]
                key_col = ds.primary_index.column \
                    if ds.primary_index is not None else None
                keys_parts, vals_parts = [], []
                for comp in comps:
                    mask = host_visible_mask(comp, key_col)
                    if view.predicate is not None:
                        env = {k: jnp.asarray(v)
                               for k, v in comp.table.columns.items()}
                        mask &= np.asarray(view.predicate.evaluate(env, []),
                                           bool)
                    keys_parts.append(
                        np.asarray(comp.table.columns[view.key])[mask])
                    vals_parts.append(
                        np.asarray(comp.table.columns[column])[mask])
            keys = np.concatenate(keys_parts)
            vals = np.concatenate(vals_parts).astype(np.float64)
            # one sort, then a binary-searched slice per affected group —
            # total work O(n log n + matching rows), not O(groups × n)
            order = np.argsort(keys, kind="stable")
            ks, vs = keys[order], vals[order]
            lo = np.searchsorted(ks, group_keys, side="left")
            hi = np.searchsorted(ks, group_keys, side="right")
            identity = -np.inf if op == "max" else np.inf
            out = np.full(len(group_keys), identity, np.float64)
            for i, (l, h) in enumerate(zip(lo, hi)):
                if h > l:
                    sel = vs[l:h]
                    out[i] = sel.max() if op == "max" else sel.min()
            dt = time.perf_counter() - t0
            tel.observe("session.view_recompute_seconds", dt, sid=self.sid)
            tel.set_gauge("session.last_view_recompute_seconds", dt,
                          sid=self.sid)
            return out

        return recompute

    # -- point lookups (the one path that bypasses compilation) -------------

    def point_lookup(self, dataverse: str, dataset: str, key):
        """Primary-key point lookup: per-component host binary searches over
        the clustered key copies, walked newest → oldest — the first
        component owning the key decides (fresh matter wins, a tombstone
        kills every older occurrence; an upsert run carries both, and its
        matter is checked first because its anti set applies to strictly
        older components only). No kernel launch, no compile, no plan-cache
        traffic: O(components × log rows).

        Returns the matching row(s) as ``{column: np.ndarray}`` or None
        (absent or deleted). ``last_physical`` / ``last_prune_report``
        reflect the lookup so ``explain``-style readers see a PointLookup
        node."""
        from repro.core import physical as PH
        from repro.core.catalog import INTERNAL_COLUMNS
        from repro.engine import lsm

        lsm.ensure_soft(self, dataverse, dataset)
        t0 = time.perf_counter()
        with self.catalog.snapshot() as snap:
            comps = list(snap.components(dataverse, dataset))
        ds = comps[0]
        primary = ds.primary_index
        if primary is None:
            raise ValueError(
                f"point lookup needs a primary key on {dataverse}.{dataset} "
                "(create the dataset with primary=<column>)")
        probed = skipped = 0
        shards = 1
        shard_probes = 0
        found_in = tombstoned_by = None
        result = None
        for comp in reversed(comps):  # newest component wins
            hk = comp.host_keys
            if hk is not None and len(hk):
                # zone short-circuit: the clustered copy is sorted, so its
                # ends ARE the key span — a miss costs two comparisons.
                if key < hk[0] or key > hk[-1]:
                    skipped += 1
                else:
                    # shard routing: the per-shard key zone spans identify
                    # the owning row partition(s); only their slice of the
                    # clustered copy is searched (host-side — no gather of
                    # the other shards' key ranges).
                    wlo, whi, owners, comp_shards = _route_key(
                        comp, primary.column, key, len(hk))
                    shards = max(shards, comp_shards)
                    if owners == 0:
                        skipped += 1  # key falls between the shard spans
                        continue
                    probed += 1
                    shard_probes += owners
                    lo = wlo + int(np.searchsorted(hk[wlo:whi], key,
                                                   side="left"))
                    hi = wlo + int(np.searchsorted(hk[wlo:whi], key,
                                                   side="right"))
                    if hi > lo:
                        # matter prefix is clustered by the primary key:
                        # index-space positions are table row positions
                        from repro.engine.table import is_lane_column
                        result = {
                            c: np.asarray(v[lo:hi])
                            for c, v in comp.table.columns.items()
                            if c not in INTERNAL_COLUMNS
                            and not c.startswith("__ix")
                            and not is_lane_column(c)}
                        found_in = f"{comp.dataverse}.{comp.name}"
                        break
            if comp.anti_rows:
                ak = comp.host_anti_keys if comp.host_anti_keys is not None \
                    else np.asarray(comp.anti_keys_arr)
                pos = int(np.searchsorted(ak, key))
                if pos < len(ak) and ak[pos] == key:
                    tombstoned_by = f"{comp.dataverse}.{comp.name}"
                    break  # deleted: nothing older is visible
        node = PH.PointLookup(dataverse, dataset, primary.column,
                              components=len(comps), probed=probed,
                              skipped=skipped, found_in=found_in,
                              tombstoned_by=tombstoned_by,
                              shards=shards, shard_probes=shard_probes)
        node.est_rows = 0 if result is None else len(next(iter(result.values())))
        node.cost = probed * 2.0  # binary-search pairs; never a scan
        if tombstoned_by is not None:
            node.note = (f"key is anti-matter in {tombstoned_by} — deleted, "
                         f"older occurrences invisible")
        elif found_in is not None:
            node.note = f"resolved in {found_in} (newest component with the key)"
        else:
            node.note = "key absent from every component span"
        self.last_physical = node
        from repro.core.physical import prune_report
        self.last_prune_report = prune_report(node)
        dt = time.perf_counter() - t0
        tel.inc("session.point_lookups_total", sid=self.sid)
        tel.observe("session.point_lookup_seconds", dt, sid=self.sid)
        tel.set_gauge("session.last_point_lookup_seconds", dt, sid=self.sid)
        return result

    def explain_lookup(self, dataverse: str, dataset: str, key) -> str:
        """The PointLookup plan for ``get(key)``, rendered like explain()."""
        from repro.core.physical import format_plan

        self.point_lookup(dataverse, dataset, key)
        return format_plan(self.last_physical)

    # -- query execution -------------------------------------------------------

    def exec_context(self, catalog=None) -> ExecContext:
        """``catalog`` is any catalog-read-surface object — execution passes
        the query's pinned Snapshot so compile-time component reads (shadow
        probe constants, leaf tables) bind against the snapshot, not the
        moving catalog."""
        return ExecContext(catalog=catalog if catalog is not None
                           else self.catalog, mesh=self.mesh,
                           data_axes=self.data_axes, mode=self.mode,
                           kernel_backend=self.kernel_backend,
                           kernel_interpret=self.kernel_interpret)

    @property
    def n_shards(self) -> int:
        """Row-partition count of this session's mesh (1 when meshless) —
        the layout zone maps are harvested over and block lists re-base to."""
        from repro.core.stats import mesh_shards

        return mesh_shards(self.mesh, self.data_axes)

    def _block_skip(self) -> bool:
        """Block skipping works on any mesh: surviving-block lists are
        expressed per shard (stats.BlockZones shard layout), so per-shard
        kernel grids and gathers consume their own local lists."""
        return self.enable_block_skip

    def _optimize(self, plan: P.Plan, catalog) -> P.Plan:
        tel.inc("session.optimizes_total", sid=self.sid)
        with tel.span("session.optimize", sid=self.sid):
            return optimize(plan, catalog,
                            enable_pushdown=self.enable_pushdown)

    def _plan_entry(self, plan: P.Plan, raw_fp: str, raw_lits: list,
                    snap) -> _PlanEntry:
        """Level 1: optimized plan + pruner per (raw fingerprint, epoch,
        LSN) — optimization, pruner construction, and stats all bind the
        pinned snapshot."""
        e = self._plans.get(raw_fp)
        if e is not None and (e.epoch, e.lsn) == (snap.stats_epoch, snap.lsn):
            tel.inc("session.plan_cache.hits_total", level="entry",
                    sid=self.sid)
            return e
        tel.inc("session.plan_cache.misses_total", level="entry",
                sid=self.sid)
        if e is not None:  # stale epoch/LSN: sweep dead executables with it
            self._compiled = {k: v for k, v in self._compiled.items()
                              if k[1:] == (snap.stats_epoch, snap.lsn)}
        opt = self._optimize(plan, snap)
        with tel.span("session.prune_build", sid=self.sid):
            pruner = build_pruner(opt, snap, raw_lits,
                                  n_shards=self.n_shards)
        e = _PlanEntry(snap.stats_epoch, snap.lsn, opt, opt.fingerprint(),
                       list(raw_lits), pruner)
        self._plans[raw_fp] = e
        return e

    def _variant(self, e: _PlanEntry, raw_lits: list, snap):
        """Levels 2+3: prune signature → (executable, binding); executables
        dedup'd across logical shapes by physical fingerprint, keyed on the
        snapshot's (epoch, LSN) so a stale executable can never read a
        retired component."""
        from repro.core.expr import ordered_lits
        from repro.core.physical_planner import NO_PRUNE

        with tel.span("session.prune", sid=self.sid):
            decisions = e.pruner.decide([l.value for l in raw_lits],
                                        block_skip=self._block_skip()) \
                if self.enable_prune else NO_PRUNE
        var = e.variants.get(decisions.signature)
        if var is not None:
            tel.inc("session.plan_cache.hits_total", level="variant",
                    sid=self.sid)
            return var
        tel.inc("session.plan_cache.misses_total", level="variant",
                sid=self.sid)
        with tel.span("session.plan", sid=self.sid):
            phys = plan_physical(e.opt, snap, mode=self.mode,
                                 decisions=decisions,
                                 enable_index=self.enable_index)
        tel.inc("session.plans_total", sid=self.sid)
        key = (phys.fingerprint(), e.epoch, e.lsn)
        cq = self._compiled.get(key)
        if cq is None:
            with tel.span("session.compile", sid=self.sid):
                cq = compile_physical(e.opt, phys, self.exec_context(snap))
            self._compiled[key] = cq
            tel.inc("session.compiles_total", sid=self.sid)
        else:
            tel.inc("session.plan_cache.hits_total", level="executable",
                    sid=self.sid)
            # reuse the executable but surface THIS binding's physical plan
            # (its pruning rationale) for explain/stats readers.
            cq = dataclasses.replace(cq, physical=phys)
        # Bind against THIS entry's physical-plan literals: an executable
        # dedup'd from another logical shape has the same fingerprint, hence
        # the same slot order, but its Lit objects chain to the OTHER raw
        # plan — only this plan's lits resolve against raw_lits0.
        from repro.core import physical as PH
        binding = _literal_binding(e.raw_lits0,
                                   ordered_lits(PH.all_exprs(phys)))
        var = (cq, binding)
        e.variants[decisions.signature] = var
        return var

    def execute(self, plan: P.Plan):
        """Optimize → cost-plan (pruning at bind time) → compile (cached) →
        run → numpy-ify.

        A repeat of a query shape (the benchmark's randomized literals) reads
        its literal values off the un-optimized plan, re-decides zone-map
        pruning (pure interval arithmetic), and — when the surviving-run set
        is unchanged — binds straight into the cached executable's param
        slots: no optimizer pass, no planner pass, no re-compile.

        Snapshot isolation: the query pins one immutable catalog snapshot
        up front and optimizes, prunes, compiles, and executes entirely
        against it — a concurrent flush or background compaction publishing
        mid-query cannot change what this plan reads (it binds the NEXT
        query, which captures a fresh snapshot).
        """
        from repro.core.expr import ordered_lits
        from repro.core.physical import prune_report

        t0 = time.perf_counter()
        raw_fp = plan.fingerprint()
        raw_lits = ordered_lits(P.all_exprs(plan))
        self._ensure_bound(plan)
        with self.catalog.snapshot() as snap:
            with tel.span("session.execute", sid=self.sid, mode=self.mode):
                e = self._plan_entry(plan, raw_fp, raw_lits, snap)
                cq, binding = self._variant(e, raw_lits, snap)
                params = _bind_params(binding, raw_lits)
                with tel.span("session.execute.run", sid=self.sid):
                    out = cq.run(snap, params=params)
                    out = jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        tel.inc("session.executes_total", sid=self.sid, mode=self.mode)
        tel.set_gauge("session.last_execute_seconds", dt, sid=self.sid)
        self.last_optimized = e.opt
        self.last_physical = cq.physical
        self.last_prune_report = prune_report(cq.physical)
        tel.inc("session.pruned_components_total",
                self.last_prune_report["pruned"], sid=self.sid)
        if cq.kind == "scalar":
            vals = {k: np.asarray(v).item() for k, v in out.items()}
            return vals if len(vals) > 1 else next(iter(vals.values()))
        env, mask = out
        return _materialize(env, mask, cq.kind)

    def explain(self, plan: P.Plan, analyze: bool = False) -> str:
        """The costed physical plan for ``plan``, rendered with per-operator
        cost estimates and the zone-map pruning rationale — what AsterixDB's
        EXPLAIN shows for the optimized Hyracks job. Runs the optimizer and
        planner but compiles/executes nothing.

        ``analyze=True`` additionally EXECUTES the query (``profile``) and
        annotates every operator line with measured self/total wall time and
        the actual row count beside the cost-model estimates."""
        if analyze:
            return self.profile(plan)["text"]
        from repro.core.expr import ordered_lits
        from repro.core.physical import format_plan

        raw_lits = ordered_lits(P.all_exprs(plan))
        self._ensure_bound(plan)
        with self.catalog.snapshot() as snap:
            e = self._plan_entry(plan, plan.fingerprint(), raw_lits, snap)
            decisions = e.pruner.decide([l.value for l in raw_lits],
                                        block_skip=self._block_skip()) \
                if self.enable_prune else None
            from repro.core.physical_planner import NO_PRUNE
            phys = plan_physical(e.opt, snap, mode=self.mode,
                                 decisions=decisions or NO_PRUNE,
                                 enable_index=self.enable_index)
        return format_plan(phys)

    def profile(self, plan: P.Plan) -> dict:
        """``explain(analyze=True)``'s engine: run ``plan`` through the full
        cached pipeline under span capture, time the jitted end-to-end run,
        then measure every operator's subtree standalone
        (``compiler.profile_physical``) so the rendered plan shows measured
        wall time and actual rows beside the cost estimates.

        Returns ``{"text", "result", "measures", "prune_report"}`` —
        ``result`` is exactly what ``execute(plan)`` returns."""
        from repro.core.compiler import profile_physical
        from repro.core.expr import ordered_lits
        from repro.core.physical import format_plan, prune_report

        tel.inc("session.profiles_total", sid=self.sid)
        raw_lits = ordered_lits(P.all_exprs(plan))
        self._ensure_bound(plan)
        with self.catalog.snapshot() as snap:
            with tel.span("session.profile", sid=self.sid, mode=self.mode):
                e = self._plan_entry(plan, plan.fingerprint(), raw_lits, snap)
                cq, binding = self._variant(e, raw_lits, snap)
                params = _bind_params(binding, raw_lits)
                tables = cq.gather_tables(snap)
                t0 = time.perf_counter()
                out = jax.block_until_ready(cq.fn(tables, params))
                jit_seconds = time.perf_counter() - t0
                measures = profile_physical(cq.physical,
                                            self.exec_context(snap),
                                            tables, params)
        measures["jit_seconds"] = jit_seconds
        self.last_optimized = e.opt
        self.last_physical = cq.physical
        self.last_prune_report = prune_report(cq.physical)
        if cq.kind == "scalar":
            vals = {k: np.asarray(v).item() for k, v in out.items()}
            result = vals if len(vals) > 1 else next(iter(vals.values()))
        else:
            env, mask = out
            result = _materialize(env, mask, cq.kind)
        return {"text": format_plan(cq.physical, analyze=measures),
                "result": result, "measures": measures,
                "prune_report": self.last_prune_report}

    def persist(self, plan: P.Plan, name: str, dataverse: str = "Default") -> Dataset:
        """CREATE DATASET AS <query> — result stays engine-resident (paper
        Input 15: no data ever leaves storage)."""
        self._ensure_bound(plan)
        with self.catalog.snapshot() as snap:
            opt = self._optimize(plan, snap)
            cq = compile_plan(opt, self.exec_context(snap),
                              enable_index=self.enable_index,
                              enable_prune=self.enable_prune)
            out = cq.run(snap)
        if cq.kind == "scalar":
            raise ValueError("cannot persist a scalar result")
        from repro.engine.table import is_lane_column
        env, mask = out
        # strip the inputs' per-component dict lanes: concatenated ids from
        # different components don't share a dictionary — _collect_stats
        # rebuilds coherent lanes for the persisted table.
        cols = {k: v for k, v in env.items() if not is_lane_column(k)}
        cols["__valid__"] = mask
        table = _collect_stats(Table(cols, num_rows=int(mask.shape[0])))
        from repro.core.stats import harvest_block_zones
        ds = Dataset(name=name, dataverse=dataverse, table=table, closed=True,
                     block_zones=harvest_block_zones(table, self.n_shards))
        self.catalog.register(ds)
        self._invalidate_plans()
        return ds


# One jitted index builder per (mesh, data_axes): the sort/zone-map program
# is column-independent, so every dataset/run index build on the same mesh
# reuses one executable (retraced only per array shape). A per-call closure
# would re-jit on EVERY flush and dominate streaming-ingest cost.
_INDEX_BUILDERS: dict = {}


def _index_builder(mesh, data_axes):
    key = (mesh, tuple(data_axes))
    fn = _INDEX_BUILDERS.get(key)
    if fn is None:
        from repro.engine.index import build_index_local

        def build(k, v):
            ix = build_index_local(k, v, "", "build")
            return ix.sorted_keys, ix.row_ids, ix.zone_min, ix.zone_max

        if mesh is not None and mesh.devices.size > 1:
            dp = data_axes if len(data_axes) > 1 else data_axes[0]
            fn = jax.jit(_shard_map(
                build, mesh=mesh, in_specs=(PS(dp), PS(dp)),
                out_specs=(PS(dp), PS(dp), PS(dp), PS(dp))))
        else:
            fn = jax.jit(build)
        _INDEX_BUILDERS[key] = fn
    return fn


def _literal_binding(raw_lits, opt_lits) -> list[tuple[str, object]]:
    """Map each optimized-plan param slot back to the raw plan's literals.

    The optimizer shares user Lit objects with the raw plan and marks any
    literal it synthesizes from one (the ``==``-as-range mirror bound) with
    ``source``; a literal reachable from neither is a plan constant (sentinel
    range bounds) and rebinds to its compile-time value. The binding lets a
    plan-cache hit feed fresh literal values into the executable without
    re-running the optimizer.

    A literal the planner synthesized through a value TRANSFORM (the dict-id
    bounds of a string predicate) carries a ``binder`` callable plus the
    user ``sources`` it derives from: the binding records the transform and
    each source's resolution, so a rebind maps the fresh string literal
    through the same dictionary."""
    index = {id(l): j for j, l in enumerate(raw_lits)}

    def resolve(lit):
        src = lit
        while id(src) not in index and getattr(src, "source", None) is not None:
            src = src.source
        if id(src) in index:
            return ("raw", index[id(src)])
        return ("const", lit.value)

    binding: list[tuple[str, object]] = []
    for lit in opt_lits:
        binder = getattr(lit, "binder", None)
        if binder is not None:
            refs = tuple(resolve(s) for s in lit.sources)
            binding.append(("xform", (binder, refs)))
        else:
            binding.append(resolve(lit))
    return binding


def _bind_params(binding, raw_lits):
    from repro.core.expr import encode_param

    def value(kind, v):
        return raw_lits[v].value if kind == "raw" else v

    out = []
    for kind, v in binding:
        if kind == "xform":
            binder, refs = v
            out.append(encode_param(binder(*[value(k, r) for k, r in refs])))
        else:
            out.append(encode_param(value(kind, v)))
    return out


def _route_key(comp, key_col: str, key, n_keys: int):
    """Shard-route a point lookup inside one component: fold the clustered
    key column's per-shard zone spans into one [lo, hi] per row partition
    and return the ``host_keys`` window covering the owning shard(s) —
    ``(window_lo, window_hi, owning_shards, n_shards)``. The matter prefix
    is clustered, so owning shards are a contiguous run and the merged
    window stays one slice (a duplicate key straddling a shard boundary is
    still found whole). Components without a sharded zone layout fall back
    to the full window."""
    bz = comp.block_zones
    if bz is None or bz.n_shards <= 1 or not bz.rows_per_shard:
        return 0, n_keys, 1, 1
    span = bz.span_of(key_col)
    if span is None:
        return 0, n_keys, 1, bz.n_shards
    per = span.reshape(bz.n_shards, bz.blocks_per_shard, 2)
    owners = np.nonzero((per[:, :, 0].min(axis=1) <= key)
                        & (key <= per[:, :, 1].max(axis=1)))[0]
    if not len(owners):
        return 0, 0, 0, bz.n_shards
    wlo = min(int(owners[0]) * bz.rows_per_shard, n_keys)
    whi = min((int(owners[-1]) + 1) * bz.rows_per_shard, n_keys)
    return wlo, whi, len(owners), bz.n_shards


def _mount_component(session: Session, dataverse: str, seg: str,
                     arrays: Mapping, meta: Mapping) -> Dataset:
    """Rehydrate one LSM component from its durable segment: hard state
    only — table columns (re-sharded onto the session's mesh), column
    metadata, and the index *inventory* (payloads stay None until the
    lazy soft-state rebuild at first bind)."""
    from repro.runtime.durable import _meta_from_json

    cols, cmeta = {}, {}
    for cname, mjson in meta["columns"]:
        cols[cname] = arrays[cname]
        cmeta[cname] = _meta_from_json(mjson)
    table = Table(cols, cmeta, int(meta["num_rows"]))
    if session.mesh is not None:
        table = table.shard(session.mesh, session.data_axes)
    ds = Dataset(name=meta["name"], dataverse=dataverse, table=table,
                 closed=bool(meta["closed"]), live_rows=meta["live_rows"],
                 anti_rows=int(meta["anti_rows"]), level=int(meta["level"]),
                 uid=int(meta["uid"]), engine_owned=True, seg_name=seg,
                 soft_stale=True)
    for key, ix_name, column, kind in meta["indexes"]:
        ds.indexes[key] = IndexInfo(name=ix_name, column=column, kind=kind)
    return ds


def _collect_stats(table: Table, like: Optional[Mapping] = None) -> Table:
    """Fill missing lo/hi/distinct for numeric columns (the statistics a
    DBMS gathers at load; the bounded-domain group-by and index selection
    read them from the catalog). Integer columns get lo/hi/distinct; float
    columns get a NaN-safe lo/hi envelope (no distinct — float domains are
    never group-by keys), so float predicates participate in run-level
    zone-span pruning too.

    String columns additionally grow their derived integer lanes here
    (engine/table.py): an always-on order-preserving ``__pfx_<col>`` prefix
    lane (int32 — zone-map pruning only), and a per-component sorted
    dictionary-id lane ``__dict_<col>`` (int32 — what string ==/IN/group-by
    lower onto the kernels through) when the live distinct count stays
    under ``DICT_THRESHOLD``. ``like`` is the base table's meta when
    building an LSM run: dict-lane presence follows the hint instead of the
    threshold, so lane presence stays uniform across one dataset's
    components (the union-concat lowering requires a uniform column set)."""
    from repro.engine.table import (DICT_THRESHOLD, ColumnMeta,
                                    decode_strings, dict_lane_name,
                                    is_lane_column, pack_prefix,
                                    prefix_lane_name)

    meta = dict(table.meta)
    cols = dict(table.columns)
    live = None  # lazily-computed visible-row mask (string lanes only)

    def live_mask():
        nonlocal live
        if live is None:
            m = np.ones(table.num_rows, bool)
            v = cols.get("__valid__")
            if v is not None:
                m &= np.asarray(v)
            am = cols.get("__antimatter__")
            if am is not None:
                m &= ~np.asarray(am)
            live = m
        return live

    for name, col in table.columns.items():
        if name in INTERNAL_COLUMNS or is_lane_column(name):
            continue
        m = meta.get(name)
        a = np.asarray(col)
        if a.ndim == 2 and a.dtype == np.uint8:
            pfx = prefix_lane_name(name)
            if pfx not in cols:
                packed = pack_prefix(a)
                lm = live_mask()
                plo, phi = ((int(packed[lm].min()), int(packed[lm].max()))
                            if lm.any() else (None, None))
                cols[pfx] = packed
                meta[pfx] = ColumnMeta(np.dtype(np.int32), plo, phi)
            dname = dict_lane_name(name)
            if dname not in cols:
                lm = live_mask()
                uniq, inv = np.unique(a[lm], axis=0, return_inverse=True)
                inv = np.asarray(inv).reshape(-1)
                hint = getattr(like.get(name), "dict_values", None) \
                    if like is not None else None
                want_dict = (hint is not None) if like is not None \
                    else len(uniq) <= DICT_THRESHOLD
                new = m if m is not None else ColumnMeta(a.dtype,
                                                         is_string=True)
                new = dataclasses.replace(new, distinct=len(uniq))
                if want_dict:
                    # dead rows carry id -1: every consumer masks them, and
                    # the lane's zone span covers live ids [0, G-1] only.
                    ids = np.full(a.shape[0], -1, np.int32)
                    ids[lm] = inv.astype(np.int32)
                    cols[dname] = ids
                    g = len(uniq)
                    meta[dname] = ColumnMeta(np.dtype(np.int32),
                                             0 if g else None,
                                             g - 1 if g else None, g)
                    new = dataclasses.replace(
                        new, dict_values=tuple(decode_strings(uniq)))
                meta[name] = new
            continue
        if m is not None and m.lo is not None:
            continue
        if a.ndim != 1 or not a.size:
            continue
        if np.issubdtype(a.dtype, np.integer):
            lo, hi = int(a.min()), int(a.max())
            distinct = min(hi - lo + 1, a.size)
            meta[name] = ColumnMeta(a.dtype, lo, hi, distinct)
        elif np.issubdtype(a.dtype, np.floating) and not np.all(np.isnan(a)):
            meta[name] = ColumnMeta(a.dtype, float(np.nanmin(a)),
                                    float(np.nanmax(a)))
    return Table(cols, meta, table.num_rows)


def _materialize(env: dict, mask, kind: str) -> dict[str, np.ndarray]:
    """Compact to valid rows on the host (result delivery boundary).
    Derived string lanes are storage internals — never delivered."""
    from repro.engine.table import is_lane_column

    m = np.asarray(mask)
    out = {}
    for k, v in env.items():
        if is_lane_column(k):
            continue
        a = np.asarray(v)
        out[k] = a[m]
    return out
