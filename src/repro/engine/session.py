"""Session: the client's connection to the engine (the paper's AsterixDB
REST endpoint analogue). Owns the catalog, the mesh, the executable cache,
and the timing hooks the DataFrame benchmark reads (creation time vs
expression time, paper §IV-D).
"""
from __future__ import annotations

import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import plan as P
from repro.core.catalog import Catalog, Dataset, IndexInfo, open_widen
from repro.core.compiler import CompiledQuery, ExecContext, compile_plan
from repro.core.optimizer import optimize
from repro.engine.table import Table

try:
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

from jax.sharding import PartitionSpec as PS


class Session:
    def __init__(self, mesh: Optional[Mesh] = None, mode: str = "auto",
                 data_axes: tuple[str, ...] = ("data",),
                 enable_index: bool = True, enable_pushdown: bool = True):
        """mode: 'auto' (shard_map when a mesh is given), 'gspmd',
        'shard_map', or 'local'."""
        self.catalog = Catalog()
        self.mesh = mesh
        if mode == "auto":
            mode = "shard_map" if mesh is not None and mesh.devices.size > 1 else "gspmd"
        self.mode = mode
        self.data_axes = data_axes
        self.enable_index = enable_index
        self.enable_pushdown = enable_pushdown
        self._cache: dict[str, CompiledQuery] = {}
        self.timings: dict[str, float] = {}
        self.stats = {"compiles": 0, "hits": 0}

    # -- DDL ----------------------------------------------------------------

    def create_dataset(self, name: str, table: Table, dataverse: str = "Default",
                       closed: bool = True, indexes: Sequence[str] = (),
                       primary: Optional[str] = None) -> Dataset:
        """Register (and shard) a dataset; optionally build indexes.

        ``primary`` sorts the stored table by that column (clustered);
        ``indexes`` build secondary sorted indexes per shard."""
        t0 = time.perf_counter()
        table = _collect_stats(table)  # DBMS-style stats on load
        if not closed:
            table = open_widen(table)
        if primary is not None:
            order = np.argsort(np.asarray(table.columns[primary]), kind="stable")
            cols = {k: np.asarray(v)[order] for k, v in table.columns.items()}
            meta = dict(table.meta)
            m = meta[primary]
            meta[primary] = type(m)(m.dtype, m.lo, m.hi, m.distinct, m.is_string, True)
            table = Table(cols, meta, table.num_rows)
        if self.mesh is not None:
            table = table.shard(self.mesh, self.data_axes)
        ds = Dataset(name=name, dataverse=dataverse, table=table, closed=closed)
        if primary is not None:
            ds.indexes["primary"] = self._build_index(table, primary, "primary")
        for col in indexes:
            ds.indexes[f"ix_{col}"] = self._build_index(table, col, "secondary")
        self.catalog.register(ds)
        self.timings[f"create:{dataverse}.{name}"] = time.perf_counter() - t0
        return ds

    def _build_index(self, table: Table, column: str, kind: str) -> IndexInfo:
        from repro.engine.index import build_index_local

        keys = table.columns[column]
        valid = table.valid
        if self.mesh is not None and self.mesh.devices.size > 1:
            dp = self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]

            def build(k, v):
                ix = build_index_local(k, v, column, kind)
                return ix.sorted_keys, ix.row_ids

            sk, rid = jax.jit(_shard_map(
                build, mesh=self.mesh,
                in_specs=(PS(dp), PS(dp)),
                out_specs=(PS(dp), PS(dp))))(keys, valid)
        else:
            def build1(k, v):
                ix = build_index_local(k, v, column, kind)
                return ix.sorted_keys, ix.row_ids

            sk, rid = jax.jit(build1)(keys, valid)
        return IndexInfo(name=f"{kind}:{column}", column=column, kind=kind,
                         sorted_keys=sk, row_ids=rid)

    # -- query execution -------------------------------------------------------

    def exec_context(self) -> ExecContext:
        return ExecContext(catalog=self.catalog, mesh=self.mesh,
                           data_axes=self.data_axes, mode=self.mode)

    def execute(self, plan: P.Plan):
        """Optimize → compile (cached by fingerprint) → run → numpy-ify."""
        t0 = time.perf_counter()
        opt = optimize(plan, self.catalog, enable_index=self.enable_index,
                       enable_pushdown=self.enable_pushdown)
        fp = opt.fingerprint()
        cq = self._cache.get(fp)
        if cq is None:
            cq = compile_plan(opt, self.exec_context())
            self._cache[fp] = cq
            self.stats["compiles"] += 1
            lits = cq.lits
        else:
            self.stats["hits"] += 1
            # rebind this plan instance's literal values to the cached slots
            from repro.core.expr import collect_params
            from repro.core.plan import all_exprs
            lits = collect_params(all_exprs(opt))
        out = cq.run(self.catalog, lits=lits)
        out = jax.block_until_ready(out)
        self.timings["last_execute"] = time.perf_counter() - t0
        self.last_optimized = opt
        if cq.kind == "scalar":
            vals = {k: np.asarray(v).item() for k, v in out.items()}
            return vals if len(vals) > 1 else next(iter(vals.values()))
        env, mask = out
        return _materialize(env, mask, cq.kind)

    def persist(self, plan: P.Plan, name: str, dataverse: str = "Default") -> Dataset:
        """CREATE DATASET AS <query> — result stays engine-resident (paper
        Input 15: no data ever leaves storage)."""
        opt = optimize(plan, self.catalog, enable_index=self.enable_index,
                       enable_pushdown=self.enable_pushdown)
        cq = compile_plan(opt, self.exec_context())
        out = cq.run(self.catalog)
        if cq.kind == "scalar":
            raise ValueError("cannot persist a scalar result")
        env, mask = out
        cols = dict(env)
        cols["__valid__"] = mask
        table = _collect_stats(Table(cols, num_rows=int(mask.shape[0])))
        ds = Dataset(name=name, dataverse=dataverse, table=table, closed=True)
        self.catalog.register(ds)
        return ds


def _collect_stats(table: Table) -> Table:
    """Fill missing lo/hi/distinct for integer columns (the statistics a DBMS
    gathers at load; the bounded-domain group-by and index selection read
    them from the catalog)."""
    from repro.engine.table import ColumnMeta

    meta = dict(table.meta)
    for name, col in table.columns.items():
        if name == "__valid__":
            continue
        m = meta.get(name)
        if m is not None and m.lo is not None:
            continue
        a = np.asarray(col)
        if a.ndim == 1 and np.issubdtype(a.dtype, np.integer) and a.size:
            lo, hi = int(a.min()), int(a.max())
            distinct = min(hi - lo + 1, a.size)
            meta[name] = ColumnMeta(a.dtype, lo, hi, distinct)
    return Table(table.columns, meta, table.num_rows)


def _materialize(env: dict, mask, kind: str) -> dict[str, np.ndarray]:
    """Compact to valid rows on the host (result delivery boundary)."""
    m = np.asarray(mask)
    out = {}
    for k, v in env.items():
        a = np.asarray(v)
        out[k] = a[m]
    return out
