"""Session: the client's connection to the engine (the paper's AsterixDB
REST endpoint analogue). Owns the catalog, the mesh, the executable cache,
and the timing hooks the DataFrame benchmark reads (creation time vs
expression time, paper §IV-D).
"""
from __future__ import annotations

import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import plan as P
from repro.core.catalog import Catalog, Dataset, IndexInfo, open_widen
from repro.core.compiler import CompiledQuery, ExecContext, compile_plan
from repro.core.optimizer import optimize
from repro.engine.table import Table

try:
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

from jax.sharding import PartitionSpec as PS


class Session:
    def __init__(self, mesh: Optional[Mesh] = None, mode: str = "auto",
                 data_axes: tuple[str, ...] = ("data",),
                 enable_index: bool = True, enable_pushdown: bool = True,
                 kernel_backend: Optional[str] = None):
        """mode: 'auto' (shard_map when a mesh is given), 'gspmd',
        'shard_map', or 'kernel' (lower fusable plan shapes onto the Pallas
        relational kernels; anything uncovered falls back to the gspmd /
        shard_map lowering).

        ``kernel_backend`` feeds the kernels/ops dispatch: 'pallas' forces
        the Pallas kernels (interpret mode off-TPU), 'xla' the jnp twins;
        None picks pallas on TPU and the ops default elsewhere."""
        self.catalog = Catalog()
        self.mesh = mesh
        if mode == "auto":
            mode = "shard_map" if mesh is not None and mesh.devices.size > 1 else "gspmd"
        if mode == "local":  # historical alias for the single-program lowering
            mode = "gspmd"
        if mode not in ("gspmd", "shard_map", "kernel"):
            raise ValueError(f"unknown mode {mode!r}: "
                             "expected auto | gspmd | shard_map | kernel")
        if kernel_backend not in (None, "xla", "pallas"):
            raise ValueError(f"unknown kernel_backend {kernel_backend!r}: "
                             "expected None | xla | pallas")
        self.mode = mode
        if kernel_backend is None and mode == "kernel" \
                and jax.default_backend() == "tpu":
            kernel_backend = "pallas"
        self.kernel_backend = kernel_backend
        self.data_axes = data_axes
        self.enable_index = enable_index
        self.enable_pushdown = enable_pushdown
        # two-level plan cache: the raw (pre-optimization) fingerprint maps to
        # (executable, literal binding, optimized plan) so repeated queries
        # skip the optimizer entirely; the optimized fingerprint still dedups
        # executables across raw plans that rewrite to the same shape (a
        # point == and a range >=/<= predicate share one executable).
        self._cache: dict[str, CompiledQuery] = {}
        self._plan_cache: dict[str, tuple] = {}
        self.timings: dict[str, float] = {}
        self.stats = {"compiles": 0, "hits": 0, "optimizes": 0}
        # incrementally-maintained materialized views (engine/lsm.py),
        # refreshed from each feed flush's delta batch.
        self.views: dict[str, "object"] = {}

    # -- DDL ----------------------------------------------------------------

    def create_dataset(self, name: str, table: Table, dataverse: str = "Default",
                       closed: bool = True, indexes: Sequence[str] = (),
                       primary: Optional[str] = None) -> Dataset:
        """Register (and shard) a dataset; optionally build indexes.

        ``primary`` sorts the stored table by that column (clustered);
        ``indexes`` build secondary sorted indexes per shard."""
        t0 = time.perf_counter()
        table = _collect_stats(table)  # DBMS-style stats on load
        if not closed:
            table = open_widen(table)
        if primary is not None:
            order = np.argsort(np.asarray(table.columns[primary]), kind="stable")
            cols = {k: np.asarray(v)[order] for k, v in table.columns.items()}
            meta = dict(table.meta)
            m = meta[primary]
            meta[primary] = type(m)(m.dtype, m.lo, m.hi, m.distinct, m.is_string, True)
            table = Table(cols, meta, table.num_rows)
        if self.mesh is not None:
            table = table.shard(self.mesh, self.data_axes)
        ds = Dataset(name=name, dataverse=dataverse, table=table, closed=closed)
        if primary is not None:
            ds.indexes["primary"] = self._build_index(table, primary, "primary")
        for col in indexes:
            ds.indexes[f"ix_{col}"] = self._build_index(table, col, "secondary")
        self.catalog.register(ds)
        self._invalidate_plans()
        self.timings[f"create:{dataverse}.{name}"] = time.perf_counter() - t0
        return ds

    def _invalidate_plans(self) -> None:
        """DDL drops every compiled plan: executables bake catalog facts
        (array shapes, index selection, kernel exactness proofs) and the
        raw-fingerprint cache additionally freezes optimizer decisions, so a
        re-registered dataset must force re-optimization and re-compile."""
        self._cache.clear()
        self._plan_cache.clear()

    def _build_index(self, table: Table, column: str, kind: str) -> IndexInfo:
        sk, rid, zmin, zmax = _index_builder(self.mesh, self.data_axes)(
            table.columns[column], table.valid)
        return IndexInfo(name=f"{kind}:{column}", column=column, kind=kind,
                         sorted_keys=sk, row_ids=rid,
                         zone_min=zmin, zone_max=zmax)

    # -- materialized views (continuous queries over fed datasets) ----------

    def create_view(self, name: str, frame_or_plan) -> "object":
        """Register a continuously-maintained group-by aggregate (the
        paper's live-dashboard scenario): ``frame_or_plan`` is an AFrame (or
        its plan) of shape ``groupby(key).agg(...)`` over a — optionally
        filtered — dataset scan. The view is seeded from the dataset's
        current contents (base ∪ runs) and from then on refreshed
        *incrementally* from each feed flush's delta batch."""
        from repro.engine.lsm import MaterializedView

        plan = getattr(frame_or_plan, "_plan", frame_or_plan)
        view = MaterializedView.from_plan(name, plan)
        ds = self.catalog.get(view.dataverse, view.dataset)
        for comp in [ds] + list(ds.runs):
            cols = {k: np.asarray(v) for k, v in comp.table.columns.items()
                    if k != "__valid__"}
            view.apply_delta(cols, np.asarray(comp.table.valid))
        self.views[name] = view
        return view

    def read_view(self, name: str) -> dict:
        """The materialized result — no query execution, dashboard-latency."""
        return self.views[name].result()

    def drop_view(self, name: str) -> None:
        self.views.pop(name, None)

    def refresh_views(self, dataverse: str, dataset: str,
                      delta_cols: dict) -> None:
        """Apply one flushed delta batch to every view over the dataset
        (called by Feed.flush)."""
        for view in self.views.values():
            if (view.dataverse, view.dataset) == (dataverse, dataset):
                view.apply_delta(delta_cols)

    # -- query execution -------------------------------------------------------

    def exec_context(self) -> ExecContext:
        return ExecContext(catalog=self.catalog, mesh=self.mesh,
                           data_axes=self.data_axes, mode=self.mode,
                           kernel_backend=self.kernel_backend)

    def _optimize(self, plan: P.Plan) -> P.Plan:
        self.stats["optimizes"] += 1
        return optimize(plan, self.catalog, enable_index=self.enable_index,
                        enable_pushdown=self.enable_pushdown,
                        enable_kernel_fusion=self.mode == "kernel")

    def execute(self, plan: P.Plan):
        """Optimize → compile (cached) → run → numpy-ify.

        Caching is keyed on the *raw* plan fingerprint: a repeat of a query
        shape (the benchmark's randomized literals) reads its literal values
        off the un-optimized plan and binds them straight into the cached
        executable's param slots — no optimizer pass, no optimized-plan walk.
        """
        from repro.core.expr import ordered_lits

        t0 = time.perf_counter()
        raw_fp = plan.fingerprint()
        raw_lits = ordered_lits(P.all_exprs(plan))
        entry = self._plan_cache.get(raw_fp)
        if entry is None:
            opt = self._optimize(plan)
            opt_fp = opt.fingerprint()
            cq = self._cache.get(opt_fp)
            if cq is None:
                cq = compile_plan(opt, self.exec_context())
                self._cache[opt_fp] = cq
                self.stats["compiles"] += 1
            else:
                self.stats["hits"] += 1
            binding = _literal_binding(raw_lits, ordered_lits(P.all_exprs(opt)))
            entry = (cq, binding, opt)
            self._plan_cache[raw_fp] = entry
        else:
            self.stats["hits"] += 1
        cq, binding, opt = entry
        params = _bind_params(binding, raw_lits)
        out = cq.run(self.catalog, params=params)
        out = jax.block_until_ready(out)
        self.timings["last_execute"] = time.perf_counter() - t0
        self.last_optimized = opt
        if cq.kind == "scalar":
            vals = {k: np.asarray(v).item() for k, v in out.items()}
            return vals if len(vals) > 1 else next(iter(vals.values()))
        env, mask = out
        return _materialize(env, mask, cq.kind)

    def persist(self, plan: P.Plan, name: str, dataverse: str = "Default") -> Dataset:
        """CREATE DATASET AS <query> — result stays engine-resident (paper
        Input 15: no data ever leaves storage)."""
        opt = self._optimize(plan)
        cq = compile_plan(opt, self.exec_context())
        out = cq.run(self.catalog)
        if cq.kind == "scalar":
            raise ValueError("cannot persist a scalar result")
        env, mask = out
        cols = dict(env)
        cols["__valid__"] = mask
        table = _collect_stats(Table(cols, num_rows=int(mask.shape[0])))
        ds = Dataset(name=name, dataverse=dataverse, table=table, closed=True)
        self.catalog.register(ds)
        self._invalidate_plans()
        return ds


# One jitted index builder per (mesh, data_axes): the sort/zone-map program
# is column-independent, so every dataset/run index build on the same mesh
# reuses one executable (retraced only per array shape). A per-call closure
# would re-jit on EVERY flush and dominate streaming-ingest cost.
_INDEX_BUILDERS: dict = {}


def _index_builder(mesh, data_axes):
    key = (mesh, tuple(data_axes))
    fn = _INDEX_BUILDERS.get(key)
    if fn is None:
        from repro.engine.index import build_index_local

        def build(k, v):
            ix = build_index_local(k, v, "", "build")
            return ix.sorted_keys, ix.row_ids, ix.zone_min, ix.zone_max

        if mesh is not None and mesh.devices.size > 1:
            dp = data_axes if len(data_axes) > 1 else data_axes[0]
            fn = jax.jit(_shard_map(
                build, mesh=mesh, in_specs=(PS(dp), PS(dp)),
                out_specs=(PS(dp), PS(dp), PS(dp), PS(dp))))
        else:
            fn = jax.jit(build)
        _INDEX_BUILDERS[key] = fn
    return fn


def _literal_binding(raw_lits, opt_lits) -> list[tuple[str, object]]:
    """Map each optimized-plan param slot back to the raw plan's literals.

    The optimizer shares user Lit objects with the raw plan and marks any
    literal it synthesizes from one (the ``==``-as-range mirror bound) with
    ``source``; a literal reachable from neither is a plan constant (sentinel
    range bounds) and rebinds to its compile-time value. The binding lets a
    plan-cache hit feed fresh literal values into the executable without
    re-running the optimizer."""
    index = {id(l): j for j, l in enumerate(raw_lits)}
    binding: list[tuple[str, object]] = []
    for lit in opt_lits:
        src = lit
        while id(src) not in index and getattr(src, "source", None) is not None:
            src = src.source
        if id(src) in index:
            binding.append(("raw", index[id(src)]))
        else:
            binding.append(("const", lit.value))
    return binding


def _bind_params(binding, raw_lits):
    from repro.core.expr import encode_param

    return [encode_param(raw_lits[v].value if kind == "raw" else v)
            for kind, v in binding]


def _collect_stats(table: Table) -> Table:
    """Fill missing lo/hi/distinct for integer columns (the statistics a DBMS
    gathers at load; the bounded-domain group-by and index selection read
    them from the catalog)."""
    from repro.engine.table import ColumnMeta

    meta = dict(table.meta)
    for name, col in table.columns.items():
        if name == "__valid__":
            continue
        m = meta.get(name)
        if m is not None and m.lo is not None:
            continue
        a = np.asarray(col)
        if a.ndim == 1 and np.issubdtype(a.dtype, np.integer) and a.size:
            lo, hi = int(a.min()), int(a.max())
            distinct = min(hi - lo + 1, a.size)
            meta[name] = ColumnMeta(a.dtype, lo, hi, distinct)
    return Table(table.columns, meta, table.num_rows)


def _materialize(env: dict, mask, kind: str) -> dict[str, np.ndarray]:
    """Compact to valid rows on the host (result delivery boundary)."""
    m = np.asarray(mask)
    out = {}
    for k, v in env.items():
        a = np.asarray(v)
        out[k] = a[m]
    return out
