"""Live data ingestion — the paper's Twitter data-feed analogue (§III-A).

AsterixDB feeds append to LSM components and maintain indexes online; the
TPU-resident analogue is run-based: arriving rows buffer on the host, flush
into device-resident *runs* (chunks), and periodically *compact* into the
base table (re-shard + re-sort + index rebuild). Queries see base ∪ runs —
the same data before and after compaction, exactly like querying an LSM tree
across its components.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.engine.table import Table, concat_tables


class Feed:
    def __init__(self, session, dataset: str, dataverse: str = "Default",
                 flush_rows: int = 4096):
        self.session = session
        self.dataset = dataset
        self.dataverse = dataverse
        self.flush_rows = flush_rows
        self._buffer: list[dict[str, np.ndarray]] = []
        self._buffered = 0
        self.stats = {"ingested": 0, "flushes": 0, "compactions": 0}

    def push(self, rows: dict[str, np.ndarray]) -> None:
        """Append a batch of arriving records (host-side buffer)."""
        n = len(next(iter(rows.values())))
        self._buffer.append(rows)
        self._buffered += n
        self.stats["ingested"] += n
        if self._buffered >= self.flush_rows:
            self.flush()

    def flush(self) -> None:
        """Move the host buffer into the stored dataset as a new run."""
        if not self._buffer:
            return
        cols = {k: np.concatenate([b[k] for b in self._buffer], axis=0)
                for k in self._buffer[0]}
        self._merge(Table(cols))
        self._buffer.clear()
        self._buffered = 0
        self.stats["flushes"] += 1

    def _merge(self, run: Table) -> None:
        ds = self.session.catalog.get(self.dataverse, self.dataset)
        base = ds.table
        # de-shard -> concat -> re-create (compaction). For the CPU-scale
        # benchmark this is the simple correct strategy; a pod deployment
        # would keep runs device-resident and merge indexes incrementally.
        base_np = {k: np.asarray(v) for k, v in base.columns.items()
                   if k != "__valid__"}
        valid = np.asarray(base.valid)
        base_np = {k: v[valid] for k, v in base_np.items()}
        merged = {k: np.concatenate([base_np[k], np.asarray(run.columns[k])], axis=0)
                  for k in base_np}
        meta = {k: m for k, m in base.meta.items() if k != "__valid__"}
        indexes = [ix.column for ix in ds.indexes.values() if ix.kind == "secondary"]
        primary = next((ix.column for ix in ds.indexes.values()
                        if ix.kind == "primary"), None)
        self.session.create_dataset(self.dataset, Table(merged, meta),
                                    dataverse=self.dataverse, closed=ds.closed,
                                    indexes=indexes, primary=primary)
        self.stats["compactions"] += 1
