"""Live data ingestion — the paper's Twitter data-feed analogue (§III-A).

AsterixDB feeds append to LSM components and maintain indexes online; the
TPU-resident analogue (engine/lsm.py) is run-based: arriving rows buffer on
the host, flush into device-resident *runs* (block-padded, mesh-sharded,
with per-run sorted secondary indexes + zone maps built at flush time), and
compaction is *deferred* until the size-ratio policy fires — then a single
re-shard merges every component into the base. Queries see base ∪ runs (the
``UnionRuns`` plan node) — the same data before and after compaction,
exactly like querying an LSM tree across its components. Registered
materialized views refresh incrementally from each flushed delta.

Mutations follow the engine's anti-matter design (AsterixDB §III):

  * ``Feed.delete(keys)`` buffers an anti-matter record per key — at query
    or merge time it annihilates every matter record with that key in
    strictly older components.
  * ``Feed.upsert(rows)`` buffers an anti-matter record for each row's
    primary key plus the fresh matter — newest wins: all older rows with
    the key die, the upserted row survives.

A flush first *normalizes* the buffer (O(batch)): mutations later in the
buffer annihilate matter earlier in the same buffer on the host, so the
flushed run holds only intra-batch survivors plus one tombstone per key
that must still subtract from older components. Flush stays O(batch);
annihilation of older components is bookkeeping (O(tombstones · log n)),
never a rewrite.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.core.physical_planner import STALL_WARN_FRAC
from repro.engine import lsm
from repro.engine.table import Table, is_lane_column
from repro.runtime import telemetry as tel


def stall_delay(pressure: float, max_delay_s: float,
                warn_frac: float = STALL_WARN_FRAC) -> float:
    """Proportional (AsterixDB-style) write-stall delay.

    ``pressure`` is the planner's stall-pressure signal — resident
    components over the stall cap. Below ``warn_frac`` (the same threshold
    the planner flags ``stall_imminent`` at) the delay is zero; above it
    the delay grows linearly, reaching ``max_delay_s`` at pressure 1.0
    (the hard cap) and saturating there. The hard cap itself remains a
    blocking ceiling — this curve only slows the writer down smoothly on
    the approach instead of letting it slam into the cap and block for
    the full timeout."""
    if max_delay_s <= 0.0 or pressure < warn_frac:
        return 0.0
    return max_delay_s * min((pressure - warn_frac) / (1.0 - warn_frac), 1.0)


class Feed:
    def __init__(self, session, dataset: str, dataverse: str = "Default",
                 flush_rows: int = 4096,
                 policy: Optional[lsm.CompactionPolicy] = None,
                 compactor: Optional["lsm.BackgroundCompactor"] = None,
                 stall_runs: Optional[int] = None,
                 stall_timeout_s: float = 5.0,
                 stall_delay_s: float = 0.05):
        """``compactor`` moves compaction off the ingest hot path: flushes
        notify the background worker instead of merging inline, and the
        write-stall policy backpressures THIS writer — never readers.
        Backpressure is proportional: as resident components approach
        ``stall_runs`` (default: 2× the policy's ``max_runs``), each flush
        sleeps up to ``stall_delay_s`` along the planner's stall-pressure
        curve; at the hard cap the writer blocks up to ``stall_timeout_s``
        for the worker to catch up (the ceiling)."""
        self.session = session
        self.dataset = dataset
        self.dataverse = dataverse
        self.flush_rows = flush_rows
        self.policy = policy if policy is not None else lsm.CompactionPolicy()
        self.compactor = compactor
        self.stall_runs = stall_runs if stall_runs is not None \
            else max(2 * self.policy.max_runs, 4)
        self.stall_timeout_s = stall_timeout_s
        self.stall_delay_s = stall_delay_s
        self._buffer: list[tuple[str, object]] = []  # (kind, payload)
        self._buffered = 0
        # Durable feed WAL (runtime/durable.py): when the session's catalog
        # has a store attached, every validated batch is appended + fsynced
        # BEFORE the ack (the push/upsert/delete return), and the covered
        # prefix is truncated only after the covering flush's manifest
        # commit. ``_replay`` marks cold-start WAL replay: batches arriving
        # through the normal path must not be re-appended to the log they
        # came from.
        self._store = getattr(session.catalog, "store", None)
        self._replay = False
        self.stats = {"ingested": 0, "flushes": 0, "compactions": 0,
                      "runs": 0, "run_rows": 0,
                      "upserts": 0, "deletes": 0, "tombstones": 0,
                      "tombstones_flushed": 0, "level_merges": 0,
                      "stalls": 0, "soft_stalls": 0, "stall_s": 0.0}

    # -- ingest ------------------------------------------------------------

    def push(self, rows: dict[str, np.ndarray]) -> None:
        """Append a batch of arriving records (host-side buffer). The batch
        is validated against the dataset schema up front — a malformed batch
        raises here, not deep inside a device merge."""
        ds = self.session.catalog.get(self.dataverse, self.dataset)
        rows = _validate_batch(rows, ds.table)
        n = len(next(iter(rows.values())))
        self._wal("push", rows)
        self._buffer.append(("push", rows))
        self._buffered += n
        self.stats["ingested"] += n
        self._maybe_flush()

    def upsert(self, rows: dict[str, np.ndarray]) -> None:
        """Insert-or-replace by primary key: every older record with one of
        the batch's keys is annihilated (anti-matter), the batch's rows
        survive. Duplicate keys *within* the batch resolve newest-wins —
        only each key's last row is kept."""
        self._key_column("upsert")  # primary key required; raises without one
        ds = self.session.catalog.get(self.dataverse, self.dataset)
        rows = _validate_batch(rows, ds.table)
        n = len(next(iter(rows.values())))
        self._wal("upsert", rows)
        self._buffer.append(("upsert", rows))
        self._buffered += n
        self.stats["ingested"] += n
        self.stats["upserts"] += n
        self._maybe_flush()

    def delete(self, keys: np.ndarray) -> None:
        """Delete by primary key: buffers one anti-matter record per key.
        Deleting an absent key is a no-op (the tombstone annihilates
        nothing). All matter with the key dies — including duplicates a
        plain ``push`` appended."""
        key_col = self._key_column("delete")
        ds = self.session.catalog.get(self.dataverse, self.dataset)
        keys = _validate_keys(keys, ds.table, key_col)
        self._wal("delete", {"__keys__": keys})
        self._buffer.append(("delete", keys))
        self._buffered += len(keys)
        self.stats["deletes"] += len(keys)
        self._maybe_flush()

    def _wal(self, kind: str, payload: dict) -> None:
        """Durability ack: append the validated batch to the dataset's WAL
        and fsync before returning. Runs AFTER validation (a rejected batch
        never reaches the log) and BEFORE buffering (a crash mid-append —
        the ``torn-write`` fault — leaves a CRC-invalid tail and an
        un-acked, un-buffered batch: lost consistently on both sides)."""
        if self._store is not None and not self._replay:
            self._store.wal_append(self.dataverse, self.dataset, kind,
                                   payload)

    def _key_column(self, op: str) -> str:
        ds = self.session.catalog.get(self.dataverse, self.dataset)
        primary = ds.primary_index
        if primary is None:
            raise ValueError(
                f"Feed.{op} needs a primary key on "
                f"{self.dataverse}.{self.dataset} (anti-matter records "
                "annihilate by primary key; create the dataset with "
                "primary=<column>)")
        return primary.column

    def _maybe_flush(self) -> None:
        if self._buffered >= self.flush_rows:
            self.flush()

    def flush(self) -> None:
        """Normalize the host buffer (intra-batch newest-wins) and move it
        into a new device-resident run — O(batch): pad + shard + per-run
        index build, never touching the base. Older components only get
        their annihilation bookkeeping updated. Views registered on the
        dataset refresh from the delta (inserts) and the retraction (the
        old rows the tombstones just annihilated); the compaction policy
        may then fold components."""
        if not self._buffer:
            return
        t0 = time.perf_counter()
        ds_label = f"{self.dataverse}.{self.dataset}"
        # cold-start mounts rebuild their soft state at first bind — the
        # flush path reads host keys (annihilation) and index inventory
        lsm.ensure_soft(self.session, self.dataverse, self.dataset)
        ds = self.session.catalog.get(self.dataverse, self.dataset)
        key_col = ds.primary_index.column if ds.primary_index is not None else None
        # the buffer is the flush's write-ahead state: it is dropped only
        # AFTER the manifest publish succeeds, so a crash at the "flush" or
        # "pre-swap" fault point loses nothing — re-flushing replays the
        # exact same batch (normalization is pure). With a durable store
        # the on-disk WAL mirrors the buffer batch for batch.
        lsm._fault(self.session, "flush")
        cols, anti_keys = _normalize_buffer(self._buffer, ds.table, key_col)
        if not len(next(iter(cols.values()))) and anti_keys is None:
            self._buffer.clear()
            self._buffered = 0
            return
        if self._store is not None:
            # the WAL sequence this flush covers: every buffered batch was
            # appended at or below the current ack counter. The manifest
            # commit inside register_run embeds it (wal_upto), making the
            # covered prefix dead for replay purposes even if the truncate
            # below never happens (the pre-wal-truncate crash point).
            self._store.set_wal_coverage(
                self.dataverse, self.dataset,
                self._store.wal_seq(self.dataverse, self.dataset))
        run = lsm.make_run(self.session, ds, Table(cols), anti_keys=anti_keys)
        retracted = lsm.register_run(self.session, ds, run)
        if self._store is not None:
            # strictly after the covering manifest commit
            self._store.wal_truncate(self.dataverse, self.dataset)
        self._buffer.clear()
        self._buffered = 0
        self.session.refresh_views(self.dataverse, self.dataset, cols,
                                   retracted)
        self.stats["flushes"] += 1
        self._refresh_run_stats()
        if anti_keys is not None:  # post-normalization: actually flushed
            self.stats["tombstones_flushed"] += len(anti_keys)
        tel.inc("ingest.flushes_total", dataset=ds_label)
        tel.inc("ingest.flushed_rows_total", run.num_live_rows,
                dataset=ds_label)
        if anti_keys is not None:
            tel.inc("ingest.flushed_tombstones_total", len(anti_keys),
                    dataset=ds_label)
        tel.observe("ingest.flush_seconds", time.perf_counter() - t0,
                    dataset=ds_label)
        tel.set_gauge("ingest.resident_runs", self.stats["runs"],
                      dataset=ds_label)
        # Gauge (not histogram) so the write-stall series is populated —
        # and monotone — even on runs where no stall occurred.
        tel.set_gauge("ingest.stall_seconds_total", self.stats["stall_s"],
                      dataset=ds_label)
        self._apply_policy()

    def drop_buffer(self) -> None:
        """Discard the buffered (un-flushed) batches. Crash recovery uses
        this after a post-swap fault: the manifest already committed the
        flush, so replaying the buffer would double-apply it. With a
        durable store the WAL mirror of the dropped batches is truncated
        too — discard means discard on both sides."""
        self._buffer.clear()
        self._buffered = 0
        if self._store is not None and not self._replay:
            self._store.set_wal_coverage(
                self.dataverse, self.dataset,
                self._store.wal_seq(self.dataverse, self.dataset))
            self._store.wal_truncate(self.dataverse, self.dataset)

    def _refresh_run_stats(self) -> None:
        runs = self.session.catalog.get(self.dataverse, self.dataset).runs
        self.stats["runs"] = len(runs)
        self.stats["run_rows"] = sum(r.num_live_rows for r in runs)
        self.stats["tombstones"] = sum(r.anti_rows for r in runs)

    def _apply_policy(self) -> None:
        """Run the compaction policy to quiescence: leveled merges may
        cascade (an L0 fold can overflow L1), the full fold ends it.

        With a background compactor attached, this only notifies the worker
        — plus write-stall backpressure: as runs pile toward the hard cap
        THIS writer sleeps a proportional delay (the planner's
        stall-pressure curve), and at the cap it blocks until the count
        drops or the stall timeout expires. Readers never block either
        way."""
        if self.compactor is not None:
            self.compactor.notify(self.dataverse, self.dataset)
            runs = self.session.catalog.get(self.dataverse,
                                            self.dataset).runs
            ds_label = f"{self.dataverse}.{self.dataset}"
            if self.stall_runs and len(runs) >= self.stall_runs:
                waited = self.compactor.wait_below(
                    self.dataverse, self.dataset, self.stall_runs,
                    self.stall_timeout_s)
                self.stats["stalls"] += 1
                self.stats["stall_s"] += waited
                tel.inc("ingest.write_stalls_total", dataset=ds_label)
                tel.observe("ingest.write_stall_seconds", waited,
                            dataset=ds_label)
                tel.set_gauge("ingest.stall_seconds_total",
                              self.stats["stall_s"], dataset=ds_label)
                self._refresh_run_stats()
                return
            if self.stall_runs:
                # below the ceiling: proportional backpressure along the
                # same pressure signal the planner gauges (max of what the
                # planner last observed and this dataset's own run count)
                pressure = max(
                    len(runs) / self.stall_runs,
                    float(tel.gauge_value("planner.stall_pressure",
                                          default=0.0) or 0.0))
                delay = stall_delay(pressure, self.stall_delay_s)
                if delay > 0.0:
                    time.sleep(delay)
                    self.stats["soft_stalls"] += 1
                    self.stats["stall_s"] += delay
                    tel.inc("ingest.write_soft_stalls_total",
                            dataset=ds_label)
                    tel.observe("ingest.write_stall_seconds", delay,
                                dataset=ds_label)
                    tel.set_gauge("ingest.stall_seconds_total",
                                  self.stats["stall_s"], dataset=ds_label)
            return
        for _ in range(16):
            m = self.session.catalog.manifest(self.dataverse, self.dataset)
            ds = m.base
            actions = self.policy.plan(lsm._ManifestView(ds, m))
            if not actions:
                return
            act = actions[0]
            if act[0] == "full":
                self.compact()
                return
            _, start, end, level = act
            lsm.merge_runs(self.session, ds, start, end, level, manifest=m)
            self.stats["level_merges"] += 1
            self._refresh_run_stats()

    def compact(self) -> None:
        """Merge base ∪ runs into a fresh base (single newest-wins merge +
        re-sort + index rebuild; annihilated matter and tombstones drop).
        Query results are unchanged — the LSM invariant."""
        ds = self.session.catalog.get(self.dataverse, self.dataset)
        if not ds.runs:
            return
        lsm.compact(self.session, ds)
        self.stats["compactions"] += 1
        self.stats["runs"] = 0
        self.stats["run_rows"] = 0
        self.stats["tombstones"] = 0


def _normalize_buffer(buffer, base: Table, key_col: Optional[str]):
    """Resolve one flush's worth of interleaved push/upsert/delete batches
    into (surviving matter columns, sorted unique anti keys or None).

    Newest wins: a matter row survives the buffer iff no strictly LATER
    batch mutated its key; an upsert batch additionally keeps only each
    key's last occurrence. One reverse walk accumulates the kill-set of
    later mutations and masks every matter batch exactly once — O(total ·
    log tombstones), never quadratic in the batch count. The resulting
    anti set applies to strictly OLDER components only — survivors in this
    very flush are newer than the tombstones by construction."""
    kill: Optional[np.ndarray] = None  # sorted unique keys of later mutations
    matter: list[tuple[dict, np.ndarray]] = []  # reversed arrival order
    for kind, payload in reversed(buffer):
        if kind == "delete":
            keys = np.unique(np.asarray(payload))
            kill = keys if kill is None else np.union1d(kill, keys)
            continue
        keys = np.asarray(payload[key_col]) if key_col is not None else None
        if kind == "push":
            n = len(next(iter(payload.values())))
            live = np.ones(n, bool)
        else:  # upsert: last occurrence per key wins within the batch
            n = keys.shape[0]
            live = np.zeros(n, bool)
            _, last_rev = np.unique(keys[::-1], return_index=True)
            live[n - 1 - last_rev] = True
        if kill is not None and keys is not None:
            live &= ~np.isin(keys, kill)
        matter.append((payload, live))
        if kind == "upsert":
            uk = np.unique(keys)
            kill = uk if kill is None else np.union1d(kill, uk)
    matter.reverse()
    schema = [c for c in base.column_names()
              if c not in lsm.INTERNAL_COLUMNS
              and not is_lane_column(c)]
    out: dict[str, np.ndarray] = {}
    for c in schema:
        parts = [np.asarray(cols[c])[m] for cols, m in matter]
        if parts:
            out[c] = np.concatenate(parts, axis=0)
        else:
            tgt = np.asarray(base.columns[c])
            shape = (0,) if tgt.ndim == 1 else (0, tgt.shape[1])
            out[c] = np.zeros(shape, tgt.dtype)
    return out, kill


def _validate_keys(keys, base: Table, key_col: str) -> np.ndarray:
    """Validate one delete batch: 1-D, losslessly castable to the primary
    key's stored dtype."""
    a = np.asarray(keys)
    if a.ndim != 1:
        raise ValueError(f"delete keys must be 1-d, got {a.ndim}-d")
    tdt = np.asarray(base.columns[key_col]).dtype
    if not np.can_cast(a.dtype, tdt, casting="same_kind"):
        raise ValueError(
            f"delete keys: dtype {a.dtype} is not safely castable to "
            f"primary key dtype {tdt}")
    cast = a.astype(tdt, copy=False)
    if cast.dtype != a.dtype:
        roundtrip = cast.astype(a.dtype, copy=False)
        if not np.array_equal(roundtrip, a,
                              equal_nan=np.issubdtype(a.dtype, np.inexact)):
            raise ValueError(
                f"delete keys do not fit primary key dtype {tdt} "
                f"(lossy narrowing from {a.dtype})")
    return cast


def _validate_batch(rows: dict[str, np.ndarray], base: Table) -> dict[str, np.ndarray]:
    """Schema-check one pushed batch against the stored table: exact column
    set, rectangular, dtypes safely castable, string widths matching.
    Returns the batch cast to the base dtypes, in base column order."""
    schema = [c for c in base.column_names()
              if c not in lsm.INTERNAL_COLUMNS
              and not is_lane_column(c)]
    missing = [c for c in schema if c not in rows]
    extra = [c for c in rows if c not in schema]
    if missing or extra:
        parts = []
        if missing:
            parts.append(f"missing columns {missing}")
        if extra:
            parts.append(f"unexpected columns {extra}")
        raise ValueError(f"feed batch does not match dataset schema: "
                         f"{'; '.join(parts)} (expected {schema})")
    arrays = {c: np.asarray(rows[c]) for c in schema}
    lengths = {c: a.shape[0] for c, a in arrays.items()}
    if len(set(lengths.values())) > 1:
        raise ValueError(f"feed batch has ragged columns: {lengths}")
    out = {}
    for c in schema:
        a = arrays[c]
        tgt = base.columns[c]
        if a.ndim != tgt.ndim:
            raise ValueError(
                f"feed batch column {c!r}: expected {tgt.ndim}-d "
                f"(shape {tuple(tgt.shape[1:])} per row), got {a.ndim}-d")
        if a.ndim == 2 and a.shape[1] != tgt.shape[1]:
            raise ValueError(
                f"feed batch column {c!r}: fixed width {tgt.shape[1]} "
                f"expected, got {a.shape[1]}")
        tdt = np.dtype(tgt.dtype)
        if not np.can_cast(a.dtype, tdt, casting="same_kind"):
            raise ValueError(
                f"feed batch column {c!r}: dtype {a.dtype} is not safely "
                f"castable to dataset dtype {tdt}")
        cast = a.astype(tdt, copy=False)
        if cast.dtype != a.dtype:
            # same_kind permits narrowing (int64->int32): admit it only when
            # every value round-trips — a wrapped key would silently corrupt
            # joins/filters downstream, the exact failure this guard exists
            # to surface at push time.
            roundtrip = cast.astype(a.dtype, copy=False)
            if not np.array_equal(roundtrip, a,
                                  equal_nan=np.issubdtype(a.dtype, np.inexact)):
                raise ValueError(
                    f"feed batch column {c!r}: values do not fit dataset "
                    f"dtype {tdt} (lossy narrowing from {a.dtype})")
        out[c] = cast
    return out
