"""Live data ingestion — the paper's Twitter data-feed analogue (§III-A).

AsterixDB feeds append to LSM components and maintain indexes online; the
TPU-resident analogue (engine/lsm.py) is run-based: arriving rows buffer on
the host, flush into device-resident *runs* (block-padded, mesh-sharded,
with per-run sorted secondary indexes + zone maps built at flush time), and
compaction is *deferred* until the size-ratio policy fires — then a single
re-shard merges every component into the base. Queries see base ∪ runs (the
``UnionRuns`` plan node) — the same data before and after compaction,
exactly like querying an LSM tree across its components. Registered
materialized views refresh incrementally from each flushed delta.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.engine import lsm
from repro.engine.table import Table


class Feed:
    def __init__(self, session, dataset: str, dataverse: str = "Default",
                 flush_rows: int = 4096,
                 policy: Optional[lsm.CompactionPolicy] = None):
        self.session = session
        self.dataset = dataset
        self.dataverse = dataverse
        self.flush_rows = flush_rows
        self.policy = policy if policy is not None else lsm.CompactionPolicy()
        self._buffer: list[dict[str, np.ndarray]] = []
        self._buffered = 0
        self.stats = {"ingested": 0, "flushes": 0, "compactions": 0,
                      "runs": 0, "run_rows": 0}

    # -- ingest ------------------------------------------------------------

    def push(self, rows: dict[str, np.ndarray]) -> None:
        """Append a batch of arriving records (host-side buffer). The batch
        is validated against the dataset schema up front — a malformed batch
        raises here, not deep inside a device merge."""
        ds = self.session.catalog.get(self.dataverse, self.dataset)
        rows = _validate_batch(rows, ds.table)
        n = len(next(iter(rows.values())))
        self._buffer.append(rows)
        self._buffered += n
        self.stats["ingested"] += n
        if self._buffered >= self.flush_rows:
            self.flush()

    def flush(self) -> None:
        """Move the host buffer into a new device-resident run — O(batch):
        pad + shard + per-run index build, never touching the base. Views
        registered on the dataset refresh from the delta; the compaction
        policy may then fold the components back into the base."""
        if not self._buffer:
            return
        cols = {k: np.concatenate([b[k] for b in self._buffer], axis=0)
                for k in self._buffer[0]}
        self._buffer.clear()
        self._buffered = 0
        ds = self.session.catalog.get(self.dataverse, self.dataset)
        run = lsm.make_run(self.session, ds, Table(cols))
        lsm.register_run(self.session, ds, run)
        self.session.refresh_views(self.dataverse, self.dataset, cols)
        self.stats["flushes"] += 1
        self.stats["runs"] = len(ds.runs)
        self.stats["run_rows"] = sum(r.num_live_rows for r in ds.runs)
        if lsm.should_compact(ds, self.policy):
            self.compact()

    def compact(self) -> None:
        """Merge base ∪ runs into a fresh base (single re-shard + re-sort +
        index rebuild). Query results are unchanged — the LSM invariant."""
        ds = self.session.catalog.get(self.dataverse, self.dataset)
        if not ds.runs:
            return
        lsm.compact(self.session, ds)
        self.stats["compactions"] += 1
        self.stats["runs"] = 0
        self.stats["run_rows"] = 0


def _validate_batch(rows: dict[str, np.ndarray], base: Table) -> dict[str, np.ndarray]:
    """Schema-check one pushed batch against the stored table: exact column
    set, rectangular, dtypes safely castable, string widths matching.
    Returns the batch cast to the base dtypes, in base column order."""
    schema = [c for c in base.column_names() if c != "__valid__"]
    missing = [c for c in schema if c not in rows]
    extra = [c for c in rows if c not in schema]
    if missing or extra:
        parts = []
        if missing:
            parts.append(f"missing columns {missing}")
        if extra:
            parts.append(f"unexpected columns {extra}")
        raise ValueError(f"feed batch does not match dataset schema: "
                         f"{'; '.join(parts)} (expected {schema})")
    arrays = {c: np.asarray(rows[c]) for c in schema}
    lengths = {c: a.shape[0] for c, a in arrays.items()}
    if len(set(lengths.values())) > 1:
        raise ValueError(f"feed batch has ragged columns: {lengths}")
    out = {}
    for c in schema:
        a = arrays[c]
        tgt = base.columns[c]
        if a.ndim != tgt.ndim:
            raise ValueError(
                f"feed batch column {c!r}: expected {tgt.ndim}-d "
                f"(shape {tuple(tgt.shape[1:])} per row), got {a.ndim}-d")
        if a.ndim == 2 and a.shape[1] != tgt.shape[1]:
            raise ValueError(
                f"feed batch column {c!r}: fixed width {tgt.shape[1]} "
                f"expected, got {a.shape[1]}")
        tdt = np.dtype(tgt.dtype)
        if not np.can_cast(a.dtype, tdt, casting="same_kind"):
            raise ValueError(
                f"feed batch column {c!r}: dtype {a.dtype} is not safely "
                f"castable to dataset dtype {tdt}")
        cast = a.astype(tdt, copy=False)
        if cast.dtype != a.dtype:
            # same_kind permits narrowing (int64->int32): admit it only when
            # every value round-trips — a wrapped key would silently corrupt
            # joins/filters downstream, the exact failure this guard exists
            # to surface at push time.
            roundtrip = cast.astype(a.dtype, copy=False)
            if not np.array_equal(roundtrip, a,
                                  equal_nan=np.issubdtype(a.dtype, np.inexact)):
                raise ValueError(
                    f"feed batch column {c!r}: values do not fit dataset "
                    f"dtype {tdt} (lossy narrowing from {a.dtype})")
        out[c] = cast
    return out
