"""Physical operators, XLA-SPMD flavor.

Every streaming operator maps ``(env, mask) -> (env, mask)`` where ``env`` is
a dict of equal-length columns and ``mask`` marks live rows (the vectorized-DB
selection-vector idea — TPU has no dynamic shapes, so filters never compact;
compaction happens only at LIMIT/TopK/collect boundaries).

This module is written in plain jnp over (possibly) sharded arrays: under
``jit`` XLA GSPMD inserts the collectives (psum for reductions, all-gathers
for sorts). ``engine/distributed.py`` holds the explicit ``shard_map``
versions with hand-scheduled collectives (the beyond-paper optimized mode).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

Env = dict[str, jax.Array]

NEG = -(2**62)
POS = 2**62


def _minval(dtype):
    return jnp.finfo(dtype).min if jnp.issubdtype(dtype, jnp.floating) else jnp.iinfo(dtype).min


def _maxval(dtype):
    return jnp.finfo(dtype).max if jnp.issubdtype(dtype, jnp.floating) else jnp.iinfo(dtype).max


# -- streaming ops ------------------------------------------------------------


def filter_(env: Env, mask: jax.Array, pred: jax.Array) -> tuple[Env, jax.Array]:
    return env, mask & pred


def project(env: Env, mask: jax.Array, outputs: Env) -> tuple[Env, jax.Array]:
    return outputs, mask


def limit(env: Env, mask: jax.Array, n: int) -> tuple[Env, jax.Array]:
    """Compact the first ``n`` live rows into a length-``n`` table."""
    idx = jnp.nonzero(mask, size=n, fill_value=mask.shape[0] - 1)[0]
    found = jnp.minimum(jnp.sum(mask), n)
    out = {k: v[idx] for k, v in env.items()}
    new_mask = jnp.arange(n) < found
    return out, new_mask


def _select_topk(score: jax.Array, mask: jax.Array, k: int) -> jax.Array:
    """Default selection primitive: indices of the k largest masked scores
    (lowest index wins ties)."""
    _, idx = jax.lax.top_k(jnp.where(mask, score, -jnp.inf), k)
    return idx


def kernel_topk_select(backend=None):
    """Selection primitive backed by the block_topk Pallas kernel
    (kernels/topk_mask.py) — same contract as :func:`_select_topk`."""
    def select(score, mask, k):
        from repro.kernels import ops

        _, idx = ops.topk(score, mask, mask.shape[0], k, backend=backend)
        return idx
    return select


def topk(env: Env, mask: jax.Array, key: str, k: int, ascending: bool,
         select=_select_topk) -> tuple[Env, jax.Array]:
    """Score prep (f32 cast, ascending negation), selection via ``select``,
    then gather/compaction — the single home of the top-k contract; the
    kernel mode only swaps the selection primitive."""
    col = env[key]
    score = col.astype(jnp.float32) if not jnp.issubdtype(col.dtype, jnp.floating) else col
    if ascending:
        score = -score
    idx = select(score, mask, k)
    found = jnp.minimum(jnp.sum(mask), k)
    out = {kk: v[idx] for kk, v in env.items()}
    return out, jnp.arange(k) < found


def sort_full(env: Env, mask: jax.Array, key: str, ascending: bool) -> tuple[Env, jax.Array]:
    """One stable argsort on the sentineled key, either direction — no float
    cast (lossless for int64 keys) and no second sort for descending."""
    col = env[key]
    sk = jnp.where(mask, col, _maxval(col.dtype) if ascending else _minval(col.dtype))
    order = jnp.argsort(sk, stable=True, descending=not ascending)
    out = {k: v[order] for k, v in env.items()}
    return out, mask[order]


# -- terminal aggregates ------------------------------------------------------


def agg_scalar(env: Env, mask: jax.Array, op: str, column: Optional[str]) -> jax.Array:
    if op == "count":
        return jnp.sum(mask, dtype=jnp.int32)
    col = env[column]
    if op == "max":
        return jnp.max(jnp.where(mask, col, _minval(col.dtype)))
    if op == "min":
        return jnp.min(jnp.where(mask, col, _maxval(col.dtype)))
    if op == "sum":
        return jnp.sum(jnp.where(mask, col, 0))
    if op == "mean":
        s = jnp.sum(jnp.where(mask, col, 0).astype(jnp.float32))
        return s / jnp.maximum(jnp.sum(mask), 1)
    raise ValueError(op)


def group_agg(env: Env, mask: jax.Array, key: str, lo: int, num_groups: int,
              aggs: list[tuple[str, str, Optional[str]]]) -> tuple[Env, jax.Array]:
    """Bounded-domain group-by: group id = key - lo.

    Aggregation is a segment reduction; on TPU the count/sum cases lower to a
    one-hot matmul on the MXU (see kernels/segment_agg.py for the Pallas
    version used by the optimized mode). Cross-shard merge: psum via GSPMD.
    """
    key_col = env[key]
    gid = (key_col - lo).astype(jnp.int32)
    gid = jnp.where(mask, gid, num_groups)  # dump dead rows in overflow bucket
    out: Env = {key: jnp.arange(lo, lo + num_groups, dtype=key_col.dtype)}
    counts = jax.ops.segment_sum(mask.astype(jnp.int32), gid, num_groups + 1)[:num_groups]
    for out_name, op, column in aggs:
        if op == "count":
            out[out_name] = counts
        elif op in ("sum", "mean"):
            col = jnp.where(mask, env[column], 0)
            s = jax.ops.segment_sum(col, gid, num_groups + 1)[:num_groups]
            out[out_name] = (s / jnp.maximum(counts, 1)) if op == "mean" else s
        elif op == "max":
            col = jnp.where(mask, env[column], _minval(env[column].dtype))
            out[out_name] = jax.ops.segment_max(col, gid, num_groups + 1)[:num_groups]
        elif op == "min":
            col = jnp.where(mask, env[column], _maxval(env[column].dtype))
            out[out_name] = jax.ops.segment_min(col, gid, num_groups + 1)[:num_groups]
        else:
            raise ValueError(op)
    return out, counts > 0


# -- joins ---------------------------------------------------------------------


def join_count(lkey: jax.Array, lmask: jax.Array, rkey: jax.Array, rmask: jax.Array) -> jax.Array:
    """Exact inner-equi-join cardinality via sort + vectorized binary search.

    TPU-native replacement for AsterixDB's hybrid-hash join: no hash table —
    sort the build side (bitonic on TPU), then each probe row finds its match
    run with two ``searchsorted`` calls; |run| = upper - lower. Correct for
    arbitrary duplicates on both sides.
    """
    sentinel = _maxval(rkey.dtype)
    rs = jnp.sort(jnp.where(rmask, rkey, sentinel))
    n_r = jnp.sum(rmask)
    lo = jnp.searchsorted(rs, lkey, side="left")
    hi = jnp.searchsorted(rs, lkey, side="right")
    hi = jnp.minimum(hi, n_r)  # sentinel region is not real data
    cnt = jnp.where(lmask, jnp.maximum(hi - lo, 0), 0)
    return jnp.sum(cnt, dtype=jnp.int32)


def join_materialize(lenv: Env, lmask: jax.Array, renv: Env, rmask: jax.Array,
                     left_on: str, right_on: str, suffix: str = "_r") -> tuple[Env, jax.Array]:
    """Left-probe inner join, unique build keys (paper's Wisconsin unique1).

    Each live left row gathers its single match from the right side; output
    has the left side's length (static), mask = matched & live.
    """
    rkey = renv[right_on]
    sentinel = _maxval(rkey.dtype)
    skey = jnp.where(rmask, rkey, sentinel)
    order = jnp.argsort(skey)
    rs = skey[order]
    lkey = lenv[left_on]
    pos = jnp.searchsorted(rs, lkey, side="left")
    pos = jnp.minimum(pos, rs.shape[0] - 1)
    matched = (rs[pos] == lkey) & lmask
    src = order[pos]
    out = dict(lenv)
    for k, v in renv.items():
        name = k if k not in lenv else k + suffix
        out[name] = v[src]
    return out, matched


# -- index access ---------------------------------------------------------------


def index_range_count(sorted_keys: jax.Array, num_valid: jax.Array,
                      lo: Optional[jax.Array], hi: Optional[jax.Array]) -> jax.Array:
    """Index-only range count: two binary searches over the sorted key column
    (paper expression 11 with ``AFrame Index`` — the order-of-magnitude win)."""
    lo_pos = jnp.searchsorted(sorted_keys, lo, side="left") if lo is not None else jnp.int32(0)
    hi_pos = jnp.searchsorted(sorted_keys, hi, side="right") if hi is not None else num_valid
    hi_pos = jnp.minimum(hi_pos, num_valid)
    lo_pos = jnp.minimum(lo_pos, num_valid)
    return jnp.maximum(hi_pos - lo_pos, 0).astype(jnp.int32)


def index_range_mask(keys: jax.Array, valid: jax.Array,
                     lo: Optional[jax.Array], hi: Optional[jax.Array]) -> jax.Array:
    m = valid
    if lo is not None:
        m = m & (keys >= lo)
    if hi is not None:
        m = m & (keys <= hi)
    return m
