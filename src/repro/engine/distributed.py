"""Explicit shared-nothing relational operators (shard_map + hand-placed
collectives) — the optimized execution mode of the engine.

The GSPMD mode (plain jnp under jit) lets XLA insert collectives; it tends to
all-gather whole columns for sorts/joins. This module is the beyond-paper
optimized path: every operator does shard-local work sized O(rows/shard) and
merges with the *minimal* collective —

  operator          local work                merge collective
  ----------------- ------------------------- -------------------------------
  filter+count      masked popcount           psum (4 B)
  scalar agg        local min/max/sum         psum/pmax/pmin (4-8 B)
  group-by agg      segment_sum (G buckets)   psum (G × aggs)
  top-k             local lax.top_k(k)        all_gather(k) + final top_k
  limit(n)          local compact(n)          all_gather(n) + recompact
  join count        local sort + probe        all_gather of build keys
                    (or hash all-to-all repartition — see
                    ``hash_repartition_counts``)
  index range count searchsorted per shard    psum

All functions take (mesh, data_axes); on a 1-device mesh they degenerate to
the local op (tests run both paths and assert equality).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

try:
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.engine import physical


def _dp(data_axes: tuple[str, ...]):
    return data_axes if len(data_axes) > 1 else data_axes[0]


def _smap(mesh, data_axes, fn, in_specs, out_specs):
    # check_vma=False: the replication checker cannot statically see that
    # all_gather + identical local computation yields replicated outputs
    # (merge-style operators below are deterministic post-gather).
    try:
        return _shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                          check_vma=False)
    except TypeError:  # older jax: check_rep
        return _shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                          check_rep=False)


# -- scalar aggregation -----------------------------------------------------------


def dist_count(mesh: Mesh, data_axes, mask: jax.Array) -> jax.Array:
    dp = _dp(data_axes)

    def local(m):
        return jax.lax.psum(jnp.sum(m, dtype=jnp.int32), data_axes)

    return _smap(mesh, data_axes, local, (P(dp),), P())(mask)


def dist_agg(mesh: Mesh, data_axes, op: str, col: jax.Array, mask: jax.Array):
    dp = _dp(data_axes)

    def local(c, m):
        v = physical.agg_scalar({"c": c}, m, op, "c")
        if op in ("count", "sum"):
            return jax.lax.psum(v, data_axes)
        if op == "max":
            return jax.lax.pmax(v, data_axes)
        if op == "min":
            return jax.lax.pmin(v, data_axes)
        if op == "mean":
            s = jax.lax.psum(jnp.sum(jnp.where(m, c, 0).astype(jnp.float32)), data_axes)
            n = jax.lax.psum(jnp.sum(m, dtype=jnp.int32), data_axes)
            return s / jnp.maximum(n, 1)
        raise ValueError(op)

    cspec = P(dp) if col.ndim == 1 else P(dp, None)
    return _smap(mesh, data_axes, local, (cspec, P(dp)), P())(col, mask)


# -- group by ----------------------------------------------------------------------


def dist_group_agg(mesh: Mesh, data_axes, key_col, mask, lo: int, num_groups: int,
                   aggs, value_cols: dict):
    """Bounded-domain group-by: local segment reduction, psum merge.

    ``aggs``: [(out_name, op, col|None)]; ``value_cols``: {col: array}.
    ``mean`` decomposes into psum(sum)/psum(count). Output replicated
    (G rows — the merged group table)."""
    dp = _dp(data_axes)
    names = sorted(value_cols)
    # decompose mean into sum+count primitives
    prim: list[tuple[str, str, Optional[str]]] = [("__n__", "count", None)]
    for o, op, c in aggs:
        if op == "mean":
            prim.append((f"__sum_{o}", "sum", c))
        else:
            prim.append((o, op, c))

    def local(key, m, *cols):
        env = {"__key__": key, **dict(zip(names, cols))}
        out, _ = physical.group_agg(env, m, "__key__", lo, num_groups, prim)
        merged = {}
        for o, op, c in prim:
            if op in ("count", "sum"):
                merged[o] = jax.lax.psum(out[o], data_axes)
            elif op == "max":
                merged[o] = jax.lax.pmax(out[o], data_axes)
            elif op == "min":
                merged[o] = jax.lax.pmin(out[o], data_axes)
        return out["__key__"], tuple(merged[o] for o, _, _ in prim)

    in_specs = (P(dp), P(dp)) + tuple(P(dp) for _ in names)
    out_specs = (P(), tuple(P() for _ in prim))
    key_out, vals = _smap(mesh, data_axes, local, in_specs, out_specs)(
        key_col, mask, *[value_cols[n] for n in names])
    merged = {o: v for (o, _, _), v in zip(prim, vals)}
    out = {"__key__": key_out}
    for o, op, c in aggs:
        if op == "mean":
            out[o] = merged[f"__sum_{o}"] / jnp.maximum(merged["__n__"], 1)
        else:
            out[o] = merged[o]
    return out, merged["__n__"] > 0


# -- top-k / limit -----------------------------------------------------------------


def dist_topk(mesh: Mesh, data_axes, env: dict, mask, key: str, k: int,
              ascending: bool, select=physical._select_topk):
    """Local top-k then k-per-shard gather + final top-k (ring merge).
    ``select`` swaps the selection primitive (the kernel mode passes the
    block_topk Pallas kernel); the merge structure is identical."""
    dp = _dp(data_axes)
    names = sorted(env)

    def local(m, *cols):
        e = dict(zip(names, cols))
        le, lm = physical.topk(e, m, key, min(k, m.shape[0]), ascending,
                               select=select)
        ge = {n: jax.lax.all_gather(le[n], data_axes, tiled=True) for n in names}
        gm = jax.lax.all_gather(lm, data_axes, tiled=True)
        return physical.topk(ge, gm, key, k, ascending, select=select)

    in_specs = (P(dp),) + tuple(P(dp) if env[n].ndim == 1 else P(dp, None) for n in names)
    out_specs = ({n: P() if env[n].ndim == 1 else P(None, None) for n in names}, P())
    return _smap(mesh, data_axes, local, in_specs, out_specs)(
        mask, *[env[n] for n in names])


def dist_limit(mesh: Mesh, data_axes, env: dict, mask, n: int):
    """Local compact(n) + gather + global first-n (order: shard-major)."""
    dp = _dp(data_axes)
    names = sorted(env)

    def local(m, *cols):
        e = dict(zip(names, cols))
        le, lm = physical.limit(e, m, n)
        ge = {k2: jax.lax.all_gather(le[k2], data_axes, tiled=True) for k2 in names}
        gm = jax.lax.all_gather(lm, data_axes, tiled=True)
        return physical.limit(ge, gm, n)

    in_specs = (P(dp),) + tuple(P(dp) if env[nm].ndim == 1 else P(dp, None) for nm in names)
    out_specs = ({nm: P() if env[nm].ndim == 1 else P(None, None) for nm in names}, P())
    return _smap(mesh, data_axes, local, in_specs, out_specs)(
        mask, *[env[nm] for nm in names])


# -- joins -------------------------------------------------------------------------


def dist_join_count(mesh: Mesh, data_axes, lkey, lmask, rkey, rmask,
                    presorted_right: bool = False) -> jax.Array:
    """Broadcast-merge join count: gather build-side keys (sorted), probe
    locally with binary search, psum. The AFrame-Index analogue — with a
    sorted index the build side skips its local sort."""
    dp = _dp(data_axes)

    def local(lk, lm, rk, rm):
        sentinel = physical._maxval(rk.dtype)
        rs = rk if presorted_right else jnp.sort(jnp.where(rm, rk, sentinel))
        n_r_local = jnp.sum(rm)
        rs_g = jax.lax.all_gather(rs, data_axes, tiled=True)  # gathered sorted runs
        rs_g = jnp.sort(rs_g)  # merge runs (single vector sort)
        n_r = jax.lax.psum(n_r_local, data_axes)
        lo = jnp.searchsorted(rs_g, lk, side="left")
        hi = jnp.searchsorted(rs_g, lk, side="right")
        hi = jnp.minimum(hi, n_r)
        cnt = jnp.where(lm, jnp.maximum(hi - lo, 0), 0)
        return jax.lax.psum(jnp.sum(cnt, dtype=jnp.int64), data_axes)

    return _smap(mesh, data_axes, local, (P(dp), P(dp), P(dp), P(dp)), P())(
        lkey, lmask, rkey, rmask)


def hash_repartition_counts(mesh: Mesh, data_axes, lkey, lmask, rkey, rmask,
                            capacity_factor: float = 2.0) -> jax.Array:
    """Hybrid-hash analogue: all-to-all repartition both sides by key hash so
    matching keys land on one shard, then local sort-merge count + psum.

    Static capacity per (src, dst) bucket with an overflow-drop counter
    (returned as part of a tuple in tests); capacity_factor=2 keeps drops at
    0 for uniform keys (Wisconsin)."""
    dp = _dp(data_axes)
    nsh = int(np.prod([mesh.shape[a] for a in data_axes]))

    def local(lk, lm, rk, rm):
        def repartition(k, m):
            n = k.shape[0]
            cap = int(np.ceil(n / nsh * capacity_factor))
            dest = (k.astype(jnp.uint32) % nsh).astype(jnp.int32)
            dest = jnp.where(m, dest, nsh)  # dead rows -> overflow bucket
            order = jnp.argsort(dest)
            ds = dest[order]
            ks = k[order]
            starts = jnp.searchsorted(ds, jnp.arange(nsh + 1), side="left")
            rank = jnp.arange(n) - starts[jnp.clip(ds, 0, nsh)]
            keep = (ds < nsh) & (rank < cap)
            slot = jnp.clip(ds, 0, nsh - 1) * cap + jnp.minimum(rank, cap - 1)
            slot = jnp.where(keep, slot, nsh * cap)  # trash slot for drops
            buf = jnp.zeros((nsh * cap + 1,), k.dtype).at[slot].set(ks)[:-1]
            bm = jnp.zeros((nsh * cap + 1,), jnp.bool_).at[slot].set(keep)[:-1]
            dropped = jnp.sum(m, dtype=jnp.int32) - jnp.sum(keep, dtype=jnp.int32)
            buf = buf.reshape(nsh, cap)
            bm = bm.reshape(nsh, cap)
            # all_to_all: axis 0 is the destination shard
            buf = jax.lax.all_to_all(buf, data_axes, split_axis=0, concat_axis=0,
                                     tiled=True)
            bm = jax.lax.all_to_all(bm, data_axes, split_axis=0, concat_axis=0,
                                    tiled=True)
            return buf.reshape(-1), bm.reshape(-1), dropped

        lbuf, lbm, ldrop = repartition(lk, lm)
        rbuf, rbm, rdrop = repartition(rk, rm)
        cnt = physical.join_count(lbuf, lbm, rbuf, rbm)
        total = jax.lax.psum(cnt.astype(jnp.int32), data_axes)
        drops = jax.lax.psum(ldrop + rdrop, data_axes)
        return total, drops

    return _smap(mesh, data_axes, local, (P(dp), P(dp), P(dp), P(dp)),
                 (P(), P()))(lkey, lmask, rkey, rmask)


# -- kernel-mode compositions -------------------------------------------------------
#
# The kernel execution mode runs the Pallas relational kernels shard-locally
# and merges partials with the same minimal collectives as the shard_map
# operators above: filter-count / group-agg psum their partial counts/sums,
# join-count gathers the (sorted) build side. (Kernel top-k reuses dist_topk
# with the block_topk selection primitive — no separate composition needed.)


def dist_kernel_filter_count(mesh: Mesh, data_axes, cols_mat: jax.Array,
                             bounds: jax.Array, backend=None,
                             block_ids=None, shard_blocks=None,
                             interpret=None) -> jax.Array:
    """cols_mat: (k, n) int32 predicate tile, row-sharded on axis 1; bounds:
    (k, 2) replicated runtime params. Each shard runs filter_count over its
    local tile (any padding rows arrive pre-folded as a mask row with bounds
    (1, 1)); merge is one 4-byte psum.

    ``block_ids`` are zone-block survivors over the GLOBAL row layout
    (single-shard meshes only, where local == global). ``shard_blocks`` is
    the multi-shard form: a host (n_shards, m) int32 matrix of per-shard
    LOCAL kernel-block ids, ``-1``-padded to the max surviving count
    (``ops.shard_block_arrays``). Row ``s`` rides to shard ``s`` through a
    ``P(dp, None)``-sharded operand, so every shard's scalar-prefetched
    grid scans only its own survivors — one compiled grid for all shards,
    pad steps are gated no-ops."""
    from repro.kernels import ops
    from repro.kernels.filter_count import BLOCK as _FC_BLOCK
    from repro.runtime import telemetry as tel

    dp = _dp(data_axes)
    if block_ids is not None:
        nsh = int(np.prod([mesh.shape[a] for a in data_axes]))
        assert nsh == 1, "global block_ids require a single-shard mesh " \
                         "(use shard_blocks on multi-shard meshes)"
    if shard_blocks is not None:
        assert block_ids is None
        sb = np.asarray(shard_blocks, np.int32)
        nsh = int(np.prod([mesh.shape[a] for a in data_axes]))
        assert sb.shape[0] == nsh, (sb.shape, nsh)
        # true scanned/skipped accounting lives here, where the pad -1s are
        # visible — the per-shard grid length over-counts by the padding.
        nb_local = -(-(cols_mat.shape[1] // nsh) // _FC_BLOCK)
        scanned = int((sb >= 0).sum())
        tel.inc("kernel.blocks_scanned_total", scanned, kernel="filter_count")
        tel.inc("kernel.blocks_skipped_total", nsh * nb_local - scanned,
                kernel="filter_count")

        def local_arr(cm, b, ids):
            c = ops.filter_count(cm, b, cm.shape[1], backend=backend,
                                 block_ids_arr=ids.reshape(-1),
                                 interpret=interpret)
            return jax.lax.psum(c, data_axes)

        return _smap(mesh, data_axes, local_arr,
                     (P(None, dp), P(None, None), P(dp, None)), P())(
            cols_mat, bounds, jnp.asarray(sb))

    def local(cm, b):
        c = ops.filter_count(cm, b, cm.shape[1], backend=backend,
                             block_ids=block_ids, interpret=interpret)
        return jax.lax.psum(c, data_axes)

    return _smap(mesh, data_axes, local, (P(None, dp), P(None, None)), P())(
        cols_mat, bounds)


def dist_kernel_group_agg(mesh: Mesh, data_axes, gids: jax.Array,
                          values: jax.Array, num_groups: int, op: str = "sum",
                          backend=None, block_ids=None, shard_blocks=None,
                          interpret=None) -> jax.Array:
    """gids: (n,) int32 (-1 for dead rows); values: (n, C) f32. Shard-local
    one-hot segment reductions, minimal-collective merge (psum for sums,
    pmax/pmin for extremes) -> replicated (G, C). ``block_ids`` /
    ``shard_blocks`` as in :func:`dist_kernel_filter_count` (shard_blocks
    ids are in segment_agg's OWN kernel-block units)."""
    from repro.kernels import ops
    from repro.kernels.segment_agg import BLOCK as _SA_BLOCK
    from repro.runtime import telemetry as tel

    dp = _dp(data_axes)
    merge = {"sum": jax.lax.psum, "max": jax.lax.pmax, "min": jax.lax.pmin}[op]
    if block_ids is not None:
        nsh = int(np.prod([mesh.shape[a] for a in data_axes]))
        assert nsh == 1, "global block_ids require a single-shard mesh " \
                         "(use shard_blocks on multi-shard meshes)"
    if shard_blocks is not None:
        assert block_ids is None
        sb = np.asarray(shard_blocks, np.int32)
        nsh = int(np.prod([mesh.shape[a] for a in data_axes]))
        assert sb.shape[0] == nsh, (sb.shape, nsh)
        nb_local = -(-(gids.shape[0] // nsh) // _SA_BLOCK)
        scanned = int((sb >= 0).sum())
        tel.inc("kernel.blocks_scanned_total", scanned, kernel="segment_agg")
        tel.inc("kernel.blocks_skipped_total", nsh * nb_local - scanned,
                kernel="segment_agg")

        def local_arr(g, v, ids):
            out = ops.segment_agg(v, g, num_groups, v.shape[0], op=op,
                                  backend=backend,
                                  block_ids_arr=ids.reshape(-1),
                                  interpret=interpret)
            return merge(out, data_axes)

        return _smap(mesh, data_axes, local_arr,
                     (P(dp), P(dp, None), P(dp, None)), P(None, None))(
            gids, values, jnp.asarray(sb))

    def local(g, v):
        out = ops.segment_agg(v, g, num_groups, v.shape[0], op=op,
                              backend=backend, block_ids=block_ids,
                              interpret=interpret)
        return merge(out, data_axes)

    return _smap(mesh, data_axes, local, (P(dp), P(dp, None)), P(None, None))(
        gids, values)


def dist_kernel_join_count(mesh: Mesh, data_axes, lkey, lmask, rkey, rmask,
                           presorted_right: bool = False, backend=None) -> jax.Array:
    """Broadcast-merge join count on the merge_join kernel: sort the local
    probe shard, gather+merge the (sorted) build side, run the block merge
    join per shard, psum. With a sorted index the build side skips its local
    sort (``presorted_right``)."""
    from repro.kernels import ops

    dp = _dp(data_axes)

    def local(lk, lm, rk, rm):
        ls = ops.sort_join_keys(lk, lm)
        rs_local = ops.sort_join_keys(rk, rm, presorted=presorted_right)
        rs = jnp.sort(jax.lax.all_gather(rs_local, data_axes, tiled=True))
        nl = jnp.sum(lm, dtype=jnp.int32)
        nr = jax.lax.psum(jnp.sum(rm, dtype=jnp.int32), data_axes)
        c = ops.merge_join_count(ls, rs, nl, nr, backend=backend)
        return jax.lax.psum(c.astype(jnp.int32), data_axes)

    return _smap(mesh, data_axes, local, (P(dp), P(dp), P(dp), P(dp)), P())(
        lkey, lmask, rkey, rmask)


# -- index -------------------------------------------------------------------------


def dist_index_count(mesh: Mesh, data_axes, sorted_keys, valid, lo, hi):
    """Index-only range count: per-shard binary search + psum.

    ``valid``: the base table's validity column (per-shard num_valid is its
    local popcount — padding rows sort to the +inf tail of the index)."""
    from repro.engine.index import index_count_local

    dp = _dp(data_axes)

    def local(sk, v, lo_, hi_):
        nv = jnp.sum(v, dtype=jnp.int32)
        c = index_count_local(sk, nv, lo_ if lo is not None else None,
                              hi_ if hi is not None else None)
        return jax.lax.psum(c.astype(jnp.int32), data_axes)

    lo_a = jnp.asarray(lo if lo is not None else 0)
    hi_a = jnp.asarray(hi if hi is not None else 0)
    return _smap(mesh, data_axes, local, (P(dp), P(dp), P(), P()), P())(
        sorted_keys, valid, lo_a, hi_a)


def dist_shadow_count(mesh: Mesh, data_axes, sorted_keys, valid, anti_keys,
                      lo, hi):
    """Anti-matter subtrahend of the index-only count: the (replicated,
    pre-deduplicated) tombstone keys probe each shard's sorted primary
    index, per-shard occurrence counts psum — the same collective shape as
    :func:`dist_index_count`."""
    from repro.engine.index import shadow_count_local

    dp = _dp(data_axes)

    def local(sk, v, ak, lo_, hi_):
        nv = jnp.sum(v, dtype=jnp.int32)
        c = shadow_count_local(sk, nv, ak,
                               lo_ if lo is not None else None,
                               hi_ if hi is not None else None)
        return jax.lax.psum(c.astype(jnp.int32), data_axes)

    lo_a = jnp.asarray(lo if lo is not None else 0)
    hi_a = jnp.asarray(hi if hi is not None else 0)
    return _smap(mesh, data_axes, local,
                 (P(dp), P(dp), P(), P(), P()), P())(
        sorted_keys, valid, anti_keys, lo_a, hi_a)
