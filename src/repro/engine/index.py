"""Indexes: sorted-column secondary indexes + clustered primary order.

AsterixDB's B-trees have no TPU analogue (pointer chasing); the TPU-native
equivalent (DESIGN.md §2) is *sorted storage*: a secondary index is the
sorted key column plus the row-id permutation, built per shard (AsterixDB's
per-NC local indexes) so every probe is a vectorized ``searchsorted``:
  * range COUNT   — two binary searches per shard + psum (index-only query)
  * range + LIMIT — gather k row-ids from the sorted run (no scan)
  * equi-join     — the build side is pre-sorted: merge-join without sorting
Zone maps (per-block min/max of the sorted keys) ride along; the filter
kernel's block skipping uses the storage-order zone maps on
``Dataset.block_zones`` (engine/table.py ``compute_block_zones``) instead,
since that is the layout its grid streams.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

ZONE_BLOCK = 1024


@dataclasses.dataclass
class SortedIndex:
    """Per-shard sorted view of one column (device arrays, possibly sharded).

    ``sorted_keys[i]`` ascending within each shard; ``row_ids`` maps back to
    base-table row positions (shard-local). Invalid (padding) rows sort to
    the end via +inf sentinel and are excluded by ``num_valid``.
    """

    column: str
    kind: str  # "primary" | "secondary"
    sorted_keys: jax.Array  # (n,) per-shard-sorted
    row_ids: jax.Array      # (n,) int32 shard-local positions
    zone_min: jax.Array     # (n / ZONE_BLOCK,)
    zone_max: jax.Array


def _sentinel_max(dtype):
    return jnp.array(np.finfo(dtype).max if jnp.issubdtype(dtype, jnp.floating)
                     else np.iinfo(dtype).max, dtype)


def build_index_local(keys: jax.Array, valid: jax.Array, column: str,
                      kind: str = "secondary") -> SortedIndex:
    """Single-shard build (wrapped in shard_map for sharded tables)."""
    sk = jnp.where(valid, keys, _sentinel_max(keys.dtype))
    order = jnp.argsort(sk)
    sorted_keys = sk[order]
    n = keys.shape[0]
    pad = (-n) % ZONE_BLOCK
    zk = jnp.pad(sorted_keys, (0, pad), constant_values=sorted_keys[-1] if n else 0)
    zk = zk.reshape(-1, ZONE_BLOCK)
    return SortedIndex(column, kind, sorted_keys, order.astype(jnp.int32),
                       zk.min(axis=1), zk.max(axis=1))


def index_count_local(ix_keys: jax.Array, num_valid: jax.Array, lo, hi) -> jax.Array:
    """Range count on one shard's sorted keys (index-only)."""
    lo_pos = jnp.searchsorted(ix_keys, lo, side="left") if lo is not None else jnp.int32(0)
    hi_pos = jnp.searchsorted(ix_keys, hi, side="right") if hi is not None else num_valid
    hi_pos = jnp.minimum(hi_pos, num_valid)
    lo_pos = jnp.minimum(lo_pos, num_valid)
    return jnp.maximum(hi_pos - lo_pos, 0).astype(jnp.int32)


def shadow_count_local(ix_keys: jax.Array, num_valid: jax.Array,
                       anti_keys: jax.Array, lo, hi) -> jax.Array:
    """Anti-matter subtrahend on one shard: for every tombstone key inside
    [lo, hi], count its matter occurrences in the sorted (primary) index —
    two batched binary searches. ``anti_keys`` must already be deduplicated
    (the compiler bakes in a sorted-unique union: a row dies exactly once)."""
    l = jnp.minimum(jnp.searchsorted(ix_keys, anti_keys, side="left"), num_valid)
    r = jnp.minimum(jnp.searchsorted(ix_keys, anti_keys, side="right"), num_valid)
    occ = jnp.maximum(r - l, 0)
    keep = jnp.ones(anti_keys.shape, jnp.bool_)
    if lo is not None:
        keep = keep & (anti_keys >= lo)
    if hi is not None:
        keep = keep & (anti_keys <= hi)
    return jnp.sum(jnp.where(keep, occ, 0), dtype=jnp.int32)


def index_head_rows_local(ix: SortedIndex, num_valid, lo, hi, k: int):
    """First-k row ids in index order within [lo, hi] (for LIMIT pushdown).

    Returns (row_ids (k,), found count). Static k — the gather the paper's
    index-NL join would do per-probe, used here for indexed head()."""
    lo_pos = jnp.searchsorted(ix.sorted_keys, lo, side="left") if lo is not None else jnp.int32(0)
    hi_pos = jnp.searchsorted(ix.sorted_keys, hi, side="right") if hi is not None else num_valid
    hi_pos = jnp.minimum(hi_pos, num_valid)
    found = jnp.maximum(hi_pos - lo_pos, 0)
    take = jnp.minimum(found, k)
    idx = lo_pos + jnp.arange(k)
    idx = jnp.minimum(idx, jnp.maximum(num_valid - 1, 0))
    return ix.row_ids[idx], take
