"""Columnar, row-sharded tables — the storage layer of the engine.

AsterixDB stores ADM records in shared-nothing LSM B-tree partitions; the
TPU-native equivalent here is a dict of equal-length device arrays, row-
sharded over the mesh's data axes. Strings are fixed-width ``uint8`` tensors
(shape ``(n, width)``) so string ops vectorize on the VPU.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

STRING_WIDTH = 16  # fixed-width template strings (Wisconsin stringu1/u2/4)

# -- derived string lanes ------------------------------------------------------
# Every string column carries fixed-width *integer* lanes derived at
# load/flush time (the TPU adaptation of gnitz's "German strings"): an
# always-present big-endian prefix lane (the first PREFIX_BYTES of the
# encoded row packed into one int32 — order-preserving, so zone-map range
# tests on it are lexicographic range tests on the strings) and, for
# columns whose live distinct count stays under DICT_THRESHOLD, a
# per-component sorted dictionary-id lane (int32 ids into the component's
# byte-lex-sorted value dictionary — what string ==/IN/group-by lower onto
# the filter_count / segment_agg kernels through).
#
# PREFIX_BYTES is 4, not 8: device arrays are 32-bit (x64 is off), so an
# int64 pack would be silently truncated at device placement and the
# recovered-from-device zone maps would disagree with the host-built ones.
# A 4-byte ASCII pack (top bit clear on every byte) is int32-exact,
# non-negative, and still order-preserving — the conservative prefix
# envelope just covers a shorter prefix.

DICT_THRESHOLD = 256   # distinct values above this: prefix lane only
PREFIX_BYTES = 4       # leading encoded bytes packed into the prefix lane

_PREFIX_LANE = "__pfx_"
_DICT_LANE = "__dict_"


def prefix_lane_name(column: str) -> str:
    return _PREFIX_LANE + column


def dict_lane_name(column: str) -> str:
    return _DICT_LANE + column


def is_lane_column(name: str) -> bool:
    """True for the derived string-lane columns (never user-visible)."""
    return name.startswith(_PREFIX_LANE) or name.startswith(_DICT_LANE)


def pack_prefix(arr: np.ndarray) -> np.ndarray:
    """Pack the first PREFIX_BYTES of each (n, width) uint8 row into one
    big-endian int32 per row. Big-endian keeps the pack order-preserving:
    ``a < b`` byte-lexicographically over the prefix iff
    ``pack(a) < pack(b)`` — the property the prefix zone maps rely on.
    ASCII rows keep the top bit clear, so the packed value stays in
    [0, 0x7F7F7F7F]: int32-exact on device, never negative."""
    a = np.asarray(arr, dtype=np.uint8)[:, :PREFIX_BYTES].astype(np.int64)
    shifts = np.arange(PREFIX_BYTES - 1, -1, -1, dtype=np.int64) * 8
    return (a << shifts).sum(axis=1).astype(np.int32)


def encode_strings(values: Sequence[str], width: int = STRING_WIDTH) -> np.ndarray:
    """Encode python strings into an (n, width) uint8 tensor (space padded)."""
    out = np.full((len(values), width), ord(" "), dtype=np.uint8)
    for i, s in enumerate(values):
        b = s.encode("ascii")[:width]
        out[i, : len(b)] = np.frombuffer(b, dtype=np.uint8)
    return out


def decode_strings(arr: np.ndarray) -> list[str]:
    arr = np.asarray(arr, dtype=np.uint8)
    return [bytes(row).decode("ascii").rstrip() for row in arr]


def canon_string(v: str, width: int = STRING_WIDTH) -> str:
    """A string literal in its stored form: ascii, truncated to ``width``,
    trailing padding stripped. Dictionary values are held in this form, so
    any literal → dict-id lookup must round-trip through it first —
    ``col == "ab  "`` and ``col == "ab"`` encode to the same (width,) row
    and must bind to the same id."""
    return v.encode("ascii")[:width].decode("ascii").rstrip()


@dataclasses.dataclass(frozen=True)
class ColumnMeta:
    """Catalog statistics for one column (the DBMS statistics analogue).

    ``lo``/``hi`` bound the value domain (None when unknown); ``distinct``
    is an upper bound on cardinality, used by the optimizer to pick the
    one-hot-matmul group-by strategy and join build sides.
    """

    dtype: np.dtype
    lo: float | None = None
    hi: float | None = None
    distinct: int | None = None
    is_string: bool = False
    sorted_ascending: bool = False  # true for a clustered (primary) index
    # For a dictionary-encoded string column: the component's sorted value
    # dictionary (byte-lex order; position == dict-lane id). Presence is the
    # signal that the ``__dict_<col>`` lane exists for this component — and
    # the hint ``_collect_stats`` follows when building runs, so lane
    # presence stays uniform across one dataset's LSM components.
    dict_values: tuple | None = None


class Table:
    """An immutable columnar table. Columns are jnp arrays of equal length.

    String columns have shape (n, STRING_WIDTH) uint8; numeric columns are
    1-D. ``meta`` carries per-column stats used by the optimizer.
    """

    def __init__(
        self,
        columns: Mapping[str, jax.Array | np.ndarray],
        meta: Mapping[str, ColumnMeta] | None = None,
        num_rows: int | None = None,
    ):
        self.columns = {k: jnp.asarray(v) for k, v in columns.items()}
        lengths = {k: int(v.shape[0]) for k, v in self.columns.items()}
        if len(set(lengths.values())) > 1:
            raise ValueError(f"ragged columns: {lengths}")
        self.num_rows = num_rows if num_rows is not None else next(iter(lengths.values()), 0)
        self.meta = dict(meta or {})
        for k, v in self.columns.items():
            if k not in self.meta:
                self.meta[k] = ColumnMeta(dtype=np.dtype(v.dtype), is_string=v.ndim == 2)

    def column_names(self) -> list[str]:
        return list(self.columns.keys())

    def __len__(self) -> int:
        return self.num_rows

    def select(self, names: Sequence[str]) -> "Table":
        return Table({n: self.columns[n] for n in names},
                     {n: self.meta[n] for n in names}, self.num_rows)

    def to_numpy(self) -> dict[str, np.ndarray]:
        return {k: np.asarray(v) for k, v in self.columns.items()}

    def head_dict(self, k: int) -> dict[str, np.ndarray]:
        return {name: np.asarray(col[:k]) for name, col in self.columns.items()}

    # -- sharding -----------------------------------------------------------
    def shard(self, mesh: Mesh, data_axes: tuple[str, ...] = ("data",)) -> "Table":
        """Row-shard every column over ``data_axes`` (pads rows to a multiple
        of the shard count; the pad rows carry a validity mask column
        ``__valid__`` so relational ops ignore them)."""
        nshards = int(np.prod([mesh.shape[a] for a in data_axes]))
        n = self.num_rows
        padded = ((n + nshards - 1) // nshards) * nshards
        cols = dict(self.columns)
        if "__valid__" not in cols:
            cols["__valid__"] = jnp.ones((n,), dtype=jnp.bool_)
        out = {}
        for k, v in cols.items():
            if padded != n:
                pad_width = [(0, padded - n)] + [(0, 0)] * (v.ndim - 1)
                v = jnp.pad(v, pad_width)
            spec = P(data_axes) if v.ndim == 1 else P(data_axes, None)
            out[k] = jax.device_put(v, NamedSharding(mesh, spec))
        meta = dict(self.meta)
        meta["__valid__"] = ColumnMeta(dtype=np.dtype(np.bool_))
        return Table(out, meta, padded)

    @property
    def valid(self) -> jax.Array:
        if "__valid__" in self.columns:
            return self.columns["__valid__"]
        return jnp.ones((self.num_rows,), dtype=jnp.bool_)


def compute_block_zones(table: Table, block: int,
                        n_shards: int = 1) -> dict[str, np.ndarray]:
    """Per-block [min, max] zone maps over the table's *physical* row layout
    — one (n_blocks, 2) array per 1-D numeric column, min/max taken over
    matter rows only (padding and anti-matter rows carry the ``[max, min]``
    empty-span sentinel — ``[int64.max, int64.min]`` for integer columns,
    ``[+inf, -inf]`` for float columns — so they never widen a span and an
    all-dead block is prunable under ANY constraint). Float NaN rows are
    treated like dead rows: a NaN never satisfies a range predicate, so it
    must never widen a span either.

    ``n_shards > 1`` lays the blocks out per shard: the table's rows are
    contiguously partitioned into ``n_shards`` equal chunks (the mesh row
    partitioning ``Table.shard`` produces), and each chunk gets its own
    ``blocks_per_shard = ceil(rows_per_shard / block)`` blocks — flat block
    index ``s * blocks_per_shard + j`` is shard ``s``'s LOCAL block ``j``.
    A shard's trailing partial block is sentinel-padded, so per-shard kernel
    grids address local tiles directly and never straddle a shard boundary.
    With ``n_shards == 1`` this degenerates to the original global layout.

    This is the intra-component half of the zone-map hierarchy: the
    column-level lo/hi stats (the run's *zone span*) gate run pruning, and
    these per-block values gate block skipping inside the kernel grid. The
    block size is ``stats.ZONE_BLOCK_ROWS`` — one zone block per
    filter_count kernel tile."""
    n = len(table)
    if n == 0:
        return {}
    if n_shards <= 1 or n % n_shards:
        n_shards = 1  # unsharded layout (or rows not evenly partitioned)
    matter = np.asarray(table.valid)
    anti = table.columns.get("__antimatter__")
    if anti is not None:
        matter = matter & ~np.asarray(anti)
    rps = n // n_shards                     # rows per shard chunk
    bp = -(-rps // block)                   # blocks per shard
    pad = bp * block - rps
    i64 = np.iinfo(np.int64)
    out: dict[str, np.ndarray] = {}
    for name, col in table.columns.items():
        if name in ("__valid__", "__antimatter__") or name.startswith("__ix"):
            continue
        a = np.asarray(col)
        if a.ndim != 1:
            continue
        if np.issubdtype(a.dtype, np.integer):
            v = a.astype(np.int64)
            live = matter
            lo_fill, hi_fill = i64.max, i64.min
        elif np.issubdtype(a.dtype, np.floating):
            v = a.astype(np.float64)
            live = matter & ~np.isnan(v)
            lo_fill, hi_fill = np.inf, -np.inf
        else:
            continue
        lo = np.where(live, v, lo_fill).reshape(n_shards, rps)
        hi = np.where(live, v, hi_fill).reshape(n_shards, rps)
        if pad:
            lo = np.concatenate(
                [lo, np.full((n_shards, pad), lo_fill, lo.dtype)], axis=1)
            hi = np.concatenate(
                [hi, np.full((n_shards, pad), hi_fill, hi.dtype)], axis=1)
        out[name] = np.stack(
            [lo.reshape(n_shards * bp, block).min(axis=1),
             hi.reshape(n_shards * bp, block).max(axis=1)], axis=1)
    return out


def pad_to_block(table: Table, block: int) -> Table:
    """Pad rows up to a multiple of ``block`` with a ``__valid__`` mask (the
    device-resident LSM runs are block-padded so kernel grids and shard
    splits stay aligned). No-op lengths still gain the mask column."""
    n = table.num_rows
    padded = ((n + block - 1) // block) * block if n else block
    cols = dict(table.columns)
    if "__valid__" not in cols:
        cols["__valid__"] = jnp.ones((n,), dtype=jnp.bool_)
    out = {}
    for k, v in cols.items():
        if padded != n:
            pad_width = [(0, padded - n)] + [(0, 0)] * (v.ndim - 1)
            v = jnp.pad(v, pad_width)  # pad rows are zeros, __valid__ False
        out[k] = v
    meta = dict(table.meta)
    meta["__valid__"] = ColumnMeta(dtype=np.dtype(np.bool_))
    return Table(out, meta, padded)


def concat_tables(a: Table, b: Table) -> Table:
    names = a.column_names()
    cols = {n: jnp.concatenate([a.columns[n], b.columns[n]], axis=0) for n in names}
    meta = {}
    for n in names:
        ma, mb = a.meta[n], b.meta[n]
        lo = None if ma.lo is None or mb.lo is None else min(ma.lo, mb.lo)
        hi = None if ma.hi is None or mb.hi is None else max(ma.hi, mb.hi)
        distinct = None if ma.distinct is None or mb.distinct is None else ma.distinct + mb.distinct
        meta[n] = ColumnMeta(ma.dtype, lo, hi, distinct, ma.is_string, False)
    return Table(cols, meta)
