"""Scalable Wisconsin benchmark dataset generator (paper §IV-A, Fig. 7).

Attributes follow DeWitt's Wisconsin benchmark as used by AFrame:
  unique1       0..MAX-1 unique, random order
  unique2       0..MAX-1 unique, sequential (declared key)
  two/four/ten/twenty          unique1 mod {2,4,10,20}
  onePercent    unique1 mod 100
  tenPercent    unique1 mod 10
  twentyPercent unique1 mod 5
  fiftyPercent  unique1 mod 2
  unique3       unique1
  evenOnePercent onePercent*2
  oddOnePercent  onePercent*2+1
  stringu1/stringu2  derived from unique1/unique2 (template strings)
  string4       cyclic A,H,O,V prefix
"""
from __future__ import annotations

import numpy as np

from repro.engine.table import ColumnMeta, Table, encode_strings

_STR4 = ["AAAAxxxx", "HHHHxxxx", "OOOOxxxx", "VVVVxxxx"]


def _stringu(values: np.ndarray, prefix: str) -> np.ndarray:
    """Wisconsin template string: 7-char base-26 rendering of the value,
    encoded as fixed-width uint8 (vectorized; no Python string loop)."""
    n = len(values)
    out = np.full((n, 16), ord(" "), dtype=np.uint8)
    out[:, 0] = ord(prefix)
    v = values.astype(np.int32)
    for pos in range(7):
        out[:, 7 - pos] = ord("a") + (v % 26)
        v = v // 26
    return out


def generate(num_rows: int, seed: int = 0) -> Table:
    """Generate a Wisconsin table of ``num_rows`` rows (uniform, unique keys)."""
    rng = np.random.default_rng(seed)
    unique2 = np.arange(num_rows, dtype=np.int32)
    unique1 = rng.permutation(num_rows).astype(np.int32)
    one_percent = unique1 % 100

    cols: dict[str, np.ndarray] = {
        "unique1": unique1,
        "unique2": unique2,
        "two": unique1 % 2,
        "four": unique1 % 4,
        "ten": unique1 % 10,
        "twenty": unique1 % 20,
        "onePercent": one_percent,
        "tenPercent": unique1 % 10,
        "twentyPercent": unique1 % 5,
        "fiftyPercent": unique1 % 2,
        "unique3": unique1.copy(),
        "evenOnePercent": one_percent * 2,
        "oddOnePercent": one_percent * 2 + 1,
        "stringu1": _stringu(unique1, "A"),
        "stringu2": _stringu(unique2, "B"),
        "string4": encode_strings([_STR4[i % 4] for i in range(num_rows)]),
    }

    def m(lo, hi, distinct, **kw):
        return ColumnMeta(np.dtype(np.int32), lo, hi, distinct, **kw)

    meta = {
        "unique1": m(0, num_rows - 1, num_rows),
        "unique2": m(0, num_rows - 1, num_rows, sorted_ascending=True),
        "two": m(0, 1, 2),
        "four": m(0, 3, 4),
        "ten": m(0, 9, 10),
        "twenty": m(0, 19, 20),
        "onePercent": m(0, 99, 100),
        "tenPercent": m(0, 9, 10),
        "twentyPercent": m(0, 4, 5),
        "fiftyPercent": m(0, 1, 2),
        "unique3": m(0, num_rows - 1, num_rows),
        "evenOnePercent": m(0, 198, 100),
        "oddOnePercent": m(1, 199, 100),
        "stringu1": ColumnMeta(np.dtype(np.uint8), is_string=True, distinct=num_rows),
        "stringu2": ColumnMeta(np.dtype(np.uint8), is_string=True, distinct=num_rows),
        "string4": ColumnMeta(np.dtype(np.uint8), is_string=True, distinct=4),
    }
    return Table(cols, meta)


# Paper dataset sizes (records): XS=0.5M .. XL=5M. Scaled down for the CPU
# container but with identical structure; the sizes are configurable.
SIZES = {"XS": 50_000, "S": 125_000, "M": 250_000, "L": 375_000, "XL": 500_000}
