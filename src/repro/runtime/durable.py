"""Durable storage: checksummed on-disk components, manifest generations,
and the per-dataset feed write-ahead log.

The device-resident LSM (engine/lsm.py) keeps *hard* state — matter rows,
tombstone rows, the manifest — in memory only; this module is the layer
that makes a process restart recoverable (AsterixDB's LSM disk format +
transaction log, generalizing the ``CheckpointManager`` tmp→fsync→rename
machinery in runtime/checkpoint.py):

  * **Segment files** (``data/<dv>/<ds>/seg/*.seg``) hold one LSM
    component's full column tensors — matter, tombstone rows, derived
    string lanes — in a versioned, length-prefixed format with a CRC32 per
    array. Segments are written at publish time (off the catalog lock for
    flush/compaction-built components), via write-temp → fsync → atomic
    rename. Soft state (index payloads, zone maps, host key copies,
    annihilation bookkeeping) is never stored: ``lsm.recover`` rebuilds it
    from the columns.
  * **Manifest generations** (``data/<dv>/<ds>/MANIFEST.<lsn>.json``) are
    the durable half of ``Catalog.publish``: each atomic in-memory swap
    commits one self-checksummed JSON manifest naming the component
    segments and the WAL sequence number the publish covers. The last
    ``keep_manifests`` generations are retained so a corrupted newest
    generation falls back to the previous one instead of failing cold
    start.
  * **The feed WAL** (``data/<dv>/<ds>/wal.log``) is append-only: every
    ``push``/``upsert``/``delete`` batch is appended and fsynced *before*
    the ack, and truncated only after the covering flush's manifest commit.
    Cold start replays the tail (records past the newest valid manifest's
    ``wal_upto``) through the normal flush path; a torn tail — the record a
    crash interrupted mid-write — is detected by CRC and dropped (that
    batch was never acked).

Crash points (``runtime/fault.py`` ``IO_FAULT_POINTS``) are threaded
through every write: ``torn-write`` (half a segment/WAL payload on disk),
``pre-rename`` (manifest tmp fully written + fsynced, not yet visible),
``pre-wal-truncate`` (manifest committed, WAL not yet truncated), and
``mid-replay`` (between replayed batches during ``Session.open``). The
contract — asserted by tests/test_durability.py in all three execution
modes — is that killing at ANY of them and reopening yields visible rows
bit-identical to the uncrashed run.

A corrupted segment or manifest (bad CRC, bad magic, truncation) is moved
to ``quarantine/`` and counted in ``storage.corruption_total``; reads fall
back to the previous manifest generation.
"""
from __future__ import annotations

import io
import json
import os
import pathlib
import struct
import threading
import zlib
from typing import Callable, Optional

import numpy as np

from repro.runtime import telemetry as tel

SEGMENT_MAGIC = b"RSEG\x01"      # segment format, version 1
WAL_MAGIC = b"RWAL"              # one per WAL record
_WAL_HEADER = struct.Struct("<4sQBQ")   # magic, seq, kind, payload_len
_WAL_CRC = struct.Struct("<I")
WAL_KINDS = ("push", "upsert", "delete")

MANIFEST_VERSION = 1
SEGMENT_VERSION = 1


class StorageCorruption(RuntimeError):
    """A checksummed on-disk structure (segment / manifest / WAL record)
    failed verification: bad magic, bad CRC, or truncation."""


class StorageLockError(RuntimeError):
    """The storage directory is already open by a live process — double
    opening would interleave two writers' segment/manifest/WAL streams."""


def _fsync_dir(path: pathlib.Path) -> None:
    """fsync the directory entry so a rename/create survives power loss.
    Best-effort: some filesystems refuse O_RDONLY dir fsync."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # pragma: no cover - platform-dependent
        return
    try:
        os.fsync(fd)
    except OSError:  # pragma: no cover
        pass
    finally:
        os.close(fd)


def _num(x):
    """JSON-safe scalar: numpy ints/floats → python; None passes through."""
    if x is None:
        return None
    if isinstance(x, (bool, np.bool_)):
        return bool(x)
    if isinstance(x, (int, np.integer)):
        return int(x)
    return float(x)


def _meta_to_json(m) -> dict:
    return {"dtype": np.dtype(m.dtype).str, "lo": _num(m.lo),
            "hi": _num(m.hi), "distinct": _num(m.distinct),
            "is_string": bool(m.is_string),
            "sorted_ascending": bool(m.sorted_ascending),
            "dict_values": list(m.dict_values)
            if m.dict_values is not None else None}


def _meta_from_json(d):
    from repro.engine.table import ColumnMeta

    return ColumnMeta(np.dtype(d["dtype"]), d["lo"], d["hi"], d["distinct"],
                      bool(d["is_string"]), bool(d["sorted_ascending"]),
                      tuple(d["dict_values"])
                      if d["dict_values"] is not None else None)


def _record_checksum(record: dict) -> int:
    """Self-checksum of a manifest record: CRC32 over the canonical JSON of
    everything except the checksum field itself."""
    body = {k: v for k, v in record.items() if k != "checksum"}
    return zlib.crc32(json.dumps(body, sort_keys=True).encode()) & 0xFFFFFFFF


# -- segment files -------------------------------------------------------------


def write_segment(path: pathlib.Path, arrays: dict[str, np.ndarray],
                  meta: dict, fault: Callable[[str], None],
                  fsync: bool = True) -> None:
    """Write one component segment: magic | u32 header-length | header JSON
    | concatenated raw array bytes, committed via tmp → fsync → atomic
    rename. The header carries per-array dtype/shape/CRC32 plus the
    component metadata, so a reader verifies every tensor independently.
    The ``torn-write`` fault point fires after half the payload bytes are
    on disk — the torn file is only ever the tmp (never renamed), which is
    exactly the protocol's claim: a crashed segment write is invisible."""
    payloads = []
    descr = []
    for name, a in arrays.items():
        a = np.ascontiguousarray(np.asarray(a))
        raw = a.tobytes()
        descr.append({"name": name, "dtype": a.dtype.str,
                      "shape": list(a.shape), "nbytes": len(raw),
                      "crc32": zlib.crc32(raw) & 0xFFFFFFFF})
        payloads.append(raw)
    header = json.dumps({"version": SEGMENT_VERSION, "arrays": descr,
                         "meta": meta}, sort_keys=True).encode()
    body = b"".join(payloads)
    half = len(body) // 2
    tmp = path.with_suffix(path.suffix + ".tmp")
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(SEGMENT_MAGIC)
        f.write(struct.pack("<I", len(header)))
        f.write(header)
        f.write(body[:half])
        fault("torn-write")
        f.write(body[half:])
        f.flush()
        if fsync:
            os.fsync(f.fileno())
    os.replace(tmp, path)
    if fsync:
        _fsync_dir(path.parent)
    tel.inc("storage.segments_written_total")
    tel.inc("storage.segment_bytes_written_total",
            len(body) + len(header) + 10)


def read_segment(path: pathlib.Path) -> tuple[dict[str, np.ndarray], dict]:
    """Read + verify one segment. Raises :class:`StorageCorruption` on any
    mismatch (missing file, bad magic, short read, per-array CRC)."""
    try:
        blob = path.read_bytes()
    except OSError as e:
        raise StorageCorruption(f"segment {path}: unreadable ({e})") from e
    if blob[:len(SEGMENT_MAGIC)] != SEGMENT_MAGIC:
        raise StorageCorruption(f"segment {path}: bad magic")
    off = len(SEGMENT_MAGIC)
    if len(blob) < off + 4:
        raise StorageCorruption(f"segment {path}: truncated header length")
    (hlen,) = struct.unpack_from("<I", blob, off)
    off += 4
    if len(blob) < off + hlen:
        raise StorageCorruption(f"segment {path}: truncated header")
    try:
        header = json.loads(blob[off:off + hlen].decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise StorageCorruption(f"segment {path}: unparseable header") from e
    off += hlen
    if header.get("version") != SEGMENT_VERSION:
        raise StorageCorruption(
            f"segment {path}: unsupported version {header.get('version')}")
    arrays: dict[str, np.ndarray] = {}
    for d in header["arrays"]:
        raw = blob[off:off + d["nbytes"]]
        if len(raw) != d["nbytes"]:
            raise StorageCorruption(
                f"segment {path}: array {d['name']!r} truncated")
        if (zlib.crc32(raw) & 0xFFFFFFFF) != d["crc32"]:
            raise StorageCorruption(
                f"segment {path}: array {d['name']!r} CRC mismatch")
        arrays[d["name"]] = np.frombuffer(raw, dtype=np.dtype(d["dtype"])) \
            .reshape(d["shape"]).copy()
        off += d["nbytes"]
    return arrays, header["meta"]


# -- the write-ahead log -------------------------------------------------------


class WriteAheadLog:
    """One dataset's append-only feed log. Records are individually CRC'd
    and length-prefixed; ``append`` fsyncs before returning (the ack), so
    an acked batch survives any later crash. A torn tail (a record a crash
    cut short) fails its CRC and is dropped at open — by definition it was
    never acked."""

    def __init__(self, path: pathlib.Path, fault: Callable[[str], None],
                 fsync: bool = True):
        self.path = path
        self._fault = fault
        self.fsync = fsync
        self._lock = threading.Lock()
        self.seq = 0          # last durably-appended sequence number
        path.parent.mkdir(parents=True, exist_ok=True)
        valid_end = 0
        for seq, _, _, end in self._scan():
            self.seq = seq
            valid_end = end
        size = path.stat().st_size if path.exists() else 0
        if size > valid_end:  # torn/corrupt tail: repair before appending
            with open(path, "r+b") as f:
                f.truncate(valid_end)
            tel.inc("storage.wal_torn_tail_total")
        self._fh = open(path, "ab")

    def _scan(self):
        """Yield (seq, kind, payload_bytes, end_offset) for every valid
        record, stopping at the first torn or corrupt one."""
        if not self.path.exists():
            return
        blob = self.path.read_bytes()
        off = 0
        while off + _WAL_HEADER.size <= len(blob):
            magic, seq, kind, plen = _WAL_HEADER.unpack_from(blob, off)
            if magic != WAL_MAGIC:
                return
            body_end = off + _WAL_HEADER.size + plen
            if body_end + _WAL_CRC.size > len(blob):
                return  # torn tail
            payload = blob[off + _WAL_HEADER.size:body_end]
            (crc,) = _WAL_CRC.unpack_from(blob, body_end)
            want = zlib.crc32(blob[off + 4:body_end]) & 0xFFFFFFFF
            if crc != want or kind >= len(WAL_KINDS):
                return
            yield seq, WAL_KINDS[kind], payload, body_end + _WAL_CRC.size
            off = body_end + _WAL_CRC.size

    def append(self, kind: str, payload: dict[str, np.ndarray]) -> int:
        """Append one batch and fsync BEFORE returning — the returned seq
        is the durability ack. The ``torn-write`` fault fires with half the
        payload written: the record fails its CRC on replay, modelling an
        un-acked batch lost to the crash."""
        buf = io.BytesIO()
        np.savez(buf, **{k: np.asarray(v) for k, v in payload.items()})
        data = buf.getvalue()
        with self._lock:
            seq = self.seq + 1
            header = _WAL_HEADER.pack(WAL_MAGIC, seq, WAL_KINDS.index(kind),
                                      len(data))
            crc = zlib.crc32(header[4:] + data) & 0xFFFFFFFF
            half = len(data) // 2
            self._fh.write(header)
            self._fh.write(data[:half])
            self._fh.flush()
            self._fault("torn-write")
            self._fh.write(data[half:])
            self._fh.write(_WAL_CRC.pack(crc))
            self._fh.flush()
            if self.fsync:
                os.fsync(self._fh.fileno())
            self.seq = seq
        tel.inc("storage.wal_appends_total")
        return seq

    def tail(self, after_seq: int) -> list[tuple[int, str, dict]]:
        """Decoded records with seq > ``after_seq`` (the replay set): the
        covering flush never committed, so these batches re-flush through
        the normal path. Records at or below ``after_seq`` are skipped —
        the idempotent-replay guarantee when a crash landed between the
        manifest commit and the WAL truncate."""
        with self._lock:
            out = []
            for seq, kind, payload, _ in self._scan():
                if seq <= after_seq:
                    continue
                with np.load(io.BytesIO(payload)) as z:
                    cols = {k: z[k] for k in z.files}
                out.append((seq, kind, cols))
            return out

    def truncate(self, upto_seq: int) -> None:
        """Drop every record with seq <= ``upto_seq`` (they are covered by
        a committed manifest). The common case — everything covered —
        truncates in place; a partial cover rewrites the survivors through
        a tmp + atomic rename."""
        with self._lock:
            survivors = [(s, k, p) for s, k, p, _ in self._scan()
                         if s > upto_seq]
            self._fh.close()
            if not survivors:
                with open(self.path, "wb") as f:
                    if self.fsync:
                        os.fsync(f.fileno())
            else:
                tmp = self.path.with_suffix(".log.tmp")
                with open(tmp, "wb") as f:
                    for seq, kind, payload in survivors:
                        header = _WAL_HEADER.pack(
                            WAL_MAGIC, seq, WAL_KINDS.index(kind),
                            len(payload))
                        crc = zlib.crc32(header[4:] + payload) & 0xFFFFFFFF
                        f.write(header + payload + _WAL_CRC.pack(crc))
                    f.flush()
                    if self.fsync:
                        os.fsync(f.fileno())
                os.replace(tmp, self.path)
            self._fh = open(self.path, "ab")
        tel.inc("storage.wal_truncations_total")

    def close(self) -> None:
        with self._lock:
            if not self._fh.closed:
                self._fh.close()


# -- the store -----------------------------------------------------------------


class DurableStore:
    """One durable storage directory:

    .. code-block:: text

        <root>/LOCK                              single-writer guard (pid)
        <root>/data/<dv>/<ds>/seg/*.seg          component segments
        <root>/data/<dv>/<ds>/MANIFEST.<lsn>.json  manifest generations
        <root>/data/<dv>/<ds>/wal.log            feed write-ahead log
        <root>/quarantine/                       corrupt files, preserved

    The store is the durable half of ``Catalog.publish``: the catalog
    calls :meth:`commit` inside every publish, which persists any
    still-unwritten component segments and then atomically renames the new
    manifest generation into place. Crash ordering is the classic WAL
    protocol — segment writes and the manifest rename are atomic or
    invisible, the WAL covers everything newer than the last committed
    manifest, and truncation happens strictly after the commit."""

    def __init__(self, root, fault: Optional[Callable[[str], None]] = None,
                 keep_manifests: int = 3, fsync: bool = True,
                 wal_fsync: bool = True):
        self.root = pathlib.Path(root)
        self.keep_manifests = max(int(keep_manifests), 1)
        self.fsync = fsync
        self.wal_fsync = wal_fsync
        self._fault = fault if fault is not None else (lambda point: None)
        self.root.mkdir(parents=True, exist_ok=True)
        (self.root / "data").mkdir(exist_ok=True)
        (self.root / "quarantine").mkdir(exist_ok=True)
        self._acquire_lock()
        self._wals: dict[tuple[str, str], WriteAheadLog] = {}
        self._wal_covered: dict[tuple[str, str], int] = {}
        # segment files written but not yet referenced by a committed
        # manifest (flush/compaction builds persist off-lock, commit links)
        self._inflight: dict[tuple[str, str], set] = {}
        # (dv, ds) -> {lsn: manifest record} for the kept generations —
        # the reference set segment GC checks before unlinking
        self._records: dict[tuple[str, str], dict[int, dict]] = {}
        self._seg_counter: dict[tuple[str, str], int] = {}
        self._lock = threading.RLock()
        # seed the recovery-visible series so they exist (and read 0)
        # before the first corruption/replay ever happens
        tel.inc("storage.corruption_total", 0)
        tel.inc("storage.wal_replayed_batches_total", 0)

    # -- lock ------------------------------------------------------------------

    def _acquire_lock(self) -> None:
        lock = self.root / "LOCK"
        while True:
            try:
                fd = os.open(lock, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            except FileExistsError:
                try:
                    pid = int(lock.read_text().strip() or "-1")
                except (OSError, ValueError):
                    pid = -1
                if pid > 0 and _pid_alive(pid):
                    raise StorageLockError(
                        f"storage directory {self.root} is already open by "
                        f"pid {pid}; close that session (Session.close) "
                        "before reopening") from None
                # stale lock from a dead process: steal it
                try:
                    lock.unlink()
                except OSError:  # pragma: no cover - lost the race
                    pass
                continue
            os.write(fd, str(os.getpid()).encode())
            os.close(fd)
            self._locked = True
            return

    def close(self) -> None:
        """Release the directory lock and the WAL handles. Used both for
        clean shutdown and by crash tests to simulate process death before
        reopening the same directory."""
        for wal in self._wals.values():
            wal.close()
        self._wals.clear()
        if getattr(self, "_locked", False):
            try:
                (self.root / "LOCK").unlink()
            except OSError:  # pragma: no cover
                pass
            self._locked = False

    # -- paths -----------------------------------------------------------------

    def _ds_dir(self, dv: str, name: str) -> pathlib.Path:
        return self.root / "data" / dv / name

    def _seg_path(self, dv: str, name: str, seg: str) -> pathlib.Path:
        return self._ds_dir(dv, name) / "seg" / seg

    def _base_name(self, comp) -> str:
        return comp.name.partition("@")[0]

    # -- segments --------------------------------------------------------------

    def write_component(self, dv: str, name: str, comp) -> str:
        """Persist one LSM component's hard state (all table columns +
        column metadata + index inventory) as a segment file. Idempotent:
        a component already persisted (``comp.seg_name`` set) is a no-op.
        Runs are named by their stable uid; bases by a per-dataset monotone
        counter (never reused, like run uids)."""
        if comp.seg_name is not None:
            return comp.seg_name
        key = (dv, name)
        if comp.uid >= 0:
            seg = f"run{comp.uid}.seg"
        else:
            with self._lock:
                n = self._seg_counter.get(key)
                if n is None:
                    n = _max_base_counter(self._ds_dir(dv, name) / "seg") + 1
                self._seg_counter[key] = n + 1
            seg = f"base.{n}.seg"
        t = comp.table
        arrays = {k: np.asarray(v) for k, v in t.columns.items()}
        meta = {
            "name": comp.name, "uid": int(comp.uid), "level": int(comp.level),
            "closed": bool(comp.closed), "num_rows": int(t.num_rows),
            "live_rows": _num(comp.live_rows), "anti_rows": int(comp.anti_rows),
            "columns": [[k, _meta_to_json(t.meta[k])] for k in t.columns],
            "indexes": [[key, ix.name, ix.column, ix.kind]
                        for key, ix in comp.indexes.items()],
        }
        write_segment(self._seg_path(dv, name, seg), arrays, meta,
                      self._fault, fsync=self.fsync)
        with self._lock:
            self._inflight.setdefault(key, set()).add(seg)
        comp.seg_name = seg
        return seg

    def discard_component(self, dv: str, name: str, comp) -> None:
        """Unlink a segment written for a build that lost its CAS (manifest
        conflict): it was never referenced by a committed manifest."""
        seg = comp.seg_name
        if seg is None:
            return
        key = (dv, name)
        with self._lock:
            referenced = any(seg in _record_segs(r)
                             for r in self._records.get(key, {}).values())
            if referenced:  # pragma: no cover - defensive
                return
            self._inflight.get(key, set()).discard(seg)
        try:
            self._seg_path(dv, name, seg).unlink()
            tel.inc("storage.segments_deleted_total")
        except OSError:  # pragma: no cover
            pass
        comp.seg_name = None

    def maybe_unlink(self, dv: str, name: str, seg: str) -> None:
        """Retired-component GC hook (Catalog._reclaim): unlink a dead
        component's segment unless a kept manifest generation still
        references it or it is an in-flight (uncommitted) build."""
        key = (dv, name)
        with self._lock:
            if seg in self._inflight.get(key, set()):
                return
            if any(seg in _record_segs(r)
                   for r in self._records.get(key, {}).values()):
                return
        try:
            self._seg_path(dv, name, seg).unlink()
            tel.inc("storage.segments_deleted_total")
        except OSError:
            pass

    # -- manifests -------------------------------------------------------------

    def commit(self, dv: str, name: str, manifest) -> None:
        """The durable half of ``Catalog.publish``: persist any missing
        component segments, then atomically commit the manifest generation
        (write-temp → fsync → rename, with the ``pre-rename`` crash point
        between). The record embeds ``wal_upto`` — the WAL sequence this
        publish covers — so cold start knows exactly which tail to replay.
        Old generations beyond ``keep_manifests`` are GC'd along with
        segments no kept generation references."""
        key = (dv, name)
        comps = (manifest.base,) + tuple(manifest.runs)
        for comp in comps:
            self.write_component(dv, name, comp)
        record = {
            "version": MANIFEST_VERSION, "lsn": int(manifest.lsn),
            "dataverse": dv, "dataset": name,
            "wal_upto": int(self._wal_covered.get(key, 0)),
            "base": {"seg": manifest.base.seg_name,
                     "uid": int(manifest.base.uid),
                     "level": int(manifest.base.level)},
            "runs": [{"seg": r.seg_name, "uid": int(r.uid),
                      "level": int(r.level)} for r in manifest.runs],
        }
        record["checksum"] = _record_checksum(record)
        d = self._ds_dir(dv, name)
        d.mkdir(parents=True, exist_ok=True)
        final = d / f"MANIFEST.{manifest.lsn}.json"
        tmp = d / f"MANIFEST.{manifest.lsn}.json.tmp"
        with open(tmp, "w") as f:
            json.dump(record, f)
            f.flush()
            if self.fsync:
                os.fsync(f.fileno())
        self._fault("pre-rename")
        os.replace(tmp, final)
        if self.fsync:
            _fsync_dir(d)
        with self._lock:
            recs = self._records.setdefault(key, {})
            recs[int(manifest.lsn)] = record
            segs = _record_segs(record)
            infl = self._inflight.get(key, set())
            infl -= segs
        tel.inc("storage.manifest_commits_total")
        self._gc_dataset(dv, name)

    def _gc_dataset(self, dv: str, name: str) -> None:
        """Rotate manifest generations (keep the newest K) and unlink
        segment files no kept generation references and no in-flight build
        owns. Also sweeps orphaned tmp files."""
        key = (dv, name)
        d = self._ds_dir(dv, name)
        with self._lock:
            recs = self._records.setdefault(key, {})
            kept = sorted(recs)[-self.keep_manifests:]
            drop = [lsn for lsn in recs if lsn not in kept]
            for lsn in drop:
                recs.pop(lsn, None)
            referenced = set()
            for lsn in kept:
                referenced |= _record_segs(recs[lsn])
            referenced |= self._inflight.get(key, set())
        for lsn in drop:
            try:
                (d / f"MANIFEST.{lsn}.json").unlink()
            except OSError:  # pragma: no cover
                pass
        segdir = d / "seg"
        if segdir.is_dir():
            for p in segdir.iterdir():
                if p.suffix == ".tmp":
                    p.unlink(missing_ok=True)
                elif p.name.endswith(".seg") and p.name not in referenced:
                    p.unlink(missing_ok=True)
                    tel.inc("storage.segments_deleted_total")

    def quarantine(self, path: pathlib.Path) -> None:
        """Move a corrupt file aside (preserved for inspection, never read
        again) and count it."""
        qdir = self.root / "quarantine"
        target = qdir / path.name
        i = 0
        while target.exists():
            i += 1
            target = qdir / f"{path.name}.{i}"
        try:
            path.replace(target)
        except OSError:  # pragma: no cover
            return
        tel.inc("storage.quarantined_files_total")

    # -- cold-start loading ----------------------------------------------------

    def list_datasets(self) -> list[tuple[str, str]]:
        out = []
        data = self.root / "data"
        if not data.is_dir():
            return out
        for dv in sorted(p for p in data.iterdir() if p.is_dir()):
            for ds in sorted(p for p in dv.iterdir() if p.is_dir()):
                if list(ds.glob("MANIFEST.*.json")):
                    out.append((dv.name, ds.name))
        return out

    def load_dataset(self, dv: str, name: str):
        """Load the newest checksum-valid manifest generation and every
        segment it references. A corrupt manifest or segment is
        quarantined (``storage.corruption_total``) and the previous
        generation is tried — cold start degrades to the last fully-valid
        publish instead of failing. Returns ``(record, segments, report)``
        where ``segments`` maps seg name → (arrays, meta)."""
        d = self._ds_dir(dv, name)
        gens = sorted((int(p.name.split(".")[1]) for p in
                       d.glob("MANIFEST.*.json")), reverse=True)
        report = {"generations": len(gens), "fallbacks": 0, "quarantined": []}
        key = (dv, name)
        for lsn in gens:
            path = d / f"MANIFEST.{lsn}.json"
            try:
                record = json.loads(path.read_text())
                if record.get("checksum") != _record_checksum(record) \
                        or record.get("version") != MANIFEST_VERSION:
                    raise StorageCorruption(
                        f"manifest {path}: checksum/version mismatch")
            except (OSError, json.JSONDecodeError, UnicodeDecodeError,
                    StorageCorruption):
                tel.inc("storage.corruption_total")
                report["quarantined"].append(path.name)
                report["fallbacks"] += 1
                self.quarantine(path)
                continue
            segments = {}
            bad = None
            for ref in [record["base"]] + list(record["runs"]):
                seg_path = self._seg_path(dv, name, ref["seg"])
                try:
                    segments[ref["seg"]] = read_segment(seg_path)
                except StorageCorruption:
                    bad = seg_path
                    break
            if bad is not None:
                tel.inc("storage.corruption_total")
                report["quarantined"].append(bad.name)
                report["fallbacks"] += 1
                self.quarantine(bad)
                # the generation referencing the corrupt segment is dead
                # too: quarantine it so the fallback is durable across
                # further reopens
                self.quarantine(path)
                continue
            with self._lock:
                self._records.setdefault(key, {})[int(record["lsn"])] = record
                self._wal_covered[key] = int(record["wal_upto"])
            return record, segments, report
        raise StorageCorruption(
            f"{dv}.{name}: no checksum-valid manifest generation "
            f"(tried {len(gens)})")

    def drop_dataset(self, dv: str, name: str) -> None:
        import shutil

        key = (dv, name)
        wal = self._wals.pop(key, None)
        if wal is not None:
            wal.close()
        with self._lock:
            self._records.pop(key, None)
            self._inflight.pop(key, None)
            self._wal_covered.pop(key, None)
        shutil.rmtree(self._ds_dir(dv, name), ignore_errors=True)

    # -- WAL surface -----------------------------------------------------------

    def wal(self, dv: str, name: str) -> WriteAheadLog:
        key = (dv, name)
        w = self._wals.get(key)
        if w is None:
            w = WriteAheadLog(self._ds_dir(dv, name) / "wal.log",
                              self._fault, fsync=self.wal_fsync)
            self._wals[key] = w
        return w

    def wal_append(self, dv: str, name: str, kind: str,
                   payload: dict[str, np.ndarray]) -> int:
        return self.wal(dv, name).append(kind, payload)

    def wal_seq(self, dv: str, name: str) -> int:
        return self.wal(dv, name).seq

    def set_wal_coverage(self, dv: str, name: str, upto: int) -> None:
        """Record the WAL sequence the NEXT manifest commit covers — called
        by the flush path just before publish, so the committed record and
        the buffered batches agree exactly."""
        self._wal_covered[(dv, name)] = int(upto)

    def wal_covered(self, dv: str, name: str) -> int:
        return self._wal_covered.get((dv, name), 0)

    def wal_tail(self, dv: str, name: str) -> list[tuple[int, str, dict]]:
        """The replay set: records past the newest committed manifest's
        coverage."""
        return self.wal(dv, name).tail(self.wal_covered(dv, name))

    def wal_truncate(self, dv: str, name: str) -> None:
        """Drop the covered WAL prefix — strictly AFTER the covering
        manifest commit (the ``pre-wal-truncate`` crash point sits between:
        a crash there leaves covered records in the log, and replay skips
        them by sequence number)."""
        self._fault("pre-wal-truncate")
        self.wal(dv, name).truncate(self.wal_covered(dv, name))

    def __enter__(self) -> "DurableStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:  # pragma: no cover - exists, owned by another
        return True
    return True


def _record_segs(record: dict) -> set:
    return {record["base"]["seg"]} | {r["seg"] for r in record["runs"]}


def _max_base_counter(segdir: pathlib.Path) -> int:
    """Highest base.<n>.seg counter on disk — base names stay unique across
    reopen cycles the same way run uids do."""
    best = -1
    if segdir.is_dir():
        for p in segdir.glob("base.*.seg"):
            try:
                best = max(best, int(p.name.split(".")[1]))
            except ValueError:  # pragma: no cover
                continue
    return best
