"""Process-wide telemetry: a thread-safe metrics registry + trace spans.

One registry serves the whole engine (every Session, Feed, compactor thread,
and kernel dispatch in the process writes to it), mirroring what a metrics
sidecar would scrape from a serving AsterixDB node:

  * **counters** — monotone event counts (plan-cache hits per level,
    compaction attempts / CAS conflicts / retries, kernel launches, ...);
  * **gauges**   — last-known values (retired-component device bytes,
    stall pressure, resident run counts, last-execute wall time);
  * **histograms** — latency/size distributions with fixed exponential
    buckets (flush build time, write-stall duration, query phases);
  * **spans**    — lightweight structured traces (name, labels, start,
    duration, parent) kept in a bounded ring; every finished span also
    feeds the ``<name>_seconds`` histogram, so phase timers and traces
    are one call site.

Series are labeled: ``inc("kernel.launches_total", kernel="filter_count")``
creates the series ``kernel.launches_total{kernel=filter_count}``. Label
sets are expected to be low-cardinality (dataset names, levels, modes).

Overhead contract: ``enabled`` gates everything that costs real time —
span capture (``perf_counter`` pairs, ring appends) and histogram
observation are no-ops when disabled. Counters and gauges always record:
they ARE the engine's operational state (``Session.stats``,
``Catalog.gc_stats`` and the ingest/compactor mirrors are thin views over
them), and an increment is one locked dict add. Disable with
``set_enabled(False)`` or the ``REPRO_TELEMETRY=0`` environment variable.

``snapshot()`` exports everything as one JSON-serializable dict; benchmarks
attach it to their result files and CI asserts on the series.
``snapshot(normalize=True)`` zeroes every time-valued field (histogram
sum/min/max/buckets, span start/duration, ``*seconds*`` gauges) so two runs
of the same deterministic workload produce identical snapshots — the form
golden tests compare.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Optional

# Exponential latency buckets (seconds): 100µs .. 10s, the range between a
# cached plan bind and a stalled flush. Sizes (rows/bytes) reuse the same
# histogram type; their buckets are irrelevant and dropped on normalize.
DEFAULT_BUCKETS = (1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2,
                   5e-2, 1e-1, 2.5e-1, 5e-1, 1.0, 2.5, 5.0, 10.0)


def series_key(name: str, labels: dict) -> str:
    """Canonical series id: ``name{k1=v1,k2=v2}`` with sorted label keys —
    snapshot keys are deterministic strings, not tuples."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class _Histogram:
    __slots__ = ("count", "total", "min", "max", "buckets")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.buckets = [0] * (len(DEFAULT_BUCKETS) + 1)  # last = +inf

    def observe(self, value: float) -> None:
        self.count += 1
        self.total += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value
        for i, le in enumerate(DEFAULT_BUCKETS):
            if value <= le:
                self.buckets[i] += 1
                return
        self.buckets[-1] += 1

    def snapshot(self, normalize: bool = False) -> dict:
        if normalize:  # timing-dependent fields zeroed, event count kept
            return {"count": self.count, "sum": 0.0, "min": 0.0, "max": 0.0}
        out = {"count": self.count, "sum": self.total,
               "min": self.min if self.count else 0.0,
               "max": self.max if self.count else 0.0,
               "buckets": {}}
        for le, n in zip(DEFAULT_BUCKETS, self.buckets):
            if n:
                out["buckets"][str(le)] = n
        if self.buckets[-1]:
            out["buckets"]["+inf"] = self.buckets[-1]
        return out


class _NoopSpan:
    """Shared do-nothing span: what ``span()`` hands out when telemetry is
    disabled — enter/exit touch no clock and allocate nothing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NOOP_SPAN = _NoopSpan()


class Span:
    __slots__ = ("_registry", "name", "labels", "start", "duration", "parent")

    def __init__(self, registry: "MetricsRegistry", name: str, labels: dict):
        self._registry = registry
        self.name = name
        self.labels = labels
        self.start = 0.0
        self.duration = 0.0
        self.parent: Optional[str] = None

    def __enter__(self) -> "Span":
        stack = self._registry._span_stack()
        self.parent = stack[-1].name if stack else None
        stack.append(self)
        self.start = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        self.duration = time.perf_counter() - self.start
        stack = self._registry._span_stack()
        if stack and stack[-1] is self:
            stack.pop()
        self._registry._finish_span(self)
        return False


class MetricsRegistry:
    def __init__(self, enabled: bool = True, max_spans: int = 1024):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}
        self._hists: dict[str, _Histogram] = {}
        self._spans: deque = deque(maxlen=max_spans)
        self._tls = threading.local()

    # -- recording ----------------------------------------------------------

    def inc(self, name: str, value=1, **labels) -> None:
        """Counter add. Unconditional (see module docstring): the engine's
        back-compat stats surfaces read these even with telemetry off."""
        key = series_key(name, labels)
        with self._lock:  # int() keeps numpy scalars out of JSON snapshots
            self._counters[key] = self._counters.get(key, 0) + int(value)

    def set_gauge(self, name: str, value, **labels) -> None:
        key = series_key(name, labels)
        with self._lock:
            self._gauges[key] = float(value)

    def observe(self, name: str, value: float, **labels) -> None:
        """Histogram observation — gated: observations carry timings/sizes
        whose capture is exactly the overhead ``enabled`` exists to avoid."""
        if not self.enabled:
            return
        key = series_key(name, labels)
        with self._lock:
            h = self._hists.get(key)
            if h is None:
                h = self._hists[key] = _Histogram()
            h.observe(value)

    def span(self, name: str, **labels):
        """Context manager timing one phase. On exit the span lands in the
        trace ring AND observes the ``<name>_seconds`` histogram (same
        labels). Returns the shared no-op when disabled."""
        if not self.enabled:
            return NOOP_SPAN
        return Span(self, name, labels)

    # -- reading ------------------------------------------------------------

    def counter_value(self, name: str, **labels):
        with self._lock:
            return self._counters.get(series_key(name, labels), 0)

    def gauge_value(self, name: str, default=None, **labels):
        with self._lock:
            return self._gauges.get(series_key(name, labels), default)

    def counters(self, prefix: str = "") -> dict:
        with self._lock:
            return {k: v for k, v in self._counters.items()
                    if k.startswith(prefix)}

    def gauges(self, prefix: str = "") -> dict:
        with self._lock:
            return {k: v for k, v in self._gauges.items()
                    if k.startswith(prefix)}

    def spans(self, name: Optional[str] = None) -> list[dict]:
        with self._lock:
            out = list(self._spans)
        return out if name is None else [s for s in out if s["name"] == name]

    def snapshot(self, normalize: bool = False, include_spans: bool = True) -> dict:
        """One JSON-serializable dict of every series. ``normalize=True``
        zeroes time-valued fields (histogram sum/min/max/buckets, span
        start/duration, gauges whose name contains "seconds") so
        deterministic workloads snapshot identically."""
        with self._lock:
            counters = dict(sorted(self._counters.items()))
            gauges = dict(sorted(self._gauges.items()))
            hists = {k: h.snapshot(normalize)
                     for k, h in sorted(self._hists.items())}
            spans = list(self._spans) if include_spans else []
        if normalize:
            gauges = {k: (0.0 if "seconds" in k else v)
                      for k, v in gauges.items()}
            spans = [dict(s, start=0.0, duration=0.0) for s in spans]
        return {"counters": counters, "gauges": gauges,
                "histograms": hists, "spans": spans}

    def to_json(self, normalize: bool = False, **kw) -> str:
        return json.dumps(self.snapshot(normalize), **kw)

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()
            self._spans.clear()

    # -- span plumbing ------------------------------------------------------

    def _span_stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def _finish_span(self, span: Span) -> None:
        record = {"name": span.name, "labels": dict(span.labels),
                  "start": span.start, "duration": span.duration,
                  "parent": span.parent}
        key = series_key(span.name + "_seconds", span.labels)
        with self._lock:
            self._spans.append(record)
            h = self._hists.get(key)
            if h is None:
                h = self._hists[key] = _Histogram()
            h.observe(span.duration)


# -- the process-wide registry -----------------------------------------------

REGISTRY = MetricsRegistry(
    enabled=os.environ.get("REPRO_TELEMETRY", "1").lower()
    not in ("0", "false", "off"))


def registry() -> MetricsRegistry:
    return REGISTRY


def set_enabled(on: bool) -> None:
    REGISTRY.enabled = bool(on)


def enabled() -> bool:
    return REGISTRY.enabled


# Module-level conveniences: call sites write `tel.inc(...)` without holding
# the registry object.

def inc(name: str, value=1, **labels) -> None:
    REGISTRY.inc(name, value, **labels)


def set_gauge(name: str, value, **labels) -> None:
    REGISTRY.set_gauge(name, value, **labels)


def observe(name: str, value: float, **labels) -> None:
    REGISTRY.observe(name, value, **labels)


def span(name: str, **labels):
    return REGISTRY.span(name, **labels)


def counter_value(name: str, **labels):
    return REGISTRY.counter_value(name, **labels)


def gauge_value(name: str, default=None, **labels):
    return REGISTRY.gauge_value(name, default, **labels)


def snapshot(normalize: bool = False, include_spans: bool = True) -> dict:
    return REGISTRY.snapshot(normalize, include_spans)
