"""Fault-tolerant training driver: checkpoint/rollback, NaN recovery,
injected node failures, straggler mitigation (simulated deadlines).

The driver owns the step loop so every failure mode has one recovery path:
restore the latest good checkpoint, fast-forward the data iterator, resume.
On a real pod the failure signal is a missing heartbeat / XLA collective
timeout; here ``FailureInjector`` raises on schedule so tests exercise the
exact same recovery code (EXPERIMENTS.md §Fault).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.checkpoint import CheckpointManager


class NodeFailure(RuntimeError):
    pass


class Straggler(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule: {step: kind} with kind in
    {"node", "nan", "straggler"}."""

    schedule: dict[int, str] = dataclasses.field(default_factory=dict)
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        kind = self.schedule.get(step)
        if kind is None or step in self.fired:
            return None
        self.fired.add(step)
        return kind


@dataclasses.dataclass
class TrainLoopConfig:
    ckpt_every: int = 10
    max_retries_per_step: int = 3
    step_deadline_s: float = 0.0  # 0 = disabled; >0 enables straggler check


class FaultTolerantLoop:
    """Drives (params, opt_state) through ``train_step`` with recovery.

    ``data_iter_factory(start_step)`` must return an iterator positioned at
    ``start_step`` — deterministic data order is what makes rollback exact
    (the fast-skip the paper-scale systems use)."""

    def __init__(self, train_step: Callable, ckpt: CheckpointManager,
                 cfg: TrainLoopConfig = TrainLoopConfig(),
                 injector: Optional[FailureInjector] = None):
        self.train_step = train_step
        self.ckpt = ckpt
        self.cfg = cfg
        self.injector = injector or FailureInjector()
        self.events: list[tuple[int, str]] = []

    def run(self, params: Any, opt_state: Any, data_iter_factory: Callable,
            num_steps: int, start_step: int = 0):
        step = start_step
        it = data_iter_factory(step)
        metrics_log = []
        retries = 0
        # step 0 checkpoint so the first rollback has a target
        self.ckpt.save(step, {"params": params, "opt": opt_state}, wait=True)
        while step < num_steps:
            try:
                kind = self.injector.check(step)
                if kind == "node":
                    raise NodeFailure(f"injected node failure at step {step}")
                if kind == "straggler":
                    raise Straggler(f"injected straggler at step {step}")
                t0 = time.perf_counter()
                batch = next(it)
                if kind == "nan":  # poison the batch -> NaN loss path
                    batch = jax.tree_util.tree_map(
                        lambda x: (x.astype(np.float32) * np.nan).astype(x.dtype)
                        if np.issubdtype(np.asarray(x).dtype, np.floating) else x,
                        batch)
                params, opt_state, metrics = self.train_step(params, opt_state, batch)
                loss = float(metrics["loss"])
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at step {step}")
                if self.cfg.step_deadline_s and \
                        time.perf_counter() - t0 > self.cfg.step_deadline_s:
                    raise Straggler(f"step {step} exceeded deadline")
                metrics_log.append((step, loss))
                step += 1
                retries = 0
                if step % self.cfg.ckpt_every == 0:
                    self.ckpt.save(step, {"params": params, "opt": opt_state})
            except (NodeFailure, Straggler, FloatingPointError) as e:
                retries += 1
                self.events.append((step, f"{type(e).__name__}: {e}"))
                if retries > self.cfg.max_retries_per_step:
                    raise RuntimeError(
                        f"step {step} failed {retries} times; giving up") from e
                # rollback: latest good checkpoint + iterator fast-skip
                good, state = self.ckpt.restore(None, {"params": params,
                                                       "opt": opt_state})
                params, opt_state = state["params"], state["opt"]
                step = good
                it = data_iter_factory(step)
        self.ckpt.save(step, {"params": params, "opt": opt_state}, wait=True)
        return params, opt_state, metrics_log
