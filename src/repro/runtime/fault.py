"""Fault injection and fault-tolerant drivers.

Two fault surfaces share this module:

  * **storage** — :class:`FaultPlan` schedules deterministic crashes at the
    LSM engine's named fault points (``flush`` / ``mid-merge`` /
    ``pre-swap`` / ``post-swap``), raising :class:`StorageFault`. The
    engine's crash-consistency contract (engine/lsm.py ``recover``): a
    crash at ANY point leaves hard state (matter + tombstone rows, the
    atomically-swapped manifest) intact and only soft state (index
    payloads, zone maps, bookkeeping, view partials) rebuildable — readers
    on the old manifest return bit-identical results throughout.
  * **training** — :class:`FailureInjector` is the step-keyed
    specialization driving :class:`FaultTolerantLoop` (checkpoint/rollback,
    NaN recovery, straggler deadlines). On a real pod the failure signal is
    a missing heartbeat / XLA collective timeout; here the injector raises
    on schedule so tests exercise the exact same recovery code
    (EXPERIMENTS.md §Fault).

Both injectors are deterministic arrival schedules — seeded CI smoke tests
replay identical failure sequences.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.checkpoint import CheckpointManager


class NodeFailure(RuntimeError):
    pass


class Straggler(RuntimeError):
    pass


class StorageFault(RuntimeError):
    """An injected storage-layer crash (the LSM analogue of NodeFailure):
    raised by FaultPlan at a named engine fault point."""


# The LSM engine's named crash points, in flush/merge order of occurrence:
#   flush      — before the buffered batch becomes a run (buffer intact)
#   mid-merge  — while a compaction builds fresh components (old set intact)
#   pre-swap   — after the build, before the atomic manifest publish
#   post-swap  — after the publish, before the soft-state bookkeeping
STORAGE_FAULT_POINTS = ("flush", "mid-merge", "pre-swap", "post-swap")

# The durable-storage I/O crash points (runtime/durable.py), in write-path
# order. A separate tuple — the in-memory points above keep their arrival
# semantics and parametrized tests unchanged:
#   torn-write       — half a segment/WAL payload is on disk (CRC-detected)
#   pre-rename       — manifest tmp fully written + fsynced, not yet renamed
#                      into place (previous generation still authoritative)
#   pre-wal-truncate — manifest generation committed, covered WAL records
#                      not yet dropped (replay skips them by sequence)
#   mid-replay       — between replayed WAL batches during Session.open
IO_FAULT_POINTS = ("torn-write", "pre-rename", "pre-wal-truncate",
                   "mid-replay")


@dataclasses.dataclass
class FaultPlan:
    """Deterministic storage fault schedule over named crash points — the
    storage generalization of :class:`FailureInjector` (which schedules by
    training step; this schedules by Nth arrival at a point).

    ``schedule`` maps a point name to the arrival indices (0-based) that
    crash, or ``True`` to crash on every arrival. Each passage of a fault
    point counts one arrival whether or not it fires, so a retry after an
    injected crash naturally proceeds past a one-shot fault — exactly how
    the BackgroundCompactor's bounded-retry loop recovers."""

    schedule: dict[str, object] = dataclasses.field(default_factory=dict)
    seen: dict[str, int] = dataclasses.field(default_factory=dict)
    fired: list[tuple[str, int]] = dataclasses.field(default_factory=list)

    @classmethod
    def once(cls, point: str, arrival: int = 0) -> "FaultPlan":
        """Crash exactly once: on the ``arrival``-th passage of ``point``."""
        return cls(schedule={point: (arrival,)})

    def check(self, point: str) -> None:
        """Count one arrival at ``point``; raise StorageFault if scheduled."""
        i = self.seen.get(point, 0)
        self.seen[point] = i + 1
        hits = self.schedule.get(point)
        if hits is True or (hits is not None and i in hits):
            self.fired.append((point, i))
            raise StorageFault(
                f"injected storage fault at {point} (arrival {i})")

    def reset(self) -> None:
        self.seen.clear()
        self.fired.clear()


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule: {step: kind} with kind in
    {"node", "nan", "straggler"}."""

    schedule: dict[int, str] = dataclasses.field(default_factory=dict)
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        kind = self.schedule.get(step)
        if kind is None or step in self.fired:
            return None
        self.fired.add(step)
        return kind


@dataclasses.dataclass
class TrainLoopConfig:
    ckpt_every: int = 10
    max_retries_per_step: int = 3
    step_deadline_s: float = 0.0  # 0 = disabled; >0 enables straggler check


class FaultTolerantLoop:
    """Drives (params, opt_state) through ``train_step`` with recovery.

    ``data_iter_factory(start_step)`` must return an iterator positioned at
    ``start_step`` — deterministic data order is what makes rollback exact
    (the fast-skip the paper-scale systems use)."""

    def __init__(self, train_step: Callable, ckpt: CheckpointManager,
                 cfg: Optional[TrainLoopConfig] = None,
                 injector: Optional[FailureInjector] = None):
        self.train_step = train_step
        self.ckpt = ckpt
        # construct per instance: a dataclass default instance would be
        # shared (and mutable) across every loop
        self.cfg = cfg if cfg is not None else TrainLoopConfig()
        self.injector = injector or FailureInjector()
        self.events: list[tuple[int, str]] = []

    def run(self, params: Any, opt_state: Any, data_iter_factory: Callable,
            num_steps: int, start_step: int = 0):
        step = start_step
        it = data_iter_factory(step)
        metrics_log = []
        retries = 0
        # step 0 checkpoint so the first rollback has a target
        self.ckpt.save(step, {"params": params, "opt": opt_state}, wait=True)
        while step < num_steps:
            try:
                kind = self.injector.check(step)
                if kind == "node":
                    raise NodeFailure(f"injected node failure at step {step}")
                if kind == "straggler":
                    raise Straggler(f"injected straggler at step {step}")
                t0 = time.perf_counter()
                batch = next(it)
                if kind == "nan":  # poison the batch -> NaN loss path
                    batch = jax.tree_util.tree_map(
                        lambda x: (x.astype(np.float32) * np.nan).astype(x.dtype)
                        if np.issubdtype(np.asarray(x).dtype, np.floating) else x,
                        batch)
                params, opt_state, metrics = self.train_step(params, opt_state, batch)
                loss = float(metrics["loss"])
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at step {step}")
                if self.cfg.step_deadline_s and \
                        time.perf_counter() - t0 > self.cfg.step_deadline_s:
                    raise Straggler(f"step {step} exceeded deadline")
                metrics_log.append((step, loss))
                step += 1
                retries = 0
                if step % self.cfg.ckpt_every == 0:
                    self.ckpt.save(step, {"params": params, "opt": opt_state})
            except (NodeFailure, Straggler, FloatingPointError) as e:
                retries += 1
                self.events.append((step, f"{type(e).__name__}: {e}"))
                if retries > self.cfg.max_retries_per_step:
                    raise RuntimeError(
                        f"step {step} failed {retries} times; giving up") from e
                # rollback: latest good checkpoint + iterator fast-skip
                good, state = self.ckpt.restore(None, {"params": params,
                                                       "opt": opt_state})
                params, opt_state = state["params"], state["opt"]
                step = good
                it = data_iter_factory(step)
        self.ckpt.save(step, {"params": params, "opt": opt_state}, wait=True)
        return params, opt_state, metrics_log
