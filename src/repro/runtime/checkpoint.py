"""Checkpointing: per-shard npz, atomic, async, CRC-verified, keep-N, and
**elastic restore** (a checkpoint saved on mesh A reshards onto mesh B).

Layout:  <dir>/step_<n>/
           meta.json                 {step, tree structure, crc per leaf}
           leaf_<i>.npy              full (unsharded) array per pytree leaf

Full-array-per-leaf keeps restore mesh-agnostic (the elastic property the
1000-node story needs: restart on fewer/more healthy hosts); on a real pod
each host would write only its shard slice + a distributed manifest — same
format, sliced writes (noted in DESIGN.md §8).
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    # -- save -------------------------------------------------------------------
    def save(self, step: int, tree: Any, wait: bool = False) -> None:
        # device->host copy happens synchronously (consistent snapshot);
        # serialization + fsync + rename run on the background thread.
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]
        self.wait()  # one in-flight save at a time

        def _write():
            tmp = self.dir / f".tmp_step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            crcs = []
            for i, a in enumerate(host_leaves):
                np.save(tmp / f"leaf_{i}.npy", a)
                crcs.append(zlib.crc32(a.tobytes()) & 0xFFFFFFFF)
            meta = {"step": step, "num_leaves": len(host_leaves), "crc": crcs,
                    "treedef": str(treedef)}
            (tmp / "meta.json").write_text(json.dumps(meta))
            final = self.dir / f"step_{step}"
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)  # atomic publish
            self._gc()

        if self.async_save and not wait:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -- restore -----------------------------------------------------------------
    def steps(self) -> list[int]:
        return sorted(int(p.name.split("_", 1)[1]) for p in self.dir.glob("step_*"))

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: Optional[int], like: Any,
                shardings: Any = None) -> tuple[int, Any]:
        """Restore into the structure of ``like``. ``shardings`` (a pytree of
        NamedSharding or None) reshards each leaf for the *current* mesh —
        elastic restore: the saved mesh shape is irrelevant."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        self.wait()
        d = self.dir / f"step_{step}"
        meta = json.loads((d / "meta.json").read_text())
        _, treedef = _flatten(like)
        arrays = []
        for i in range(meta["num_leaves"]):
            a = np.load(d / f"leaf_{i}.npy")
            crc = zlib.crc32(a.tobytes()) & 0xFFFFFFFF
            if crc != meta["crc"][i]:
                raise IOError(f"checkpoint corruption: leaf {i} crc mismatch "
                              f"({crc:#x} != {meta['crc'][i]:#x})")
            arrays.append(a)
        tree = jax.tree_util.tree_unflatten(treedef, arrays)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s) if s is not None else jax.device_put(a),
                tree, shardings)
        else:
            tree = jax.tree_util.tree_map(jax.device_put, tree)
        return step, tree
