"""Error-feedback int8 gradient compression for the DP all-reduce.

At 1000+ nodes the data-parallel gradient reduce-scatter is the largest
inter-host collective; int8 quantization cuts its wire bytes 4× vs f32.
Per-leaf symmetric scaling (max-abs / 127) + an error-feedback accumulator
(the quantization residual is carried into the next step) keeps SGD/Adam
convergence — validated in tests/test_compress.py on a real training loss.

``compressed_psum`` is the shard_map building block: quantize → psum int32
(ring all-reduce of 1-byte payload upcast at the reducer; on real hardware
the int8 payload rides the wire) → dequantize.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_state(params: Any) -> Any:
    return jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def compress_grads(grads: Any, err: Any) -> tuple[Any, Any, Any]:
    """Error-feedback quantization: g' = Q(g + e); e' = (g + e) - deQ(g').

    Returns (quantized tree, scales tree, new error tree)."""
    def one(g, e):
        t = g.astype(jnp.float32) + e
        q, s = quantize(t)
        return q, s, t - dequantize(q, s)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    qs = treedef.unflatten([o[0] for o in out])
    ss = treedef.unflatten([o[1] for o in out])
    es = treedef.unflatten([o[2] for o in out])
    return qs, ss, es


def decompress_grads(qs: Any, ss: Any) -> Any:
    return jax.tree_util.tree_map(dequantize, qs, ss)


def compressed_psum(grads: Any, err: Any, axis_name) -> tuple[Any, Any]:
    """Inside shard_map: int8 error-feedback all-reduce of a gradient tree.

    Every shard quantizes against one SHARED scale (pmax of local max-abs —
    a 4-byte collective) so the int32 psum of payloads dequantizes exactly:
    Σ_i q_i · s == Σ_i deQ(q_i). Error feedback uses the same shared scale.
    Returns (mean gradients, new error state)."""
    def one(g, e):
        t = g.astype(jnp.float32) + e
        m = jax.lax.pmax(jnp.max(jnp.abs(t)), axis_name)
        s = jnp.maximum(m, 1e-12) / 127.0
        q = jnp.clip(jnp.round(t / s), -127, 127).astype(jnp.int8)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)  # int8 payload
        mean = total.astype(jnp.float32) * s / n
        return mean, t - q.astype(jnp.float32) * s

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
