"""Mesh construction for the production pod(s) and local test meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run forces 512 host devices *before*
any jax import; tests and benches see the default single device.

Axis convention (DESIGN.md §5):
  single-pod : (16, 16)    over ("data", "model")            — 256 chips
  multi-pod  : (2, 16, 16) over ("pod", "data", "model")     — 512 chips

The DataFrame engine row-shards tables over the data axes (("pod","data") in
multi-pod — flattened shared-nothing partitions); models do FSDP over the
data axes and tensor/expert parallelism over "model".
"""
from __future__ import annotations

import dataclasses
import math

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = math.prod(shape)
    devices = jax.devices()
    if len(devices) < ndev:
        raise RuntimeError(
            f"production mesh needs {ndev} devices, found {len(devices)}; "
            "the dry-run launcher must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before importing jax"
        )
    return jax.make_mesh(shape, axes, devices=devices[:ndev])


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """A small mesh over whatever devices exist (tests, CPU benches)."""
    ndev = data * model
    devices = jax.devices()
    if len(devices) < ndev:
        raise RuntimeError(f"need {ndev} devices, have {len(devices)}")
    return jax.make_mesh((data, model), ("data", "model"), devices=devices[:ndev])


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Names of the mesh axes a program should shard over.

    ``data`` may be a multi-axis tuple (("pod","data") on the multi-pod mesh) —
    every data-parallel sharding spec uses the tuple so the pod axis simply
    joins the FSDP/row-partition dimension.
    """

    data: tuple[str, ...] = ("data",)
    model: str = "model"

    @staticmethod
    def for_mesh(mesh: Mesh) -> "MeshAxes":
        names = mesh.axis_names
        if "pod" in names:
            return MeshAxes(data=("pod", "data"), model="model")
        if "model" in names:
            return MeshAxes(data=("data",), model="model")
        return MeshAxes(data=tuple(names), model=names[-1])

    def data_size(self, mesh: Mesh) -> int:
        return math.prod(mesh.shape[a] for a in self.data)

    def model_size(self, mesh: Mesh) -> int:
        return mesh.shape[self.model] if self.model in mesh.shape else 1
