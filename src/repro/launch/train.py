"""Production training launcher: mesh + sharded state + fault-tolerant loop.

On real hardware:   python -m repro.launch.train --arch qwen3-1.7b --multi-pod
In this container:  add --local-devices 8 (forces host devices BEFORE jax
init) and a reduced config is substituted automatically on CPU.

Everything the dry-run lowers is what runs here: same step functions, same
shardings, plus CheckpointManager/FaultTolerantLoop around the loop.
"""
import argparse
import os
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-lm")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--local-devices", type=int, default=0,
                    help="force N host devices (CPU dry runs)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config")
    args = ap.parse_args()

    if args.local_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.local_devices}")

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.launch.mesh import MeshAxes, make_local_mesh, make_production_mesh
    from repro.models import registry
    from repro.models.optim import OptimConfig, init_opt_state
    from repro.models.sharding import param_shardings, sharding_ctx, sanitize_spec_tree
    from repro.models.steps import init_train_state, make_train_step
    from repro.runtime.checkpoint import CheckpointManager
    from repro.runtime.fault import FaultTolerantLoop, TrainLoopConfig

    cfg = get_config(args.arch)
    on_cpu = jax.default_backend() != "tpu"
    if args.reduced or (on_cpu and cfg.n_params() > 5e8):
        cfg = cfg.reduced()
        print(f"[cpu] using reduced config {cfg.name}")
    api = registry.get_api(cfg)

    ndev = len(jax.devices())
    if ndev >= 512:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        model_par = 2 if ndev % 2 == 0 and ndev > 1 else 1
        mesh = make_local_mesh(data=ndev // model_par, model=model_par)
    axes = MeshAxes.for_mesh(mesh)
    print(f"mesh: {dict(mesh.shape)} ({mesh.devices.size} devices)")

    params, opt = init_train_state(jax.random.key(0), cfg, api)
    shards = param_shardings(params, mesh, axes)
    params = jax.device_put(params, shards)
    opt = init_opt_state(params)

    opt_cfg = OptimConfig(total_steps=args.steps)
    with sharding_ctx(mesh, axes):
        step_fn = jax.jit(make_train_step(cfg, opt_cfg, api),
                          donate_argnums=(0, 1))

        B, S = args.global_batch, args.seq
        tok_sharding = NamedSharding(mesh, P(axes.data, None)) \
            if B % axes.data_size(mesh) == 0 else None

        def data_factory(start):
            def gen():
                i = start
                while True:
                    rng = np.random.default_rng(777 + i)
                    t = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
                    if tok_sharding is not None:
                        t = jax.device_put(t, tok_sharding)
                    batch = {"tokens": t}
                    if cfg.family == "encdec":
                        batch["frames"] = jnp.asarray(
                            rng.normal(size=(B, cfg.enc_len, cfg.d_model)), jnp.bfloat16)
                    if cfg.family == "vlm":
                        batch["patches"] = jnp.asarray(
                            rng.normal(size=(B, cfg.num_patches, cfg.patch_dim)),
                            jnp.bfloat16)
                    yield batch
                    i += 1
            return gen()

        ckpt = CheckpointManager(args.ckpt_dir, keep=3)
        start = 0
        if args.resume and ckpt.latest_step() is not None:
            start, state = ckpt.restore(None, {"params": params, "opt": opt},
                                        shardings={"params": shards, "opt": None})
            params, opt = state["params"], state["opt"]
            print(f"resumed at step {start}")
        loop = FaultTolerantLoop(step_fn, ckpt,
                                 TrainLoopConfig(ckpt_every=args.ckpt_every))
        params, opt, log = loop.run(params, opt, data_factory, args.steps,
                                    start_step=start)
    for s, l in log[:: max(len(log) // 10, 1)]:
        print(f"step {s:5d}  loss {l:.4f}")
    print(f"done; final loss {log[-1][1]:.4f}; events: {loop.events or 'none'}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
