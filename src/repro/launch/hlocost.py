"""Trip-count-aware cost model over compiled (post-SPMD) HLO text.

XLA's built-in ``Executable.cost_analysis()`` counts a ``while`` body ONCE,
so any scan-over-layers model is undercounted by ~L× (verified empirically —
see EXPERIMENTS.md §Dry-run methodology). This module re-derives the three
roofline inputs from ``compiled.as_text()`` with loop bodies multiplied by
their ``known_trip_count`` backend annotation:

  * flops            — 2·M·N·K for dots (batch dims included), 1/elem for
                       elementwise arithmetic, operand-size for reductions
  * bytes            — fusion-aware: a fusion reads its operands and writes
                       its result; internals stay in registers/VMEM
  * collective bytes — per-kind result-shape bytes (per-device, since the
                       module is already SPMD-partitioned) × ring multiplier

Everything is *per chip*: post-partitioning shapes are per-device shapes.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")
_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
                "f32": 4, "s32": 4, "u32": 4, "bf16": 2, "f16": 2, "s16": 2,
                "u16": 2, "s8": 1, "u8": 1, "pred": 1}


def _dtype_bytes(dt: str) -> int:
    return _DTYPE_BYTES.get(dt, 1)  # f8* and friends default to 1
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n":"(\d+)"')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"(?:branch_computations|true_computation|false_computation)=\{?%?([\w.\-,% ]+)\}?")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "negate",
    "abs", "and", "or", "xor", "not", "compare", "select", "clamp", "convert",
    "exponential", "exponential-minus-one", "tanh", "sine", "cosine", "sqrt",
    "rsqrt", "log", "log-plus-one", "power", "remainder", "sign", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "is-finite", "atan2",
    "logistic", "cbrt", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "popcnt", "erf",
}
ZERO_BYTES = {"parameter", "get-tuple-element", "tuple", "bitcast",
              "after-all", "partition-id", "replica-id", "add-dependency",
              "opt-barrier"}
COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all"}
WIRE_MULT = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
             "all-to-all": 1.0, "collective-permute": 1.0,
             "ragged-all-to-all": 1.0}


def _shapes_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * _dtype_bytes(m.group(1))
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _shape_elems(type_str: str) -> int:
    n = 1
    for d in _shape_dims(type_str):
        n *= d
    return n


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str  # operands + attributes


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    coll_count: dict = dataclasses.field(default_factory=lambda: defaultdict(int))

    def add(self, other: "Totals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] += v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] += int(v * mult)


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, list[Op]] = {}
        self._parse(hlo_text)
        self._memo: dict[str, Totals] = {}
        self.entry = self._entry_name(hlo_text)

    def _parse(self, text: str):
        current = None
        for line in text.splitlines():
            mc = _COMP_RE.match(line.strip()) if line.strip().endswith("{") else None
            if mc:
                current = mc.group(1)
                self.comps[current] = []
                continue
            if current is None:
                continue
            if line.strip() == "}":
                current = None
                continue
            mo = _OP_RE.match(line)
            if mo:
                self.comps[current].append(Op(mo.group(1), mo.group(2),
                                              mo.group(3), mo.group(4)))

    @staticmethod
    def _entry_name(text: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)\s*\(", text, re.M)
        return m.group(1) if m else next(iter([]), "")

    # -- per-computation totals ----------------------------------------------
    def comp_totals(self, name: str) -> Totals:
        if name in self._memo:
            return self._memo[name]
        t = Totals()
        self._memo[name] = t  # break cycles defensively
        shapes = {op.name: op.type_str for op in self.comps.get(name, [])}
        for op in self.comps.get(name, []):
            self._add_op(t, op, shapes)
        return t

    def _add_op(self, t: Totals, op: Op, shapes: dict):
        oc = op.opcode
        if oc == "while":
            trip = 1
            mt = _TRIP_RE.search(op.rest)
            if mt:
                trip = int(mt.group(1))
            mb, mc2 = _BODY_RE.search(op.rest), _COND_RE.search(op.rest)
            if mb:
                t.add(self.comp_totals(mb.group(1)), trip)
            if mc2:
                t.add(self.comp_totals(mc2.group(1)), trip + 1)
            return
        if oc == "fusion":
            mcall = _CALLS_RE.search(op.rest)
            if mcall:
                sub = self.comp_totals(mcall.group(1))
                t.flops += sub.flops  # flops from internals
                t.add(Totals(coll_bytes=dict(sub.coll_bytes),
                             coll_count=dict(sub.coll_count)))
                t.bytes += self._fusion_bytes(mcall.group(1), op, shapes)
            else:
                t.bytes += self._operand_bytes(op, shapes) + _shapes_bytes(op.type_str)
            return
        if oc in ("call", "async-start"):
            mcall = _CALLS_RE.search(op.rest) or _CALLS_RE.search(op.type_str)
            if mcall:
                t.add(self.comp_totals(mcall.group(1)))
            return
        if oc == "conditional":
            # count the most expensive branch (documented upper bound)
            branches = re.findall(r"%([\w.\-]+)", op.rest.split("(")[-1])
            cands = [b for b in branches if b in self.comps]
            if cands:
                best = max((self.comp_totals(b) for b in cands),
                           key=lambda s: s.flops + s.bytes)
                t.add(best)
            return
        if oc in COLLECTIVES or (oc.endswith("-start") and oc[:-6] in COLLECTIVES):
            kind = oc[:-6] if oc.endswith("-start") else oc
            b = _shapes_bytes(op.type_str)
            # XLA's host AllReducePromotion pass upcasts bf16 reduces to f32
            # (to_apply=%..._promoted); the TPU target reduces bf16 natively
            # with in-hardware f32 accumulation, so wire bytes are half.
            if "_promoted" in op.rest:
                b *= 0.5
            t.coll_bytes[kind] += b
            t.coll_count[kind] += 1
            t.bytes += self._operand_bytes(op, shapes) + b
            return
        if oc.endswith("-done"):
            return
        if oc in ZERO_BYTES:
            return
        if oc in ("slice", "dynamic-slice"):
            t.bytes += 2 * _shapes_bytes(op.type_str)  # read slice + write
            return
        if oc == "dynamic-update-slice":
            ops_names = _OPERAND_RE.findall(op.rest.split(")", 1)[0])
            upd = _shapes_bytes(shapes.get(ops_names[1], "")) if len(ops_names) > 1 else 0
            t.bytes += 2 * upd  # in-place: read update, write region
            return
        if oc in ("broadcast", "iota", "constant"):
            t.bytes += _shapes_bytes(op.type_str)  # write-only (tiny reads)
            return
        if oc == "dot":
            out_elems = _shape_elems(op.type_str)
            contract = 1
            mcd = _CONTRACT_RE.search(op.rest)
            lhs = _OPERAND_RE.search(op.rest)
            if mcd and lhs and lhs.group(1) in shapes:
                ldims = _shape_dims(shapes[lhs.group(1)])
                for ci in mcd.group(1).split(","):
                    if ci and int(ci) < len(ldims):
                        contract *= ldims[int(ci)]
            t.flops += 2.0 * out_elems * contract
            t.bytes += self._operand_bytes(op, shapes) + _shapes_bytes(op.type_str)
            return
        if oc in ("reduce", "reduce-window", "sort", "scatter", "gather",
                  "cumsum", "select-and-scatter"):
            t.flops += self._operand_elems(op, shapes)
            t.bytes += self._operand_bytes(op, shapes) + _shapes_bytes(op.type_str)
            return
        if oc in ELEMENTWISE:
            t.flops += _shape_elems(op.type_str)
            t.bytes += self._operand_bytes(op, shapes) + _shapes_bytes(op.type_str)
            return
        # default data-movement ops (slice, concat, copy, dus, broadcast,
        # transpose, reshape, iota, constant, pad, custom-call, rng, ...)
        t.bytes += self._operand_bytes(op, shapes) + _shapes_bytes(op.type_str)

    def _fusion_bytes(self, fused_name: str, op: Op, shapes: dict) -> float:
        """HBM bytes for one fusion call.

        Reads: per fusion parameter — if every internal consumer (through
        bitcast/reshape/convert chains) is a slice/dynamic-slice, only the
        sliced region is pulled from HBM (the scan-over-layers param-stack
        pattern); otherwise the whole operand. The operand aliased by a
        root dynamic-update-slice is a pass-through (0 read).
        Writes: root DUS → update region only (in-place); else result shape.
        """
        ops = self.comps.get(fused_name, [])
        if not ops:
            return self._operand_bytes(op, shapes) + _shapes_bytes(op.type_str)
        ishapes = {o.name: o.type_str for o in ops}
        params: dict[int, str] = {}
        consumers: dict[str, list[Op]] = defaultdict(list)
        for o in ops:
            if o.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", o.opcode + "(" + o.rest)
                if m:
                    params[int(m.group(1))] = o.name
            seg = o.rest.split(")", 1)[0]
            for mm in _OPERAND_RE.finditer(seg):
                consumers[mm.group(1)].append(o)
        root = ops[-1]
        dus_alias: str | None = None
        write_bytes: float = _shapes_bytes(root.type_str)
        if root.opcode == "dynamic-update-slice":
            names = _OPERAND_RE.findall(root.rest.split(")", 1)[0])
            if names:
                dus_alias = names[0]
                write_bytes = 2 * _shapes_bytes(ishapes.get(names[1], "")) \
                    if len(names) > 1 else 0

        passthrough = {"bitcast", "reshape", "convert", "copy", "transpose"}

        def read_size(pname: str, seen: frozenset) -> float:
            if pname in seen:
                return _shapes_bytes(ishapes.get(pname, ""))
            total = 0.0
            for c in consumers.get(pname, []):
                if c.opcode in ("slice", "dynamic-slice"):
                    total += _shapes_bytes(c.type_str)
                elif c.opcode in passthrough:
                    total += read_size(c.name, seen | {pname})
                elif c.opcode == "dynamic-update-slice" and \
                        _OPERAND_RE.findall(c.rest.split(")", 1)[0])[:1] == [pname]:
                    total += 0  # aliased through DUS
                else:
                    return _shapes_bytes(ishapes.get(pname, ""))
            return min(total, _shapes_bytes(ishapes.get(pname, "")))

        # map call-site operands (in order) to parameter numbers
        call_operands = _OPERAND_RE.findall(op.rest.split(")", 1)[0])
        read_total = 0.0
        for i, outer in enumerate(call_operands):
            pname = params.get(i)
            if pname is None:
                read_total += _shapes_bytes(shapes.get(outer, ""))
                continue
            if pname == dus_alias:
                continue  # in-place aliased operand
            full = _shapes_bytes(shapes.get(outer, "")) or _shapes_bytes(ishapes.get(pname, ""))
            refined = read_size(pname, frozenset())
            read_total += min(refined, full) if refined else full
        return read_total + write_bytes

    def _operand_bytes(self, op: Op, shapes: dict) -> int:
        operands = op.rest.split(")", 1)[0] if ")" in op.rest else op.rest
        total = 0
        for m in _OPERAND_RE.finditer(operands):
            if m.group(1) in shapes:
                total += _shapes_bytes(shapes[m.group(1)])
        return total

    def _operand_elems(self, op: Op, shapes: dict) -> int:
        operands = op.rest.split(")", 1)[0] if ")" in op.rest else op.rest
        total = 0
        for m in _OPERAND_RE.finditer(operands):
            if m.group(1) in shapes:
                total += _shape_elems(shapes[m.group(1)])
        return total

    # -- public ---------------------------------------------------------------
    def totals(self) -> dict:
        t = self.comp_totals(self.entry)
        wire = sum(WIRE_MULT.get(k, 1.0) * v for k, v in t.coll_bytes.items())
        return {
            "flops": t.flops,
            "bytes": t.bytes,
            "collectives": {
                "by_kind": {k: {"count": t.coll_count[k], "bytes": v}
                            for k, v in sorted(t.coll_bytes.items())},
                "wire_bytes_per_device": wire,
            },
        }


def analyze(hlo_text: str) -> dict:
    return HloCostModel(hlo_text).totals()
