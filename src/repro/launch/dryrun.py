import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) cell against the production mesh and extract the roofline terms.

The two lines above MUST stay the first statements in this module — jax locks
the host device count at first init, and 512 placeholder CPU devices are what
lets ``jax.make_mesh`` build the 2×16×16 production mesh in this container.
Nothing else in the repo sets this flag (smoke tests and benches see 1 dev).

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all [--mesh pod|multipod|both] [--skip-done]

Each cell writes results/dryrun/<mesh>/<arch>__<shape>.json with
memory_analysis, cost_analysis, the per-collective HLO byte breakdown, and
the derived roofline terms (EXPERIMENTS.md §Dry-run / §Roofline read these).
"""
import argparse
import json
import pathlib
import re
import subprocess
import sys
import time
import traceback

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

# -- TPU v5e hardware model (targets; this container is CPU-only) ---------------
PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link (assignment constant)


def _mesh_tag(multi_pod: bool) -> str:
    return "multipod" if multi_pod else "pod"


# -- HLO collective parsing ------------------------------------------------------

_SHAPE_RE = re.compile(r"(f32|f16|bf16|f64|s32|s8|u8|u32|s64|u64|pred|s16|u16)\[([0-9,]*)\]")
_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}
_COLL_RE = re.compile(
    r"^\s*(?:[%\w.\-]+)\s*=\s*(?:\([^)]*\)|[\w\[\],{}: ]+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _first_shape_bytes(line: str) -> int:
    """Sum the byte sizes of the result shapes on an HLO line (post-SPMD these
    are *per-device* shapes)."""
    total = 0
    # result part is before the op name's '('; take shapes up to the '=' rhs op
    lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split("(", 1)[0]
    for m in _SHAPE_RE.finditer(lhs):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# wire-byte multiplier per op kind (ring algorithms, group size g):
#   all-gather: each device receives (g-1)/g of the result       -> ~1x result
#   all-reduce: reduce-scatter + all-gather                      -> ~2x
#   reduce-scatter: sends (g-1)/g of the (larger) operand; the result shape is
#     already 1/g so ~g x result ≈ operand — we approximate with operand ≈
#     result × g unavailable, use 1x result (lower bound) and record kind.
_WIRE_MULT = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}


def parse_collectives(hlo: str) -> dict:
    """Per-op-kind result-shape bytes (per device) from post-SPMD HLO."""
    by_kind: dict[str, dict] = {}
    for line in hlo.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        if "-done(" in line:  # async pair: count the -start only
            continue
        kind = m.group(1)
        b = _first_shape_bytes(line)
        rec = by_kind.setdefault(kind, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += b
    total_wire = sum(_WIRE_MULT[k] * v["bytes"] for k, v in by_kind.items())
    return {"by_kind": by_kind, "wire_bytes_per_device": total_wire}


# -- cell lowering ----------------------------------------------------------------


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: dict | None = None) -> dict:
    import dataclasses

    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.launch.mesh import MeshAxes, make_production_mesh
    from repro.models import registry, steps
    from repro.models.config import SHAPES, cell_applicable
    from repro.models.optim import OptimConfig
    from repro.models.sharding import sharding_ctx

    cfg = get_config(arch)
    if overrides:
        typed = {}
        for k, v in overrides.items():
            cur = getattr(cfg, k)
            typed[k] = type(cur)(v) if cur is not None and not isinstance(cur, str) else v
        cfg = dataclasses.replace(cfg, **typed)
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": _mesh_tag(multi_pod),
                "status": "skipped", "reason": why}
    cfg = registry.shape_adjusted_cfg(cfg, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = MeshAxes.for_mesh(mesh)
    chips = mesh.devices.size

    from repro.models.sharding import sanitize_spec_tree

    def ns(spec_tree, abstract_tree):
        """Shardings sanitized against actual shapes (jit in_shardings
        rejects uneven partitions — e.g. whisper's 51865 vocab, batch=1)."""
        clean = sanitize_spec_tree(spec_tree, abstract_tree, mesh)
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), clean,
            is_leaf=lambda x: isinstance(x, P))

    params_abs = registry.abstract_params(cfg)
    pspecs = registry.params_pspecs(cfg, axes)
    api = registry.get_api(cfg)
    if shape.kind != "train" and cfg.serve_params_dtype == "bf16":
        import jax.numpy as jnp

        params_abs = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
            if len(s.shape) >= 2 else s, params_abs)
    t0 = time.time()

    with sharding_ctx(mesh, axes):
        if shape.kind == "train":
            from repro.models.optim import init_opt_state

            step = steps.make_train_step(cfg, OptimConfig())
            opt_abs = jax.eval_shape(init_opt_state, params_abs)
            opt_specs = {"m": pspecs, "v": pspecs, "step": P()}
            batch_abs = registry.batch_specs(cfg, shape.global_batch, shape.seq_len)
            bspecs = registry.batch_pspecs(cfg, axes)
            jitted = jax.jit(step,
                             in_shardings=(ns(pspecs, params_abs),
                                           ns(opt_specs, opt_abs),
                                           ns(bspecs, batch_abs)),
                             out_shardings=(ns(pspecs, params_abs),
                                            ns(opt_specs, opt_abs), None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        elif shape.kind == "prefill":
            clen = registry.prefill_cache_len(cfg, shape.seq_len)
            step = steps.make_prefill_step(cfg, max_len=clen)
            batch_abs = registry.batch_specs(cfg, shape.global_batch, shape.seq_len)
            bspecs = registry.batch_pspecs(cfg, axes)
            cache_abs = api.make_cache(cfg, shape.global_batch, clen, abstract=True)
            cspecs = registry.cache_pspecs(cfg, axes)
            jitted = jax.jit(step,
                             in_shardings=(ns(pspecs, params_abs),
                                           ns(bspecs, batch_abs)),
                             out_shardings=(ns(cspecs, cache_abs), None))
            lowered = jitted.lower(params_abs, batch_abs)
        else:  # decode: one new token against a seq_len-deep cache
            step = steps.make_decode_step(cfg)
            tok_abs, cache_abs = registry.decode_specs(cfg, shape.global_batch,
                                                       shape.seq_len)
            cspecs = registry.cache_pspecs(cfg, axes)
            tok_sharding = ns({"tokens": P(axes.data, None)}, tok_abs)["tokens"]
            jitted = jax.jit(step,
                             in_shardings=(ns(pspecs, params_abs),
                                           ns(cspecs, cache_abs), tok_sharding),
                             out_shardings=(ns(cspecs, cache_abs), None),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_abs, cache_abs, tok_abs["tokens"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    try:
        mem = compiled.memory_analysis()
        mem_rec = {k: int(getattr(mem, k)) for k in
                   ("argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes",
                    "alias_size_in_bytes")
                   if hasattr(mem, k)}
    except Exception as e:  # pragma: no cover
        mem_rec = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        cost_rec = {k: float(v) for k, v in cost.items()
                    if isinstance(v, (int, float)) and
                    (k in ("flops", "bytes accessed", "optimal_seconds")
                     or k.startswith("bytes accessed"))}
    except Exception as e:  # pragma: no cover
        cost_rec = {"error": str(e)}

    hlo = compiled.as_text()
    from repro.launch import hlocost

    model = hlocost.analyze(hlo)  # trip-count-corrected, per chip
    coll = model["collectives"]

    # -- roofline terms (per chip; the SPMD module's shapes are per-chip).
    # NOTE: XLA's executable.cost_analysis() counts while bodies once, so the
    # flops/bytes here come from launch/hlocost.py (trip-count aware); the raw
    # cost_analysis record is kept for reference.
    flops = model["flops"]
    bytes_acc = model["bytes"]
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    collective_s = coll["wire_bytes_per_device"] / ICI_BW
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", collective_s)), key=lambda kv: kv[1])[0]

    # MODEL_FLOPS: 6·N·D train, 2·N·D forward (prefill), 2·N·B decode
    n_params = cfg.n_active_params() if cfg.moe else cfg.n_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_params * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_params * tokens
    else:
        model_flops = 2 * n_params * shape.global_batch
    model_flops_per_chip = model_flops / chips
    useful_ratio = model_flops_per_chip / flops if flops else 0.0

    return {
        "arch": arch, "shape": shape_name, "mesh": _mesh_tag(multi_pod),
        "status": "ok", "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": mem_rec, "cost_analysis_raw": cost_rec,
        "hlo_model": {"flops": flops, "bytes": bytes_acc},
        "collectives": coll,
        "roofline": {
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s, "dominant": dominant,
            "model_flops_total": model_flops,
            "model_flops_per_chip": model_flops_per_chip,
            "useful_flop_ratio": useful_ratio,
        },
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: pathlib.Path,
             overrides: dict | None = None) -> dict:
    rec = lower_cell(arch, shape_name, multi_pod, overrides)
    if overrides:
        rec["overrides"] = overrides
    out = out_dir / _mesh_tag(multi_pod) / f"{arch}__{shape_name}.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=2))
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--out", default=str(RESULTS))
    ap.add_argument("--set", action="append", default=[], metavar="KEY=VALUE",
                    help="ArchConfig override (perf iterations), e.g. "
                         "--set attn_impl=flash")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)
    meshes = [False, True] if args.mesh == "both" else [args.mesh == "multipod"]
    overrides = dict(kv.split("=", 1) for kv in args.set) or None

    if not args.all:
        assert args.arch and args.shape
        for mp in meshes:
            rec = run_cell(args.arch, args.shape, mp, out_dir, overrides)
            status = rec["status"]
            extra = ""
            if status == "ok":
                r = rec["roofline"]
                extra = (f" compile={rec['compile_s']}s dominant={r['dominant']}"
                         f" terms=({r['compute_s']:.4f},{r['memory_s']:.4f},"
                         f"{r['collective_s']:.4f})s useful={r['useful_flop_ratio']:.2f}")
            print(f"[{rec['mesh']}] {args.arch} × {args.shape}: {status}{extra}")
        return 0

    # --all: one fresh subprocess per cell (isolation against compiler state)
    from repro.configs import ALL_ARCHS
    from repro.models.config import SHAPES

    failures = []
    for mp in meshes:
        for arch in ALL_ARCHS:
            for shape_name in SHAPES:
                dest = out_dir / _mesh_tag(mp) / f"{arch}__{shape_name}.json"
                if args.skip_done and dest.exists():
                    try:
                        if json.loads(dest.read_text()).get("status") in ("ok", "skipped"):
                            continue
                    except Exception:
                        pass
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape_name,
                       "--mesh", "multipod" if mp else "pod", "--out", str(out_dir)]
                t0 = time.time()
                r = subprocess.run(cmd, capture_output=True, text=True)
                sys.stdout.write(r.stdout)
                if r.returncode != 0:
                    failures.append((arch, shape_name, _mesh_tag(mp)))
                    dest.parent.mkdir(parents=True, exist_ok=True)
                    dest.write_text(json.dumps({
                        "arch": arch, "shape": shape_name, "mesh": _mesh_tag(mp),
                        "status": "error", "stderr": r.stderr[-4000:],
                        "elapsed_s": round(time.time() - t0, 1)}, indent=2))
                    sys.stdout.write(f"[{_mesh_tag(mp)}] {arch} × {shape_name}: ERROR\n")
                sys.stdout.flush()
    if failures:
        print(f"{len(failures)} failures: {failures}")
        return 1
    print("all cells ok")
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except Exception:
        traceback.print_exc()
        sys.exit(1)
