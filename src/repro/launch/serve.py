"""Production serving launcher: prefill + decode over the mesh, batched
request loop (the serving counterpart of launch/train.py).

  python -m repro.launch.serve --arch qwen3-1.7b [--local-devices 8 --reduced]
"""
import argparse
import os
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-lm")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--local-devices", type=int, default=0)
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    if args.local_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.local_devices}")

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.launch.mesh import MeshAxes, make_local_mesh, make_production_mesh
    from repro.models import registry
    from repro.models.sharding import param_shardings, sharding_ctx
    from repro.models.steps import make_decode_step, make_prefill_step

    cfg = get_config(args.arch)
    if args.reduced or (jax.default_backend() != "tpu" and cfg.n_params() > 5e8):
        cfg = cfg.reduced()
        print(f"[cpu] using reduced config {cfg.name}")
    api = registry.get_api(cfg)

    ndev = len(jax.devices())
    if ndev >= 512:
        mesh = make_production_mesh()
    else:
        mp = 2 if ndev % 2 == 0 and ndev > 1 else 1
        mesh = make_local_mesh(data=ndev // mp, model=mp)
    axes = MeshAxes.for_mesh(mesh)
    print(f"mesh: {dict(mesh.shape)}")

    params = api.init(jax.random.key(0), cfg)
    params = jax.device_put(params, param_shardings(params, mesh, axes))

    max_len = args.prompt + args.new_tokens
    with sharding_ctx(mesh, axes):
        prefill = jax.jit(make_prefill_step(cfg, api, max_len=max_len))
        decode = jax.jit(make_decode_step(cfg, api), donate_argnums=(1,))

        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (args.batch, args.prompt)), jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = jnp.asarray(
                rng.normal(size=(args.batch, cfg.enc_len, cfg.d_model)), jnp.bfloat16)
        if cfg.family == "vlm":
            batch["patches"] = jnp.asarray(
                rng.normal(size=(args.batch, cfg.num_patches, cfg.patch_dim)),
                jnp.bfloat16)

        t0 = time.perf_counter()
        cache, tok = prefill(params, batch)
        jax.block_until_ready(tok)
        print(f"prefill {args.batch}×{args.prompt}: "
              f"{(time.perf_counter()-t0)*1e3:.1f}ms")
        toks = [tok]
        t0 = time.perf_counter()
        for _ in range(args.new_tokens - 1):
            cache, tok = decode(params, cache, tok)
            toks.append(tok)
        jax.block_until_ready(tok)
        dt = time.perf_counter() - t0
        print(f"decode {args.new_tokens-1} steps: {dt*1e3:.1f}ms "
              f"({args.batch*(args.new_tokens-1)/dt:.0f} tok/s)")
        out = jnp.concatenate(toks, axis=1)
        print("request 0 continuation:", np.asarray(out[0])[:16].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
