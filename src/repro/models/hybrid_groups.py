"""Group decomposition for the hybrid stack.

The zamba2 layer loop is decomposed into ``n_invocations`` *groups* — one
shared-attention application followed by an inner ``lax.scan`` over the
group's SSD blocks. Groups are unrolled in Python (static invocation index →
no ``lax.cond``/dynamic indexing), keeping HLO size O(groups + one block)
while making per-op cost attribution exact (launch/hlocost.py counts each
group once, inner scan bodies × trip count).
"""
from __future__ import annotations

import jax

from repro.models.config import ArchConfig


def group_bounds(cfg: ArchConfig) -> list[tuple[int, int]]:
    """[(start, end)) layer ranges; a shared-attn invocation precedes each."""
    out = []
    s = 0
    while s < cfg.n_layers:
        out.append((s, min(s + cfg.attn_every, cfg.n_layers)))
        s += cfg.attn_every
    return out


def slice_stack(tree, start: int, end: int):
    return jax.tree_util.tree_map(lambda a: a[start:end], tree)
