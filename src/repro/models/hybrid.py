"""Zamba2-style hybrid: a Mamba2 (SSD) backbone with ONE shared-weight
attention block applied every ``attn_every`` layers (arXiv:2411.15242).

Faithful structural features kept: the shared block's input is
``concat(hidden, original_embedding)`` (2·d wide), its weights are shared
across invocations, and each invocation owns a small unshared output linear.
Deviation (DESIGN.md §6): at 500k context the shared block uses a sliding
window (ring-buffer KV cache) so serving stays sub-quadratic — zamba2 is one
of the two archs that *runs* the long_500k cell.

The layer loop is a lax.scan over stacked SSD blocks with a ``lax.cond``
deciding shared-attention application, so the HLO stays two-blocks-sized.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import _project_qkv, attention, init_attention
from repro.models.config import ArchConfig
from repro.models.layers import (chunked_ce_loss, embed_tokens, he_init,
                                 init_embed, logits_from_hidden, rms_norm)
from repro.models.sharding import constrain
from repro.models.ssm import CONV_W, dims, init_ssm_block, ssm_mixer

NEG_INF = -1e30


def _attn_cfg(cfg: ArchConfig) -> ArchConfig:
    return dataclasses.replace(cfg, d_head=(2 * cfg.d_model) // cfg.n_heads)


def n_invocations(cfg: ArchConfig) -> int:
    return (cfg.n_layers + cfg.attn_every - 1) // cfg.attn_every


def init_hybrid(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 6)
    L = cfg.n_layers
    n_inv = n_invocations(cfg)
    d = cfg.d_model
    layer_keys = jax.random.split(ks[0], L)
    from repro.models.layers import init_mlp

    return {
        "embed": init_embed(ks[1], cfg.vocab, d),
        "layers": jax.vmap(lambda k: init_ssm_block(k, cfg))(layer_keys),
        "shared_attn": init_attention(ks[2], _attn_cfg(cfg), d_in=2 * d),
        "shared_ln": jnp.ones((2 * d,)),
        "shared_mlp": init_mlp(ks[5], d, cfg.d_ff, gated=True),
        "shared_mlp_ln": jnp.ones((d,)),
        "inv_proj": he_init(ks[3], (n_inv, d, d), fan_in=d),
        "final_norm": jnp.ones((d,)),
        "lm_head": he_init(ks[4], (d, cfg.vocab), fan_in=d),
    }


def _shared_mlp(h, params, cfg: ArchConfig):
    from repro.models.layers import mlp

    return h + mlp(rms_norm(h, params["shared_mlp_ln"], cfg.norm_eps),
                   params["shared_mlp"])


def _shared_attn_full(x, emb0, params, cfg: ArchConfig, inv, positions):
    xin = jnp.concatenate([x, emb0], axis=-1)
    xin = rms_norm(xin, params["shared_ln"], cfg.norm_eps)
    h = attention(xin, params["shared_attn"], _attn_cfg(cfg), positions=positions)
    h = _shared_mlp(h, params, cfg)
    W = params["inv_proj"][inv]  # static invocation index (unrolled groups)
    return x + h @ W.astype(x.dtype)


def forward_hidden(params, tokens, cfg: ArchConfig):
    from repro.models.hybrid_groups import group_bounds, slice_stack

    x = embed_tokens(params["embed"], tokens)
    emb0 = x
    positions = jnp.arange(x.shape[1])

    def body(carry, lp):
        h, _ = ssm_mixer(rms_norm(carry, lp["ln"], cfg.norm_eps), lp["ssm"], cfg)
        return constrain(carry + h, "data", None, None), None

    step = jax.checkpoint(body) if cfg.remat else body
    shared = jax.checkpoint(_shared_attn_full, static_argnums=(3, 4)) \
        if cfg.remat else _shared_attn_full
    for inv, (s, e) in enumerate(group_bounds(cfg)):
        x = shared(x, emb0, params, cfg, inv, positions)
        x, _ = jax.lax.scan(step, x, slice_stack(params["layers"], s, e))
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def hybrid_loss(params, batch, cfg: ArchConfig):
    tokens = batch["tokens"]
    hidden = forward_hidden(params, tokens, cfg)
    loss_sum = chunked_ce_loss(hidden[:, :-1], params["lm_head"], tokens[:, 1:],
                               chunk=cfg.loss_chunk)
    ntok = tokens.shape[0] * (tokens.shape[1] - 1)
    loss = loss_sum / ntok
    return loss, {"ce": loss}


# -- serving -------------------------------------------------------------------


def effective_window(cfg: ArchConfig, max_len: int) -> int:
    return min(cfg.sliding_window, max_len) if cfg.sliding_window else max_len


def make_cache(cfg: ArchConfig, batch: int, max_len: int, abstract: bool = False) -> dict:
    di, H, P, N = dims(cfg)
    acfg = _attn_cfg(cfg)
    W = effective_window(cfg, max_len)
    n_inv = n_invocations(cfg)
    shapes = {
        "conv": ((cfg.n_layers, batch, CONV_W - 1, di + 2 * N), jnp.bfloat16),
        "state": ((cfg.n_layers, batch, H, N, P), jnp.float32),
        "attn_k": ((n_inv, batch, W, acfg.n_kv_heads, acfg.d_head), jnp.bfloat16),
        "attn_v": ((n_inv, batch, W, acfg.n_kv_heads, acfg.d_head), jnp.bfloat16),
        "pos": ((), jnp.int32),
    }
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
    return {k: jnp.zeros(s, d) for k, (s, d) in shapes.items()}


def _ring_slot_positions(pos, W):
    """Absolute position stored in each ring slot at current write pos."""
    j = jnp.arange(W)
    return pos - jnp.mod(pos - j, W)


def _shared_attn_decode(x, emb0, params, cfg: ArchConfig, inv, ck_inv, cv_inv, pos):
    """Ring-buffer SWA decode for the shared block. x/emb0: (B,1,d);
    ck_inv/cv_inv: this invocation's (B,W,KV,hd) ring buffers."""
    acfg = _attn_cfg(cfg)
    B = x.shape[0]
    W = ck_inv.shape[1]
    xin = rms_norm(jnp.concatenate([x, emb0], axis=-1), params["shared_ln"], cfg.norm_eps)
    positions = pos + jnp.arange(1)
    q, k_new, v_new = _project_qkv(xin, xin, params["shared_attn"], acfg,
                                   positions, positions, True)
    slot = jnp.mod(pos, W)
    onehot = (jnp.arange(W)[:, None] == slot[None, None]).astype(ck_inv.dtype)
    keep = (1 - onehot.sum(1))[None, :, None, None]
    ck2 = ck_inv * keep + jnp.einsum("st,btkh->bskh", onehot, k_new.astype(ck_inv.dtype))
    cv2 = cv_inv * keep + jnp.einsum("st,btkh->bskh", onehot, v_new.astype(cv_inv.dtype))

    KV, G = acfg.n_kv_heads, acfg.n_heads // acfg.n_kv_heads
    qq = q.reshape(B, 1, KV, G, acfg.d_head)
    scores = jnp.einsum("bckgh,bskh->bkgcs", qq, ck2,
                        preferred_element_type=jnp.float32) / np.sqrt(acfg.d_head)
    valid = _ring_slot_positions(pos, W) >= 0
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgcs,bskh->bckgh", probs.astype(cv2.dtype), cv2)
    out = out.reshape(B, 1, acfg.n_heads * acfg.d_head)
    h = out @ params["shared_attn"]["wo"].astype(x.dtype)
    h = _shared_mlp(h, params, cfg)
    Wp = params["inv_proj"][inv]
    return x + h @ Wp.astype(x.dtype), ck2, cv2


def hybrid_prefill(params, batch, cfg: ArchConfig, max_len: int | None = None):
    """Forward pass capturing SSD states + shared-attn ring KV."""
    tokens = batch["tokens"]
    S = tokens.shape[1]
    max_len = max_len or S
    W = effective_window(cfg, max_len)
    x = embed_tokens(params["embed"], tokens)
    emb0 = x
    positions = jnp.arange(S)
    acfg = _attn_cfg(cfg)
    n_inv = n_invocations(cfg)

    # final ring layout: slot j holds position S-1-((S-1-j) mod W)
    ring_src = S - 1 - jnp.mod(S - 1 - jnp.arange(W), W)

    def shared_kv(x):
        xin = rms_norm(jnp.concatenate([x, emb0], axis=-1), params["shared_ln"], cfg.norm_eps)
        q, k, v = _project_qkv(xin, xin, params["shared_attn"], acfg,
                               positions, positions, True)
        from repro.models.attention import attention_core
        o = attention_core(q, k, v, positions, positions, acfg, causal=True)
        o = o.reshape(x.shape[0], S, -1) @ params["shared_attn"]["wo"].astype(x.dtype)
        o = _shared_mlp(o, params, cfg)
        return o, k[:, ring_src].astype(jnp.bfloat16), v[:, ring_src].astype(jnp.bfloat16)

    def body(carry, lp):
        h, st = ssm_mixer(rms_norm(carry, lp["ln"], cfg.norm_eps), lp["ssm"], cfg)
        return constrain(carry + h, "data", None, None), (
            st["conv"].astype(jnp.bfloat16), st["state"])

    from repro.models.hybrid_groups import group_bounds, slice_stack

    aks, avs, convs_l, states_l = [], [], [], []
    for inv, (s, e) in enumerate(group_bounds(cfg)):
        o, k_r, v_r = shared_kv(x)
        Wp = params["inv_proj"][inv]
        x = x + o @ Wp.astype(x.dtype)
        aks.append(k_r)
        avs.append(v_r)
        x, (cv_g, st_g) = jax.lax.scan(jax.checkpoint(body), x,
                                       slice_stack(params["layers"], s, e))
        convs_l.append(cv_g)
        states_l.append(st_g)
    ak = jnp.stack(aks, axis=0)
    av = jnp.stack(avs, axis=0)
    convs = jnp.concatenate(convs_l, axis=0)
    states = jnp.concatenate(states_l, axis=0)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(x[:, -1:, :], params["lm_head"])
    cache = {"conv": convs, "state": states, "attn_k": ak, "attn_v": av,
             "pos": jnp.asarray(S, jnp.int32)}
    return cache, logits


def hybrid_decode_step(params, cache, tokens, cfg: ArchConfig):
    from repro.models.hybrid_groups import group_bounds, slice_stack

    x = embed_tokens(params["embed"], tokens)
    emb0 = x
    pos = cache["pos"]

    def body(carry, xs):
        lp, conv_l, state_l = xs
        h, st = ssm_mixer(rms_norm(carry, lp["ln"], cfg.norm_eps), lp["ssm"], cfg,
                          cache={"conv": conv_l.astype(carry.dtype), "state": state_l},
                          sequential=True)
        return constrain(carry + h, "data", None, None), (
            st["conv"].astype(jnp.bfloat16), st["state"])

    aks, avs, convs_l, states_l = [], [], [], []
    for inv, (s, e) in enumerate(group_bounds(cfg)):
        x, ak2, av2 = _shared_attn_decode(x, emb0, params, cfg, inv,
                                          cache["attn_k"][inv], cache["attn_v"][inv],
                                          pos)
        aks.append(ak2)
        avs.append(av2)
        x, (cv_g, st_g) = jax.lax.scan(body, x,
                                       (slice_stack(params["layers"], s, e),
                                        cache["conv"][s:e], cache["state"][s:e]))
        convs_l.append(cv_g)
        states_l.append(st_g)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(x, params["lm_head"])
    new_cache = {"conv": jnp.concatenate(convs_l, axis=0),
                 "state": jnp.concatenate(states_l, axis=0),
                 "attn_k": jnp.stack(aks, axis=0),
                 "attn_v": jnp.stack(avs, axis=0),
                 "pos": pos + tokens.shape[1]}
    return new_cache, logits
