"""Mamba2 (state-space dual / SSD) blocks — used by the zamba2 hybrid.

Scalar-per-head decay makes the chunked form *unconditionally* stable: every
exponent is a within-chunk decay difference ≤ 0 (contrast rwkv.py, whose
per-channel factorization needs a clamp). Intra-chunk work is (C×C) matmuls
on the MXU; inter-chunk state ((H,N,P) per sequence) flows through lax.scan.
Decode is the exact O(1) recurrence plus a depthwise-conv ring cache — this
is why zamba2 runs the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import he_init, rms_norm
from repro.models.sharding import constrain

SSD_CHUNK = 64
CONV_W = 4


def dims(cfg: ArchConfig):
    di = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_head_dim
    H = di // P
    N = cfg.ssm_state
    return di, H, P, N


def init_ssm_block(key, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    di, H, P, N = dims(cfg)
    ks = jax.random.split(key, 4)
    conv_ch = di + 2 * N
    return {
        "ssm": {
            "w_in": he_init(ks[0], (d, 2 * di + 2 * N + H)),
            "conv_w": jax.random.normal(ks[1], (conv_ch, CONV_W)) * 0.2,
            "conv_b": jnp.zeros((conv_ch,)),
            "dt_bias": jnp.zeros((H,)),
            "A_log": jnp.zeros((H,)),  # a = exp(-exp(A_log)·dt)
            "D": jnp.ones((H,)),
            "norm": jnp.ones((di,)),
            "w_out": he_init(ks[2], (di, d), fan_in=di),
        },
        "ln": jnp.ones((d,)),
    }


def _causal_conv(x, w, b, x_prev=None):
    """Depthwise causal conv. x: (B,S,Ch); w: (Ch,W); x_prev: (B,W-1,Ch)."""
    B, S, Ch = x.shape
    W = w.shape[1]
    if x_prev is None:
        x_prev = jnp.zeros((B, W - 1, Ch), x.dtype)
    xp = jnp.concatenate([x_prev, x], axis=1)  # (B, S+W-1, Ch)
    out = sum(xp[:, j:j + S, :] * w[:, j].astype(x.dtype) for j in range(W))
    out = out + b.astype(x.dtype)
    return jax.nn.silu(out), xp[:, -(W - 1):, :]


def ssd_chunked(xh, Bc, Cc, la, dt, state0=None, chunk: int = SSD_CHUNK):
    """Chunked SSD scan.

    xh: (B,S,H,P) head inputs; Bc/Cc: (B,S,N); la: (B,S,H) log-decay ≤ 0;
    dt: (B,S,H) input gates. Returns (y (B,S,H,P), state (B,H,N,P) fp32).
    """
    B, S, H, P = xh.shape
    N = Bc.shape[-1]
    chunk = min(chunk, S)
    if S % chunk:  # pad tail: dt=0 adds no state, la=0 leaves decay at 1
        pad = chunk - S % chunk
        p3 = [(0, 0), (0, pad), (0, 0)]
        p4 = [(0, 0), (0, pad), (0, 0), (0, 0)]
        out, state = ssd_chunked(jnp.pad(xh, p4), jnp.pad(Bc, p3), jnp.pad(Cc, p3),
                                 jnp.pad(la, p3), jnp.pad(dt, p3), state0, chunk)
        return out[:, :S], state
    nc = S // chunk
    f32 = jnp.float32

    def split(a, tail):
        return a.astype(f32).reshape((B, nc, chunk) + tail).swapaxes(0, 1)

    xs = (split(xh, (H, P)), split(Bc, (N,)), split(Cc, (N,)),
          split(la, (H,)), split(dt, (H,)))
    if state0 is None:
        state0 = jnp.zeros((B, H, N, P), f32)
    mask = jnp.tril(jnp.ones((chunk, chunk), f32))  # s <= t inclusive

    def body(S_in, x):
        xc, bc, cc, lac, dtc = x  # (B,C,H,P) (B,C,N) (B,C,N) (B,C,H) (B,C,H)
        cum = jnp.cumsum(lac, axis=1)  # (B,C,H) inclusive
        total = cum[:, -1:, :]  # (B,1,H)
        cb = jnp.einsum("btn,bsn->bts", cc, bc)  # shared across heads
        dec = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,t,s,H)
        att = cb[..., None] * dec * dtc[:, None, :, :] * mask[None, :, :, None]
        y = jnp.einsum("btsh,bshp->bthp", att, xc)
        # carry-in contribution
        y = y + jnp.einsum("btn,bhnp->bthp", cc, S_in) * jnp.exp(cum)[..., None]
        # state update (all exponents ≤ 0)
        khat = jnp.exp(total - cum) * dtc  # (B,C,H)
        S_out = jnp.exp(total)[:, 0, :, None, None] * S_in \
            + jnp.einsum("bsn,bshp,bsh->bhnp", bc, xc, khat)
        return S_out, y

    state, ys = jax.lax.scan(body, state0, xs)
    y = ys.swapaxes(0, 1).reshape(B, S, H, P)
    return y.astype(xh.dtype), state


def ssd_sequential(xh, Bc, Cc, la, dt, state0=None):
    """Exact per-step oracle / decode path. Same signature as ssd_chunked."""
    B, S, H, P = xh.shape
    N = Bc.shape[-1]
    if state0 is None:
        state0 = jnp.zeros((B, H, N, P), jnp.float32)
    f32 = jnp.float32
    xs = (xh.astype(f32).swapaxes(0, 1), Bc.astype(f32).swapaxes(0, 1),
          Cc.astype(f32).swapaxes(0, 1), la.astype(f32).swapaxes(0, 1),
          dt.astype(f32).swapaxes(0, 1))

    def step(S, x):
        xt, bt, ct, lat, dtt = x  # (B,H,P) (B,N) (B,N) (B,H) (B,H)
        S_new = jnp.exp(lat)[:, :, None, None] * S \
            + jnp.einsum("bn,bhp,bh->bhnp", bt, xt, dtt)
        y = jnp.einsum("bn,bhnp->bhp", ct, S_new)
        return S_new, y

    state, ys = jax.lax.scan(step, state0, xs)
    return ys.swapaxes(0, 1).astype(xh.dtype), state


def ssm_mixer(x, p, cfg: ArchConfig, cache=None, *, sequential=False):
    """Mamba2 mixer. x: (B,S,d). cache: {conv: (B,W-1,Ch), state: (B,H,N,P)}."""
    B, S, d = x.shape
    di, H, P, N = dims(cfg)
    c = cache or {}
    proj = x @ p["w_in"].astype(x.dtype)
    proj = constrain(proj, "data", None, "model")
    z, xBC, dt_raw = jnp.split(proj, [di, 2 * di + 2 * N], axis=-1)
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"], c.get("conv"))
    xc, Bc, Cc = jnp.split(xBC, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    la = -jnp.exp(p["A_log"].astype(jnp.float32)) * dt  # log decay ≤ 0
    xh = xc.reshape(B, S, H, P)
    fn = ssd_sequential if sequential else ssd_chunked
    y, state = fn(xh, Bc, Cc, la, dt, c.get("state"))
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["w_out"].astype(x.dtype)
    return constrain(out, "data", None, None), {"conv": conv_state, "state": state}
