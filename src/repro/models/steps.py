"""Step functions: train / prefill / decode — the units the launcher jits,
the dry-run lowers, and the fault-tolerant trainer drives.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.optim import OptimConfig, adamw_update, init_opt_state
from repro.models.registry import ModelAPI, get_api


def cast_once(params, cfg: ArchConfig):
    """Optional step-entry bf16 cast of matrix params (on the local FSDP
    shard) so weight all-gathers move bf16 (cfg.cast_params_once, §Perf)."""
    if not cfg.cast_params_once:
        return params
    return jax.tree_util.tree_map(
        lambda a: a.astype(jnp.bfloat16)
        if a.ndim >= 2 and a.dtype == jnp.float32 else a, params)


def make_train_step(cfg: ArchConfig, opt_cfg: OptimConfig, api: ModelAPI | None = None):
    api = api or get_api(cfg)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return api.loss(cast_once(p, cfg), batch, cfg)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, opt_metrics = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}

    return train_step


def make_eval_step(cfg: ArchConfig, api: ModelAPI | None = None):
    api = api or get_api(cfg)

    def eval_step(params, batch):
        loss, metrics = api.loss(params, batch, cfg)
        return {"loss": loss, **metrics}

    return eval_step


def make_prefill_step(cfg: ArchConfig, api: ModelAPI | None = None,
                      max_len: int | None = None):
    api = api or get_api(cfg)

    def prefill_step(params, batch):
        cache, logits = api.prefill(params, batch, cfg, max_len)
        next_token = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return cache, next_token[:, None]

    return prefill_step


def make_decode_step(cfg: ArchConfig, api: ModelAPI | None = None):
    api = api or get_api(cfg)

    def decode_step(params, cache, tokens):
        cache, logits = api.decode(params, cache, tokens, cfg)
        next_token = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return cache, next_token[:, None]

    return decode_step


def init_train_state(key, cfg: ArchConfig, api: ModelAPI | None = None
                     ) -> tuple[Any, dict]:
    api = api or get_api(cfg)
    params = api.init(key, cfg)
    return params, init_opt_state(params)
