"""GQA attention: memory-blocked (query-chunked) prefill/train path, KV-cache
decode path, optional sliding window, qk-norm, biases, cross-attention.

The XLA path here is the *algorithmically same* computation as the Pallas
flash kernels in ``repro/kernels`` (online per-chunk softmax over query
blocks, fp32 accumulation): scores never materialize beyond one
(B, KV, G, chunk_q, S_kv) block, which is what keeps the 32k-prefill cells
inside HBM. Kernel selection is a config flag; the dry-run lowers this path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models.layers import apply_rope, he_init, rms_norm
from repro.models.sharding import constrain

NEG_INF = -1e30


def init_attention(key, cfg: ArchConfig, d_in: int | None = None,
                   d_kv_in: int | None = None, rope: bool = True) -> dict:
    d_in = d_in or cfg.d_model
    d_kv_in = d_kv_in or d_in
    hq = cfg.n_heads * cfg.d_head
    hkv = cfg.n_kv_heads * cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": he_init(ks[0], (d_in, hq)),
        "wk": he_init(ks[1], (d_kv_in, hkv)),
        "wv": he_init(ks[2], (d_kv_in, hkv)),
        "wo": he_init(ks[3], (hq, cfg.d_model), fan_in=hq),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq,))
        p["bk"] = jnp.zeros((hkv,))
        p["bv"] = jnp.zeros((hkv,))
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.d_head,))
        p["k_norm"] = jnp.ones((cfg.d_head,))
    return p


def _project_qkv(x, x_kv, p, cfg: ArchConfig, positions, positions_kv, rope: bool):
    B, Sq, _ = x.shape
    Skv = x_kv.shape[1]
    q = x @ p["wq"].astype(x.dtype)
    k = x_kv @ p["wk"].astype(x.dtype)
    v = x_kv @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = constrain(q, "data", None, "model").reshape(B, Sq, cfg.n_heads, cfg.d_head)
    k = constrain(k, "data", None, None).reshape(B, Skv, cfg.n_kv_heads, cfg.d_head)
    v = constrain(v, "data", None, None).reshape(B, Skv, cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions_kv, cfg.rope_theta)
    return q, k, v


def _blocked_attention(q, k, v, q_pos, k_pos, *, causal: bool, window: int, chunk_q: int):
    """q: (B,Sq,H,hd); k,v: (B,Skv,KV,hd). Returns (B,Sq,H,hd).

    lax.scan over query chunks; per chunk the full key range is visited with
    an fp32 masked softmax (one block of scores live at a time).
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / np.sqrt(hd)
    chunk = min(chunk_q, Sq)
    n = Sq // chunk
    rem = Sq - n * chunk

    kg = k.reshape(B, -1, KV, hd)
    vg = v.reshape(B, -1, KV, hd)

    def one_chunk(qc, qpos_c):
        qq = qc.reshape(B, qc.shape[1], KV, G, hd)
        scores = jnp.einsum("bckgh,bskh->bkgcs", qq, kg, preferred_element_type=jnp.float32)
        scores = scores * scale
        if causal:
            m = qpos_c[:, None] >= k_pos[None, :]
            if window:
                m &= (qpos_c[:, None] - k_pos[None, :]) < window
            scores = jnp.where(m[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgcs,bskh->bckgh", probs.astype(v.dtype), vg)
        return out.reshape(B, -1, H, hd)

    if n > 0:
        qs = q[:, : n * chunk].reshape(B, n, chunk, H, hd).swapaxes(0, 1)
        ps = q_pos[: n * chunk].reshape(n, chunk)

        def body(_, xs):
            qc, pc = xs
            return None, one_chunk(qc, pc)

        _, outs = jax.lax.scan(body, None, (qs, ps))
        out = outs.swapaxes(0, 1).reshape(B, n * chunk, H, hd)
    else:
        out = jnp.zeros((B, 0, H, hd), q.dtype)
    if rem:
        out = jnp.concatenate([out, one_chunk(q[:, n * chunk:], q_pos[n * chunk:])], axis=1)
    return out


def attention_core(q, k, v, q_pos, k_pos, cfg: ArchConfig, *, causal: bool):
    """Dispatch between the baseline blocked-softmax path and the flash
    custom_vjp op (cfg.attn_impl). Flash covers the aligned full-window
    case; sliding windows stay on the blocked path."""
    aligned = (q.shape[1] == k.shape[1])
    if cfg.attn_impl == "flash" and cfg.sliding_window == 0 and aligned:
        from repro.kernels import ops as kops

        qt = q.transpose(0, 2, 1, 3)  # (B,H,S,D)
        kt = k.transpose(0, 2, 1, 3)
        vt = v.transpose(0, 2, 1, 3)
        out = kops.flash_attention(qt, kt, vt, causal, cfg.chunk_q)
        return out.transpose(0, 2, 1, 3)
    return _blocked_attention(q, k, v, q_pos, k_pos, causal=causal,
                              window=cfg.sliding_window, chunk_q=cfg.chunk_q)


def attention(x, p, cfg: ArchConfig, *, x_kv=None, causal=True, rope=True,
              positions=None, positions_kv=None) -> jax.Array:
    """Full-sequence (train/prefill) attention. x: (B, S, d_in)."""
    B, Sq, _ = x.shape
    x_kv = x if x_kv is None else x_kv
    Skv = x_kv.shape[1]
    if positions is None:
        positions = jnp.arange(Sq)
    if positions_kv is None:
        positions_kv = positions if x_kv.shape[1] == Sq else jnp.arange(Skv)
    q, k, v = _project_qkv(x, x_kv, p, cfg, positions, positions_kv, rope)
    out = attention_core(q, k, v, positions, positions_kv, cfg, causal=causal)
    out = out.reshape(B, Sq, cfg.n_heads * cfg.d_head)
    return out @ p["wo"].astype(x.dtype)


# -- KV-cache decode -------------------------------------------------------------


def init_kv_cache(cfg: ArchConfig, n_layers: int, batch: int, max_len: int,
                  dtype=jnp.bfloat16) -> dict:
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_spec(cfg: ArchConfig, n_layers: int, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return {
        "k": jax.ShapeDtypeStruct(shape, dtype),
        "v": jax.ShapeDtypeStruct(shape, dtype),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def update_cache_layer(cache_k_l, cache_v_l, k_new, v_new, pos):
    """Masked one-hot write at ``pos`` — sharding-friendly (no gather/scatter
    across the sequence-sharded cache dim; see DESIGN.md §5).

    cache_*_l: (B, S, KV, hd); k_new/v_new: (B, T, KV, hd) with T << S.
    """
    S = cache_k_l.shape[1]
    T = k_new.shape[1]
    onehot = (jnp.arange(S)[:, None] == (pos + jnp.arange(T))[None, :]).astype(cache_k_l.dtype)
    add_k = jnp.einsum("st,btkh->bskh", onehot, k_new.astype(cache_k_l.dtype))
    add_v = jnp.einsum("st,btkh->bskh", onehot, v_new.astype(cache_v_l.dtype))
    keep = (1 - onehot.sum(axis=1))[None, :, None, None]
    return cache_k_l * keep + add_k, cache_v_l * keep + add_v


def update_cache_layer_dus(cache_k_l, cache_v_l, k_new, v_new, pos):
    """In-place dynamic_update_slice cache write (optimized mode): with the
    cache donated, XLA aliases the buffer and only the written row moves —
    vs. the one-hot path's two full-cache passes (§Perf iteration)."""
    ck = jax.lax.dynamic_update_slice_in_dim(
        cache_k_l, k_new.astype(cache_k_l.dtype), pos, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(
        cache_v_l, v_new.astype(cache_v_l.dtype), pos, axis=1)
    return ck, cv


def _decode_attention_smap(q, k_new, v_new, cache_k_l, cache_v_l, pos, cfg, ctx):
    """Explicit shard_map decode: the cache sequence dim stays shard-LOCAL,
    so the cache write is a 1-token in-place DUS on the owning rank (GSPMD's
    sharded-dim DUS lowers to a full-buffer select — §Perf iteration C4) and
    the softmax reduces over "model" with two tiny psums."""
    try:
        from jax import shard_map as _sm
    except ImportError:  # pragma: no cover
        from jax.experimental.shard_map import shard_map as _sm
    from jax.sharding import PartitionSpec as P

    mesh, axes = ctx.mesh, ctx.axes
    M = axes.model
    dp = axes.data if len(axes.data) > 1 else axes.data[0]
    KV, G, hd = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.d_head
    nm = mesh.shape[M]

    def local(q, kn, vn, ck, cv, pos):
        B, S_loc = ck.shape[0], ck.shape[1]
        rank = jax.lax.axis_index(M)
        # -- 1-token in-place write on the owning rank --------------------
        lpos = pos - rank * S_loc
        in_range = (lpos >= 0) & (lpos < S_loc)
        idx = jnp.clip(lpos, 0, S_loc - 1)
        old_k = jax.lax.dynamic_slice_in_dim(ck, idx, 1, axis=1)
        old_v = jax.lax.dynamic_slice_in_dim(cv, idx, 1, axis=1)
        wk = jnp.where(in_range, kn.astype(ck.dtype), old_k)
        wv = jnp.where(in_range, vn.astype(cv.dtype), old_v)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, wk, idx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, wv, idx, axis=1)
        # -- local scores + distributed online softmax ---------------------
        qq = q.reshape(B, 1, KV, G, hd)
        s = jnp.einsum("bckgh,bskh->bkgcs", qq, ck,
                       preferred_element_type=jnp.float32) / np.sqrt(hd)
        kpos = rank * S_loc + jnp.arange(S_loc)
        valid = kpos <= pos
        if cfg.sliding_window:
            valid &= (pos - kpos) < cfg.sliding_window
        s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
        m_loc = jnp.max(s, axis=-1)
        m = jax.lax.pmax(m_loc, M)
        p_ = jnp.exp(s - m[..., None])
        l = jax.lax.psum(jnp.sum(p_, axis=-1), M)
        o = jnp.einsum("bkgcs,bskh->bckgh", p_.astype(cv.dtype), cv)
        o = jax.lax.psum(o.astype(jnp.float32), M)  # (B, 1, KV, G, hd)
        norm = jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]  # (B,1,KV,G,1)
        return (o / norm).astype(q.dtype), ck, cv

    kwargs = dict(
        mesh=mesh,
        in_specs=(P(dp, None, None, None), P(dp, None, None, None),
                  P(dp, None, None, None), P(dp, M, None, None),
                  P(dp, M, None, None), P()),
        out_specs=(P(dp, None, None, None, None), P(dp, M, None, None),
                   P(dp, M, None, None)))
    try:
        smapped = _sm(local, **kwargs, check_vma=False)
    except TypeError:  # older jax: check_rep
        smapped = _sm(local, **kwargs, check_rep=False)
    return smapped(q, k_new, v_new, cache_k_l, cache_v_l, pos)


def decode_attention(x, p, cfg: ArchConfig, cache_k_l, cache_v_l, pos, *, rope=True):
    """Single-token decode. x: (B, 1, d); cache_*_l: (B, S, KV, hd).

    Returns (out (B,1,d), new_k (B,S,KV,hd), new_v). Softmax statistics reduce
    over the (possibly model-axis-sharded) cache sequence dim.
    """
    from repro.models.sharding import current_ctx

    B = x.shape[0]
    S = cache_k_l.shape[1]
    positions = pos + jnp.arange(x.shape[1])
    q, k_new, v_new = _project_qkv(x, x, p, cfg, positions, positions, rope)
    ctx = current_ctx()
    if cfg.decode_cache_update == "shardmap" and ctx is not None \
            and S % ctx.mesh.shape[ctx.axes.model] == 0:
        out5, ck, cv = _decode_attention_smap(q, k_new, v_new, cache_k_l,
                                              cache_v_l, pos, cfg, ctx)
        out = out5.reshape(B, 1, cfg.n_heads * cfg.d_head)
        return out @ p["wo"].astype(x.dtype), ck, cv
    upd = update_cache_layer_dus if cfg.decode_cache_update == "dus" \
        else update_cache_layer
    ck, cv = upd(cache_k_l, cache_v_l, k_new, v_new, pos)

    KV, G = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    qq = q.reshape(B, 1, KV, G, cfg.d_head)
    scores = jnp.einsum("bckgh,bskh->bkgcs", qq, ck, preferred_element_type=jnp.float32)
    scores = scores / np.sqrt(cfg.d_head)
    kpos = jnp.arange(S)
    m = kpos[None, :] <= positions[:, None]
    if cfg.sliding_window:
        m &= (positions[:, None] - kpos[None, :]) < cfg.sliding_window
    scores = jnp.where(m[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgcs,bskh->bckgh", probs.astype(cv.dtype), cv)
    out = out.reshape(B, 1, cfg.n_heads * cfg.d_head)
    return out @ p["wo"].astype(x.dtype), ck, cv
