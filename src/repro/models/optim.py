"""AdamW (+ global-norm clip, cosine schedule) in pure JAX.

State is a pytree congruent with params (m, v) so it inherits the params'
shardings via ``jit`` out_shardings — i.e. optimizer state is automatically
ZeRO-sharded wherever params are FSDP-sharded.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def init_opt_state(params: Any) -> dict:
    zeros = lambda p: jax.tree_util.tree_map(lambda x: jnp.zeros_like(x), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def schedule(cfg: OptimConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def adamw_update(params: Any, grads: Any, state: dict, cfg: OptimConfig
                 ) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.betas
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
