"""Sharding rules: parameter PartitionSpecs and activation constraints.

2-D weight sharding (DESIGN.md §5): FSDP over the data axes × tensor
parallelism over "model". Column-parallel matrices (qkv / up-projections /
gate) shard their output dim over "model" and input dim over data; row-
parallel matrices (attention out / down-projection) shard input over "model"
and output over data. Expert weights shard the expert dim over "model"
(expert parallelism) and d_model over data. Layer-stacked params (leading L
dim from the scan layout) keep L unsharded.

Specs are assigned by *path pattern* over the param pytree, so every model in
the zoo shares one rule table. ``constrain`` is a no-op outside a mesh
context, letting the same model code run on 1 CPU device in tests.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import MeshAxes

# pattern -> spec builder; D = data axes tuple, M = model axis name.
# Patterns are matched against "/"-joined pytree paths, first match wins.
# The trailing-dims spec applies to the *last* n dims; leading (scan) dims
# are unsharded.
_RULES: list[tuple[str, Any]] = [
    # -- embeddings / heads ---------------------------------------------------
    (r"embed$", lambda D, M: P(M, D)),            # (V, d): vocab over model
    (r"lm_head$", lambda D, M: P(D, M)),          # (d, V): vocab over model
    (r"patch_proj$", lambda D, M: P(None, D)),    # (patch_dim, d)
    # -- MoE ------------------------------------------------------------------
    (r"router$", lambda D, M: P(D, None)),        # (d, E) replicated-ish
    (r"experts/w(1|3)$", lambda D, M: P(M, D, None)),  # (E, d, fe): EP over model
    (r"experts/w2$", lambda D, M: P(M, None, D)),       # (E, fe, d)
    (r"shared/w(1|3)$", lambda D, M: P(D, M)),
    (r"shared/w2$", lambda D, M: P(M, D)),
    # -- attention ------------------------------------------------------------
    (r"(attn|xattn|shared_attn)/w(q|k|v)$", lambda D, M: P(D, M)),
    (r"(attn|xattn|shared_attn)/b(q|k|v)$", lambda D, M: P(M)),
    (r"(attn|xattn|shared_attn)/wo$", lambda D, M: P(M, D)),
    # -- mlp -------------------------------------------------------------------
    (r"mlp/w(1|3)$", lambda D, M: P(D, M)),
    (r"mlp/w2$", lambda D, M: P(M, D)),
    (r"mlp/b1$", lambda D, M: P(M)),
    # -- rwkv ------------------------------------------------------------------
    (r"wkv/w(r|k|v|g)$", lambda D, M: P(D, M)),
    (r"wkv/wo$", lambda D, M: P(M, D)),
    (r"wkv/(w_lora_a)$", lambda D, M: P(D, None)),
    (r"wkv/(w_lora_b)$", lambda D, M: P(None, M)),
    # -- mamba2 ----------------------------------------------------------------
    (r"ssm/w_in$", lambda D, M: P(D, M)),         # (d, 2*di + 2N + H)
    (r"ssm/w_out$", lambda D, M: P(M, D)),        # (di, d)
]


def spec_for_path(path: str, ndim: int, axes: MeshAxes) -> P:
    D, M = axes.data, axes.model
    for pat, fn in _RULES:
        if re.search(pat, path):
            spec = fn(D, M)
            pad = ndim - len(spec)
            if pad < 0:  # spec longer than array rank (e.g. scalar bias)
                return P()
            return P(*([None] * pad), *spec)
    return P()  # norms, scales, small vectors: replicated


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params_tree: Any, axes: MeshAxes) -> Any:
    """PartitionSpec pytree matching ``params_tree`` (arrays or SDStructs)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for_path(_path_str(path), np.ndim(leaf) if not hasattr(leaf, "ndim") else leaf.ndim, axes),
        params_tree,
    )


def param_shardings(params_tree: Any, mesh: Mesh, axes: MeshAxes) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(params_tree, axes)
    )


"""Trace-time mesh context.

Model code calls ``constrain(x, "data", None, "model")`` with *symbolic* axis
names; the active :class:`ShardingCtx` (installed by the dry-run / trainer
around tracing) resolves "data" to the data-axis tuple and "model" to the TP
axis. With no context installed (CPU unit tests) every constraint is a no-op,
so the exact same model code runs on one device.
"""

import contextlib
import threading

_TLS = threading.local()


class ShardingCtx:
    def __init__(self, mesh: Mesh, axes: MeshAxes | None = None):
        self.mesh = mesh
        self.axes = axes or MeshAxes.for_mesh(mesh)

    def resolve(self, spec: tuple) -> P:
        out = []
        for s in spec:
            if s == "data":
                out.append(self.axes.data if len(self.axes.data) > 1 else self.axes.data[0])
            elif s == "model":
                out.append(self.axes.model)
            else:
                out.append(s)
        return P(*out)


@contextlib.contextmanager
def sharding_ctx(mesh: Mesh, axes: MeshAxes | None = None):
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = ShardingCtx(mesh, axes)
    try:
        yield _TLS.ctx
    finally:
        _TLS.ctx = prev


def current_ctx() -> ShardingCtx | None:
    return getattr(_TLS, "ctx", None)


def constrain(x: jax.Array, *spec) -> jax.Array:
    """Symbolic with_sharding_constraint; identity with no ctx installed.
    Axis entries whose mesh extent does not divide the dim are dropped
    (e.g. batch=1 long-context decode cannot batch-shard)."""
    ctx = current_ctx()
    if ctx is None:
        return x
    resolved = sanitize_pspec(ctx.resolve(spec), x.shape, ctx.mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, resolved))


def sanitize_pspec(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on dims the mesh axes do not divide evenly — jit
    in_shardings rejects uneven partitions (no implicit padding)."""
    out = []
    for d, entry in enumerate(spec):
        if entry is None or d >= len(shape):
            out.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        ext = 1
        for nm in names:
            ext *= mesh.shape.get(nm, 1)
        out.append(entry if ext and shape[d] % ext == 0 else None)
    return P(*out)


def sanitize_spec_tree(spec_tree, abstract_tree, mesh: Mesh):
    """tree_map sanitize_pspec over matching (specs, ShapeDtypeStruct) trees."""
    return jax.tree_util.tree_map(
        lambda s, a: sanitize_pspec(s, a.shape, mesh),
        spec_tree, abstract_tree,
        is_leaf=lambda x: isinstance(x, P))
