"""Uniform model API + abstract input/cache/param specs per (arch × shape).

Everything the launcher needs to lower a cell without allocating a byte:
  * ``get_api(cfg)``      — init/loss/prefill/decode for the arch family
  * ``batch_specs``       — ShapeDtypeStructs for the train/prefill batch
  * ``decode_specs``      — token + cache ShapeDtypeStructs for decode cells
  * ``abstract_params``   — eval_shape over init (no allocation)
  * ``*_pspecs``          — PartitionSpecs for params / batch / cache
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import MeshAxes
from repro.models import hybrid, rwkv, transformer, whisper
from repro.models.config import ArchConfig, ShapeSpec
from repro.models.sharding import param_specs


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    init: Callable
    loss: Callable
    prefill: Callable
    decode: Callable
    make_cache: Callable


def get_api(cfg: ArchConfig) -> ModelAPI:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return ModelAPI(transformer.init_lm, transformer.lm_loss,
                        transformer.lm_prefill, transformer.lm_decode_step,
                        transformer.make_cache)
    if fam == "rwkv":
        return ModelAPI(rwkv.init_rwkv_lm, rwkv.rwkv_loss, rwkv.rwkv_prefill,
                        rwkv.rwkv_decode_step, rwkv.make_cache)
    if fam == "hybrid":
        return ModelAPI(hybrid.init_hybrid, hybrid.hybrid_loss,
                        hybrid.hybrid_prefill, hybrid.hybrid_decode_step,
                        hybrid.make_cache)
    if fam == "encdec":
        return ModelAPI(whisper.init_whisper, whisper.whisper_loss,
                        whisper.whisper_prefill, whisper.whisper_decode_step,
                        whisper.make_cache)
    raise ValueError(f"unknown family {fam}")


def shape_adjusted_cfg(cfg: ArchConfig, shape: ShapeSpec) -> ArchConfig:
    """Per-shape config tweaks: zamba2's shared attention gets a 4k sliding
    window at 500k context (DESIGN.md §6 deviation — sub-quadratic serving)."""
    if cfg.family == "hybrid" and shape.seq_len > 100_000:
        return dataclasses.replace(cfg, sliding_window=4096)
    return cfg


# -- abstract specs ---------------------------------------------------------------


def batch_specs(cfg: ArchConfig, batch: int, seq: int) -> dict:
    """Train / prefill batch ShapeDtypeStructs."""
    specs = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct((batch, cfg.enc_len, cfg.d_model),
                                               jnp.bfloat16)
    if cfg.family == "vlm":
        specs["patches"] = jax.ShapeDtypeStruct((batch, cfg.num_patches, cfg.patch_dim),
                                                jnp.bfloat16)
    return specs


def decode_specs(cfg: ArchConfig, batch: int, cache_len: int) -> tuple[dict, dict]:
    """(token spec, cache specs) for a decode cell."""
    api = get_api(cfg)
    tokens = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    cache = api.make_cache(cfg, batch, cache_len, abstract=True)
    return {"tokens": tokens}, cache


def abstract_params(cfg: ArchConfig) -> Any:
    api = get_api(cfg)
    return jax.eval_shape(lambda k: api.init(k, cfg), jax.random.key(0))


def prefill_cache_len(cfg: ArchConfig, seq: int) -> int:
    """Cache depth a prefill of ``seq`` tokens produces (vlm prepends its
    projected patch prefix to the context)."""
    return seq + (cfg.num_patches if cfg.family == "vlm" else 0)


# -- PartitionSpecs ----------------------------------------------------------------


def batch_pspecs(cfg: ArchConfig, axes: MeshAxes) -> dict:
    D = axes.data if len(axes.data) > 1 else axes.data[0]
    specs = {"tokens": P(D, None)}
    if cfg.family == "encdec":
        specs["frames"] = P(D, None, None)
    if cfg.family == "vlm":
        specs["patches"] = P(D, None, None)
    return specs


def cache_pspecs(cfg: ArchConfig, axes: MeshAxes) -> dict:
    """Decode-cache shardings: batch over data; model axis placement is
    cfg.cache_shard_dim:
      "seq"  — baseline: cache sequence over "model". Memory-balanced, but
               GSPMD lowers the dynamic cache write on a sharded dim as a
               full-buffer select (every step rewrites the local cache).
      "head" — head_dim over "model" (d_head % TP == 0 for every assigned
               arch): the sequence dim stays local so the cache write is a
               true in-place DUS; attention contracts the sharded head_dim
               with one small score psum (§Perf iteration C3)."""
    D = axes.data if len(axes.data) > 1 else axes.data[0]
    M = axes.model
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        if cfg.cache_shard_dim == "head":
            spec = P(None, D, None, None, M)
        else:
            spec = P(None, D, M, None, None)
        return {"k": spec, "v": spec, "pos": P()}
    if fam == "rwkv":
        return {"att_x": P(None, D, None), "att_state": P(None, D, M, None, None),
                "ffn_x": P(None, D, None), "pos": P()}
    if fam == "hybrid":
        return {"conv": P(None, D, None, M), "state": P(None, D, M, None, None),
                "attn_k": P(None, D, None, M, None),
                "attn_v": P(None, D, None, M, None), "pos": P()}
    if fam == "encdec":
        return {"k": P(None, D, M, None, None), "v": P(None, D, M, None, None),
                "xk": P(None, D, None, None, None), "xv": P(None, D, None, None, None),
                "pos": P()}
    raise ValueError(fam)


def params_pspecs(cfg: ArchConfig, axes: MeshAxes) -> Any:
    return param_specs(abstract_params(cfg), axes)
