"""Decoder-only transformer LM covering the dense, moe and vlm families.

Layer parameters are *stacked* along a leading L dim (init via vmap over
per-layer keys) so the layer loop is one ``jax.lax.scan`` over a
``jax.checkpoint``-ed block: the HLO stays one-layer-sized (compile time at
512 devices) and activation memory is one layer's worth per remat segment.
DeepSeek-style MoE keeps its first ``first_dense_layers`` blocks dense —
those live outside the scan as separately-stacked params.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import (attention, decode_attention, init_attention)
from repro.models.config import ArchConfig
from repro.models.layers import (chunked_ce_loss, embed_tokens, he_init,
                                 init_embed, init_mlp, logits_from_hidden,
                                 mlp, rms_norm)
from repro.models.moe import init_moe, moe_ffn
from repro.models.sharding import constrain


def _init_block(key, cfg: ArchConfig, moe_layer: bool) -> dict:
    k1, k2 = jax.random.split(key)
    p = {
        "attn": init_attention(k1, cfg),
        "ln1": jnp.ones((cfg.d_model,)),
        "ln2": jnp.ones((cfg.d_model,)),
    }
    if moe_layer:
        p["moe"] = init_moe(k2, cfg, cfg.moe)
    else:
        d_ff = cfg.d_ff
        if cfg.moe is not None:  # dense layer inside a MoE arch
            d_ff = (cfg.moe.top_k + cfg.moe.num_shared) * cfg.moe.d_ff_expert
        p["mlp"] = init_mlp(k2, cfg.d_model, d_ff, gated=True)
    return p


def init_lm(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 4)
    n_first = cfg.moe.first_dense_layers if cfg.moe else 0
    n_scan = cfg.n_layers - n_first
    params: dict = {
        "embed": init_embed(ks[0], cfg.vocab, cfg.d_model),
        "final_norm": jnp.ones((cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = he_init(ks[1], (cfg.d_model, cfg.vocab), fan_in=cfg.d_model)
    layer_keys = jax.random.split(ks[2], n_scan)
    params["layers"] = jax.vmap(lambda k: _init_block(k, cfg, cfg.moe is not None))(layer_keys)
    if n_first:
        fkeys = jax.random.split(ks[3], n_first)
        params["first_layers"] = jax.vmap(lambda k: _init_block(k, cfg, False))(fkeys)
    if cfg.family == "vlm":
        params["patch_proj"] = he_init(ks[1], (cfg.patch_dim, cfg.d_model),
                                       fan_in=cfg.patch_dim)
    return params


def _head(params, cfg: ArchConfig):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def _residual_constrain(x, cfg: ArchConfig):
    if cfg.seq_parallel:
        return constrain(x, "data", "model", None)
    return constrain(x, "data", None, None)


def _norm_in(x, scale, cfg: ArchConfig):
    """Norm for a block input. Under sequence parallelism the norm runs in
    the S-sharded domain (elementwise over d) and the SP all-gather is pinned
    to its bf16 OUTPUT — otherwise GSPMD floats the gather onto the f32 norm
    intermediates and doubles the wire bytes (§Perf iteration A4)."""
    h = rms_norm(x, scale, cfg.norm_eps)
    if cfg.seq_parallel:
        h = constrain(h, "data", None, None)
    return h


def _block_apply(x, lp, cfg: ArchConfig, positions, moe_layer: bool):
    h = attention(_norm_in(x, lp["ln1"], cfg), lp["attn"], cfg,
                  positions=positions)
    x = _residual_constrain(x + h, cfg)
    hidden = _norm_in(x, lp["ln2"], cfg)
    if moe_layer:
        f, aux = moe_ffn(hidden, lp["moe"], cfg, cfg.moe)
    else:
        f, aux = mlp(hidden, lp["mlp"]), jnp.zeros((), jnp.float32)
    x = _residual_constrain(x + f, cfg)
    return x, aux


def embed_input(params, tokens, cfg: ArchConfig, patches=None):
    """Token (+ optional projected patch prefix) embedding."""
    x = embed_tokens(params["embed"], tokens)
    if cfg.family == "vlm":
        assert patches is not None, "vlm needs patch embeddings (stub frontend)"
        pe = (patches.astype(x.dtype) @ params["patch_proj"].astype(x.dtype))
        x = jnp.concatenate([pe, x], axis=1)
    return x


def forward_hidden(params, tokens, cfg: ArchConfig, patches=None):
    """Training/prefill trunk: (B,S[,+P],d) hidden states + MoE aux loss."""
    x = embed_input(params, tokens, cfg, patches)
    S_total = x.shape[1]
    positions = jnp.arange(S_total)
    moe_layer = cfg.moe is not None

    if "first_layers" in params:
        n_first = cfg.moe.first_dense_layers

        def first_body(carry, lp):
            return _block_apply(carry, lp, cfg, positions, False)[0], None

        x, _ = jax.lax.scan(jax.checkpoint(first_body), x, params["first_layers"])

    def body(carry, lp):
        return _block_apply(carry, lp, cfg, positions, moe_layer)

    n_scan = jax.tree_util.tree_leaves(params["layers"])[0].shape[0]
    segs = cfg.remat_segments
    if cfg.remat and segs and n_scan % segs == 0 and segs < n_scan:
        # nested remat: outer scan saves `segs` carries; inner layers
        # recompute during the outer segment's backward.
        inner = n_scan // segs
        stacked = jax.tree_util.tree_map(
            lambda a: a.reshape(segs, inner, *a.shape[1:]), params["layers"])

        def seg_body(carry, seg_params):
            # per-layer checkpoint INSIDE the segment: the segment backward
            # re-runs layers one at a time instead of storing their internals
            x2, auxs = jax.lax.scan(jax.checkpoint(body), carry, seg_params)
            return x2, jnp.sum(auxs)

        x, auxs = jax.lax.scan(jax.checkpoint(seg_body), x, stacked)
    else:
        step = jax.checkpoint(body) if cfg.remat else body
        x, auxs = jax.lax.scan(step, x, params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, jnp.sum(auxs)


def lm_loss(params, batch, cfg: ArchConfig):
    """batch: {"tokens": (B,S) int32[, "patches": (B,P,pd)]}"""
    tokens = batch["tokens"]
    hidden, aux = forward_hidden(params, tokens, cfg, batch.get("patches"))
    S = tokens.shape[1]
    hidden = hidden[:, -S:]  # drop patch positions (vlm)
    loss_sum = chunked_ce_loss(hidden[:, :-1], _head(params, cfg), tokens[:, 1:],
                               chunk=cfg.loss_chunk)
    ntok = tokens.shape[0] * (S - 1)
    loss = loss_sum / ntok
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_loss_weight * aux
    return loss, {"ce": loss_sum / ntok, "aux": aux}


# -- serving -----------------------------------------------------------------


def make_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               abstract: bool = False) -> dict:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    if abstract:
        return {"k": jax.ShapeDtypeStruct(shape, dtype),
                "v": jax.ShapeDtypeStruct(shape, dtype),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.zeros((), jnp.int32)}


def lm_prefill(params, batch, cfg: ArchConfig, max_len: int | None = None):
    """Runs the trunk capturing per-layer KV; returns (cache, last logits).

    Every attention layer caches — including DeepSeek-style first dense-FFN
    layers, whose cache entries simply occupy the leading slots of the
    (n_layers, ...) cache arrays.
    """
    from repro.models.attention import _project_qkv, attention_core

    tokens = batch["tokens"]
    x = embed_input(params, tokens, cfg, batch.get("patches"))
    B, S_total = x.shape[0], x.shape[1]
    max_len = max(max_len or 0, S_total)  # vlm: patch prefix extends context
    positions = jnp.arange(S_total)

    def make_body(moe_layer: bool):
        def body(carry, lp):
            x = carry
            h_in = rms_norm(x, lp["ln1"], cfg.norm_eps)
            q, k, v = _project_qkv(h_in, h_in, lp["attn"], cfg, positions, positions, True)
            o = attention_core(q, k, v, positions, positions, cfg, causal=True)
            o = o.reshape(B, S_total, -1) @ lp["attn"]["wo"].astype(x.dtype)
            x = constrain(x + o, "data", None, None)
            hidden = rms_norm(x, lp["ln2"], cfg.norm_eps)
            if moe_layer:
                f, _ = moe_ffn(hidden, lp["moe"], cfg, cfg.moe)
            else:
                f = mlp(hidden, lp["mlp"])
            x = constrain(x + f, "data", None, None)
            pad = [(0, 0), (0, max_len - S_total), (0, 0), (0, 0)]
            return x, (jnp.pad(k, pad).astype(jnp.bfloat16),
                       jnp.pad(v, pad).astype(jnp.bfloat16))
        return body

    caches = []
    if "first_layers" in params:
        x, kv = jax.lax.scan(jax.checkpoint(make_body(False)), x, params["first_layers"])
        caches.append(kv)
    x, kv = jax.lax.scan(jax.checkpoint(make_body(cfg.moe is not None)), x, params["layers"])
    caches.append(kv)
    ck = jnp.concatenate([c[0] for c in caches], axis=0)
    cv = jnp.concatenate([c[1] for c in caches], axis=0)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(x[:, -1:, :], _head(params, cfg))
    cache = {"k": ck, "v": cv, "pos": jnp.asarray(S_total, jnp.int32)}
    return cache, logits


def lm_decode_step(params, cache, tokens, cfg: ArchConfig):
    """One decode step. tokens: (B, 1). Returns (new_cache, logits (B,1,V))."""
    x = embed_tokens(params["embed"], tokens)
    pos = cache["pos"]

    def make_body(moe_layer: bool):
        def body(carry, xs):
            lp, ck_l, cv_l = xs
            h, ck2, cv2 = decode_attention(rms_norm(carry, lp["ln1"], cfg.norm_eps),
                                           lp["attn"], cfg, ck_l, cv_l, pos)
            x2 = constrain(carry + h, "data", None, None)
            hidden = rms_norm(x2, lp["ln2"], cfg.norm_eps)
            if moe_layer:
                f, _ = moe_ffn(hidden, lp["moe"], cfg, cfg.moe)
            else:
                f = mlp(hidden, lp["mlp"])
            return constrain(x2 + f, "data", None, None), (ck2, cv2)
        return body

    n_first = cfg.moe.first_dense_layers if (cfg.moe and "first_layers" in params) else 0
    new_k, new_v = [], []
    if n_first:
        x, (k0, v0) = jax.lax.scan(make_body(False), x,
                                   (params["first_layers"],
                                    cache["k"][:n_first], cache["v"][:n_first]))
        new_k.append(k0)
        new_v.append(v0)
    x, (ck, cv) = jax.lax.scan(make_body(cfg.moe is not None), x,
                               (params["layers"], cache["k"][n_first:],
                                cache["v"][n_first:]))
    new_k.append(ck)
    new_v.append(cv)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(x, _head(params, cfg))
    new_cache = {"k": jnp.concatenate(new_k, axis=0) if n_first else ck,
                 "v": jnp.concatenate(new_v, axis=0) if n_first else cv,
                 "pos": pos + tokens.shape[1]}
    return new_cache, logits
