"""Whisper-base backbone: 6L bidirectional encoder over precomputed frame
embeddings (the conv frontend is a STUB per the assignment — ``input_specs``
supplies (B, 1500, 512) frames) + 6L causal decoder with cross-attention.

Deviations (DESIGN.md §7): sinusoidal (not learned) positions so parameter
shapes are independent of the assigned cache lengths; pre-LN RMS norms in
place of whisper's LayerNorm+bias (a norm-flavor substitution, not a
structural one). Embeddings are tied as in the original.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import (_project_qkv, attention, decode_attention,
                                    init_attention)
from repro.models.config import ArchConfig
from repro.models.layers import (chunked_ce_loss, embed_tokens, init_embed,
                                 init_mlp, logits_from_hidden, mlp, rms_norm)
from repro.models.sharding import constrain


def sinusoid(positions, d):
    inv = 1.0 / (10000 ** (np.arange(0, d, 2) / d))
    ang = positions[:, None].astype(jnp.float32) * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_enc_block(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"attn": init_attention(k1, cfg), "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, gated=False),
            "ln1": jnp.ones((cfg.d_model,)), "ln2": jnp.ones((cfg.d_model,))}


def _init_dec_block(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"attn": init_attention(k1, cfg), "xattn": init_attention(k2, cfg),
            "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, gated=False),
            "ln1": jnp.ones((cfg.d_model,)), "ln2": jnp.ones((cfg.d_model,)),
            "ln3": jnp.ones((cfg.d_model,))}


def init_whisper(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 3)
    ekeys = jax.random.split(ks[0], cfg.enc_layers)
    dkeys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "embed": init_embed(ks[2], cfg.vocab, cfg.d_model),
        "enc_layers": jax.vmap(lambda k: _init_enc_block(k, cfg))(ekeys),
        "enc_norm": jnp.ones((cfg.d_model,)),
        "dec_layers": jax.vmap(lambda k: _init_dec_block(k, cfg))(dkeys),
        "final_norm": jnp.ones((cfg.d_model,)),
    }


def encode(params, frames, cfg: ArchConfig):
    """frames: (B, enc_len, d) stub frame embeddings."""
    x = frames.astype(jnp.bfloat16)
    x = x + sinusoid(jnp.arange(x.shape[1]), cfg.d_model).astype(x.dtype)
    x = constrain(x, "data", None, None)

    def body(carry, lp):
        h = attention(rms_norm(carry, lp["ln1"], cfg.norm_eps), lp["attn"], cfg,
                      causal=False, rope=False)
        x = carry + h
        x = x + mlp(rms_norm(x, lp["ln2"], cfg.norm_eps), lp["mlp"])
        return constrain(x, "data", None, None), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _decoder_hidden(params, tokens, enc_out, cfg: ArchConfig):
    x = embed_tokens(params["embed"], tokens)
    x = x + sinusoid(jnp.arange(x.shape[1]), cfg.d_model).astype(x.dtype)

    def body(carry, lp):
        h = attention(rms_norm(carry, lp["ln1"], cfg.norm_eps), lp["attn"], cfg,
                      causal=True, rope=False)
        x = carry + h
        h = attention(rms_norm(x, lp["ln2"], cfg.norm_eps), lp["xattn"], cfg,
                      x_kv=enc_out, causal=False, rope=False)
        x = x + h
        x = x + mlp(rms_norm(x, lp["ln3"], cfg.norm_eps), lp["mlp"])
        return constrain(x, "data", None, None), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["dec_layers"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def whisper_loss(params, batch, cfg: ArchConfig):
    enc_out = encode(params, batch["frames"], cfg)
    hidden = _decoder_hidden(params, batch["tokens"], enc_out, cfg)
    tokens = batch["tokens"]
    loss_sum = chunked_ce_loss(hidden[:, :-1], params["embed"].T, tokens[:, 1:],
                               chunk=cfg.loss_chunk)
    ntok = tokens.shape[0] * (tokens.shape[1] - 1)
    return loss_sum / ntok, {"ce": loss_sum / ntok}


# -- serving -------------------------------------------------------------------


def make_cache(cfg: ArchConfig, batch: int, max_len: int, abstract: bool = False) -> dict:
    L = cfg.n_layers
    shapes = {
        "k": ((L, batch, max_len, cfg.n_kv_heads, cfg.d_head), jnp.bfloat16),
        "v": ((L, batch, max_len, cfg.n_kv_heads, cfg.d_head), jnp.bfloat16),
        "xk": ((L, batch, cfg.enc_len, cfg.n_kv_heads, cfg.d_head), jnp.bfloat16),
        "xv": ((L, batch, cfg.enc_len, cfg.n_kv_heads, cfg.d_head), jnp.bfloat16),
        "pos": ((), jnp.int32),
    }
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
    return {k: jnp.zeros(s, d) for k, (s, d) in shapes.items()}


def whisper_prefill(params, batch, cfg: ArchConfig, max_len: int | None = None):
    """Encode + run decoder prompt, capturing self- and cross-KV caches."""
    from repro.models.attention import attention_core

    enc_out = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    max_len = max_len or S
    positions = jnp.arange(S)
    enc_pos = jnp.arange(cfg.enc_len)
    x = embed_tokens(params["embed"], tokens)
    x = x + sinusoid(positions, cfg.d_model).astype(x.dtype)

    def body(carry, lp):
        x = carry
        h_in = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = _project_qkv(h_in, h_in, lp["attn"], cfg, positions, positions, False)
        o = attention_core(q, k, v, positions, positions, cfg, causal=True)
        x = x + o.reshape(B, S, -1) @ lp["attn"]["wo"].astype(x.dtype)
        h_in = rms_norm(x, lp["ln2"], cfg.norm_eps)
        q2, xk, xv = _project_qkv(h_in, enc_out, lp["xattn"], cfg, positions, enc_pos, False)
        o2 = attention_core(q2, xk, xv, positions, enc_pos, cfg, causal=False)
        x = x + o2.reshape(B, S, -1) @ lp["xattn"]["wo"].astype(x.dtype)
        x = x + mlp(rms_norm(x, lp["ln3"], cfg.norm_eps), lp["mlp"])
        pad = [(0, 0), (0, max_len - S), (0, 0), (0, 0)]
        return constrain(x, "data", None, None), (
            jnp.pad(k, pad).astype(jnp.bfloat16), jnp.pad(v, pad).astype(jnp.bfloat16),
            xk.astype(jnp.bfloat16), xv.astype(jnp.bfloat16))

    x, (ck, cv, xk, xv) = jax.lax.scan(jax.checkpoint(body), x, params["dec_layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(x[:, -1:, :], params["embed"].T)
    cache = {"k": ck, "v": cv, "xk": xk, "xv": xv, "pos": jnp.asarray(S, jnp.int32)}
    return cache, logits


def _cross_decode(x, lp, cfg, xk, xv):
    B = x.shape[0]
    q = (x @ lp["wq"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + lp["bq"].astype(x.dtype)
    KV, G = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    qq = q.reshape(B, 1, KV, G, cfg.d_head)
    scores = jnp.einsum("bckgh,bskh->bkgcs", qq, xk,
                        preferred_element_type=jnp.float32) / np.sqrt(cfg.d_head)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgcs,bskh->bckgh", probs.astype(xv.dtype), xv)
    return out.reshape(B, 1, cfg.n_heads * cfg.d_head) @ lp["wo"].astype(x.dtype)


def whisper_decode_step(params, cache, tokens, cfg: ArchConfig):
    x = embed_tokens(params["embed"], tokens)
    pos = cache["pos"]
    x = x + sinusoid(pos + jnp.arange(1), cfg.d_model).astype(x.dtype)

    def body(carry, xs):
        lp, ck_l, cv_l, xk_l, xv_l = xs
        h, ck2, cv2 = decode_attention(rms_norm(carry, lp["ln1"], cfg.norm_eps),
                                       lp["attn"], cfg, ck_l, cv_l, pos, rope=False)
        x = carry + h
        x = x + _cross_decode(rms_norm(x, lp["ln2"], cfg.norm_eps), lp["xattn"],
                              cfg, xk_l, xv_l)
        x = x + mlp(rms_norm(x, lp["ln3"], cfg.norm_eps), lp["mlp"])
        return constrain(x, "data", None, None), (ck2, cv2)

    x, (ck, cv) = jax.lax.scan(body, x, (params["dec_layers"], cache["k"],
                                         cache["v"], cache["xk"], cache["xv"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(x, params["embed"].T)
    new_cache = {"k": ck, "v": cv, "xk": cache["xk"], "xv": cache["xv"],
                 "pos": pos + tokens.shape[1]}
    return new_cache, logits
