"""Architecture configuration.

One frozen dataclass describes every assigned architecture; the per-arch
modules in ``src/repro/configs/`` instantiate it with the exact published
numbers. ``reduced()`` derives the small same-family config used by the CPU
smoke tests (full configs are only ever lowered via ShapeDtypeStructs in the
dry-run — never allocated).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int = 64
    top_k: int = 6
    num_shared: int = 2
    d_ff_expert: int = 1408
    capacity_factor: float = 1.25
    first_dense_layers: int = 1  # DeepSeek-MoE: layer 0 keeps a dense FFN
    aux_loss_weight: float = 0.001


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | rwkv | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    d_head: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    sliding_window: int = 0  # 0 = full attention

    moe: Optional[MoESpec] = None

    # rwkv6
    rwkv_head_dim: int = 64
    rwkv_lora: int = 64  # decay-LoRA rank (data-dependent decay, Finch)

    # mamba2 / zamba2 hybrid
    ssm_state: int = 0  # N; 0 = no SSM blocks
    ssm_head_dim: int = 64  # P
    ssm_expand: int = 2
    attn_every: int = 0  # zamba2: shared attention block every k SSM blocks

    # whisper (enc-dec): encoder layers + fixed frame count (stub frontend)
    enc_layers: int = 0
    enc_len: int = 1500

    # llava (vlm): projected patch-embedding prefix (stub anyres frontend)
    num_patches: int = 0
    patch_dim: int = 1024

    # execution
    chunk_q: int = 512  # query-block size for the memory-blocked attention
    loss_chunk: int = 2048  # sequence-chunked cross entropy
    scan_layers: bool = True
    remat: bool = True
    # 0 = flat layer scan (one remat per layer: saves L carries). N>0 = nested
    # scan of N checkpointed segments × L/N inner layers: saves N + L/N
    # carries at ~ one extra forward of recompute (§Perf memory-peak fix)
    remat_segments: int = 0
    # Megatron-style sequence parallelism: residual-stream activations (and
    # therefore every remat carry) shard their sequence dim over "model" —
    # ÷TP on activation memory; GSPMD turns the TP psum into
    # reduce-scatter + all-gather around each block (§Perf)
    seq_parallel: bool = False
    # attention implementation: "blocked" (baseline: XLA chunked softmax,
    # prob residuals stacked for backward) | "flash" (kernels/ops custom_vjp:
    # O(S) residuals, probs recomputed in backward — §Perf iteration)
    attn_impl: str = "blocked"
    # decode KV-cache write: "onehot" (baseline: masked elementwise rewrite of
    # the whole cache — sharding-trivial but 2 extra full-cache passes) |
    # "dus" (in-place dynamic_update_slice on the donated cache — §Perf)
    decode_cache_update: str = "onehot"
    # dtype the FSDP all-gather moves MoE expert weights in: "f32" (baseline,
    # params' storage dtype on the wire) | "bf16" (cast before gather; halves
    # the dominant EP collective — §Perf)
    moe_gather_dtype: str = "f32"
    # cast f32 master params to bf16 ONCE at step entry (on the local shard)
    # so every FSDP weight all-gather moves bf16, not f32 — vs the baseline's
    # per-use .astype, which GSPMD places after the gather (§Perf)
    cast_params_once: bool = False
    # dtype served weights are STORED in ("f32" | "bf16"): serving from a
    # bf16 checkpoint halves the per-token parameter read — the dominant
    # decode-cell traffic (§Perf iteration C2)
    serve_params_dtype: str = "f32"
    # which cache dim the TP axis shards at decode: "seq" (baseline) |
    # "head" (in-place DUS cache writes; see registry.cache_pspecs — §Perf)
    cache_shard_dim: str = "seq"

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // max(self.n_heads, 1))

    # -- derived -------------------------------------------------------------
    @property
    def attention_free(self) -> bool:
        return self.family == "rwkv"

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k shape (DESIGN.md §6)."""
        return self.family in ("rwkv", "hybrid")

    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    def n_params(self) -> int:
        """Total parameter count (used for MODEL_FLOPS = 6·N·D)."""
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        hq = self.n_heads * self.d_head
        hkv = self.n_kv_heads * self.d_head
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "rwkv":
            # r/k/v/g/o projections + decay LoRA + channel-mix (ffn)
            per_layer = 5 * d * d + d * self.rwkv_lora * 2 + 2 * d * f + 2 * d
        elif self.family == "hybrid":
            di = self.ssm_expand * d
            H = di // self.ssm_head_dim
            n_inv = (L + self.attn_every - 1) // max(self.attn_every, 1)
            per_layer = d * (2 * di + 2 * self.ssm_state + H) + di * d
            shared = (2 * d) * (hq + 2 * hkv) + hq * d  # concat(h, emb) input
            shared += 3 * d * f + n_inv * d * d          # shared MLP + inv projs
            return emb + L * per_layer + shared + d
        else:
            attn = d * (hq + 2 * hkv) + hq * d
            if self.moe is not None:
                fe = self.moe.d_ff_expert
                ffn = self.moe.num_experts * 3 * d * fe + self.moe.num_shared * 3 * d * fe
                ffn += d * self.moe.num_experts  # router
                dense_ffn = 3 * d * f
                per_layer = attn + ffn
                extra = self.moe.first_dense_layers * (dense_ffn - ffn)
                return emb + L * per_layer + extra + d
            ffn = 3 * d * f if self.family != "encdec" else 2 * d * f
            per_layer = attn + ffn
            if self.family == "encdec":
                per_layer += attn  # decoder cross-attention
        total = emb + L * per_layer + d
        if self.family == "encdec":
            total += self.enc_layers * (d * (hq + 2 * hkv) + hq * d + 2 * d * f)
        if self.family == "vlm":
            total += self.patch_dim * d  # patch projector
        return total

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: 6·N_active·D)."""
        if self.moe is None:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        fe = self.moe.d_ff_expert
        hq = self.n_heads * self.d_head
        hkv = self.n_kv_heads * self.d_head
        attn = d * (hq + 2 * hkv) + hq * d
        active_ffn = (self.moe.top_k + self.moe.num_shared) * 3 * d * fe + d * self.moe.num_experts
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return emb + L * (attn + active_ffn) + d

    def reduced(self) -> "ArchConfig":
        """Same-family tiny config for CPU smoke tests."""
        kw: dict = dict(
            name=self.name + "-smoke",
            family=self.family,
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128,
            vocab=256,
            d_head=16,
            qkv_bias=self.qkv_bias,
            qk_norm=self.qk_norm,
            tie_embeddings=self.tie_embeddings,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            rwkv_head_dim=16,
            rwkv_lora=8,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16,
            ssm_expand=self.ssm_expand,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            enc_layers=2 if self.enc_layers else 0,
            enc_len=8 if self.enc_layers else 1500,
            num_patches=4 if self.num_patches else 0,
            patch_dim=32 if self.num_patches else 1024,
            chunk_q=8,
            loss_chunk=16,
        )
        if self.moe is not None:
            # capacity_factor=8: dropless at smoke scale so serve-consistency
            # tests are exact (capacity drops vary with batch composition)
            kw["moe"] = MoESpec(num_experts=4, top_k=2, num_shared=1, d_ff_expert=32,
                                first_dense_layers=self.moe.first_dense_layers,
                                capacity_factor=8.0)
        return ArchConfig(**kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cell_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; reason when skipped."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k needs sub-quadratic attention; this arch is full-attention (skip noted in DESIGN.md)"
    return True, ""
