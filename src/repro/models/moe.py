"""Fine-grained MoE (DeepSeek-MoE / Moonlight family): shared experts +
top-k routed experts, expert-parallel over the mesh "model" axis.

TPU-native design (DESIGN.md §2): tokens stay sharded over the data axes and
*replicated* over "model" (they already are at the FFN input of a TP block).
Each model rank therefore dispatches only to its E/M local experts and emits a
partial token output; one psum over "model" combines — the same all-gather +
psum comm pattern as a dense TP MLP, with **no token all-to-all at all**.
Dispatch itself is sort-based with a capacity bound (static shapes), and the
combine is the one-hot ``segment_sum`` primitive the DataFrame group-by also
uses (kernels/segment_agg.py is its Pallas form).

Per-rank routing is recomputed redundantly on every model rank — 2·T·d·E
FLOPs, noise against the expert GEMMs — buying zero-collective dispatch.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig, MoESpec
from repro.models.layers import he_init, mlp
from repro.models.sharding import current_ctx

try:  # jax >= 0.6 exposes shard_map at top level
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

from jax.sharding import PartitionSpec as P


def init_moe(key, cfg: ArchConfig, spec: MoESpec) -> dict:
    d, fe, E = cfg.d_model, spec.d_ff_expert, spec.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": he_init(ks[0], (d, E)),
        "experts": {
            "w1": he_init(ks[1], (E, d, fe), fan_in=d),
            "w3": he_init(ks[2], (E, d, fe), fan_in=d),
            "w2": he_init(ks[3], (E, fe, d), fan_in=fe),
        },
    }
    if spec.num_shared:
        from repro.models.layers import init_mlp

        p["shared"] = init_mlp(ks[4], d, spec.num_shared * fe, gated=True)
    return p


def _capacity(tokens: int, spec: MoESpec) -> int:
    return max(int(math.ceil(tokens * spec.top_k * spec.capacity_factor / spec.num_experts)), 4)


def _local_moe(xl, router_w, w1, w3, w2, *, spec: MoESpec, e_local: int,
               rank, psum, pmean):
    """Per-(data, model)-shard MoE body. xl: (B_loc, S, d)."""
    B, S, d = xl.shape
    T = B * S
    xf = xl.reshape(T, d)
    k = spec.top_k
    E = spec.num_experts
    C = _capacity(T, spec)
    off = rank * e_local

    logits = (xf @ router_w.astype(xf.dtype)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)  # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # switch-style load-balance aux loss over *global* tokens
    onehot_frac = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(1), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(pmean(onehot_frac) * pmean(mean_prob)) / k

    # -- local dispatch (sort-based rank-in-expert, capacity C) --------------
    flat_idx = idx.reshape(-1)  # (T*k,)
    flat_gate = gates.reshape(-1)
    is_local = (flat_idx >= off) & (flat_idx < off + e_local)
    lidx = jnp.clip(flat_idx - off, 0, e_local - 1)
    sort_key = jnp.where(is_local, lidx, e_local).astype(jnp.int32)
    order = jnp.argsort(sort_key, stable=True)
    sorted_key = sort_key[order]
    starts = jnp.searchsorted(sorted_key, jnp.arange(e_local + 1), side="left")
    rank_sorted = jnp.arange(T * k) - starts[jnp.clip(sorted_key, 0, e_local)]
    rank_in_e = jnp.zeros((T * k,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    keep = is_local & (rank_in_e < C)
    slot = lidx * C + jnp.minimum(rank_in_e, C - 1)
    token_of = jnp.arange(T * k) // k

    contrib = jnp.where(keep[:, None], xf[token_of], 0)
    xdisp = jax.ops.segment_sum(contrib, slot, num_segments=e_local * C)
    xdisp = xdisp.reshape(e_local, C, d)

    # -- expert FFN (swiglu), E_local experts resident on this rank ----------
    h1 = jnp.einsum("ecd,edf->ecf", xdisp, w1.astype(xdisp.dtype))
    h3 = jnp.einsum("ecd,edf->ecf", xdisp, w3.astype(xdisp.dtype))
    yd = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h1) * h3, w2.astype(xdisp.dtype))

    # -- combine: gather own slots, weight, sum over k, psum over model ------
    y_flat = yd.reshape(e_local * C, d)
    w = jnp.where(keep, flat_gate, 0.0).astype(y_flat.dtype)
    y_tok = y_flat[slot] * w[:, None]
    y_part = y_tok.reshape(T, k, d).sum(axis=1)
    y = psum(y_part)
    return y.reshape(B, S, d), aux


def moe_ffn(x: jax.Array, p: dict, cfg: ArchConfig, spec: MoESpec) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss). Shared experts add on top (dense TP)."""
    ctx = current_ctx()
    if ctx is not None and ctx.axes.model in ctx.mesh.shape \
            and spec.num_experts % ctx.mesh.shape[ctx.axes.model] == 0 \
            and ctx.mesh.shape[ctx.axes.model] > 1:
        mesh, axes = ctx.mesh, ctx.axes
        M = mesh.shape[axes.model]
        e_local = spec.num_experts // M
        dp = axes.data if len(axes.data) > 1 else axes.data[0]

        def mapped(xl, router_w, w1, w3, w2):
            r = jax.lax.axis_index(axes.model)
            y_l, aux_l = _local_moe(
                xl, router_w, w1, w3, w2, spec=spec, e_local=e_local,
                rank=r,
                psum=lambda v: jax.lax.psum(v, axes.model),
                pmean=lambda v: jax.lax.pmean(v, axes.data),
            )
            # identical across model ranks; pmean makes replication provable
            return y_l, jax.lax.pmean(aux_l, axes.model)

        gather_dt = jnp.bfloat16 if cfg.moe_gather_dtype == "bf16" else None
        cast = (lambda w: w.astype(gather_dt)) if gather_dt else (lambda w: w)
        y, aux = _shard_map(
            mapped, mesh=mesh,
            in_specs=(P(dp, None, None), P(None, None),
                      P(axes.model, None, None), P(axes.model, None, None),
                      P(axes.model, None, None)),
            out_specs=(P(dp, None, None), P()),
        )(x, p["router"], cast(p["experts"]["w1"]), cast(p["experts"]["w3"]),
          cast(p["experts"]["w2"]))
    else:
        y, aux = _local_moe(
            x, p["router"], p["experts"]["w1"], p["experts"]["w3"],
            p["experts"]["w2"], spec=spec, e_local=spec.num_experts,
            rank=0, psum=lambda v: v, pmean=lambda v: v,
        )
    if "shared" in p:
        y = y + mlp(x, p["shared"])
    return y, aux
