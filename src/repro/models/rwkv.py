"""RWKV6 "Finch" — attention-free LM with data-dependent per-channel decay.

Training / prefill uses a *chunked* linear-recurrence form: within a chunk of
length C the pairwise decay factorizes into r̃ = r·exp(ecum), k̃ = k·exp(-cum)
so intra-chunk interaction is one (C×C) matmul per head (MXU-friendly);
chunk-to-chunk state flows through a ``lax.scan``. Decode keeps the exact
O(1) recurrence: state is one (N×N) matrix per head per layer — this is why
rwkv6 *runs* the long_500k cell that full-attention archs must skip.

Numerical note (recorded deviation, DESIGN.md §7): the chunked factorization
bounds per-chunk decay, so log-decay is clamped to ≥ -4/step and C = 16,
keeping exp magnitudes ≤ e^64 < f32 max. The sequential oracle
(``wkv6_sequential``) has no clamp; tests compare the two under benign decay.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import he_init, layer_norm, rms_norm
from repro.models.sharding import constrain

CHUNK = 16
LW_MIN = -4.0  # per-step log-decay clamp for the chunked path


def init_rwkv_block(key, cfg: ArchConfig) -> dict:
    d, f, r = cfg.d_model, cfg.d_ff, cfg.rwkv_lora
    H = d // cfg.rwkv_head_dim
    N = cfg.rwkv_head_dim
    ks = jax.random.split(key, 12)
    wkv = {
        "wr": he_init(ks[0], (d, d)),
        "wk": he_init(ks[1], (d, d)),
        "wv": he_init(ks[2], (d, d)),
        "wg": he_init(ks[3], (d, d)),
        "wo": he_init(ks[4], (d, d)),
        "w_lora_a": he_init(ks[5], (d, r)) * 0.1,
        "w_lora_b": he_init(ks[6], (r, d)) * 0.1,
        "w0": jnp.full((d,), -0.6),  # decay ≈ exp(-exp(-0.6)) ≈ 0.58
        "u": jnp.zeros((H, N)),
        "mu_r": jnp.full((d,), 0.5), "mu_k": jnp.full((d,), 0.5),
        "mu_v": jnp.full((d,), 0.5), "mu_w": jnp.full((d,), 0.5),
        "mu_g": jnp.full((d,), 0.5),
        "ln_x": jnp.ones((d,)),
    }
    cmix = {
        "mu_k": jnp.full((d,), 0.5), "mu_r": jnp.full((d,), 0.5),
        "ck": he_init(ks[7], (d, f)),
        "cv": he_init(ks[8], (f, d)),
        "cr": he_init(ks[9], (d, d)),
    }
    return {
        "wkv": wkv, "cmix": cmix,
        "ln1": jnp.ones((d,)), "ln1_b": jnp.zeros((d,)),
        "ln2": jnp.ones((d,)), "ln2_b": jnp.zeros((d,)),
    }


def _token_shift(x, x_prev_last):
    """x: (B,S,D); x_prev_last: (B,D) carry from previous segment (zeros at
    sequence start). Returns x shifted right one step."""
    return jnp.concatenate([x_prev_last[:, None, :], x[:, :-1, :]], axis=1)


def _lerp(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def _project_rkvwg(x, xs, p, H, N):
    B, S, d = x.shape
    r = _lerp(x, xs, p["mu_r"]) @ p["wr"].astype(x.dtype)
    k = _lerp(x, xs, p["mu_k"]) @ p["wk"].astype(x.dtype)
    v = _lerp(x, xs, p["mu_v"]) @ p["wv"].astype(x.dtype)
    g = jax.nn.silu(_lerp(x, xs, p["mu_g"]) @ p["wg"].astype(x.dtype))
    xw = _lerp(x, xs, p["mu_w"])
    lora = jnp.tanh(xw @ p["w_lora_a"].astype(x.dtype)) @ p["w_lora_b"].astype(x.dtype)
    lw = -jnp.exp(jnp.clip(p["w0"].astype(jnp.float32) + lora.astype(jnp.float32),
                           -20.0, 1.386))  # log-decay in (-4, 0)
    lw = jnp.maximum(lw, LW_MIN)
    shp = (B, S, H, N)
    return (r.reshape(shp), k.reshape(shp), v.reshape(shp), g,
            lw.reshape(shp))


def wkv6_chunked(r, k, v, lw, u, state0=None, chunk: int = CHUNK):
    """Chunked WKV6. r,k,v,lw: (B,S,H,N) — lw is log-decay (fp32, ≤0);
    u: (H,N). Returns (out (B,S,H,N), final state (B,H,N,N) fp32)."""
    B, S, H, N = r.shape
    chunk = min(chunk, S)
    if S % chunk:  # pad tail: k=v=0 adds nothing, lw=0 leaves state untouched
        pad = chunk - S % chunk
        pw = [(0, 0), (0, pad), (0, 0), (0, 0)]
        out, state = wkv6_chunked(jnp.pad(r, pw), jnp.pad(k, pw), jnp.pad(v, pw),
                                  jnp.pad(lw, pw), u, state0, chunk)
        return out[:, :S], state
    nc = S // chunk
    rf = r.astype(jnp.float32).reshape(B, nc, chunk, H, N).transpose(1, 0, 3, 2, 4)
    kf = k.astype(jnp.float32).reshape(B, nc, chunk, H, N).transpose(1, 0, 3, 2, 4)
    vf = v.astype(jnp.float32).reshape(B, nc, chunk, H, N).transpose(1, 0, 3, 2, 4)
    lwf = lw.astype(jnp.float32).reshape(B, nc, chunk, H, N).transpose(1, 0, 3, 2, 4)
    # shapes now (nc, B, H, C, N)
    if state0 is None:
        state0 = jnp.zeros((B, H, N, N), jnp.float32)

    uu = u.astype(jnp.float32)  # (H, N)
    mask = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)  # strict lower

    def body(S_in, xs):
        rc, kc, vc, lwc = xs  # (B,H,C,N)
        cum = jnp.cumsum(lwc, axis=2)          # inclusive
        ecum = cum - lwc                        # exclusive (cum_{t-1})
        total = cum[:, :, -1:, :]               # (B,H,1,N)
        r_t = rc * jnp.exp(ecum)
        k_t = kc * jnp.exp(-cum)
        att = jnp.einsum("bhcn,bhsn->bhcs", r_t, k_t) * mask
        diag = jnp.einsum("bhcn,hn->bhc", rc * kc, uu)
        out = jnp.einsum("bhcs,bhsn->bhcn", att, vc) + diag[..., None] * vc
        out = out + jnp.einsum("bhcn,bhnm->bhcm", r_t, S_in)
        k_hat = kc * jnp.exp(total - cum)
        S_out = jnp.exp(total).transpose(0, 1, 3, 2) * S_in \
            + jnp.einsum("bhsn,bhsm->bhnm", k_hat, vc)
        return S_out, out

    state, outs = jax.lax.scan(body, state0, (rf, kf, vf, lwf))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, N)
    return out.astype(r.dtype), state


def wkv6_sequential(r, k, v, lw, u, state0=None):
    """Exact per-step recurrence (oracle + decode). Same signature."""
    B, S, H, N = r.shape
    if state0 is None:
        state0 = jnp.zeros((B, H, N, N), jnp.float32)
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    lwf = lw.astype(jnp.float32)
    uu = u.astype(jnp.float32)

    def step(S, xs):
        rt, kt, vt, lwt = xs  # (B,H,N)
        kv = jnp.einsum("bhn,bhm->bhnm", kt, vt)
        out = jnp.einsum("bhn,bhnm->bhm", rt, S + uu[None, :, :, None] * kv)
        S_new = jnp.exp(lwt)[..., None] * S + kv
        return S_new, out

    xs = tuple(a.swapaxes(0, 1) for a in (rf, kf, vf, lwf))  # (S,B,H,N)
    state, outs = jax.lax.scan(step, state0, xs)
    return outs.swapaxes(0, 1).astype(r.dtype), state


def rwkv_time_mix(x, p, cfg: ArchConfig, x_prev=None, state=None, *, sequential=False):
    """x: (B,S,D). Returns (y, (new_x_prev, new_state))."""
    B, S, d = x.shape
    H, N = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    if x_prev is None:
        x_prev = jnp.zeros((B, d), x.dtype)
    xs = _token_shift(x, x_prev)
    r, k, v, g, lw = _project_rkvwg(x, xs, p, H, N)
    fn = wkv6_sequential if sequential else wkv6_chunked
    out, new_state = fn(r, k, v, lw, p["u"], state)
    out = rms_norm(out, p["ln_x"].reshape(H, N), cfg.norm_eps).reshape(B, S, d)
    out = out * g
    y = out @ p["wo"].astype(x.dtype)
    return constrain(y, "data", None, None), (x[:, -1, :], new_state)


def rwkv_channel_mix(x, p, x_prev=None):
    B, S, d = x.shape
    if x_prev is None:
        x_prev = jnp.zeros((B, d), x.dtype)
    xs = _token_shift(x, x_prev)
    k = jnp.square(jax.nn.relu(_lerp(x, xs, p["mu_k"]) @ p["ck"].astype(x.dtype)))
    k = constrain(k, "data", None, "model")
    kv = k @ p["cv"].astype(x.dtype)
    rgate = jax.nn.sigmoid(_lerp(x, xs, p["mu_r"]) @ p["cr"].astype(x.dtype))
    return rgate * kv, x[:, -1, :]


def rwkv_block(x, p, cfg: ArchConfig, cache=None, *, sequential=False):
    """Full block. cache: None (train) or dict with att_x/att_state/ffn_x."""
    c = cache or {}
    att, (ax, astate) = rwkv_time_mix(
        layer_norm(x, p["ln1"], p["ln1_b"], cfg.norm_eps), p["wkv"], cfg,
        c.get("att_x"), c.get("att_state"), sequential=sequential)
    x = x + att
    ffn, fx = rwkv_channel_mix(layer_norm(x, p["ln2"], p["ln2_b"], cfg.norm_eps),
                               p["cmix"], c.get("ffn_x"))
    x = x + ffn
    return x, {"att_x": ax, "att_state": astate, "ffn_x": fx}


# -- LM assembly -----------------------------------------------------------------


def init_rwkv_lm(key, cfg: ArchConfig) -> dict:
    from repro.models.layers import he_init as _he, init_embed

    ks = jax.random.split(key, 3)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    return {
        "embed": init_embed(ks[1], cfg.vocab, cfg.d_model),
        "ln0": jnp.ones((cfg.d_model,)), "ln0_b": jnp.zeros((cfg.d_model,)),
        "layers": jax.vmap(lambda k: init_rwkv_block(k, cfg))(layer_keys),
        "final_norm": jnp.ones((cfg.d_model,)),
        "final_norm_b": jnp.zeros((cfg.d_model,)),
        "lm_head": _he(ks[2], (cfg.d_model, cfg.vocab), fan_in=cfg.d_model),
    }


def rwkv_forward_hidden(params, tokens, cfg: ArchConfig):
    from repro.models.layers import embed_tokens

    x = embed_tokens(params["embed"], tokens)
    x = layer_norm(x, params["ln0"], params["ln0_b"], cfg.norm_eps)

    def body(carry, lp):
        out, _ = rwkv_block(carry, lp, cfg)
        return constrain(out, "data", None, None), None

    step = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(step, x, params["layers"])
    return layer_norm(x, params["final_norm"], params["final_norm_b"], cfg.norm_eps)


def rwkv_loss(params, batch, cfg: ArchConfig):
    from repro.models.layers import chunked_ce_loss

    tokens = batch["tokens"]
    hidden = rwkv_forward_hidden(params, tokens, cfg)
    loss_sum = chunked_ce_loss(hidden[:, :-1], params["lm_head"], tokens[:, 1:],
                               chunk=cfg.loss_chunk)
    ntok = tokens.shape[0] * (tokens.shape[1] - 1)
    return loss_sum / ntok, {"ce": loss_sum / ntok}


def make_cache(cfg: ArchConfig, batch: int, max_len: int, abstract: bool = False) -> dict:
    """RWKV cache is O(1) in sequence length — (N×N) state per head per layer
    plus the token-shift carries. ``max_len`` is irrelevant (the reason this
    arch runs long_500k)."""
    d = cfg.d_model
    H, N = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    L = cfg.n_layers
    shapes = {
        "att_x": ((L, batch, d), jnp.bfloat16),
        "att_state": ((L, batch, H, N, N), jnp.float32),
        "ffn_x": ((L, batch, d), jnp.bfloat16),
        "pos": ((), jnp.int32),
    }
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, dt) for k, (s, dt) in shapes.items()}
    return {k: jnp.zeros(s, dt) for k, (s, dt) in shapes.items()}


def rwkv_prefill(params, batch, cfg: ArchConfig, max_len: int | None = None):
    from repro.models.layers import embed_tokens, logits_from_hidden

    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens)
    x = layer_norm(x, params["ln0"], params["ln0_b"], cfg.norm_eps)

    def body(carry, lp):
        out, c = rwkv_block(carry, lp, cfg)
        return constrain(out, "data", None, None), (
            c["att_x"].astype(jnp.bfloat16), c["att_state"],
            c["ffn_x"].astype(jnp.bfloat16))

    x, (ax, ast, fx) = jax.lax.scan(jax.checkpoint(body), x, params["layers"])
    x = layer_norm(x, params["final_norm"], params["final_norm_b"], cfg.norm_eps)
    logits = logits_from_hidden(x[:, -1:, :], params["lm_head"])
    cache = {"att_x": ax, "att_state": ast, "ffn_x": fx,
             "pos": jnp.asarray(tokens.shape[1], jnp.int32)}
    return cache, logits


def rwkv_decode_step(params, cache, tokens, cfg: ArchConfig):
    from repro.models.layers import embed_tokens, logits_from_hidden

    x = embed_tokens(params["embed"], tokens)
    x = layer_norm(x, params["ln0"], params["ln0_b"], cfg.norm_eps)

    def body(carry, xs):
        lp, ax_l, st_l, fx_l = xs
        out, c = rwkv_block(carry, lp, cfg,
                            cache={"att_x": ax_l.astype(carry.dtype),
                                   "att_state": st_l,
                                   "ffn_x": fx_l.astype(carry.dtype)},
                            sequential=True)
        return out, (c["att_x"].astype(jnp.bfloat16), c["att_state"],
                     c["ffn_x"].astype(jnp.bfloat16))

    x, (ax, ast, fx) = jax.lax.scan(body, x, (params["layers"], cache["att_x"],
                                              cache["att_state"], cache["ffn_x"]))
    x = layer_norm(x, params["final_norm"], params["final_norm_b"], cfg.norm_eps)
    logits = logits_from_hidden(x, params["lm_head"])
    new_cache = {"att_x": ax, "att_state": ast, "ffn_x": fx,
                 "pos": cache["pos"] + tokens.shape[1]}
    return new_cache, logits
