"""Shared neural-net building blocks (pure JAX, no flax).

Parameters are plain dict pytrees. Initializers take an int seed-stream via
``jax.random`` keys. Compute dtype is bf16 with fp32 norms/softmax; params
are stored fp32 (the optimizer keeps fp32 master state anyway).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.sharding import constrain

COMPUTE_DTYPE = jnp.bfloat16


def he_init(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[-2] if len(shape) > 1 else shape[0]
    return (jax.random.normal(key, shape) * (1.0 / np.sqrt(fan_in))).astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """f32 statistics, compute-dtype application: the reduction runs in f32
    (fused cast, no f32 tensor materializes) but every full-size tensor —
    and therefore every cotangent GSPMD might move across the mesh — stays
    in x.dtype (§Perf iteration A5: halves activation-collective bytes)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * scale.astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True) - jnp.square(mu)
    inv = jax.lax.rsqrt(jnp.maximum(var, 0) + eps).astype(x.dtype)
    return (x - mu.astype(x.dtype)) * inv * scale.astype(x.dtype) + bias.astype(x.dtype)


# -- rotary embeddings ---------------------------------------------------------


def rope_freqs(d_head: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, d_head, 2, dtype=np.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta))  # (d/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, d/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- mlp -------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, gated: bool = True) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w1": he_init(ks[0], (d_model, d_ff)), "w2": he_init(ks[1], (d_ff, d_model))}
    if gated:
        p["w3"] = he_init(ks[2], (d_model, d_ff))
    return p


def mlp(x: jax.Array, p: dict) -> jax.Array:
    h = x @ p["w1"].astype(x.dtype)
    if "w3" in p:  # swiglu
        h = jax.nn.silu(h) * (x @ p["w3"].astype(x.dtype))
    else:  # gelu (whisper)
        h = jax.nn.gelu(h)
    h = constrain(h, "data", None, "model")
    return h @ p["w2"].astype(x.dtype)


# -- embedding / logits / loss ---------------------------------------------------


def init_embed(key, vocab: int, d_model: int) -> jax.Array:
    return jax.random.normal(key, (vocab, d_model)) * 0.02


def embed_tokens(embed: jax.Array, tokens: jax.Array) -> jax.Array:
    out = jnp.take(embed, tokens, axis=0).astype(COMPUTE_DTYPE)
    return constrain(out, "data", None, None)


def logits_from_hidden(h: jax.Array, head: jax.Array) -> jax.Array:
    """h: (..., d); head: (d, V) -> fp32 logits, vocab sharded over model."""
    out = (h @ head.astype(h.dtype)).astype(jnp.float32)
    return constrain(out, "data", None, "model")


def _ce_from_logits(logits: jax.Array, labels: jax.Array, mask: jax.Array) -> jax.Array:
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.sum((lse - gold) * mask)


def chunked_ce_loss(hidden: jax.Array, head: jax.Array, labels: jax.Array,
                    mask: jax.Array | None = None, chunk: int = 2048) -> jax.Array:
    """Cross entropy without materializing full (B,S,V) fp32 logits.

    Scans over sequence chunks; ``jax.checkpoint`` makes the backward re-
    compute the per-chunk logits, so peak memory is one chunk of logits.
    Returns summed loss (caller divides by token count).
    """
    B, S, D = hidden.shape
    if mask is None:
        mask = jnp.ones((B, S), dtype=jnp.float32)
    chunk = min(chunk, S)
    n = S // chunk
    rem = S - n * chunk

    @jax.checkpoint
    def chunk_loss(h_c, l_c, m_c):
        logits = logits_from_hidden(h_c, head)
        return _ce_from_logits(logits, l_c, m_c)

    def body(acc, xs):
        h_c, l_c, m_c = xs
        return acc + chunk_loss(h_c, l_c, m_c), None

    hs = hidden[:, : n * chunk].reshape(B, n, chunk, D).swapaxes(0, 1)
    ls = labels[:, : n * chunk].reshape(B, n, chunk).swapaxes(0, 1)
    ms = mask[:, : n * chunk].reshape(B, n, chunk).swapaxes(0, 1)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ls, ms))
    if rem:
        total = total + chunk_loss(hidden[:, n * chunk:], labels[:, n * chunk:], mask[:, n * chunk:])
    return total
