"""Model UDFs: JAX models applied inside query programs (paper §III-C).

The paper drops a locally-trained sklearn pipeline into AsterixDB as a UDF
and applies it per-row, distributed. Here the registered UDF is a JAX model
from ``repro/models``; applied to a fixed-width token column it runs batched
inside the *same* jitted SPMD program as the rest of the plan — TP-sharded
over "model", row-parallel over the data axes, no serialization boundary.

    register_model("sentiment", params, cfg)          # Fig. 4's `dump`
    df["sentiment"] = df["text_tokens"].map(ModelHandle("sentiment"))
    df.persist("demo.negTweets")                      # Fig. 6
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

_REGISTRY: dict[str, "ModelHandle"] = {}


@dataclasses.dataclass
class ModelHandle:
    name: str
    fn: Optional[Callable] = None  # (tokens (n, seq) int32) -> (n,) predictions

    def __call__(self, tokens: jax.Array) -> jax.Array:
        return _REGISTRY[self.name].fn(tokens)


def register_fn(name: str, fn: Callable) -> ModelHandle:
    """Register a raw (n, seq) -> (n,) JAX function as a UDF."""
    h = ModelHandle(name, fn)
    _REGISTRY[name] = h
    return h


def register_model(name: str, params, cfg, *, classes: int | None = None,
                   microbatch: int | None = None) -> ModelHandle:
    """Register an LM from the zoo as a classification UDF.

    Prediction = argmax over the first ``classes`` logits at the last token
    (the sentiment-head convention of the example pipeline). ``microbatch``
    bounds activation memory for very wide columns via lax.map."""
    from repro.models.registry import get_api

    api = get_api(cfg)

    def predict(tokens: jax.Array) -> jax.Array:
        tokens = tokens.astype(jnp.int32)

        def run(chunk):
            _, logits = api.prefill(params, {"tokens": chunk}, cfg)
            head = logits[:, -1, :]
            if classes is not None:
                head = head[:, :classes]
            return jnp.argmax(head, axis=-1).astype(jnp.int32)

        if microbatch is not None and tokens.shape[0] > microbatch:
            n = tokens.shape[0]
            pad = (-n) % microbatch
            t = jnp.pad(tokens, ((0, pad), (0, 0)))
            out = jax.lax.map(run, t.reshape(-1, microbatch, tokens.shape[1]))
            return out.reshape(-1)[:n]
        return run(tokens)

    return register_fn(name, predict)


def get_udf(name: str) -> Callable:
    if name not in _REGISTRY:
        raise KeyError(f"no model UDF {name!r} registered "
                       f"(known: {sorted(_REGISTRY)})")
    return _REGISTRY[name].fn


def clear_registry() -> None:
    _REGISTRY.clear()
