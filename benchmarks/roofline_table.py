"""Render the §Roofline table from the dry-run JSON records."""
from __future__ import annotations

import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load(mesh: str = "pod") -> list[dict]:
    d = RESULTS / mesh
    recs = [json.loads(p.read_text()) for p in sorted(d.glob("*.json"))]
    return recs


def markdown_table(mesh: str = "pod", include_skips: bool = True) -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | dominant | "
            "useful FLOP ratio | HBM GB/chip (temp) |",
            "|---|---|---|---|---|---|---|---|"]
    for r in load(mesh):
        if r["status"] == "skipped":
            if include_skips:
                rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                            f"skip (full attention at 500k) | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | |")
            continue
        rf = r["roofline"]
        temp = r.get("memory_analysis", {}).get("temp_size_in_bytes", 0) / 1e9
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.4f} | "
            f"{rf['memory_s']:.4f} | {rf['collective_s']:.4f} | "
            f"{rf['dominant']} | {rf['useful_flop_ratio']:.2f} | {temp:.2f} |")
    return "\n".join(rows)


def summary(mesh: str = "pod") -> dict:
    recs = [r for r in load(mesh) if r["status"] == "ok"]
    dom = {}
    for r in recs:
        dom.setdefault(r["roofline"]["dominant"], []).append(
            (r["arch"], r["shape"]))
    worst = sorted(
        (r for r in recs if r["shape"] == "train_4k"),
        key=lambda r: r["roofline"]["useful_flop_ratio"])
    most_coll = sorted(
        recs, key=lambda r: -(r["roofline"]["collective_s"] /
                              max(sum(r["roofline"][k] for k in
                                      ("compute_s", "memory_s", "collective_s")), 1e-12)))
    return {"dominant_counts": {k: len(v) for k, v in dom.items()},
            "worst_useful_train": [(r["arch"], r["shape"],
                                    round(r["roofline"]["useful_flop_ratio"], 3))
                                   for r in worst[:3]],
            "most_collective_bound": [(r["arch"], r["shape"]) for r in most_coll[:3]]}


if __name__ == "__main__":
    print(markdown_table("pod"))
    print()
    print(json.dumps(summary("pod"), indent=2))
