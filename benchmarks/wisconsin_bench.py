"""The paper's DataFrame micro-benchmark (§IV): 12 expressions × variants ×
dataset sizes, expression-only vs total (creation + expression) timing.

Variants (paper labels):
  numpy-eager    — "Pandas": eager evaluation over host arrays loaded from
                   disk files; every expression materializes fully.
  aframe         — open datatype, no indexes (schema-on-read cast per access)
  aframe-schema  — closed datatype (typed columns); mode=gspmd baseline
  aframe-index   — closed + primary(unique2) + secondary(onePercent, unique1)
  aframe-kernel  — closed datatype, mode=kernel: fusable plans lower onto the
                   Pallas relational kernels (filter_count / segment_agg /
                   merge_join / topk). Compare against aframe-schema for the
                   gspmd-vs-kernel speedup (same data, same plans, different
                   physical operators).

Methodology mirrors §IV-B: each expression runs WARMUP+RUNS times with
randomized predicate literals; the first WARMUP results are dropped (JIT
compile plays the role of the paper's JVM warmup) and the rest average.
"""
from __future__ import annotations

import pathlib
import tempfile
import time
from typing import Callable

import numpy as np

from repro.core.frame import AFrame
from repro.data import wisconsin
from repro.engine.session import Session
from repro.engine.table import Table

WARMUP, RUNS = 3, 7

SIZES = {"XS": 50_000, "S": 125_000, "M": 250_000, "L": 375_000, "XL": 500_000}


# -- variant harnesses -------------------------------------------------------------


class NumpyEager:
    """The Pandas stand-in: data lives in files; creation = full load."""

    name = "numpy-eager"

    def __init__(self, disk_dir: pathlib.Path):
        self.disk = disk_dir

    def create(self):
        self.df = {p.stem: np.load(p) for p in sorted(self.disk.glob("*.npy"))}
        return self

    def e1(self):
        return len(self.df["unique1"])

    def e2(self):
        return {k: self.df[k][:5].copy() for k in ("two", "four")}

    def e3(self, x, y, z):
        m = (self.df["ten"] == x) & (self.df["twentyPercent"] == y) & (self.df["two"] == z)
        return int(m.sum())

    def e4(self):
        k, c = np.unique(self.df["oddOnePercent"], return_counts=True)
        return c

    def e5(self):
        # eager: uppercases the WHOLE column before head (paper exp-5 eager-evaluation cost)
        col = self.df["stringu1"]
        up = np.where((col >= ord("a")) & (col <= ord("z")), col - 32, col)
        return up[:5]

    def e6(self):
        return int(self.df["unique1"].max())

    def e7(self):
        return int(self.df["unique1"].min())

    def e8(self):
        out = {}
        tw, fo = self.df["twenty"], self.df["four"]
        for g in np.unique(tw):
            out[g] = fo[tw == g].max()
        return out

    def e9(self):
        order = np.argsort(self.df["unique1"])[::-1][:5]
        return {k: v[order] for k, v in self.df.items()}

    def e10(self, x):
        m = self.df["ten"] == x
        rows = {k: v[m] for k, v in self.df.items()}  # eager full selection
        return {k: v[:5] for k, v in rows.items()}

    def e11(self, x, y):
        m = (self.df["onePercent"] >= x) & (self.df["onePercent"] <= y)
        return int(m.sum())

    def e12(self):
        l = self.df["unique1"]
        r = np.sort(self.df["unique1"])
        lo = np.searchsorted(r, l, "left")
        hi = np.searchsorted(r, l, "right")
        return int((hi - lo).sum())


class AFrameVariant:
    def __init__(self, name: str, session: Session, dataset: str):
        self.name = name
        self.sess = session
        self.dataset = dataset

    def create(self):
        self.df = AFrame("bench", self.dataset, session=self.sess)
        return self

    def e1(self):
        return len(self.df)

    def e2(self):
        return self.df[["two", "four"]].head()

    def e3(self, x, y, z):
        d = self.df
        return len(d[(d["ten"] == x) & (d["twentyPercent"] == y) & (d["two"] == z)])

    def e4(self):
        return self.df.groupby("oddOnePercent").agg("count")

    def e5(self):
        return self.df["stringu1"].map(str.upper).head()

    def e6(self):
        return self.df["unique1"].max()

    def e7(self):
        return self.df["unique1"].min()

    def e8(self):
        return self.df.groupby("twenty")["four"].agg("max")

    def e9(self):
        return self.df.sort_values("unique1", ascending=False).head()

    def e10(self, x):
        return self.df[self.df["ten"] == x].head()

    def e11(self, x, y):
        d = self.df
        return len(d[(d["onePercent"] >= x) & (d["onePercent"] <= y)])

    def e12(self):
        other = AFrame("bench", self.dataset + "_r", session=self.sess)
        return len(self.df.merge(other, left_on="unique1", right_on="unique1"))


EXPRESSIONS: list[tuple[str, Callable]] = [
    ("1_count", lambda v, rng, n: v.e1()),
    ("2_project_head", lambda v, rng, n: v.e2()),
    ("3_filter_count", lambda v, rng, n: v.e3(int(rng.integers(10)),
                                              int(rng.integers(5)),
                                              int(rng.integers(2)))),
    ("4_group_count", lambda v, rng, n: v.e4()),
    ("5_map_head", lambda v, rng, n: v.e5()),
    ("6_max", lambda v, rng, n: v.e6()),
    ("7_min", lambda v, rng, n: v.e7()),
    ("8_group_max", lambda v, rng, n: v.e8()),
    ("9_sort_head", lambda v, rng, n: v.e9()),
    ("10_select_head", lambda v, rng, n: v.e10(int(rng.integers(10)))),
    ("11_range_count", lambda v, rng, n: (lambda a, b: v.e11(min(a, b), max(a, b)))(
        int(rng.integers(100)), int(rng.integers(100)))),
    ("12_join_count", lambda v, rng, n: v.e12()),
]


def build_variants(n_rows: int, tmp: pathlib.Path, mesh=None, mode="auto"):
    table = wisconsin.generate(n_rows, seed=11)
    disk = tmp / f"disk_{n_rows}"
    disk.mkdir(parents=True, exist_ok=True)
    for k, v in table.columns.items():
        np.save(disk / f"{k}.npy", np.asarray(v))

    variants = [NumpyEager(disk)]
    for name, closed, indexes, primary, vmode in [
        ("aframe", False, [], None, mode),
        ("aframe-schema", True, [], None, mode),
        ("aframe-index", True, ["onePercent", "unique1"], "unique2", mode),
        ("aframe-kernel", True, [], None, "kernel"),
    ]:
        sess = Session(mesh=mesh, mode=vmode)
        sess.create_dataset("data", table, dataverse="bench", closed=closed,
                            indexes=indexes, primary=primary)
        sess.create_dataset("data_r", table, dataverse="bench", closed=closed,
                            indexes=indexes, primary=primary)
        variants.append(AFrameVariant(name, sess, "data"))
    return variants


def run_benchmark(sizes: dict[str, int], out_csv: pathlib.Path, mesh=None,
                  mode="auto") -> list[dict]:
    rows = []
    with tempfile.TemporaryDirectory() as td:
        tmp = pathlib.Path(td)
        for size_name, n in sizes.items():
            variants = build_variants(n, tmp, mesh=mesh, mode=mode)
            for v in variants:
                t0 = time.perf_counter()
                v.create()
                creation = time.perf_counter() - t0
                for expr_name, fn in EXPRESSIONS:
                    rng = np.random.default_rng(5)
                    times = []
                    for i in range(WARMUP + RUNS):
                        t0 = time.perf_counter()
                        fn(v, rng, n)
                        times.append(time.perf_counter() - t0)
                    expr_s = float(np.mean(times[WARMUP:]))
                    sess = getattr(v, "sess", None)
                    rows.append({
                        "size": size_name, "rows": n, "variant": v.name,
                        "mode": sess.mode if sess is not None else "eager",
                        "expression": expr_name,
                        "expr_s": expr_s, "creation_s": creation,
                        "total_s": expr_s + creation,
                    })
                    print(f"{size_name:3s} {v.name:14s} {expr_name:15s} "
                          f"expr={expr_s*1e3:9.2f}ms total={(expr_s+creation)*1e3:9.2f}ms")
    out_csv.parent.mkdir(parents=True, exist_ok=True)
    import csv

    with open(out_csv, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    return rows
