"""Streaming-ingestion benchmark: sustained rows/sec and query freshness.

Two variants ingest the same stream into the same base table:

  * ``compact-every-flush`` — the pre-LSM behaviour: every flush de-shards,
    concatenates, re-sorts and re-indexes the whole base (O(base) per batch).
    Expressed as ``CompactionPolicy(size_ratio=0)``.
  * ``deferred``           — the LSM path: flushes become device-resident
    runs (O(batch)), compaction fires only on the size-ratio policy.

Reported per size: sustained ingest rows/sec (wall time of push+flush+any
compaction), the deferred/baseline speedup, and query-freshness latency
(time to answer ``COUNT(*)`` + an indexed range count right after each
flush — base ∪ runs, including the recompile a fresh component set forces).

The deferred variant additionally runs a **query-freshness-under-selectivity
sweep**: with N runs resident, a range predicate on the monotone ``unique2``
key that hits exactly 1 of the N runs is answered with zone-map pruning on
vs. off — tracking the pruning win (latency + physical rows touched + runs
skipped) in ``results/bench/ingest.json`` across PRs.

A **mutation sweep** rides along: the same stream replayed as append-only
vs. upsert-heavy vs. delete-heavy workloads (anti-matter records through
``Feed.upsert``/``Feed.delete``), each with deferred and compact-every-flush
policies — sustained mutation ops/sec, post-flush query freshness, and an
uncompacted == compacted consistency check per cell.

A **block_skip sweep** measures the second pruning level: selective range
predicates over a clustered (sorted, unindexed) column, with bind-time
block zone-map skipping on vs. off — latency plus blocks touched, which
must scale with the predicate's block footprint, not the dataset. A
**block_skip_sharded sweep** repeats the cell over an 8-way simulated host
mesh (subprocess with forced device count): zone maps are laid out per row
partition and each shard's kernel grid scans only its own survivors.

A **concurrent-serving sweep** replays the stream with a reader thread
(its own Session on the SHARED catalog) hammering an indexed range count
the whole time, under two serving modes: ``synchronous`` (merges run
inline on the writer) vs ``background`` (a BackgroundCompactor thread,
write-stall backpressure only past the hard run cap). Reported per cell:
reader p50/p99/max latency, per-batch writer latency p50/p99, and
write-stall seconds. The reader p99 of the background cell is asserted
under a hard cap — the "no query ever blocks on a running compaction"
guarantee, enforced where it would regress first.
"""
from __future__ import annotations

import json
import pathlib
import threading
import time

import numpy as np

from repro.core.frame import AFrame
from repro.data import wisconsin
from repro.engine import lsm
from repro.engine.ingest import Feed
from repro.engine.session import Session

# size: (base_rows, n_batches, batch_rows)
SIZES = {
    "XS": (2_000, 6, 512),
    "S": (10_000, 10, 1_024),
    "M": (50_000, 16, 2_048),
    "L": (150_000, 24, 2_048),
}

POLICIES = {
    "compact-every-flush": lambda: lsm.CompactionPolicy(size_ratio=0.0),
    "deferred": lambda: lsm.CompactionPolicy(size_ratio=1.0, max_runs=8),
}


def _stream(base_rows: int, n_batches: int, batch_rows: int):
    """Pre-generated arrival batches (unique2 keys keep increasing — the
    timestamped-tweet pattern)."""
    batches = []
    for i in range(n_batches):
        t = wisconsin.generate(batch_rows, seed=1_000 + i)
        rows = {k: np.asarray(v) for k, v in t.columns.items()}
        rows["unique2"] = rows["unique2"] + base_rows + i * batch_rows
        batches.append(rows)
    return batches


def _run_variant(size: str, variant: str, mode: str = "gspmd") -> dict:
    base_rows, n_batches, batch_rows = SIZES[size]
    base = wisconsin.generate(base_rows, seed=7)
    sess = Session(mode=mode)
    sess.create_dataset("Stream", base, dataverse="bench",
                        indexes=["onePercent"], primary="unique2")
    feed = Feed(sess, "Stream", "bench", flush_rows=batch_rows,
                policy=POLICIES[variant]())
    batches = _stream(base_rows, n_batches, batch_rows)
    df = AFrame("bench", "Stream", session=sess)
    len(df)  # warm the count executable for the base-only shape

    ingest_s = 0.0
    freshness = []
    for rows in batches:
        t0 = time.perf_counter()
        feed.push(rows)  # flush_rows == batch_rows: flushes synchronously
        ingest_s += time.perf_counter() - t0
        t0 = time.perf_counter()
        n = len(df)
        len(df[(df["onePercent"] >= 10) & (df["onePercent"] <= 30)])
        freshness.append(time.perf_counter() - t0)
        assert n == base_rows + feed.stats["ingested"]
    total_rows = n_batches * batch_rows
    out = {
        "size": size,
        "variant": variant,
        "rows": total_rows,
        "batches": n_batches,
        "ingest_s": round(ingest_s, 4),
        "rows_per_s": round(total_rows / ingest_s, 1),
        "freshness_median_s": round(float(np.median(freshness)), 4),
        "freshness_p95_s": round(float(np.percentile(freshness, 95)), 4),
        "flushes": feed.stats["flushes"],
        "compactions": feed.stats["compactions"],
        "final_runs": feed.stats["runs"],
    }
    if variant == "deferred" and feed.stats["runs"] >= 2:
        out["prune_sweep"] = _selectivity_sweep(
            sess, df, base_rows, n_batches, batch_rows, feed.stats["runs"])
    return out


def _selectivity_sweep(sess: Session, df: AFrame, base_rows: int,
                       n_batches: int, batch_rows: int, n_runs: int,
                       repeats: int = 5) -> dict:
    """Selective range count hitting exactly 1 of the resident runs, with
    zone-map pruning on vs. off (the planner's bind-time decision): reports
    the latency and the rows-touched / runs-pruned the physical plan shows.
    Toggling ``enable_prune`` is cache-safe — the two settings produce
    different prune signatures, so they bind different executables."""
    lo = base_rows + (n_batches - 1) * batch_rows  # the newest run's key span
    hi = lo + batch_rows - 1
    sweep: dict = {"runs_resident": n_runs}
    for prune in (True, False):
        sess.enable_prune = prune
        label = "pruned" if prune else "unpruned"
        n = len(df[(df["unique2"] >= lo) & (df["unique2"] <= hi)])  # warm/compile
        assert n == batch_rows, (n, batch_rows)
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            len(df[(df["unique2"] >= lo) & (df["unique2"] <= hi)])
            times.append(time.perf_counter() - t0)
        report = sess.last_prune_report
        sweep[label] = {
            "query_median_s": round(float(np.median(times)), 5),
            "rows_touched": int(report["rows_touched"]),
            "components": int(report["components"]),
            "runs_pruned": int(report["pruned"]),
            "rows_pruned": int(report["rows_pruned"]),
        }
    sess.enable_prune = True
    p, u = sweep["pruned"], sweep["unpruned"]
    sweep["query_speedup"] = round(
        u["query_median_s"] / max(p["query_median_s"], 1e-9), 2)
    print(f"     prune sweep (1 of {n_runs} runs hit): "
          f"{p['runs_pruned']}/{p['components']} components pruned, "
          f"rows touched {u['rows_touched']:,} -> {p['rows_touched']:,}, "
          f"query {u['query_median_s']*1e3:.1f} -> "
          f"{p['query_median_s']*1e3:.1f} ms "
          f"({sweep['query_speedup']}x)")
    return sweep


def _block_skip_sweep(size: str, repeats: int = 5) -> list[dict]:
    """Intra-run block skipping (the second pruning level): a clustered
    dataset (rows sorted by the primary key, a time-ordered ``unique2``-like
    column with no secondary index) takes selective range predicates of
    decreasing selectivity, with the bind-time block zone-map test on vs.
    off. Reports latency plus the blocks-touched accounting from the
    physical plan — the blocks scanned must shrink proportionally to the
    predicate's block footprint. Runs in kernel mode: the filter_count grid
    is driven through the surviving-block list."""
    base_rows, _, _ = SIZES[size]
    n = max(base_rows, 8 * 4096)  # at least 8 zone blocks
    ids = np.arange(n, dtype=np.int32)
    rng = np.random.default_rng(11)
    table_cols = {"id": ids, "ts": ids.copy(),
                  "val": rng.integers(0, 100, n).astype(np.int32)}
    from repro.engine.table import Table

    sess = Session(mode="kernel", enable_index=False)
    sess.create_dataset("Clustered", Table(table_cols), dataverse="bench",
                        primary="id")
    df = AFrame("bench", "Clustered", session=sess)
    n_blocks = -(-n // 4096)
    rows = []
    for label, span_blocks in (("1-block", 1),
                               ("10pct", max(n_blocks // 10, 1)),
                               ("50pct", max(n_blocks // 2, 1))):
        lo = 4096  # start on a block boundary past block 0
        hi = min(lo + span_blocks * 4096 - 1, n - 1)
        cell: dict = {"size": size, "variant": "block_skip",
                      "selectivity": label, "n_rows": n,
                      "blocks_total": n_blocks}
        for skip in (True, False):
            sess.enable_block_skip = skip
            tag = "skipped" if skip else "unskipped"
            want = hi - lo + 1
            got = len(df[(df["ts"] >= lo) & (df["ts"] <= hi)])  # warm/compile
            assert got == want, (got, want)
            times = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                len(df[(df["ts"] >= lo) & (df["ts"] <= hi)])
                times.append(time.perf_counter() - t0)
            rep = sess.last_prune_report
            cell[tag] = {
                "query_median_s": round(float(np.median(times)), 5),
                "blocks_scanned": int(rep["blocks_scanned"]),
                "blocks_skipped": int(rep["blocks_skipped"]),
            }
        sess.enable_block_skip = True
        s, u = cell["skipped"], cell["unskipped"]
        cell["query_speedup"] = round(
            u["query_median_s"] / max(s["query_median_s"], 1e-9), 2)
        print(f"  {size:>2} block_skip {label:<8} blocks "
              f"{u['blocks_scanned']} -> {s['blocks_scanned']} "
              f"of {n_blocks}  query {u['query_median_s']*1e3:.2f} -> "
              f"{s['query_median_s']*1e3:.2f} ms "
              f"({cell['query_speedup']}x)")
        rows.append(cell)
    return rows


def _string_predicate_sweep(size: str, repeats: int = 5) -> list[dict]:
    """String fast-path sweep (the PR 9 tentpole): equality predicates on a
    LOW-cardinality clustered string column (dictionary-id lane → lowered
    onto the filter_count kernel, dict-id zone maps skip blocks) and on a
    HIGH-cardinality clustered column (past DICT_THRESHOLD: no dict lane,
    the big-endian prefix lane's zone maps do the skipping), each with the
    bind-time block test on vs. off. Reports latency, blocks touched, and
    whether the plan lowered onto the kernel."""
    from repro.core import physical as PH
    from repro.engine.table import Table, encode_strings

    base_rows, _, _ = SIZES[size]
    n = max(base_rows, 8 * 4096)
    n_blocks = -(-n // 4096)
    # low cardinality: one tag per zone block (16 distinct << threshold);
    # high cardinality: sorted unique names (prefix spans are disjoint)
    lo_tags = ["T%02d" % ((i // 4096) % 16) for i in range(n)]
    hi_names = ["u%07d" % i for i in range(n)]
    sess = Session(mode="kernel", enable_index=False)
    sess.create_dataset("Str", Table({
        "id": np.arange(n, dtype=np.int32),
        "tag": encode_strings(lo_tags),
        "name": encode_strings(hi_names),
    }), dataverse="bench", primary="id")
    df = AFrame("bench", "Str", session=sess)
    rows = []
    for label, col, lit, want in (
            ("low-card:dict", "tag", "T03", 4096 * len(
                [b for b in range(n_blocks) if b % 16 == 3])),
            ("high-card:prefix", "name", "u%07d" % (4096 * 2 + 7), 1)):
        cell: dict = {"size": size, "variant": "string_predicate",
                      "column": col, "cardinality": label.split(":")[0],
                      "pruning_lane": label.split(":")[1], "n_rows": n,
                      "blocks_total": n_blocks}
        for skip in (True, False):
            sess.enable_block_skip = skip
            tag = "skipped" if skip else "unskipped"
            got = len(df[df[col] == lit])  # warm/compile
            assert got == want, (label, got, want)
            times = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                len(df[df[col] == lit])
                times.append(time.perf_counter() - t0)
            rep = sess.last_prune_report
            cell[tag] = {
                "query_median_s": round(float(np.median(times)), 5),
                "blocks_scanned": int(rep["blocks_scanned"]),
                "blocks_skipped": int(rep["blocks_skipped"]),
            }
        sess.enable_block_skip = True
        cell["kernel_lowered"] = any(
            isinstance(nd, PH.KernelRangeCount)
            for nd in PH.walk(sess.last_physical))
        s, u = cell["skipped"], cell["unskipped"]
        cell["query_speedup"] = round(
            u["query_median_s"] / max(s["query_median_s"], 1e-9), 2)
        print(f"  {size:>2} string_predicate {label:<16} blocks "
              f"{u['blocks_scanned']} -> {s['blocks_scanned']} "
              f"of {n_blocks}  kernel={cell['kernel_lowered']}  query "
              f"{u['query_median_s']*1e3:.2f} -> "
              f"{s['query_median_s']*1e3:.2f} ms "
              f"({cell['query_speedup']}x)")
        rows.append(cell)
    return rows


def _block_skip_sharded_sweep(size: str, repeats: int = 5,
                              devices: int = 8) -> list[dict]:
    """Multi-shard variant of the block-skip sweep: the same clustered
    dataset laid out over an ``devices``-way simulated host mesh, where the
    zone maps are harvested per row partition and each shard's kernel grid
    scans only its own surviving blocks. jax locks the process device count
    at first init, so the cell runs in a fresh interpreter with forced host
    devices (the tests' subprocess pattern) and reports back as JSON."""
    import os
    import subprocess
    import sys

    base_rows, _, _ = SIZES[size]
    n = max(base_rows, devices * 4096)
    n -= n % devices  # even row partitions -> the sharded zone-map layout
    body = f"""
import json, time
import numpy as np
from repro.core.frame import AFrame
from repro.engine.session import Session
from repro.engine.table import Table
from repro.launch.mesh import make_local_mesh
from repro.runtime import telemetry as tel

n, repeats, devices = {n}, {repeats}, {devices}
ids = np.arange(n, dtype=np.int32)
rng = np.random.default_rng(11)
sess = Session(mesh=make_local_mesh(data=devices, model=1), mode="kernel",
               enable_index=False)
sess.create_dataset("Clustered",
                    Table({{"id": ids, "ts": ids.copy(),
                            "val": rng.integers(0, 100, n).astype(np.int32)}}),
                    dataverse="bench", primary="id")
df = AFrame("bench", "Clustered", session=sess)
bz = sess.catalog.get("bench", "Clustered").block_zones
n_blocks = bz.n_blocks
cells = []
for label, span_blocks in (("1-block", 1),
                           ("10pct", max(n_blocks // 10, 1)),
                           ("50pct", max(n_blocks // 2, 1))):
    lo = 4096
    hi = min(lo + span_blocks * 4096 - 1, n - 1)
    cell = {{"size": {size!r}, "variant": "block_skip_sharded",
             "selectivity": label, "n_rows": n, "shards": devices,
             "blocks_total": n_blocks}}
    for skip in (True, False):
        sess.enable_block_skip = skip
        tag = "skipped" if skip else "unskipped"
        want = hi - lo + 1
        got = len(df[(df["ts"] >= lo) & (df["ts"] <= hi)])  # warm/compile
        assert got == want, (got, want)
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            len(df[(df["ts"] >= lo) & (df["ts"] <= hi)])
            times.append(time.perf_counter() - t0)
        rep = sess.last_prune_report
        cell[tag] = {{
            "query_median_s": round(float(np.median(times)), 5),
            "blocks_scanned": int(rep["blocks_scanned"]),
            "blocks_skipped": int(rep["blocks_skipped"]),
        }}
    sess.enable_block_skip = True
    s, u = cell["skipped"], cell["unskipped"]
    cell["query_speedup"] = round(
        u["query_median_s"] / max(s["query_median_s"], 1e-9), 2)
    cells.append(cell)
cells.append({{"size": {size!r}, "variant": "block_skip_sharded:telemetry",
               "blocks_skipped_total": int(tel.counter_value(
                   "kernel.blocks_skipped_total", kernel="filter_count")
                   or 0)}})
print("CELLS=" + json.dumps(cells))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    r = subprocess.run([sys.executable, "-c", body], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, (
        f"sharded block-skip cell failed:\n{r.stdout}\n{r.stderr[-3000:]}")
    line = [l for l in r.stdout.splitlines() if l.startswith("CELLS=")][-1]
    cells = json.loads(line[len("CELLS="):])
    for c in cells:
        if "skipped" not in c:
            continue
        s, u = c["skipped"], c["unskipped"]
        print(f"  {size:>2} block_skip_sharded {c['selectivity']:<8} "
              f"({c['shards']} shards) blocks {u['blocks_scanned']} -> "
              f"{s['blocks_scanned']} of {c['blocks_total']}  query "
              f"{u['query_median_s']*1e3:.2f} -> "
              f"{s['query_median_s']*1e3:.2f} ms ({c['query_speedup']}x)")
    return cells


# Hard cap on the background cell's reader tail latency: generously above a
# post-flush recompile, far below an O(base) merge a blocked reader would eat.
READER_P99_CAP_S = 2.0


def _serving_cell(size: str, serving: str) -> dict:
    """One concurrent-serving cell: writer replays the stream while a reader
    thread on the shared catalog runs an indexed range count continuously."""
    base_rows, n_batches, batch_rows = SIZES[size]
    sess = Session()
    sess.create_dataset("Serve", wisconsin.generate(base_rows, seed=7),
                        dataverse="bench", indexes=["onePercent"],
                        primary="unique2")
    # real triggers, small cap: compaction fires repeatedly during the replay
    policy = lsm.CompactionPolicy(size_ratio=1.0, max_runs=4)
    reader = Session(catalog=sess.catalog)
    rdf = AFrame("bench", "Serve", session=reader)
    len(rdf[(rdf["onePercent"] >= 10) & (rdf["onePercent"] <= 30)])  # warm

    stop = threading.Event()
    lat: list[float] = []

    def read_loop():
        while not stop.is_set():
            t0 = time.perf_counter()
            len(rdf[(rdf["onePercent"] >= 10) & (rdf["onePercent"] <= 30)])
            lat.append(time.perf_counter() - t0)

    bc = (lsm.BackgroundCompactor(sess, policy=policy)
          if serving == "background" else None)
    feed = Feed(sess, "Serve", "bench", flush_rows=batch_rows,
                policy=policy, compactor=bc)
    batches = _stream(base_rows, n_batches, batch_rows)
    t = threading.Thread(target=read_loop, daemon=True)
    t.start()
    write_lat = []
    t_all = time.perf_counter()
    try:
        for rows in batches:
            t0 = time.perf_counter()
            feed.push(rows)  # flush_rows == batch_rows: flushes synchronously
            write_lat.append(time.perf_counter() - t0)
        if bc is not None:
            bc.wait_idle(60.0)
        ingest_s = time.perf_counter() - t_all
    finally:
        stop.set()
        t.join(timeout=30.0)
        if bc is not None:
            bc.close()
    lat_arr = np.asarray(lat) if lat else np.asarray([0.0])
    cell = {
        "size": size,
        "variant": f"serving:{serving}",
        "serving": serving,
        "rows": n_batches * batch_rows,
        "ingest_s": round(ingest_s, 4),
        "rows_per_s": round(n_batches * batch_rows / ingest_s, 1),
        "writer_batch_p50_s": round(float(np.median(write_lat)), 4),
        "writer_batch_p99_s": round(float(np.percentile(write_lat, 99)), 4),
        "reader_queries": len(lat),
        "reader_p50_s": round(float(np.median(lat_arr)), 5),
        "reader_p99_s": round(float(np.percentile(lat_arr, 99)), 5),
        "reader_max_s": round(float(lat_arr.max()), 5),
        "write_stalls": feed.stats.get("stalls", 0),
        "write_stall_s": round(feed.stats.get("stall_s", 0.0), 4),
        "compactions": feed.stats["compactions"] + (
            bc.stats["compactions"] + bc.stats["level_merges"]
            if bc is not None else 0),
        "final_runs": len(sess.catalog.get("bench", "Serve").runs),
    }
    if serving == "background":
        assert cell["reader_p99_s"] < READER_P99_CAP_S, (
            f"reader p99 {cell['reader_p99_s']}s breaches the no-block cap "
            f"({READER_P99_CAP_S}s) — a query waited on compaction")
    return cell


def _serving_sweep(size: str) -> list[dict]:
    rows = []
    per = {}
    for serving in ("synchronous", "background"):
        r = _serving_cell(size, serving)
        per[serving] = r
        rows.append(r)
        print(f"  {size:>2} serving:{serving:<12} "
              f"reader p50 {r['reader_p50_s']*1e3:6.1f} ms  "
              f"p99 {r['reader_p99_s']*1e3:7.1f} ms  "
              f"writer batch p99 {r['writer_batch_p99_s']*1e3:7.1f} ms  "
              f"stall {r['write_stall_s']*1e3:6.1f} ms  "
              f"({r['reader_queries']} reads, "
              f"{r['compactions']} compactions)")
    speedup = (per["synchronous"]["writer_batch_p99_s"]
               / max(per["background"]["writer_batch_p99_s"], 1e-9))
    rows.append({"size": size, "variant": "serving:speedup",
                 "writer_p99_speedup": round(speedup, 2)})
    print(f"  {size:>2} background-compaction writer p99 speedup: "
          f"{speedup:.1f}x")
    return rows


# mutation mix per workload: fractions of batches issued as (push, upsert,
# delete); deletes target previously-ingested keys, upserts overwrite them.
MUTATION_WORKLOADS = {
    "append-only": (1.0, 0.0, 0.0),
    "upsert-heavy": (0.4, 0.6, 0.0),
    "delete-heavy": (0.4, 0.2, 0.4),
}


def _run_mutation_cell(size: str, workload: str, variant: str) -> dict:
    """One mutation-sweep cell: replay the stream with the workload's
    push/upsert/delete mix, measure sustained mutation ops/sec and post-
    flush freshness, then assert uncompacted == compacted."""
    base_rows, n_batches, batch_rows = SIZES[size]
    base = wisconsin.generate(base_rows, seed=7)
    sess = Session()
    sess.create_dataset("MutStream", base, dataverse="bench",
                        indexes=["onePercent"], primary="unique2")
    feed = Feed(sess, "MutStream", "bench", flush_rows=batch_rows,
                policy=POLICIES[variant]())
    df = AFrame("bench", "MutStream", session=sess)
    len(df)  # warm the base-only count executable

    push_f, upsert_f, delete_f = MUTATION_WORKLOADS[workload]
    rng = np.random.default_rng(13)
    batches = _stream(base_rows, n_batches, batch_rows)
    kinds = rng.choice(["push", "upsert", "delete"], size=n_batches,
                       p=[push_f, upsert_f, delete_f])
    hi_key = base_rows
    ops = 0
    mutate_s = 0.0
    freshness = []
    for i, rows in enumerate(batches):
        kind = kinds[i]
        t0 = time.perf_counter()
        if kind == "push":
            feed.push(rows)
            hi_key = int(np.asarray(rows["unique2"]).max()) + 1
        elif kind == "upsert":
            rows = dict(rows)
            rows["unique2"] = rng.choice(hi_key, size=batch_rows,
                                         replace=False).astype(
                np.asarray(rows["unique2"]).dtype)
            feed.upsert(rows)
        else:
            keys = rng.choice(hi_key, size=batch_rows, replace=False)
            feed.delete(keys.astype(np.asarray(rows["unique2"]).dtype))
        feed.flush()
        mutate_s += time.perf_counter() - t0
        ops += batch_rows
        t0 = time.perf_counter()
        len(df)
        len(df[(df["onePercent"] >= 10) & (df["onePercent"] <= 30)])
        freshness.append(time.perf_counter() - t0)
    uncompacted = len(df)
    feed.compact()
    assert len(df) == uncompacted, "mutation invariant violated"
    return {
        "size": size,
        "variant": f"mutation:{workload}:{variant}",
        "workload": workload,
        "policy": variant,
        "ops": ops,
        "ops_per_s": round(ops / mutate_s, 1),
        "freshness_median_s": round(float(np.median(freshness)), 4),
        "freshness_p95_s": round(float(np.percentile(freshness, 95)), 4),
        "flushes": feed.stats["flushes"],
        "compactions": feed.stats["compactions"],
        "level_merges": feed.stats["level_merges"],
        "final_rows": uncompacted,
        "mutation_ops": int(feed.stats["deletes"] + feed.stats["upserts"]),
        "tombstones_flushed": int(feed.stats["tombstones_flushed"]),
    }


def _mutation_sweep(size: str) -> list[dict]:
    rows = []
    for workload in MUTATION_WORKLOADS:
        per_policy = {}
        for variant in POLICIES:
            r = _run_mutation_cell(size, workload, variant)
            per_policy[variant] = r
            rows.append(r)
            print(f"  {size:>2} {workload:<13} {variant:<20} "
                  f"{r['ops_per_s']:>10,.0f} ops/s  freshness p50 "
                  f"{r['freshness_median_s'] * 1e3:6.1f} ms  "
                  f"(compactions={r['compactions']})")
        speedup = (per_policy["deferred"]["ops_per_s"]
                   / per_policy["compact-every-flush"]["ops_per_s"])
        rows.append({"size": size, "variant": f"mutation:{workload}:speedup",
                     "mutation_speedup": round(speedup, 2)})
    return rows


def _durability_cell(size: str, durability: str) -> dict:
    """One durability cell: the deferred-policy stream with the WAL off
    (memory-only), on with per-batch fsync, on without fsync, or on with
    compact-every-flush (the 1-component recovery point). Durable cells
    additionally close the session and time ``Session.open`` cold-start
    recovery over the resulting component chain."""
    import shutil
    import tempfile

    from repro.runtime.durable import DurableStore

    base_rows, n_batches, batch_rows = SIZES[size]
    base = wisconsin.generate(base_rows, seed=7)
    policy = lsm.CompactionPolicy(size_ratio=0.0) \
        if durability == "wal-fsync-compacted" \
        else lsm.CompactionPolicy(size_ratio=1.0, max_runs=8)
    tmp = None
    if durability == "memory-only":
        sess = Session()
    else:
        tmp = tempfile.mkdtemp(prefix="repro-durability-")
        store = DurableStore(tmp, wal_fsync=(durability != "wal-nofsync"))
        sess = Session(storage=store)
    sess.create_dataset("Stream", base, dataverse="bench",
                        indexes=["onePercent"], primary="unique2")
    feed = Feed(sess, "Stream", "bench", flush_rows=batch_rows, policy=policy)
    batches = _stream(base_rows, n_batches, batch_rows)
    ingest_s = 0.0
    # batch 0 is the warm-up: it pays the flush-path compilations (cached
    # process-wide by shape), which would otherwise bill the first cell
    for i, rows in enumerate(batches):
        t0 = time.perf_counter()
        feed.push(rows)  # flush_rows == batch_rows: flushes synchronously
        if i > 0:
            ingest_s += time.perf_counter() - t0
    total_rows = (n_batches - 1) * batch_rows
    out = {
        "size": size,
        "variant": "durability",
        "durability": durability,
        "rows": total_rows,
        "ingest_s": round(ingest_s, 4),
        "rows_per_s": round(total_rows / ingest_s, 1),
        "components": 1 + len(sess.catalog.get("bench", "Stream").runs),
    }
    if tmp is not None:
        expect = base_rows + n_batches * batch_rows
        sess.close()
        t0 = time.perf_counter()
        re = Session.open(tmp)
        recovery_s = time.perf_counter() - t0
        n = len(AFrame("bench", "Stream", session=re))
        assert n == expect, (n, expect)
        out["recovery_s"] = round(recovery_s, 4)
        re.close()
        shutil.rmtree(tmp, ignore_errors=True)
    return out


def _durability_sweep(size: str) -> list[dict]:
    """WAL-on vs memory-only ingest throughput, fsync-batching sensitivity,
    and cold-start recovery latency vs resident component count."""
    _durability_cell(size, "memory-only")  # throwaway pass: warm every
    #                                        flush/compaction executable so
    #                                        no timed cell bills compiles
    cells = [_durability_cell(size, d) for d in
             ("memory-only", "wal-fsync", "wal-nofsync",
              "wal-fsync-compacted")]
    by = {c["durability"]: c for c in cells}
    overhead = by["memory-only"]["rows_per_s"] / by["wal-fsync"]["rows_per_s"]
    fsync_cost = (by["wal-nofsync"]["rows_per_s"]
                  / by["wal-fsync"]["rows_per_s"])
    for c in cells:
        rec = f"  recovery {c['recovery_s'] * 1e3:7.1f} ms " \
              f"({c['components']} comps)" if "recovery_s" in c else ""
        print(f"  {size:>2} durability {c['durability']:<20} "
              f"{c['rows_per_s']:>12,.0f} rows/s{rec}")
    print(f"  {size:>2} WAL ingest overhead: {overhead:.2f}x   "
          f"fsync cost: {fsync_cost:.2f}x")
    cells.append({"size": size, "variant": "durability",
                  "durability": "summary",
                  "wal_overhead_x": round(overhead, 3),
                  "fsync_cost_x": round(fsync_cost, 3)})
    return cells


def run_ingest_bench(sizes=None, out_path: pathlib.Path | None = None) -> list[dict]:
    names = list(sizes) if sizes else ["XS", "S"]
    rows = []
    for size in names:
        per_size = {}
        for variant in POLICIES:
            r = _run_variant(size, variant)
            per_size[variant] = r
            rows.append(r)
            print(f"  {size:>2} {variant:<20} {r['rows_per_s']:>12,.0f} rows/s  "
                  f"freshness p50 {r['freshness_median_s'] * 1e3:7.1f} ms  "
                  f"(compactions={r['compactions']})")
        speedup = (per_size["deferred"]["rows_per_s"]
                   / per_size["compact-every-flush"]["rows_per_s"])
        print(f"  {size:>2} deferred-compaction ingest speedup: {speedup:.1f}x")
        rows.append({"size": size, "variant": "speedup",
                     "ingest_speedup": round(speedup, 2)})
        rows.extend(_block_skip_sweep(size))
        rows.extend(_string_predicate_sweep(size))
        rows.extend(_block_skip_sharded_sweep(size))
        rows.extend(_mutation_sweep(size))
        rows.extend(_serving_sweep(size))
        rows.extend(_durability_sweep(size))
    # attach the engine-wide telemetry snapshot (counters/gauges/histograms
    # accumulated across every sweep above — plan cache, flush/compaction,
    # write stalls, retired-manifest bytes, kernel launches); spans are
    # dropped: the ring holds only the trailing queries and bloats the file.
    from repro.runtime import telemetry as tel
    rows.append({"variant": "telemetry",
                 "snapshot": tel.snapshot(include_spans=False)})
    if out_path is not None:
        out_path.write_text(json.dumps(rows, indent=2) + "\n")
        print(f"ingest benchmark -> {out_path}")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=str, default="XS,S")
    args = ap.parse_args()
    out = pathlib.Path(__file__).resolve().parents[1] / "results" / "bench"
    out.mkdir(parents=True, exist_ok=True)
    run_ingest_bench(args.sizes.split(","), out / "ingest.json")
