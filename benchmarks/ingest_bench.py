"""Streaming-ingestion benchmark: sustained rows/sec and query freshness.

Two variants ingest the same stream into the same base table:

  * ``compact-every-flush`` — the pre-LSM behaviour: every flush de-shards,
    concatenates, re-sorts and re-indexes the whole base (O(base) per batch).
    Expressed as ``CompactionPolicy(size_ratio=0)``.
  * ``deferred``           — the LSM path: flushes become device-resident
    runs (O(batch)), compaction fires only on the size-ratio policy.

Reported per size: sustained ingest rows/sec (wall time of push+flush+any
compaction), the deferred/baseline speedup, and query-freshness latency
(time to answer ``COUNT(*)`` + an indexed range count right after each
flush — base ∪ runs, including the recompile a fresh component set forces).
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from repro.core.frame import AFrame
from repro.data import wisconsin
from repro.engine import lsm
from repro.engine.ingest import Feed
from repro.engine.session import Session

# size: (base_rows, n_batches, batch_rows)
SIZES = {
    "XS": (2_000, 6, 512),
    "S": (10_000, 10, 1_024),
    "M": (50_000, 16, 2_048),
    "L": (150_000, 24, 2_048),
}

POLICIES = {
    "compact-every-flush": lambda: lsm.CompactionPolicy(size_ratio=0.0),
    "deferred": lambda: lsm.CompactionPolicy(size_ratio=1.0, max_runs=8),
}


def _stream(base_rows: int, n_batches: int, batch_rows: int):
    """Pre-generated arrival batches (unique2 keys keep increasing — the
    timestamped-tweet pattern)."""
    batches = []
    for i in range(n_batches):
        t = wisconsin.generate(batch_rows, seed=1_000 + i)
        rows = {k: np.asarray(v) for k, v in t.columns.items()}
        rows["unique2"] = rows["unique2"] + base_rows + i * batch_rows
        batches.append(rows)
    return batches


def _run_variant(size: str, variant: str, mode: str = "gspmd") -> dict:
    base_rows, n_batches, batch_rows = SIZES[size]
    base = wisconsin.generate(base_rows, seed=7)
    sess = Session(mode=mode)
    sess.create_dataset("Stream", base, dataverse="bench",
                        indexes=["onePercent"], primary="unique2")
    feed = Feed(sess, "Stream", "bench", flush_rows=batch_rows,
                policy=POLICIES[variant]())
    batches = _stream(base_rows, n_batches, batch_rows)
    df = AFrame("bench", "Stream", session=sess)
    len(df)  # warm the count executable for the base-only shape

    ingest_s = 0.0
    freshness = []
    for rows in batches:
        t0 = time.perf_counter()
        feed.push(rows)  # flush_rows == batch_rows: flushes synchronously
        ingest_s += time.perf_counter() - t0
        t0 = time.perf_counter()
        n = len(df)
        len(df[(df["onePercent"] >= 10) & (df["onePercent"] <= 30)])
        freshness.append(time.perf_counter() - t0)
        assert n == base_rows + feed.stats["ingested"]
    total_rows = n_batches * batch_rows
    return {
        "size": size,
        "variant": variant,
        "rows": total_rows,
        "batches": n_batches,
        "ingest_s": round(ingest_s, 4),
        "rows_per_s": round(total_rows / ingest_s, 1),
        "freshness_median_s": round(float(np.median(freshness)), 4),
        "freshness_p95_s": round(float(np.percentile(freshness, 95)), 4),
        "flushes": feed.stats["flushes"],
        "compactions": feed.stats["compactions"],
        "final_runs": feed.stats["runs"],
    }


def run_ingest_bench(sizes=None, out_path: pathlib.Path | None = None) -> list[dict]:
    names = list(sizes) if sizes else ["XS", "S"]
    rows = []
    for size in names:
        per_size = {}
        for variant in POLICIES:
            r = _run_variant(size, variant)
            per_size[variant] = r
            rows.append(r)
            print(f"  {size:>2} {variant:<20} {r['rows_per_s']:>12,.0f} rows/s  "
                  f"freshness p50 {r['freshness_median_s'] * 1e3:7.1f} ms  "
                  f"(compactions={r['compactions']})")
        speedup = (per_size["deferred"]["rows_per_s"]
                   / per_size["compact-every-flush"]["rows_per_s"])
        print(f"  {size:>2} deferred-compaction ingest speedup: {speedup:.1f}x")
        rows.append({"size": size, "variant": "speedup",
                     "ingest_speedup": round(speedup, 2)})
    if out_path is not None:
        out_path.write_text(json.dumps(rows, indent=2) + "\n")
        print(f"ingest benchmark -> {out_path}")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=str, default="XS,S")
    args = ap.parse_args()
    out = pathlib.Path(__file__).resolve().parents[1] / "results" / "bench"
    out.mkdir(parents=True, exist_ok=True)
    run_ingest_bench(args.sizes.split(","), out / "ingest.json")
