"""Model-side benchmarks: UDF application throughput (paper Fig. 5/6 —
applying a model to a column), serve decode rate, and train step rate, on
the CPU-feasible reduced paper-lm."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.frame import AFrame
from repro.engine.session import Session
from repro.engine.table import Table
from repro.models.optim import OptimConfig
from repro.models.registry import get_api
from repro.models.steps import (init_train_state, make_decode_step,
                                make_prefill_step, make_train_step)
from repro.udf import model_udf


def bench_udf(rows: int = 1024, seq: int = 16) -> dict:
    model_udf.clear_registry()
    cfg = get_config("paper-lm").reduced()
    api = get_api(cfg)
    params = api.init(jax.random.key(0), cfg)
    model_udf.register_model("clf", params, cfg, classes=3)

    rng = np.random.default_rng(0)
    sess = Session()
    sess.create_dataset("T", Table({
        "id": np.arange(rows, dtype=np.int32),
        "toks": rng.integers(0, cfg.vocab, (rows, seq)).astype(np.int32),
    }), dataverse="m")
    df = AFrame("m", "T", session=sess)
    df["pred"] = df["toks"].map("clf")

    df.head(2)  # warm (compile)
    t0 = time.perf_counter()
    n_runs = 5
    for _ in range(n_runs):
        out = df.collect()
    dt = (time.perf_counter() - t0) / n_runs
    return {"rows": rows, "s_per_pass": dt, "rows_per_s": rows / dt}


def bench_serve(batch: int = 8, prompt: int = 64, new_tokens: int = 16) -> dict:
    cfg = get_config("paper-lm").reduced()
    api = get_api(cfg)
    params = api.init(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (batch, prompt), 0, cfg.vocab)
    prefill = jax.jit(make_prefill_step(cfg, api, max_len=prompt + new_tokens))
    decode = jax.jit(make_decode_step(cfg, api))
    cache, tok = prefill(params, {"tokens": toks})
    cache, tok = decode(params, cache, tok)  # warm
    jax.block_until_ready(tok)
    t0 = time.perf_counter()
    for _ in range(new_tokens):
        cache, tok = decode(params, cache, tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    return {"batch": batch, "decode_steps_per_s": new_tokens / dt,
            "tokens_per_s": batch * new_tokens / dt}


def bench_train(batch: int = 4, seq: int = 64, steps: int = 5) -> dict:
    cfg = get_config("paper-lm").reduced()
    api = get_api(cfg)
    params, opt = init_train_state(jax.random.key(0), cfg, api)
    step = jax.jit(make_train_step(cfg, OptimConfig(total_steps=100), api))
    b = {"tokens": jax.random.randint(jax.random.key(1), (batch, seq), 0, cfg.vocab)}
    params, opt, m = step(params, opt, b)  # warm
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for _ in range(steps):
        params, opt, m = step(params, opt, b)
    jax.block_until_ready(m["loss"])
    dt = (time.perf_counter() - t0) / steps
    return {"s_per_step": dt, "tokens_per_s": batch * seq / dt,
            "final_loss": float(m["loss"])}


def run_model_bench() -> dict:
    out = {"udf": bench_udf(), "serve": bench_serve(), "train": bench_train()}
    for k, v in out.items():
        print(f"{k}: {v}")
    return out
