"""Speedup / scaleup (paper §IV-D2, Tables VII/VIII).

Each (shards, rows) point runs in a FRESH subprocess with
``--xla_force_host_platform_device_count=<shards>`` so the shard_map engine
partitions exactly as it would across machines.

CPU-container caveat (recorded in EXPERIMENTS.md): one physical core executes
all shards, so wall-clock cannot show hardware speedup — what these curves
measure is the *distribution overhead structure* (per-shard work + collective
emulation), i.e. the flat-or-gently-rising scaleup line and the
overhead-dominated speedup line one expects from emulated shards.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

_CHILD = r"""
import json, sys, time
import numpy as np
shards, rows = int(sys.argv[1]), int(sys.argv[2])
from repro.data import wisconsin
from repro.engine.session import Session
from repro.core.frame import AFrame
from repro.launch.mesh import make_local_mesh
from benchmarks.wisconsin_bench import EXPRESSIONS, AFrameVariant, WARMUP, RUNS

mesh = make_local_mesh(data=shards, model=1) if shards > 1 else None
sess = Session(mesh=mesh, mode="shard_map" if shards > 1 else "gspmd")
table = wisconsin.generate(rows, seed=11)
sess.create_dataset("data", table, dataverse="bench", closed=True,
                    indexes=["onePercent", "unique1"], primary="unique2")
sess.create_dataset("data_r", table, dataverse="bench", closed=True,
                    indexes=["onePercent", "unique1"], primary="unique2")
v = AFrameVariant("aframe-index", sess, "data")
t0 = time.perf_counter(); v.create(); creation = time.perf_counter() - t0
out = {}
for name, fn in EXPRESSIONS:
    rng = np.random.default_rng(5)
    ts = []
    for _ in range(WARMUP + RUNS):
        t0 = time.perf_counter(); fn(v, rng, rows); ts.append(time.perf_counter() - t0)
    out[name] = float(np.mean(ts[WARMUP:]))
print(json.dumps({"shards": shards, "rows": rows, "creation_s": creation,
                  "expr_s": out}))
"""


def run_point(shards: int, rows: int, timeout: int = 560) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={max(shards, 1)}"
    env["PYTHONPATH"] = f"{ROOT / 'src'}:{ROOT}"
    r = subprocess.run([sys.executable, "-c", _CHILD, str(shards), str(rows)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    return json.loads(r.stdout.strip().splitlines()[-1])


def speedup(rows: int = 200_000, shard_counts=(1, 2, 4, 8)) -> list[dict]:
    """Fixed data, growing shards (paper Table VII)."""
    return [run_point(s, rows) for s in shard_counts]


def scaleup(rows_per_shard: int = 50_000, shard_counts=(1, 2, 4, 8)) -> list[dict]:
    """Data grows with shards (paper Table VIII)."""
    return [run_point(s, rows_per_shard * s) for s in shard_counts]


def run_scaling(out_json: pathlib.Path, quick: bool = False) -> dict:
    counts = (1, 2, 4) if quick else (1, 2, 4, 8)
    res = {"speedup": speedup(100_000 if quick else 200_000, counts),
           "scaleup": scaleup(25_000 if quick else 50_000, counts)}
    out_json.parent.mkdir(parents=True, exist_ok=True)
    out_json.write_text(json.dumps(res, indent=2))
    for kind in ("speedup", "scaleup"):
        print(f"-- {kind} --")
        for rec in res[kind]:
            tot = sum(rec["expr_s"].values())
            print(f"  shards={rec['shards']:2d} rows={rec['rows']:7d} "
                  f"sum(expr)={tot*1e3:9.1f}ms create={rec['creation_s']*1e3:7.1f}ms")
    return res
