"""Benchmark orchestrator — one entry per paper table/figure.

  --single-node : Fig. 8-11 / Tables V-VI (12 expressions × variants × sizes)
  --scaling     : Tables VII-VIII (speedup / scaleup via subprocess shards)
  --model       : Fig. 5/6 analogue (model-UDF / serve / train rates)
  --roofline    : §Roofline table from the dry-run artifacts
  --ingest      : streaming ingestion (deferred compaction vs
                  compact-every-flush rows/sec + query freshness)
  (no flags)    : quick versions of all of the above

Outputs land in results/bench/.
"""
from __future__ import annotations

import argparse
import json
import pathlib

OUT = pathlib.Path(__file__).resolve().parents[1] / "results" / "bench"


def _mode_comparison(rows: list[dict]) -> dict:
    """Per-size, per-expression gspmd (aframe-schema) vs kernel
    (aframe-kernel) expression timings + speedup — the BENCH_*.json artifact
    that tracks the fused-kernel win across PRs."""
    out: dict = {}
    for r in rows:
        if r["variant"] not in ("aframe-schema", "aframe-kernel"):
            continue
        cell = out.setdefault(r["size"], {}).setdefault(r["expression"], {})
        key = "gspmd_s" if r["variant"] == "aframe-schema" else "kernel_s"
        cell[key] = r["expr_s"]
    for exprs in out.values():
        for cell in exprs.values():
            if "gspmd_s" in cell and "kernel_s" in cell and cell["kernel_s"] > 0:
                cell["speedup"] = round(cell["gspmd_s"] / cell["kernel_s"], 3)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--single-node", action="store_true")
    ap.add_argument("--scaling", action="store_true")
    ap.add_argument("--model", action="store_true")
    ap.add_argument("--roofline", action="store_true")
    ap.add_argument("--ingest", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="full dataset sizes (XS..XL); default quick=XS,S")
    ap.add_argument("--sizes", type=str, default=None,
                    help="comma-separated size names (e.g. XS) — overrides "
                         "--full; used by the CI smoke run")
    args = ap.parse_args()
    run_all = not (args.single_node or args.scaling or args.model
                   or args.roofline or args.ingest)
    OUT.mkdir(parents=True, exist_ok=True)

    if args.single_node or run_all:
        from benchmarks.wisconsin_bench import SIZES, run_benchmark

        if args.sizes:
            sizes = {k: SIZES[k] for k in args.sizes.split(",")}
        elif args.full:
            sizes = SIZES
        else:
            sizes = {k: SIZES[k] for k in ("XS", "S")}
        print(f"== single-node DataFrame benchmark (sizes={list(sizes)}) ==")
        rows = run_benchmark(sizes, OUT / "single_node.csv")
        bench = _mode_comparison(rows)
        bench_path = OUT.parents[1] / "BENCH_wisconsin.json"
        bench_path.write_text(json.dumps(bench, indent=2) + "\n")
        print(f"gspmd-vs-kernel comparison -> {bench_path}")

    if args.ingest or run_all:
        from benchmarks.ingest_bench import SIZES as INGEST_SIZES, run_ingest_bench

        if args.sizes:
            sizes = [s for s in args.sizes.split(",") if s in INGEST_SIZES]
        elif args.full:
            sizes = list(INGEST_SIZES)
        else:
            sizes = ["XS", "S"]
        print(f"== streaming ingestion benchmark (sizes={sizes}) ==")
        run_ingest_bench(sizes, OUT / "ingest.json")

    if args.scaling or run_all:
        from benchmarks.scaling_bench import run_scaling

        print("== speedup / scaleup (subprocess shards) ==")
        run_scaling(OUT / "scaling.json", quick=not args.full)

    if args.model or run_all:
        from benchmarks.model_bench import run_model_bench

        print("== model UDF / serve / train ==")
        (OUT / "model.json").write_text(json.dumps(run_model_bench(), indent=2))

    if args.roofline or run_all:
        from benchmarks.roofline_table import markdown_table, summary

        print("== roofline (from dry-run artifacts) ==")
        md = markdown_table("pod")
        (OUT / "roofline_pod.md").write_text(md)
        print(md)
        print(json.dumps(summary("pod"), indent=2))


if __name__ == "__main__":
    main()
