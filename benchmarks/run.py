"""Benchmark orchestrator — one entry per paper table/figure.

  --single-node : Fig. 8-11 / Tables V-VI (12 expressions × variants × sizes)
  --scaling     : Tables VII-VIII (speedup / scaleup via subprocess shards)
  --model       : Fig. 5/6 analogue (model-UDF / serve / train rates)
  --roofline    : §Roofline table from the dry-run artifacts
  (no flags)    : quick versions of all of the above

Outputs land in results/bench/.
"""
from __future__ import annotations

import argparse
import json
import pathlib

OUT = pathlib.Path(__file__).resolve().parents[1] / "results" / "bench"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--single-node", action="store_true")
    ap.add_argument("--scaling", action="store_true")
    ap.add_argument("--model", action="store_true")
    ap.add_argument("--roofline", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="full dataset sizes (XS..XL); default quick=XS,S")
    args = ap.parse_args()
    run_all = not (args.single_node or args.scaling or args.model or args.roofline)
    OUT.mkdir(parents=True, exist_ok=True)

    if args.single_node or run_all:
        from benchmarks.wisconsin_bench import SIZES, run_benchmark

        sizes = SIZES if args.full else {k: SIZES[k] for k in ("XS", "S")}
        print(f"== single-node DataFrame benchmark (sizes={list(sizes)}) ==")
        run_benchmark(sizes, OUT / "single_node.csv")

    if args.scaling or run_all:
        from benchmarks.scaling_bench import run_scaling

        print("== speedup / scaleup (subprocess shards) ==")
        run_scaling(OUT / "scaling.json", quick=not args.full)

    if args.model or run_all:
        from benchmarks.model_bench import run_model_bench

        print("== model UDF / serve / train ==")
        (OUT / "model.json").write_text(json.dumps(run_model_bench(), indent=2))

    if args.roofline or run_all:
        from benchmarks.roofline_table import markdown_table, summary

        print("== roofline (from dry-run artifacts) ==")
        md = markdown_table("pod")
        (OUT / "roofline_pod.md").write_text(md)
        print(md)
        print(json.dumps(summary("pod"), indent=2))


if __name__ == "__main__":
    main()
