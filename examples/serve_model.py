"""End-to-end serving driver: batched requests through prefill + decode with
continuous batched generation — the serving-side e2e deliverable (the paper
is an analytics/serving system, so serving is the primary driver).

Run:  PYTHONPATH=src python examples/serve_model.py [--arch paper-lm] [--tokens 32]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.registry import get_api
from repro.models.steps import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-lm")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (not reduced) config — TPU-sized!")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.reduced()
    api = get_api(cfg)
    print(f"serving {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"({cfg.n_params()/1e6:.1f}M params)")

    params = api.init(jax.random.key(0), cfg)
    max_len = args.prompt + args.tokens
    prefill = jax.jit(make_prefill_step(cfg, api, max_len=max_len))
    decode = jax.jit(make_decode_step(cfg, api))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt)),
                          jnp.int32)
    batch = {"tokens": prompts}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.enc_len, cfg.d_model)), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.num_patches, cfg.patch_dim)), jnp.bfloat16)

    t0 = time.perf_counter()
    cache, tok = prefill(params, batch)
    jax.block_until_ready(tok)
    t_prefill = time.perf_counter() - t0
    print(f"prefill: {args.batch}×{args.prompt} tokens in {t_prefill*1e3:.1f}ms "
          f"({args.batch*args.prompt/t_prefill:.0f} tok/s)")

    generated = [tok]
    t0 = time.perf_counter()
    for _ in range(args.tokens - 1):
        cache, tok = decode(params, cache, tok)
        generated.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"decode:  {args.tokens-1} steps × batch {args.batch} in "
          f"{t_decode*1e3:.1f}ms ({args.batch*(args.tokens-1)/t_decode:.0f} tok/s)")
    print(f"sample continuation (request 0): {np.asarray(out[0])[:16].tolist()}")


if __name__ == "__main__":
    main()
