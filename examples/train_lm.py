"""Training driver with the full production substrate: fault-tolerant loop,
checkpoint/restart, NaN rollback, deterministic data order — a scaled-down
run of exactly what launch/train.py does on a pod.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 60] [--resume]
"""
import argparse
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.optim import OptimConfig
from repro.models.registry import get_api
from repro.models.steps import init_train_state, make_train_step
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.fault import (FailureInjector, FaultTolerantLoop,
                                 TrainLoopConfig)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-lm")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-failures", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    api = get_api(cfg)
    print(f"training {cfg.name}: {cfg.n_params()/1e6:.1f}M params, "
          f"{args.steps} steps of {args.batch}×{args.seq} tokens")

    params, opt = init_train_state(jax.random.key(0), cfg, api)
    step_fn = jax.jit(make_train_step(
        cfg, OptimConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps), api))

    def data_factory(start_step):
        def gen():
            i = start_step
            while True:  # deterministic per-step batches => exact rollback
                rng = np.random.default_rng(1234 + i)
                yield {"tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab, (args.batch, args.seq)), jnp.int32)}
                i += 1
        return gen()

    ckpt = CheckpointManager(args.ckpt_dir, keep=3)
    start = 0
    if args.resume and ckpt.latest_step() is not None:
        start, state = ckpt.restore(None, {"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        print(f"resumed from checkpoint step {start}")

    injector = FailureInjector({args.steps // 3: "node",
                                2 * args.steps // 3: "nan"}
                               if args.inject_failures else {})
    loop = FaultTolerantLoop(step_fn, ckpt, TrainLoopConfig(ckpt_every=10),
                             injector)
    params, opt, log = loop.run(params, opt, data_factory, args.steps,
                                start_step=start)
    for s, l in log[:: max(len(log) // 8, 1)]:
        print(f"  step {s:4d}  loss {l:.4f}")
    print(f"final loss {log[-1][1]:.4f}; recoveries: {loop.events or 'none'}")
    print(f"checkpoints kept: {ckpt.steps()} under {args.ckpt_dir}")


if __name__ == "__main__":
    main()
