"""Quickstart: the AFrame user experience (paper Figs. 2-3).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.frame import AFrame
from repro.data import wisconsin
from repro.engine.session import Session

# -- "CREATE DATASET ... / LOAD DATASET" (paper Fig. 1) ------------------------
sess = Session()
table = wisconsin.generate(100_000, seed=0)
sess.create_dataset("TrainingData", table, dataverse="demo",
                    indexes=["onePercent"], primary="unique2")

# -- In [2]: initializing an AFrame object is O(1): data is *managed* ----------
df = AFrame("demo", "TrainingData", session=sess)

# -- lazy expressions (paper Inputs 4-5): nothing executes yet -----------------
evens = df[df["two"] == 0]
small = evens[["unique1", "ten", "stringu1"]]

# -- Inputs 7-8: inspect the incrementally-built query -------------------------
print("underlying query:")
print(" ", small.query)
print("optimized form:")
print(" ", small.optimized_query)

# -- Input 6: an ACTION triggers evaluation (LIMIT pushed into the plan) -------
print("\nhead(3):")
for k, v in small.head(3).items():
    print(f"  {k:10s} {v[:3]}")

# -- aggregates / groupby / sort ------------------------------------------------
print("\nlen(df)            =", len(df))
print("df['unique1'].max() =", df["unique1"].max())
g = df.groupby("twenty")["four"].agg("max")
print("groupby('twenty')['four'].max() ->", dict(zip(g["twenty"][:5].tolist(),
                                                     g["max_four"][:5].tolist())))
top = df.sort_values("unique1", ascending=False).head(3)
print("top-3 by unique1    =", top["unique1"].tolist())

# -- index-accelerated range count (paper expression 11) ------------------------
n = len(df[(df["onePercent"] >= 10) & (df["onePercent"] <= 19)])
print("range count (index-only query) =", n)
print("  executed as:", sess.last_optimized.to_sql())

# -- persist (paper Input 15) ----------------------------------------------------
saved = small.persist("EvenRows")
print("\npersisted demo.EvenRows; len =", len(saved))
print("plan cache:", sess.stats)
