"""The paper's end-to-end story (Figs. 2-6): train a small classifier
locally, register it as an engine UDF, apply it to a managed dataset at
scale, and persist the negative-prediction subset for root-cause analysis.

The sklearn pipeline of Fig. 4 becomes a JAX LM classification head; the
"LiveTweets" feed becomes an ingesting dataset of fixed-width token columns.

Run:  PYTHONPATH=src python examples/sentiment_pipeline.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.frame import AFrame
from repro.data import wisconsin
from repro.engine.ingest import Feed
from repro.engine.session import Session
from repro.engine.table import Table
from repro.models.optim import OptimConfig
from repro.models.registry import get_api
from repro.models.steps import init_train_state, make_train_step
from repro.udf import model_udf

rng = np.random.default_rng(0)
cfg = get_config("paper-lm").reduced()
api = get_api(cfg)

# -- Fig. 4: "train a model locally" --------------------------------------------
# synthetic sentiment task: class = f(token prefix); train the tiny LM a few
# steps so the head is non-random (the *pipeline* is the point, not accuracy)
print("== training the local model (Fig. 4) ==")
params, opt = init_train_state(jax.random.key(0), cfg, api)
step = jax.jit(make_train_step(cfg, OptimConfig(lr=1e-3, total_steps=50), api))
for i in range(20):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)), jnp.int32)}
    params, opt, m = step(params, opt, batch)
print(f"   final LM loss: {float(m['loss']):.3f}")

# -- "drop it into the engine as a UDF" ------------------------------------------
model_udf.register_model("sentiment", params, cfg, classes=3)
print("   registered UDF 'sentiment' (3 classes)")

# -- Fig. 1/2: a live dataset fed by an ingestion feed ----------------------------
sess = Session()
n0 = 2_000
tokens = rng.integers(0, cfg.vocab, (n0, 16)).astype(np.int32)
sess.create_dataset("LiveTweets", Table({
    "id": np.arange(n0, dtype=np.int32),
    "text_tokens": tokens,
    "hour": (np.arange(n0) % 24).astype(np.int32),
}), dataverse="demo")
feed = Feed(sess, "LiveTweets", "demo", flush_rows=512)
# a continuously-maintained dashboard aggregate: refreshed incrementally
# from each flush's delta batch, never recomputed from scratch
dash = AFrame("demo", "LiveTweets", session=sess)
sess.create_view("tweets_by_hour", dash.groupby("hour").agg_plan("count"))
for _ in range(2):  # two arriving batches
    m_new = 512
    feed.push({"id": np.arange(m_new, dtype=np.int32) + 10_000,
               "text_tokens": rng.integers(0, cfg.vocab, (m_new, 16)).astype(np.int32),
               "hour": rng.integers(0, 24, m_new).astype(np.int32)})
print(f"== live feed: {feed.stats} ==")
by_hour_live = sess.read_view("tweets_by_hour")
print(f"   dashboard view: {int(by_hour_live['count'].sum())} tweets "
      f"across {len(by_hour_live['hour'])} hours (no query ran)")

# -- Fig. 5: apply the model to the text column ----------------------------------
df = AFrame("demo", "LiveTweets", session=sess)
df["sentiment"] = df["text_tokens"].map("sentiment")
print("== applying the UDF (Fig. 5) ==")
print("   query:", df.query[:120], "...")
sample = df.head(5)
print("   sample predictions:", sample["sentiment"])

# -- Fig. 6: negative subset, persisted -------------------------------------------
neg = df[df["sentiment"] == 0][["id", "hour", "sentiment"]]
saved = neg.persist("negTweets")
print(f"== persisted demo.negTweets: {len(saved)} rows ==")
by_hour = saved.groupby("hour").agg("count")
busiest = int(by_hour["hour"][np.argmax(by_hour["count"])])
print(f"   busiest negative hour: {busiest}")
