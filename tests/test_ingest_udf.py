"""Live ingestion (paper §III-A data feeds) and model UDFs (§III-C)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.frame import AFrame
from repro.data import wisconsin
from repro.engine.ingest import Feed
from repro.engine.session import Session
from repro.udf import model_udf


def test_feed_flush_and_query_consistency():
    t = wisconsin.generate(2000, seed=3)
    sess = Session()
    sess.create_dataset("Live", t, dataverse="d", indexes=["onePercent"],
                        primary="unique2")
    df = AFrame("d", "Live", session=sess)
    assert len(df) == 2000
    feed = Feed(sess, "Live", "d", flush_rows=500)
    extra = wisconsin.generate(600, seed=9)
    rows = {k: np.asarray(v) for k, v in extra.columns.items()}
    # shift keys so they do not collide
    rows["unique2"] = rows["unique2"] + 2000
    feed.push(rows)  # 600 >= 500 -> auto-flush
    assert feed.stats["flushes"] == 1
    df = AFrame("d", "Live", session=sess)
    assert len(df) == 2600
    # index still answers correctly after compaction
    n = len(df[(df["onePercent"] >= 0) & (df["onePercent"] <= 4)])
    raw1 = np.asarray(t.columns["onePercent"])
    raw2 = rows["onePercent"]
    want = int(((raw1 >= 0) & (raw1 <= 4)).sum() + ((raw2 >= 0) & (raw2 <= 4)).sum())
    assert n == want


def test_feed_buffers_below_threshold():
    t = wisconsin.generate(100, seed=3)
    sess = Session()
    sess.create_dataset("Live", t, dataverse="d")
    feed = Feed(sess, "Live", "d", flush_rows=1000)
    feed.push({k: np.asarray(v)[:10] for k, v in t.columns.items()})
    assert feed.stats["flushes"] == 0
    assert len(AFrame("d", "Live", session=sess)) == 100  # not yet visible
    feed.flush()
    assert len(AFrame("d", "Live", session=sess)) == 110


@pytest.fixture()
def sentiment_setup():
    """Tiny end-to-end: 'tweets' as fixed-width token columns + a trained
    classifier UDF (the paper's Fig. 4/5 pipeline in miniature)."""
    model_udf.clear_registry()
    from repro.configs import get_config
    from repro.models.registry import get_api

    cfg = get_config("paper-lm").reduced()
    api = get_api(cfg)
    params = api.init(jax.random.key(0), cfg)
    model_udf.register_model("sentiment", params, cfg, classes=3)

    rng = np.random.default_rng(0)
    n = 256
    tokens = rng.integers(0, cfg.vocab, (n, 16)).astype(np.int32)
    sess = Session()
    from repro.engine.table import Table

    sess.create_dataset("Tweets", Table({
        "id": np.arange(n, dtype=np.int32),
        "text_tokens": tokens,
        "ten": (np.arange(n) % 10).astype(np.int32),
    }), dataverse="demo")
    return sess, cfg, params, tokens


def test_model_udf_map_and_persist(sentiment_setup):
    sess, cfg, params, tokens = sentiment_setup
    df = AFrame("demo", "Tweets", session=sess)
    df["sentiment"] = df["text_tokens"].map("sentiment")
    out = df.head(8)
    assert set(out) >= {"id", "sentiment"}
    assert np.all((out["sentiment"] >= 0) & (out["sentiment"] < 3))
    # paper Input 14/15: filter on the prediction, persist
    neg = df[df["sentiment"] == 0][["id", "sentiment"]]
    saved = neg.persist("negTweets")
    got = saved.collect()
    assert np.all(got["sentiment"] == 0)
    # prediction matches direct model application
    from repro.udf.model_udf import get_udf

    direct = np.asarray(get_udf("sentiment")(jnp.asarray(tokens)))
    assert len(got["id"]) == int((direct == 0).sum())


def test_udf_lazy_limit_pushdown(sentiment_setup):
    """head(2) after map must run the model on 2 rows, not the table —
    the paper's expression-5 lazy-evaluation win."""
    sess, cfg, params, tokens = sentiment_setup
    df = AFrame("demo", "Tweets", session=sess)
    mapped = df["text_tokens"].map("sentiment")
    plan_sql = sess.last_optimized if hasattr(sess, "last_optimized") else None
    out = mapped.head(2)
    from repro.core import plan as P

    opt = sess.last_optimized
    # optimized plan: Project(UDF) sits ABOVE Limit
    assert isinstance(opt, P.Project)
    assert isinstance(opt.children[0], P.Limit)
    assert len(out[list(out)[0]]) == 2


def test_unknown_udf_raises():
    model_udf.clear_registry()
    with pytest.raises(KeyError, match="no model UDF"):
        model_udf.get_udf("nope")
