"""Block-level zone-map skipping (the second pruning level: run → block),
the point-lookup fast path, the read-amplification cost term, and the
interpret auto-detection plumbing.

The acceptance property: block-skipped results are bit-identical to
unskipped in gspmd, shard_map, and kernel modes — including over mutated,
uncompacted datasets — with the kernel grid (or stream gather) provably
touching fewer blocks on selective predicates over clustered columns.
"""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import physical as PH
from repro.core import plan as P
from repro.core.frame import AFrame
from repro.core.stats import ZONE_BLOCK_ROWS
from repro.engine import lsm
from repro.engine.ingest import Feed
from repro.engine.session import Session
from repro.engine.table import Table
from repro.kernels import ops, ref

N = 20_000  # 5 zone blocks of 4096


def _session(mode, **kw):
    if mode == "shard_map":
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        return Session(mesh=mesh, mode="shard_map", **kw)
    return Session(mode=mode, **kw)


def _clustered_table(n=N, seed=0):
    """id primary (clustered), ts == id (time-ordered), val random — the
    timestamped-event layout block skipping shines on."""
    rng = np.random.default_rng(seed)
    ids = np.arange(n, dtype=np.int32)
    return Table({"id": ids, "ts": ids.copy(),
                  "val": rng.integers(0, 100, n).astype(np.int32)})


def _range_count(df, col, lo, hi):
    return len(df[(df[col] >= lo) & (df[col] <= hi)])


# -- constants stay in lockstep ----------------------------------------------


def test_zone_block_granularity_pinned():
    from repro.kernels.filter_count import BLOCK as FC_BLOCK
    from repro.kernels.segment_agg import BLOCK as SA_BLOCK

    assert ZONE_BLOCK_ROWS == ops.ZONE_BLOCK_ROWS == FC_BLOCK
    assert ZONE_BLOCK_ROWS % SA_BLOCK == 0  # zone blocks expand cleanly


# -- kernel-level equivalence ------------------------------------------------


@pytest.mark.parametrize("backend", ["pallas", "xla"])
@pytest.mark.parametrize("n", [4096, 10_000, 12_288])
def test_filter_count_block_ids_match_full(backend, n):
    rng = np.random.default_rng(3)
    cols = rng.integers(0, 50, size=(2, n)).astype(np.int32)
    bounds = np.array([[5, 20], [0, 49]], np.int32)
    nv = n - 7
    want = int(ref.filter_count(cols, bounds, nv))
    nb = -(-n // ZONE_BLOCK_ROWS)
    got = int(ops.filter_count(cols, bounds, nv, backend=backend,
                               block_ids=tuple(range(nb))))
    assert got == want
    # zero out everything outside one zone block; skipping the rest agrees
    one = min(1, nb - 1)
    sel = cols.copy()
    sel[0, :one * ZONE_BLOCK_ROWS] = 99
    sel[0, (one + 1) * ZONE_BLOCK_ROWS:] = 99
    want1 = int(ref.filter_count(sel, bounds, nv))
    got1 = int(ops.filter_count(sel, bounds, nv, backend=backend,
                                block_ids=(one,)))
    assert got1 == want1


@pytest.mark.parametrize("backend", ["pallas", "xla"])
@pytest.mark.parametrize("op", ["sum", "max", "min"])
def test_segment_agg_block_ids_match_full(backend, op):
    rng = np.random.default_rng(4)
    n, g = 10_000, 6
    gids = np.full(n, -1, np.int32)
    gids[4096:8192] = rng.integers(0, g, 4096)  # live rows in zone block 1
    vals = rng.integers(0, 40, size=(n, 2)).astype(np.float32)
    nv = n - 11
    want = np.asarray(ref.segment_agg(vals, gids, g, nv, op))
    got = np.asarray(ops.segment_agg(vals, gids, g, nv, op=op,
                                     backend=backend, block_ids=(1,)))
    np.testing.assert_array_equal(want, got)


def test_kernel_interpret_auto_detects_and_session_overrides():
    """interpret=None auto-detects per backend (regression: the kernels used
    to hardcode interpret=True, so TPU runs never compiled); an explicit
    Session(kernel_interpret=...) plumbs through to the launch."""
    from repro.kernels.filter_count import filter_count as fc

    cols = np.arange(8192, dtype=np.int32).reshape(1, -1)
    bounds = np.array([[10, 20]], np.int32)
    want = 11
    assert int(fc(cols, bounds, 8192)) == want  # default = auto
    on_tpu = jax.default_backend() == "tpu"
    assert int(fc(cols, bounds, 8192, interpret=not on_tpu)) == want

    t = _clustered_table(8192)
    sess = Session(mode="kernel", kernel_backend="pallas",
                   kernel_interpret=not on_tpu, enable_index=False)
    sess.create_dataset("Ev", t, dataverse="ki", primary="id")
    df = AFrame("ki", "Ev", session=sess)
    assert _range_count(df, "ts", 10, 20) == 11


# -- end-to-end equivalence + blocks-touched accounting ----------------------


@pytest.mark.parametrize("mode", ["gspmd", "shard_map", "kernel"])
def test_block_skip_matches_unskipped_and_touches_fewer_blocks(mode):
    sess = _session(mode, enable_index=False)
    sess.create_dataset("Ev", _clustered_table(), dataverse="b", primary="id")
    df = AFrame("b", "Ev", session=sess)
    lo, hi = 8192, 8700  # inside zone block 2 of 5
    n_skip = _range_count(df, "ts", lo, hi)
    rep = sess.last_prune_report
    assert n_skip == hi - lo + 1
    assert rep["blocks_total"] == 5
    assert rep["blocks_scanned"] == 1
    assert rep["blocks_skipped"] == 4
    if mode == "kernel":
        assert isinstance(sess.last_physical, PH.KernelRangeCount)
        assert sess.last_physical.block_ids == (2,)
    sess.enable_block_skip = False
    assert _range_count(df, "ts", lo, hi) == n_skip
    assert sess.last_prune_report["blocks_scanned"] == 5
    sess.enable_block_skip = True
    # a range off every block's span floors at one block and still counts 0
    assert _range_count(df, "ts", 10 * N, 11 * N) == 0
    assert sess.last_prune_report["blocks_scanned"] == 1


def test_block_skip_table_results_identical():
    """Materializing paths (collect/head over a filtered scan) gather only
    surviving blocks — same rows, same order."""
    sess = Session(enable_index=False)
    sess.create_dataset("Ev", _clustered_table(), dataverse="b", primary="id")
    df = AFrame("b", "Ev", session=sess)
    sel = df[(df["ts"] >= 4000) & (df["ts"] <= 4500)]  # straddles blocks 0/1
    got = sel.collect()
    sess.enable_block_skip = False
    want = sel.collect()
    for k in want:
        np.testing.assert_array_equal(got[k], want[k], err_msg=k)
    assert len(got["ts"]) == 501


def test_groupagg_kernel_grid_hoists_block_list():
    """A filtered group-by on the kernel path hoists the surviving-block
    list into the segment_agg grid (no stream gather) and matches gspmd."""
    t = _clustered_table()
    results = {}
    for mode in ("gspmd", "kernel"):
        sess = Session(mode=mode, enable_index=False)
        sess.create_dataset("Ev", t, dataverse="g", primary="id")
        df = AFrame("g", "Ev", session=sess)
        results[mode] = df[(df["ts"] >= 8192) & (df["ts"] <= 12287)] \
            .groupby("val").agg("count")
        if mode == "kernel":
            assert isinstance(sess.last_physical, PH.KernelSegmentAgg)
            blocks = [b for b in sess.last_physical.comp_blocks
                      if b is not None]
            assert blocks and blocks[0][0] == (2,)
            assert "skipped" in sess.last_physical.note
    for k in results["gspmd"]:
        np.testing.assert_array_equal(
            np.asarray(results["gspmd"][k]), np.asarray(results["kernel"][k]),
            err_msg=k)


def test_block_skip_plan_cache_keyed_by_surviving_blocks():
    """Literals that keep the surviving-block set reuse the executable;
    literals that move to another block rebuild (the block list is static
    plan structure) — and both count correctly."""
    sess = Session(mode="kernel", enable_index=False)
    sess.create_dataset("Ev", _clustered_table(), dataverse="c", primary="id")
    df = AFrame("c", "Ev", session=sess)
    assert _range_count(df, "ts", 100, 200) == 101      # block 0: compile
    c0 = sess.stats["compiles"]
    assert _range_count(df, "ts", 300, 420) == 121      # still block 0: hit
    assert sess.stats["compiles"] == c0
    assert sess.stats["hits"] >= 1
    assert _range_count(df, "ts", 8200, 8300) == 101    # block 2: new variant
    assert sess.stats["compiles"] == c0 + 1


def test_shared_scan_object_keeps_branch_constraints_apart():
    """Derived frames share the base frame's Scan OBJECT: a join of two
    differently-filtered views must not alias both branches' predicates
    onto one scan (the optimizer uniquifies the plan into a tree before
    per-occurrence identity keying). Regression: the merged constraints
    ts<=100 AND ts>=8192 would keep zero blocks and count 0."""
    sess = Session(enable_index=False)
    sess.create_dataset("Ev", _clustered_table(), dataverse="sh",
                        primary="id")
    df = AFrame("sh", "Ev", session=sess)
    left = df[df["ts"] <= 100]
    right = df[df["ts"] >= 8192]
    got = len(left.merge(right, left_on="val", right_on="val"))
    sess.enable_block_skip = False
    want = len(left.merge(right, left_on="val", right_on="val"))
    sess.enable_block_skip = True
    assert got == want > 0

    # run-level pruning rides the same constraint map: over a fed dataset
    # the aliased conjuncts would wrongly prune the right branch's run
    sess2, _ = _mutated_fed("gspmd")
    df2 = AFrame("m", "Mut", session=sess2)
    l2 = df2[df2["ts"] <= 100]
    r2 = df2[df2["ts"] >= 20_480]
    got2 = len(l2.merge(r2, left_on="val", right_on="val"))
    sess2.enable_prune = False
    want2 = len(l2.merge(r2, left_on="val", right_on="val"))
    sess2.enable_prune = True
    assert got2 == want2 > 0


def test_no_block_skip_through_positional_operators():
    """A Limit or Window between the filter and the scan consumes rows
    positionally — the outer filter's conjuncts must NOT block-gather the
    scan (regression for the constraint-descent rule)."""
    sess = Session(enable_index=False)
    sess.create_dataset("Ev", _clustered_table(), dataverse="pos",
                        primary="id")
    df = AFrame("pos", "Ev", session=sess)
    cond = (df["ts"] >= 8192).expr

    # Filter(Limit(Scan)): the first 10 rows all have ts < 8192 — skipping
    # to block 2 would wrongly let 10 high-ts rows through
    out = sess.execute(P.Filter(P.Limit(P.Scan("Ev", "pos"), 10), cond))
    assert len(out["ts"]) == 0

    # Filter(Window(Scan)) cumsum: window state accumulates over ALL rows
    # before the filter — gathered blocks would restart the running sum
    wf = df.window(order_by="id").cumsum("val")
    filtered = AFrame._from_plan(wf, P.Filter(wf._plan, cond))
    got = filtered.collect()
    sess.enable_block_skip = False
    want = filtered.collect()
    sess.enable_block_skip = True
    np.testing.assert_array_equal(got["cumsum_val"], want["cumsum_val"])
    assert got["cumsum_val"][0] > 0  # the pre-8192 prefix contributed


# -- mutated, uncompacted datasets -------------------------------------------


def _mutated_fed(mode, **kw):
    """Base keys 0..19999 (clustered); run0 appends 20480..21503; run1
    deletes two keys inside block 2 and upserts one. Tombstones live in
    newer runs whose matter spans never overlap the queried block."""
    sess = _session(mode, enable_index=False, **kw)
    sess.create_dataset("Mut", _clustered_table(), dataverse="m",
                        primary="id")
    feed = Feed(sess, "Mut", "m", flush_rows=10**9,
                policy=lsm.CompactionPolicy(size_ratio=100.0, max_runs=64))
    ids = np.arange(20_480, 21_504, dtype=np.int32)
    feed.push({"id": ids, "ts": ids.copy(),
               "val": np.zeros(len(ids), np.int32)})
    feed.flush()
    feed.delete(np.array([8200, 8300], np.int32))
    feed.upsert({"id": np.array([8400], np.int32),
                 "ts": np.array([8400], np.int32),
                 "val": np.array([7], np.int32)})
    feed.flush()
    return sess, feed


@pytest.mark.parametrize("mode", ["gspmd", "shard_map", "kernel"])
def test_block_skip_mutation_safe_and_tombstones_retained(mode):
    """Skipped blocks in pruned components still contribute tombstones: the
    queried block's matter must shrink by the two deletes (and keep the
    upserted key exactly once), with every other block skipped."""
    sess, feed = _mutated_fed(mode)
    df = AFrame("m", "Mut", session=sess)
    lo, hi = 8192, 8700
    want = (hi - lo + 1) - 2  # two deletes; the upsert replaces, not adds
    got = _range_count(df, "ts", lo, hi)
    assert got == want, (mode, got, want)
    rep = sess.last_prune_report
    assert rep["blocks_skipped"] > 0
    sess.enable_block_skip = False
    assert _range_count(df, "ts", lo, hi) == want
    sess.enable_block_skip = True
    feed.compact()
    assert _range_count(df, "ts", lo, hi) == want  # LSM invariant holds


# -- hypothesis: skipped ≡ unskipped, all modes, mutated + compacted ---------


@pytest.fixture(scope="module")
def property_sessions():
    out = {}
    for mode in ("gspmd", "shard_map", "kernel"):
        sess, feed = _mutated_fed(mode)
        out[mode] = sess
    compacted, feed_c = _mutated_fed("gspmd")
    feed_c.compact()
    out["compacted"] = compacted
    # newest-wins oracle over the final key set
    alive = set(range(N)) | set(range(20_480, 21_504))
    alive -= {8200, 8300}
    out["oracle_keys"] = np.array(sorted(alive))
    return out


def test_block_skip_equivalence_property(property_sessions):
    hypothesis = pytest.importorskip("hypothesis")
    given, settings = hypothesis.given, hypothesis.settings
    st = hypothesis.strategies

    @settings(deadline=None, max_examples=12)
    @given(st.integers(0, 22_000), st.integers(0, 3_000))
    def check(lo, width):
        hi = lo + width
        keys = property_sessions["oracle_keys"]
        want = int(((keys >= lo) & (keys <= hi)).sum())
        for label in ("gspmd", "shard_map", "kernel", "compacted"):
            sess = property_sessions[label]
            df = AFrame("m", "Mut", session=sess)
            try:
                for skip in (True, False):
                    sess.enable_block_skip = skip
                    got = _range_count(df, "ts", lo, hi)
                    assert got == want, (label, skip, lo, hi, got, want)
            finally:
                sess.enable_block_skip = True

    check()


# -- explain golden -----------------------------------------------------------


def _normalize(text):
    import re

    text = re.sub(r"\[cost=[^\]]*\]", "[cost]", text)
    text = re.sub(r"cost=[\d,]+", "cost=#", text)
    text = re.sub(r"total estimated cost: [\d,]+", "total estimated cost: #",
                  text)
    return text


GOLDEN_BLOCK_SKIP = """\
KernelRangeCount e.Ev [ts, ts] [filter_count kernel] [blocks 1/5]  [cost]
· zone maps: 1/5 block(s) scanned, 4 skipped — chosen over MaskCount cost=#
total estimated cost: #"""


def test_explain_golden_block_skip_rationale():
    sess = Session(mode="kernel", enable_index=False)
    sess.create_dataset("Ev", _clustered_table(), dataverse="e", primary="id")
    df = AFrame("e", "Ev", session=sess)
    plan = P.Agg(df[(df["ts"] >= 8192) & (df["ts"] <= 8700)]._plan,
                 [P.AggSpec("count", "count", None)])
    assert _normalize(sess.explain(plan)) == GOLDEN_BLOCK_SKIP
    # and the generic stream path renders the same rationale on its scan
    sess2 = Session(mode="gspmd", enable_index=False)
    sess2.create_dataset("Ev", _clustered_table(), dataverse="e",
                         primary="id")
    df2 = AFrame("e", "Ev", session=sess2)
    text = sess2.explain(P.Agg(
        df2[(df2["ts"] >= 8192) & (df2["ts"] <= 8700)]._plan,
        [P.AggSpec("count", "count", None)]))
    assert "[blocks 1/5]" in text
    assert "zone maps: 1/5 block(s) scanned, 4 skipped" in text


# -- point-lookup fast path ---------------------------------------------------


def test_point_lookup_newest_wins_anti_matter_aware():
    sess, feed = _mutated_fed("gspmd")
    df = AFrame("m", "Mut", session=sess)
    compiles = sess.stats["compiles"]

    row = df.get(123)                      # base matter
    assert row["val"].shape == (1,) and int(row["id"][0]) == 123
    assert isinstance(sess.last_physical, PH.PointLookup)

    assert df.get(8200) is None            # deleted by run1's tombstone
    assert "anti-matter" in sess.last_physical.note

    row = df.get(8400)                     # upserted: run1's matter wins
    assert int(row["val"][0]) == 7 and row["val"].shape == (1,)

    row = df.get(20_500)                   # run0 matter
    assert int(row["ts"][0]) == 20_500

    assert df.get(10**8) is None           # absent everywhere
    assert sess.last_physical.probed == 0  # every span short-circuited

    assert sess.stats["compiles"] == compiles  # never touched the query path
    text = df.explain_get(8400)
    assert "PointLookup" in text and "newest-wins" in text
    # after compaction the same lookups resolve from the folded base
    feed.compact()
    assert df.get(8200) is None
    assert int(df.get(8400)["val"][0]) == 7


def test_point_lookup_requires_primary_and_bare_frame():
    sess = Session()
    t = _clustered_table(1000)
    sess.create_dataset("NoPk", t, dataverse="p")
    df = AFrame("p", "NoPk", session=sess)
    with pytest.raises(ValueError, match="primary"):
        df.get(5)
    sess.create_dataset("Pk", t, dataverse="p", primary="id")
    df2 = AFrame("p", "Pk", session=sess)
    with pytest.raises(ValueError, match="point lookup"):
        df2[df2["val"] >= 0].get(5)


# -- float zone maps ----------------------------------------------------------


def test_float_zone_maps_nan_safe_with_empty_sentinel():
    """Float columns harvest NaN-safe per-block spans; all-NaN (and pad)
    blocks carry the [+inf, -inf] empty sentinel, so they fail every
    predicate test and are always skipped."""
    from repro.core.stats import harvest_block_zones

    n = 2 * ZONE_BLOCK_ROWS + 100  # trailing partial block
    ids = np.arange(n, dtype=np.int32)
    fts = ids.astype(np.float32)
    fts[0] = np.nan                          # dead row must not widen block 0
    fts[ZONE_BLOCK_ROWS:2 * ZONE_BLOCK_ROWS] = np.nan  # block 1 all dead
    bz = harvest_block_zones(Table({"id": ids, "fts": fts}))
    sp = np.asarray(bz.span_of("fts"))
    assert sp.shape == (3, 2)
    assert not np.isnan(sp).any()
    assert sp[0, 0] == 1.0 and sp[0, 1] == float(ZONE_BLOCK_ROWS - 1)
    assert sp[1, 0] == np.inf and sp[1, 1] == -np.inf  # empty sentinel
    assert sp[2, 0] == float(2 * ZONE_BLOCK_ROWS)
    assert sp[2, 1] == float(n - 1)


@pytest.mark.parametrize("mode", ["gspmd", "shard_map"])
def test_float_block_skip_matches_unskipped(mode):
    """A range predicate over a clustered FLOAT column prunes blocks off the
    float zone maps and stays bit-identical to the unskipped scan — NaN rows
    simply never match."""
    rng = np.random.default_rng(9)
    ids = np.arange(N, dtype=np.int32)
    fts = ids.astype(np.float32)
    fts[7] = np.nan  # a dead row inside block 0
    t = Table({"id": ids, "fts": fts,
               "val": rng.integers(0, 100, N).astype(np.int32)})
    sess = _session(mode, enable_index=False)
    sess.create_dataset("Ev", t, dataverse="f", primary="id")
    df = AFrame("f", "Ev", session=sess)
    got = _range_count(df, "fts", 8192.0, 8700.0)
    rep = sess.last_prune_report
    assert got == 509
    assert rep["blocks_scanned"] == 1 and rep["blocks_skipped"] == 4
    sess.enable_block_skip = False
    assert _range_count(df, "fts", 8192.0, 8700.0) == got
    sess.enable_block_skip = True
    # the NaN row is invisible to every range — including one over block 0
    assert _range_count(df, "fts", 0.0, 100.0) == 100


# -- sharded pruning (8 simulated devices, subprocess) ------------------------


_SHARDED_PRELUDE = """
import numpy as np
from repro.core.frame import AFrame
from repro.engine import lsm
from repro.engine.ingest import Feed
from repro.engine.session import Session
from repro.engine.table import Table
from repro.launch.mesh import make_local_mesh

N = 20_000

def clustered(n=N, seed=0):
    rng = np.random.default_rng(seed)
    ids = np.arange(n, dtype=np.int32)
    return Table({"id": ids, "ts": ids.copy(),
                  "val": rng.integers(0, 100, n).astype(np.int32)})

def mutated(sess):
    sess.create_dataset("Mut", clustered(), dataverse="m", primary="id")
    feed = Feed(sess, "Mut", "m", flush_rows=10**9,
                policy=lsm.CompactionPolicy(size_ratio=100.0, max_runs=64))
    ids = np.arange(20_480, 21_504, dtype=np.int32)
    feed.push({"id": ids, "ts": ids.copy(),
               "val": np.zeros(len(ids), np.int32)})
    feed.flush()
    feed.delete(np.array([8200, 8300], np.int32))
    feed.upsert({"id": np.array([8400], np.int32),
                 "ts": np.array([8400], np.int32),
                 "val": np.array([7], np.int32)})
    feed.flush()
    return sess

def rc(df, lo, hi):
    return len(df[(df["ts"] >= lo) & (df["ts"] <= hi)])
"""


def test_sharded_block_skip_equivalence_property():
    """The acceptance property on an 8-shard mesh: sharded-with-block-skip ≡
    unsharded ≡ skip-disabled in all three modes over a mutated,
    uncompacted dataset (hypothesis sweeps the predicate range), and the
    per-shard kernel grids provably skip blocks. Hypothesis drives the
    sweep when installed; otherwise a deterministic grid covers the same
    boundary cases (block edges, shard edges, run spans, empty ranges)."""
    from test_distributed import run_script

    run_script(_SHARDED_PRELUDE + """
sessions = {"unsharded": mutated(Session(enable_index=False))}
for mode in ("gspmd", "shard_map", "kernel"):
    sessions[mode] = mutated(Session(mesh=make_local_mesh(data=8, model=1),
                                     mode=mode, enable_index=False))

alive = (set(range(N)) | set(range(20_480, 21_504))) - {8200, 8300}
keys = np.array(sorted(alive))

def check_one(qlo, qw):
    lo, hi = qlo * 512, (qlo + qw) * 512
    want = int(((keys >= lo) & (keys <= hi)).sum())
    for label, sess in sessions.items():
        df = AFrame("m", "Mut", session=sess)
        try:
            for skip in (True, False):
                sess.enable_block_skip = skip
                got = rc(df, lo, hi)
                assert got == want, (label, skip, lo, hi, got, want)
        finally:
            sess.enable_block_skip = True

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    # deterministic boundary grid: shard edges (2500-row partitions land at
    # 512-multiples nearby), zone-block edges, the appended run's span, the
    # tombstoned block, and off-the-end empties
    for qlo, qw in [(0, 0), (0, 6), (4, 1), (7, 3), (15, 4), (16, 0),
                    (16, 6), (19, 2), (38, 5), (40, 3), (43, 6)]:
        check_one(qlo, qw)
else:
    @settings(deadline=None, max_examples=8, database=None)
    @given(st.integers(0, 43), st.integers(0, 6))
    def check(qlo, qw):
        check_one(qlo, qw)

    check()

# a 1-block-selective predicate on the 8-shard mesh provably skips: the base
# lays out 8 per-shard blocks and only the owning shard's block is scanned
k = sessions["kernel"]
df = AFrame("m", "Mut", session=k)
assert rc(df, 8192, 8700) == 507
rep = k.last_prune_report
assert rep["blocks_skipped"] > 0, rep
from repro.runtime import telemetry as tel
assert (tel.counter_value("kernel.blocks_skipped_total",
                          kernel="filter_count") or 0) > 0
print("OK")
""")


def test_sharded_point_lookup_routes_to_owning_shard():
    """``get(key)`` on an 8-shard mesh searches only the owning row
    partition's slice of the clustered key copy — and stays newest-wins
    correct against tombstoned and upserted keys."""
    from test_distributed import run_script

    run_script(_SHARDED_PRELUDE + """
sess = mutated(Session(mesh=make_local_mesh(data=8, model=1),
                       mode="gspmd", enable_index=False))
df = AFrame("m", "Mut", session=sess)

row = df.get(123)                         # base matter, shard 0
assert int(row["id"][0]) == 123
ph = sess.last_physical
assert ph.shards == 8, ph.shards          # base laid out over the mesh
assert 1 <= ph.shard_probes < ph.probed * 8, (ph.probed, ph.shard_probes)
rep = sess.last_prune_report
assert rep["shards"] == 8 and rep["shard_probes"] >= 1
assert "shard-routed" in ph.label()

assert df.get(8200) is None               # run1 tombstone still annihilates
assert "anti-matter" in sess.last_physical.note
assert int(df.get(8400)["val"][0]) == 7   # upserted matter wins
assert int(df.get(20_500)["ts"][0]) == 20_500  # run0 matter
assert df.get(10**8) is None              # absent: every span short-circuits
assert sess.last_physical.probed == 0
print("OK")
""")


# -- read-amplification cost term ---------------------------------------------


def test_read_amp_recommends_compaction():
    """Enough components (or tombstone mass) per query → the planner's
    read-amplification term flags it in explain() and the prune report."""
    sess = Session(enable_index=False)
    sess.create_dataset("Amp", _clustered_table(4096), dataverse="r",
                        primary="id")
    feed = Feed(sess, "Amp", "r", flush_rows=10**9,
                policy=lsm.CompactionPolicy(size_ratio=100.0, max_runs=64))
    for i in range(8):  # 8 runs + base > READ_AMP_COMPONENTS
        ids = np.arange(5000 + i * 100, 5100 + i * 100, dtype=np.int32)
        feed.push({"id": ids, "ts": ids.copy(),
                   "val": np.zeros(100, np.int32)})
        feed.flush()
    df = AFrame("r", "Amp", session=sess)
    plan = P.Agg(df[(df["val"] >= 0) & (df["val"] <= 100)]._plan,
                 [P.AggSpec("count", "count", None)])
    text = sess.explain(plan)
    assert "compaction recommended" in text
    assert "read amplification" in text
    _range_count(df, "val", 0, 100)
    assert sess.last_prune_report["compaction_recommended"]
    # a freshly compacted dataset does not nag
    feed.compact()
    assert "compaction recommended" not in sess.explain(plan)


def test_sharded_string_fastpath_equivalence():
    """PR 9 string lanes on an 8-shard mesh: string ==/IN/group-by over a
    fed, mutated, UNCOMPACTED dataset stay bit-identical across all three
    modes and equal to the unsharded session, with skip on and off; a
    selective string equality provably skips per-shard blocks."""
    from test_distributed import run_script

    run_script("""
import numpy as np
from repro.core.frame import AFrame
from repro.data import wisconsin
from repro.engine import lsm
from repro.engine.ingest import Feed
from repro.engine.session import Session
from repro.engine.table import decode_strings
from repro.launch.mesh import make_local_mesh

DEFERRED = lsm.CompactionPolicy(size_ratio=10.0, max_runs=64)
BASE, PUSH = 20_000, 1_024

def rows_of(n, seed, lo):
    t = wisconsin.generate(n, seed=seed)
    r = {k: np.asarray(v) for k, v in t.columns.items()}
    r["unique2"] = np.arange(lo, lo + n, dtype=r["unique2"].dtype)
    return r

def build(sess):
    sess.create_dataset("S", wisconsin.generate(BASE, seed=5),
                        dataverse="s8", primary="unique2")
    feed = Feed(sess, "S", "s8", flush_rows=10**9, policy=DEFERRED)
    feed.push(rows_of(PUSH, 31, BASE))
    feed.flush()
    feed.upsert(rows_of(200, 77, 500))
    feed.delete(np.arange(0, 128, dtype=np.int64))
    feed.flush()
    return sess

def probe(sess):
    df = AFrame("s8", "S", session=sess)
    g = df.groupby("string4").agg({"four": "sum"})
    return (len(df[df["string4"] == "OOOOxxxx"]),
            len(df[df["string4"].isin(["AAAAxxxx", "VVVVxxxx", "no"])]),
            tuple(decode_strings(np.asarray(g["string4"]))),
            tuple(np.asarray(g["sum_four"]).tolist()),
            str(np.asarray(g["sum_four"]).dtype))

sessions = {"unsharded": build(Session(enable_index=False))}
for mode in ("gspmd", "shard_map", "kernel"):
    sessions[mode] = build(Session(mesh=make_local_mesh(data=8, model=1),
                                   mode=mode, enable_index=False))
want = probe(sessions["unsharded"])
for label, sess in sessions.items():
    try:
        for skip in (True, False):
            sess.enable_block_skip = skip
            got = probe(sess)
            assert got == want, (label, skip, got, want)
    finally:
        sess.enable_block_skip = True

# a CLUSTERED string column on the 8-shard mesh: a selective equality
# scans only the blocks whose dict-id/prefix zones can hold the literal
from repro.engine.table import Table, encode_strings
k = sessions["kernel"]
n2 = 32_768  # 8 shards x 4096: one zone block per shard
tags = ["T%02d" % (i // 4096) for i in range(n2)]
k.create_dataset("CL", Table({"k": np.arange(n2, dtype=np.int32),
                              "tag": encode_strings(tags)}),
                 dataverse="s8", primary="k")
dfc = AFrame("s8", "CL", session=k)
assert len(dfc[dfc["tag"] == "T03"]) == 4096
rep = k.last_prune_report
assert rep["shards"] == 8, rep
assert rep["blocks_skipped"] > 0, rep
from repro.runtime import telemetry as tel
assert (tel.counter_value("kernel.blocks_skipped_total",
                          kernel="filter_count") or 0) > 0
# compaction (dict-id remap on the merged component) moves nothing
for label, sess in sessions.items():
    Feed(sess, "S", "s8", flush_rows=10**9, policy=DEFERRED).compact()
    assert probe(sess) == want, label
print("OK")
""")
