"""Serving-path specifics: zamba2 sliding-window ring cache past the wrap
point, long-context decode state stability, and MoE decode capacity floor."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.registry import get_api


def test_hybrid_ring_buffer_wraps_correctly():
    """With window W < context, decode logits must match a model whose
    window covers the same tokens — checked by teacher-forcing the same
    sequence through prefill+decode vs prefill-at-once."""
    base = get_config("zamba2-1.2b").reduced()
    cfg = dataclasses.replace(base, sliding_window=8)  # tiny ring
    api = get_api(cfg)
    params = api.init(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, 21), 0, cfg.vocab)

    # path 1: prefill all 21 tokens (blocked SWA attention)
    _, logits_full = api.prefill(params, {"tokens": toks}, cfg, 24)

    # path 2: prefill 12, then decode 9 tokens teacher-forced (ring wraps:
    # pos 12..20 with W=8 overwrites slots)
    cache, _ = api.prefill(params, {"tokens": toks[:, :12]}, cfg, 24)
    logits_dec = None
    for t in range(12, 21):
        cache, logits_dec = api.decode(params, cache, toks[:, t:t + 1], cfg)
    d = float(jnp.max(jnp.abs(logits_full[:, -1] - logits_dec[:, -1])))
    assert d < 0.1, d


def test_rwkv_long_decode_state_stable():
    """1k decode steps: state norms stay bounded (no blow-up — the property
    long_500k relies on)."""
    cfg = get_config("rwkv6-1.6b").reduced()
    api = get_api(cfg)
    params = api.init(jax.random.key(0), cfg)
    cache = api.make_cache(cfg, 1, 8)
    tok = jnp.zeros((1, 1), jnp.int32)
    step = jax.jit(lambda c, t: api.decode(params, c, t, cfg))
    for i in range(50):
        cache, logits = step(cache, tok)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    assert np.isfinite(np.asarray(logits)).all()
    assert float(jnp.max(jnp.abs(cache["att_state"]))) < 1e4


def test_moe_decode_capacity_floor_no_crash():
    """Tiny decode batches (T*k << E) must not zero-capacity crash."""
    cfg = get_config("deepseek-moe-16b").reduced()
    api = get_api(cfg)
    params = api.init(jax.random.key(0), cfg)
    cache, _ = api.prefill(params, {"tokens": jnp.zeros((1, 4), jnp.int32)}, cfg, 8)
    cache, logits = api.decode(params, cache, jnp.zeros((1, 1), jnp.int32), cfg)
    assert np.isfinite(np.asarray(logits)).all()


def test_whisper_cross_attention_consistency():
    """Decode cross-attn over the cached encoder KV == prefill cross-attn."""
    cfg = get_config("whisper-base").reduced()
    api = get_api(cfg)
    params = api.init(jax.random.key(0), cfg)
    B = 2
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (B, 9), 0, cfg.vocab),
        "frames": jax.random.normal(jax.random.key(2), (B, cfg.enc_len, cfg.d_model),
                                    jnp.bfloat16),
    }
    _, logits_full = api.prefill(params, batch, cfg, 12)
    part = dict(batch)
    part["tokens"] = batch["tokens"][:, :8]
    cache, _ = api.prefill(params, part, cfg, 12)
    cache, logits_dec = api.decode(params, cache, batch["tokens"][:, 8:9], cfg)
    d = float(jnp.max(jnp.abs(logits_full[:, -1] - logits_dec[:, -1])))
    assert d < 0.1, d


def test_decode_cache_update_variants_agree():
    """onehot vs dus cache updates produce identical decode logits."""
    cfg0 = get_config("qwen3-1.7b").reduced()
    api = get_api(cfg0)
    params = api.init(jax.random.key(0), cfg0)
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg0.vocab)
    outs = {}
    for mode in ("onehot", "dus"):
        cfg = dataclasses.replace(cfg0, decode_cache_update=mode)
        cache, _ = api.prefill(params, {"tokens": toks}, cfg, 16)
        cache, logits = api.decode(params, cache, jnp.ones((2, 1), jnp.int32), cfg)
        outs[mode] = np.asarray(logits)
    np.testing.assert_allclose(outs["onehot"], outs["dus"], rtol=1e-3, atol=1e-3)


def test_flash_impl_serve_matches_blocked():
    cfg0 = get_config("qwen3-1.7b").reduced()
    api = get_api(cfg0)
    params = api.init(jax.random.key(0), cfg0)
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg0.vocab)
    cfgf = dataclasses.replace(cfg0, attn_impl="flash")
    _, l_b = api.prefill(params, {"tokens": toks}, cfg0, 16)
    _, l_f = api.prefill(params, {"tokens": toks}, cfgf, 16)
    np.testing.assert_allclose(np.asarray(l_b), np.asarray(l_f), rtol=5e-2, atol=5e-2)
