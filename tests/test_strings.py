"""String fast-path tests (the PR 9 tentpole): every string column carries
two derived integer lanes — an order-preserving big-endian prefix lane
(int32, zone-map pruning only) and, under the cardinality threshold, a
per-component sorted dictionary-id lane that string ==/IN/group-by lower
onto the existing filter_count/segment_agg kernels through.

The acceptance property: over a fed, MUTATED, uncompacted dataset
(upserts + deletes producing anti-matter runs), string equality, IN, and
group-by are bit-identical across gspmd/shard_map/kernel, match a pure
numpy oracle, and survive both a run merge (dictionary-id remap) and a
full compaction unchanged. Hypothesis drives the literal sweep when
installed; a deterministic grid covers the same cases otherwise."""
import numpy as np
import pytest

from repro.core import physical as PH
from repro.core import plan as P
from repro.core.frame import AFrame
from repro.data import wisconsin
from repro.engine import lsm
from repro.engine.ingest import Feed
from repro.engine.session import Session
from repro.engine.table import (DICT_THRESHOLD, Table, decode_strings,
                                dict_lane_name, encode_strings, pack_prefix,
                                prefix_lane_name)
from repro.kernels import ops

DEFERRED = lsm.CompactionPolicy(size_ratio=10.0, max_runs=64)
MODES = ("gspmd", "shard_map", "kernel")

BASE = 2000
PUSH = 600

_STR4 = ["AAAAxxxx", "HHHHxxxx", "OOOOxxxx", "VVVVxxxx"]


# -- lane unit tests ----------------------------------------------------------


def test_pack_prefix_order_preserving_int32():
    vals = ["", "A", "AAAA", "AAAAzzzz", "HHHH", "ZZZZZZZZ", "aaaa", "zzzz"]
    packed = pack_prefix(encode_strings(vals))
    assert packed.dtype == np.int32
    assert (packed >= 0).all()  # ASCII top bit clear: int32-exact on device
    # big-endian pack is order-preserving over the prefix: the packs of
    # byte-lex-sorted (space-padded) inputs are sorted
    order = np.argsort(packed, kind="stable")
    assert [vals[i] for i in order] == sorted(vals, key=lambda s: s.ljust(4))


def test_lanes_materialize_and_stay_hidden():
    sess = Session()
    t = wisconsin.generate(512, seed=0)
    sess.create_dataset("W", t, dataverse="lane", primary="unique2")
    ds = sess.catalog.get("lane", "W")
    names = ds.table.column_names()
    assert prefix_lane_name("string4") in names
    assert dict_lane_name("string4") in names          # distinct=4 < threshold
    assert prefix_lane_name("stringu1") in names
    assert dict_lane_name("stringu1") not in names     # distinct=512 > 256
    meta = ds.table.meta["string4"]
    assert meta.dict_values == tuple(sorted(set(_STR4[: 4])))
    # lanes never leak into user-visible column lists or row materialization
    df = AFrame("lane", "W", session=sess)
    assert not any(c.startswith("__") for c in df._current_columns())
    assert not any(c.startswith("__") for c in df.head(4))


# -- the acceptance property --------------------------------------------------


def _push_rows(n, seed, key_lo):
    t = wisconsin.generate(n, seed=seed)
    rows = {k: np.asarray(v) for k, v in t.columns.items()}
    rows["unique2"] = np.arange(key_lo, key_lo + n,
                                dtype=rows["unique2"].dtype)
    return rows


def _build(mode):
    """Base + two pushed runs + an upsert run + a delete: the uncompacted
    tree holds anti-matter and per-run dictionaries built independently."""
    sess = Session(mode=mode)
    sess.create_dataset("Live", wisconsin.generate(BASE, seed=3),
                        dataverse="s", primary="unique2")
    feed = Feed(sess, "Live", "s", flush_rows=PUSH, policy=DEFERRED)
    for i in range(2):
        feed.push(_push_rows(PUSH, 20 + i, BASE + i * PUSH))
    feed.upsert(_push_rows(100, 99, 100))
    feed.delete(np.arange(0, 50, dtype=np.int64))
    feed.flush()
    return sess, feed


def _oracle():
    """Pure python/numpy replay of _build's visible rows: key -> row dict."""
    rows = {}

    def absorb(t_rows):
        u2 = np.asarray(t_rows["unique2"])
        s4 = decode_strings(np.asarray(t_rows["string4"]))
        four = np.asarray(t_rows["four"])
        for i, k in enumerate(u2.tolist()):
            rows[k] = {"string4": s4[i], "four": int(four[i])}

    base = wisconsin.generate(BASE, seed=3)
    absorb({k: np.asarray(v) for k, v in base.columns.items()})
    for i in range(2):
        absorb(_push_rows(PUSH, 20 + i, BASE + i * PUSH))
    absorb(_push_rows(100, 99, 100))
    for k in range(0, 50):
        rows.pop(k, None)
    return rows


def _suite(sess, lit, members):
    df = AFrame("s", "Live", session=sess)
    return {
        "eq": len(df[df["string4"] == lit]),
        "eq_miss": len(df[df["string4"] == "ZZZZnope"]),
        "isin": len(df[df["string4"].isin(members)]),
        "group": df.groupby("string4").agg({"four": "sum"}),
        "group_count": df.groupby("string4").agg("count"),
    }


def _assert_equal(a, b, ctx):
    for k, v in a.items():
        w = b[k]
        if isinstance(v, dict):
            assert set(v) == set(w), (ctx, k)
            for c in v:
                x, y = np.asarray(v[c]), np.asarray(w[c])
                assert x.dtype == y.dtype, (ctx, k, c, x.dtype, y.dtype)
                np.testing.assert_array_equal(x, y, err_msg=f"{ctx}:{k}:{c}")
        else:
            assert v == w, (ctx, k, v, w)


def test_string_fastpath_mutated_equivalence_property():
    rows = _oracle()
    vals = np.array([r["string4"] for r in rows.values()])
    fours = np.array([r["four"] for r in rows.values()])
    sessions = {m: _build(m) for m in MODES}

    def check_one(li, mi):
        lit = (_STR4 + ["ZZZZnope"])[li]
        members = [m for j, m in enumerate(_STR4 + ["QQQQnope"])
                   if (mi >> j) & 1]
        want_keys = sorted(set(vals))
        want = {
            "eq": int((vals == lit).sum()),
            "eq_miss": 0,
            "isin": int(np.isin(vals, members).sum()),
            "group": {"string4": np.asarray(encode_strings(want_keys)),
                      "sum_four": np.array([fours[vals == g].sum()
                                            for g in want_keys])},
            "group_count": {"string4": np.asarray(encode_strings(want_keys)),
                            "count": np.array([(vals == g).sum()
                                               for g in want_keys])},
        }
        outs = {}
        for mode, (sess, _) in sessions.items():
            outs[mode] = _suite(sess, lit, members)
            assert outs[mode]["eq"] == want["eq"], (mode, lit)
            assert outs[mode]["eq_miss"] == 0, mode
            assert outs[mode]["isin"] == want["isin"], (mode, members)
            for k in ("group", "group_count"):
                got = outs[mode][k]
                g_keys = decode_strings(np.asarray(got["string4"]))
                assert g_keys == want_keys, (mode, k)
                col = "sum_four" if k == "group" else "count"
                np.testing.assert_array_equal(
                    np.asarray(got[col]).astype(np.int64),
                    want[k][col].astype(np.int64), err_msg=f"{mode}:{k}")
        for m in MODES[1:]:  # bit-identity: values AND dtypes
            _assert_equal(outs[MODES[0]], outs[m], f"gspmd-vs-{m}")

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        for li, mi in [(0, 0), (1, 3), (3, 31), (4, 1), (2, 16), (1, 21)]:
            check_one(li, mi)
    else:
        @settings(deadline=None, max_examples=10, database=None)
        @given(st.integers(0, 4), st.integers(0, 31))
        def check(li, mi):
            check_one(li, mi)

        check()

    # merge the two pushed runs (dictionary-id remap across the merge),
    # then fully compact — results must not move at either step
    before = {m: _suite(s, _STR4[1], _STR4[:2])
              for m, (s, _) in sessions.items()}
    for mode, (sess, feed) in sessions.items():
        ds = sess.catalog.get("s", "Live")
        assert len(ds.manifest.runs) >= 2
        lsm.merge_runs(sess, ds, 0, 2, level=1)
        _assert_equal(before[mode], _suite(sess, _STR4[1], _STR4[:2]),
                      f"{mode}:merged")
        feed.compact()
        _assert_equal(before[mode], _suite(sess, _STR4[1], _STR4[:2]),
                      f"{mode}:compacted")


def test_dict_remap_across_merge_disjoint_dictionaries():
    """Two runs with DISJOINT value sets: the merged run's dictionary is the
    sorted union and both runs' local ids are remapped — equality counts and
    group-bys stay exact through merge and compaction."""
    sess = Session(mode="kernel")
    keys = np.arange(256, dtype=np.int32)
    base = Table({"k": keys, "tag": encode_strings(["mm"] * 256),
                  "v": np.ones(256, np.int32)})
    sess.create_dataset("T", base, dataverse="rm", primary="k")
    feed = Feed(sess, "T", "rm", flush_rows=10**9, policy=DEFERRED)
    for lo, tags in ((1000, ["aa", "bb"]), (2000, ["yy", "zz"])):
        ks = np.arange(lo, lo + 128, dtype=np.int32)
        feed.push({"k": ks, "tag": encode_strings(tags * 64),
                   "v": np.full(128, 2, np.int32)})
        feed.flush()
    df = AFrame("rm", "T", session=sess)

    def probe():
        return (len(df[df["tag"] == "bb"]), len(df[df["tag"] == "mm"]),
                len(df[df["tag"].isin(["aa", "zz", "nope"])]),
                {k: np.asarray(v).tolist()
                 for k, v in df.groupby("tag").agg({"v": "sum"}).items()})

    want = probe()
    assert want[:3] == (64, 256, 128)
    ds = sess.catalog.get("rm", "T")
    lsm.merge_runs(sess, ds, 0, 2, level=1)
    merged = sess.catalog.get("rm", "T").manifest.runs[0]
    md = merged.table.meta["tag"].dict_values
    assert md == ("aa", "bb", "yy", "zz")  # sorted union of disjoint dicts
    assert probe() == want
    feed.compact()
    assert probe() == want


def test_non_canonical_literal_spellings_bind_same_dict_id():
    """A trailing-space literal encodes to the same (16,) row as its
    stripped spelling, so every mode must count it identically — the dict
    binder canonicalizes before the id lookup (a raw-string lookup would
    miss and silently return 0 in kernel mode only). Two IN members that
    canonicalize to the same value count as duplicates, never twice."""
    n = 4 * 4096  # clustered: one tag per 4096-row block, so skipping wins
    tags = [_STR4[i // 4096] for i in range(n)]
    t = Table({"k": np.arange(n, dtype=np.int32),
               "string4": encode_strings(tags)})
    padded = _STR4[2] + "        "  # same encoded row as _STR4[2]
    want_eq = 4096
    for mode in MODES:
        sess = Session(mode=mode)
        sess.create_dataset("P", t, dataverse="pad", closed=True)
        df = AFrame("pad", "P", session=sess)
        assert len(df[df["string4"] == padded]) == want_eq, mode
        dup_in = [padded, _STR4[2], _STR4[0]]  # first two: one member
        assert len(df[df["string4"].isin(dup_in)]) == 2 * want_eq, mode
        if mode == "kernel":
            krc = [nd for nd in PH.walk(sess.last_physical)
                   if isinstance(nd, PH.KernelRangeCount)]
            assert krc and all(dict_lane_name("string4") in nd.cols
                               for nd in krc), mode


# -- kernel lowering + pruning ------------------------------------------------


def test_string_eq_lowers_onto_filter_count_with_block_skip():
    """A selective string equality must take the kernel fast path — lowered
    onto KernelRangeCount over the dict lane, dispatched to filter_count —
    and string-prefix/dict-id zone maps must skip blocks on a clustered
    column."""
    sess = Session(mode="kernel", enable_index=False)
    n = 8192
    ks = np.arange(n, dtype=np.int32)
    # clustered string column: block-sized alternating zones
    tags = ["AA" if (i // 4096) == 0 else "ZZ" for i in range(n)]
    t = Table({"k": ks, "tag": encode_strings(tags),
               "v": np.ones(n, np.int32)})
    sess.create_dataset("C", t, dataverse="bs", primary="k")
    df = AFrame("bs", "C", session=sess)
    ops.reset_dispatch_counts()
    assert len(df[df["tag"] == "ZZ"]) == 4096
    assert ops.DISPATCH_COUNTS.get("filter_count", 0) >= 1
    krcs = [nd for nd in PH.walk(sess.last_physical)
            if isinstance(nd, PH.KernelRangeCount)]
    assert krcs, "string == did not lower onto KernelRangeCount"
    assert any(dict_lane_name("tag") in nd.cols for nd in krcs)
    rep = sess.last_prune_report
    assert rep["blocks_skipped"] > 0, rep  # the all-"AA" block is skipped
    # miss probes don't even need the kernel: dict-id zone spans exclude
    # every block, but the min-one-block guard still scans one
    assert len(df[df["tag"] == "QQ"]) == 0


def test_string_isin_lowers_as_merged_rangecounts():
    """IN over a clustered dict-encoded column: one KernelRangeCount per
    live member id (block skipping discounts each to its own zone), partial
    counts summed — the k-launch plan beats the one-pass mask scan."""
    sess = Session(mode="kernel", enable_index=False)
    n = 12288  # three 4096-row zones: "AA" | "MM" | "ZZ"
    tags = ["AA"] * 4096 + ["MM"] * 4096 + ["ZZ"] * 4096
    t = Table({"k": np.arange(n, dtype=np.int32),
               "tag": encode_strings(tags), "v": np.ones(n, np.int32)})
    sess.create_dataset("C", t, dataverse="ki", primary="k")
    df = AFrame("ki", "C", session=sess)
    ops.reset_dispatch_counts()
    got = len(df[df["tag"].isin(["AA", "ZZ", "missing!"])])
    assert got == 8192
    assert ops.DISPATCH_COUNTS.get("filter_count", 0) >= 2  # one per live id
    ms = [nd for nd in PH.walk(sess.last_physical)
          if isinstance(nd, PH.MergeScalars)]
    assert ms and all(isinstance(c, PH.KernelRangeCount)
                      for c in ms[0].children)
    rep = sess.last_prune_report
    assert rep["blocks_skipped"] > 0, rep  # each member scans its own zone


def test_string_groupby_lowers_onto_segment_agg():
    sess = Session(mode="kernel")
    t = wisconsin.generate(2048, seed=7)
    sess.create_dataset("W", t, dataverse="kg", primary="unique2")
    df = AFrame("kg", "W", session=sess)
    ops.reset_dispatch_counts()
    out = df.groupby("string4").agg({"four": "sum"})
    assert ops.DISPATCH_COUNTS.get("segment_agg", 0) >= 1
    assert decode_strings(np.asarray(out["string4"])) == _STR4
    segs = [nd for nd in PH.walk(sess.last_physical)
            if isinstance(nd, PH.KernelSegmentAgg)]
    assert segs and segs[0].key_values == tuple(_STR4)


def test_string_selectivity_estimates_from_dictionary():
    """Literal-aware selectivity: string4 equality on Wisconsin estimates
    ~n/4 rows from the harvested distinct count, and explain() renders the
    bound dict id beside the literal."""
    sess = Session(mode="kernel", enable_index=False)
    n = 4096
    t = wisconsin.generate(n, seed=1)
    sess.create_dataset("W", t, dataverse="sel", primary="unique2")
    df = AFrame("sel", "W", session=sess)
    plan = P.Agg(df[df["string4"] == "HHHHxxxx"]._plan,
                 [P.AggSpec("count", "count", None)])
    text = sess.explain(plan)
    assert "string4 == 'HHHHxxxx'" in text and "id 1/4" in text
    sess.execute(plan)
    root = sess.last_physical
    krcs = [nd for nd in PH.walk(root)
            if isinstance(nd, PH.KernelRangeCount)]
    assert krcs and abs(krcs[0].est_rows - n / 4) <= n / 16
    # IN estimates k/distinct — and executes exactly
    plan2 = P.Agg(df[df["string4"].isin(_STR4[:2])]._plan,
                  [P.AggSpec("count", "count", None)])
    assert int(sess.execute(plan2)) == n // 2


@pytest.mark.parametrize("mode", MODES)
def test_high_cardinality_prefix_pruning(mode):
    """Columns past DICT_THRESHOLD get no dict lane, but the prefix lane
    still prunes whole runs: a literal outside a run's prefix span excludes
    it from the scan (visible in prune_report), and results stay exact."""
    assert DICT_THRESHOLD == 256
    sess = Session(mode=mode, enable_index=False)
    mk = lambda lo, pre: Table({
        "k": np.arange(lo, lo + 512, dtype=np.int32),
        "name": encode_strings([f"{pre}{i:05d}" for i in range(512)]),
    })
    sess.create_dataset("H", mk(0, "alpha"), dataverse="pp", primary="k")
    feed = Feed(sess, "H", "pp", flush_rows=10**9, policy=DEFERRED)
    feed.push({k: np.asarray(v) for k, v in mk(5000, "omega").columns.items()})
    feed.flush()
    ds = sess.catalog.get("pp", "H")
    assert dict_lane_name("name") not in ds.table.column_names()
    df = AFrame("pp", "H", session=sess)
    assert len(df[df["name"] == "omega00007"]) == 1
    recs = [pc for nd in PH.walk(sess.last_physical)
            for pc in (getattr(nd, "pruned", None) or ())]
    assert any(pc.column == prefix_lane_name("name") for pc in recs), recs
    assert len(df[df["name"] == "zzzzz"]) == 0
