"""Cost-based physical planning: the unified statistics layer, bind-time
zone-map run pruning, and the three-level plan cache keyed by
(logical fingerprint, stats_epoch, prune signature)."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import physical as PH
from repro.core import plan as P
from repro.core.stats import component_stats, view_stats
from repro.data import wisconsin
from repro.engine import lsm
from repro.engine.ingest import Feed
from repro.engine.session import Session
from repro.engine.table import Table

BASE_ROWS = 3_000
PUSH_ROWS = 600

DEFERRED = lsm.CompactionPolicy(size_ratio=100.0, max_runs=64)  # never auto


def _session(mode, **kw):
    if mode == "shard_map":
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        return Session(mesh=mesh, mode="shard_map", **kw)
    return Session(mode=mode, **kw)


def _fed_session(mode, n_pushes=3, **kw):
    """unique2 keys increase monotonically across pushes, so each run holds a
    disjoint key span — the timestamped-feed pattern zone maps shine on."""
    sess = _session(mode, **kw)
    t = wisconsin.generate(BASE_ROWS, seed=3)
    sess.create_dataset("Live", t, dataverse="d", indexes=["onePercent"],
                        primary="unique2")
    feed = Feed(sess, "Live", "d", flush_rows=PUSH_ROWS, policy=DEFERRED)
    for i in range(n_pushes):
        extra = wisconsin.generate(PUSH_ROWS, seed=20 + i)
        rows = {k: np.asarray(v) for k, v in extra.columns.items()}
        rows["unique2"] = rows["unique2"] + BASE_ROWS + i * PUSH_ROWS
        feed.push(rows)
    return sess, feed


def _run_span(i):
    lo = BASE_ROWS + i * PUSH_ROWS
    return lo, lo + PUSH_ROWS - 1


def _range_count(df, lo, hi):
    return len(df[(df["unique2"] >= lo) & (df["unique2"] <= hi)])


# -- unified statistics layer ------------------------------------------------


def test_stats_harvested_uniformly_from_base_runs_and_views():
    sess, feed = _fed_session("gspmd")
    base = component_stats(sess.catalog, "d", "Live")
    assert base.kind == "dataset" and base.rows == BASE_ROWS
    assert base.span("unique2") == (0, BASE_ROWS - 1)
    assert base.index_on("onePercent") == "secondary"
    assert base.index_on("unique2") == "primary"
    run = component_stats(sess.catalog, "d", "Live@run1")
    assert run.kind == "run" and run.rows == PUSH_ROWS
    assert run.span("unique2") == _run_span(1)  # the run's zone span
    assert run.padded_rows % lsm.RUN_BLOCK == 0
    assert run.index_on("onePercent") == "secondary"  # built at flush time
    # views harvest through the same shape
    plan = P.GroupAgg(P.Scan("Live", "d"), ["ten"],
                      [P.AggSpec("count", "count", None)])
    view = sess.create_view("by_ten", plan)
    vs = view_stats(view)
    assert vs.kind == "view" and vs.rows == 10
    assert vs.span("ten") == (0, 9)


def test_stats_epoch_bumps_on_ddl_flush_and_compaction():
    sess, feed = _fed_session("gspmd", n_pushes=0)
    e0 = sess.catalog.stats_epoch
    extra = wisconsin.generate(PUSH_ROWS, seed=9)
    rows = {k: np.asarray(v) for k, v in extra.columns.items()}
    rows["unique2"] = rows["unique2"] + BASE_ROWS
    feed.push(rows)  # flush
    e1 = sess.catalog.stats_epoch
    assert e1 > e0
    feed.compact()
    e2 = sess.catalog.stats_epoch
    assert e2 > e1
    sess.create_dataset("Other", wisconsin.generate(100, seed=1), dataverse="d")
    assert sess.catalog.stats_epoch > e2


# -- plan-cache invalidation (regression: stale executables on flush/compact) -


def test_flush_rebinds_pruned_plans_and_compaction_drops_stale_runs():
    """A cached executable bakes in the LSM component set; flushing must
    rebind (the new run's rows must be visible) and compaction must never
    let a stale plan read a dropped run."""
    sess, feed = _fed_session("gspmd", n_pushes=1)
    df_lo, df_hi = _run_span(0)
    df = __import__("repro.core.frame", fromlist=["AFrame"]).AFrame(
        "d", "Live", session=sess)
    assert _range_count(df, df_lo, df_hi) == PUSH_ROWS
    assert sess.last_prune_report["pruned"] == 1  # base pruned, run0 probed
    compiles0 = sess.stats["compiles"]

    # flush a second run: epoch bump forces a rebind; the same query now
    # sees three components and still prunes down to run0
    extra = wisconsin.generate(PUSH_ROWS, seed=21)
    rows = {k: np.asarray(v) for k, v in extra.columns.items()}
    rows["unique2"] = rows["unique2"] + BASE_ROWS + PUSH_ROWS
    feed.push(rows)
    assert _range_count(df, df_lo, df_hi) == PUSH_ROWS
    assert sess.stats["compiles"] > compiles0  # stale executable not reused
    assert sess.last_prune_report["pruned"] == 2
    lo1, hi1 = _run_span(1)
    assert _range_count(df, lo1, hi1) == PUSH_ROWS  # new run's rows visible

    # compaction drops every run: a stale cached executable would KeyError
    # on "Live@run0" — the epoch key makes it unreachable instead
    feed.compact()
    assert not sess.catalog.get("d", "Live").runs
    assert _range_count(df, df_lo, df_hi) == PUSH_ROWS
    # no union left: the plan reads the single compacted base, nothing prunes
    assert sess.last_prune_report["components"] == 0
    assert sess.last_prune_report["pruned"] == 0


def test_same_prune_signature_reuses_executable_new_signature_rebinds():
    """Randomized literals that keep the surviving-run set hit the cached
    executable; literals that change which runs the zone maps prune rebuild
    only the physical plan (one compile per signature)."""
    sess, feed = _fed_session("gspmd", n_pushes=2)
    df = __import__("repro.core.frame", fromlist=["AFrame"]).AFrame(
        "d", "Live", session=sess)
    lo0, hi0 = _run_span(0)
    lo1, hi1 = _run_span(1)
    assert _range_count(df, lo0, hi0) == PUSH_ROWS
    compiles0, plans0 = sess.stats["compiles"], sess.stats["plans"]
    # same shape, different literals, SAME surviving set (still only run0)
    assert _range_count(df, lo0 + 5, hi0 - 5) == PUSH_ROWS - 10
    assert sess.stats["compiles"] == compiles0
    assert sess.stats["plans"] == plans0          # planner skipped too
    assert sess.stats["hits"] >= 1
    # different literals, DIFFERENT surviving set (run1): new physical plan
    assert _range_count(df, lo1, hi1) == PUSH_ROWS
    assert sess.stats["plans"] == plans0 + 1
    # ...but the executable is deduplicated by physical fingerprint when the
    # surviving component is the same *shape* (one index probe): it may
    # compile fresh only because the component address differs
    assert sess.last_prune_report["pruned"] == 2


def test_all_components_pruned_keeps_identity_result():
    """A predicate outside every zone span: the planner keeps one component
    so the merged identity (count 0, ±inf extremes) is computed on-device,
    bit-identical to unpruned execution."""
    for prune in (True, False):
        sess, _ = _fed_session("gspmd", n_pushes=2, enable_prune=prune)
        df = __import__("repro.core.frame", fromlist=["AFrame"]).AFrame(
            "d", "Live", session=sess)
        n = _range_count(df, 10_000_000, 10_000_100)
        assert n == 0, prune


# -- pruning equivalence (property): pruned == unpruned in all three modes ---


@pytest.mark.parametrize("mode", ["gspmd", "shard_map", "kernel"])
def test_selective_predicate_prunes_and_matches_unpruned(mode):
    """Acceptance: a selective range predicate over a fed dataset prunes ≥1
    LSM run via zone maps and answers bit-identically to the unpruned
    execution — in every session mode."""
    sess_p, _ = _fed_session(mode, n_pushes=3, enable_prune=True)
    sess_u, _ = _fed_session(mode, n_pushes=3, enable_prune=False)
    from repro.core.frame import AFrame

    dfp = AFrame("d", "Live", session=sess_p)
    dfu = AFrame("d", "Live", session=sess_u)
    lo, hi = _run_span(1)
    got, want = _range_count(dfp, lo, hi), _range_count(dfu, lo, hi)
    assert got == want == PUSH_ROWS
    assert sess_p.last_prune_report["pruned"] >= 1
    assert sess_u.last_prune_report["pruned"] == 0
    # table-producing and grouped families over the same pruned union
    sel_p = dfp[(dfp["unique2"] >= lo) & (dfp["unique2"] <= hi)]
    sel_u = dfu[(dfu["unique2"] >= lo) & (dfu["unique2"] <= hi)]
    for a, b in ((sel_p.sort_values("unique1").head(9),
                  sel_u.sort_values("unique1").head(9)),
                 (sel_p.groupby("ten").agg({"four": "sum"}),
                  sel_u.groupby("ten").agg({"four": "sum"}))):
        assert set(a) == set(b)
        for k in a:
            av, bv = np.asarray(a[k]), np.asarray(b[k])
            assert av.dtype == bv.dtype
            np.testing.assert_array_equal(av, bv, err_msg=f"{mode}:{k}")
    assert sess_p.last_prune_report["pruned"] >= 1  # grouped path pruned too


def test_explain_shows_costed_plan_with_pruned_runs():
    """Acceptance: explain() renders the physical plan with cost estimates
    and the zone-span rationale for every pruned run."""
    sess, _ = _fed_session("gspmd", n_pushes=3)
    from repro.core.frame import AFrame

    df = AFrame("d", "Live", session=sess)
    lo, hi = _run_span(1)
    text = df[(df["unique2"] >= lo) & (df["unique2"] <= hi)].explain()
    assert "PRUNED" in text and "zone span" in text
    assert "cost=" in text and "total estimated cost" in text
    assert text.count("✂") >= 1
    # the scalar count plan shows per-component access paths and the merge
    plan = P.Agg(df[(df["unique2"] >= lo) & (df["unique2"] <= hi)]._plan,
                 [P.AggSpec("count", "count", None)])
    text = sess.explain(plan)
    assert "MergeScalars" in text and "PRUNED" in text


def test_pruning_equivalence_property():
    """Property test over randomized feeds, predicates, and all three modes:
    pruned == unpruned == numpy oracle, whatever the zone spans do."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    modes = ["gspmd", "shard_map", "kernel"]

    batch = st.lists(st.tuples(st.integers(0, 400), st.integers(-50, 50)),
                     min_size=1, max_size=40)

    @settings(max_examples=15, deadline=None)
    @given(st.lists(batch, min_size=1, max_size=4),
           st.integers(-20, 450), st.integers(-20, 450),
           st.integers(0, 2**31 - 1), st.sampled_from(modes))
    def run(batches, a, b, seed, mode):
        lo, hi = min(a, b), max(a, b)
        rng = np.random.default_rng(seed)
        n0 = int(rng.integers(4, 60))
        base = {"k": rng.integers(0, 120, n0).astype(np.int32),
                "v": rng.integers(-50, 51, n0).astype(np.int32)}
        sessions = {}
        for prune in (True, False):
            sess = _session(mode, enable_prune=prune)
            sess.create_dataset("H", Table({k: v.copy() for k, v in base.items()}),
                                dataverse="d")
            feed = Feed(sess, "H", "d", flush_rows=1, policy=DEFERRED)
            for bt in batches:
                feed.push({"k": np.array([x[0] for x in bt], np.int32),
                           "v": np.array([x[1] for x in bt], np.int32)})
            sessions[prune] = sess
        all_k = np.concatenate([base["k"]]
                               + [np.array([x[0] for x in bt], np.int32)
                                  for bt in batches])
        all_v = np.concatenate([base["v"]]
                               + [np.array([x[1] for x in bt], np.int32)
                                  for bt in batches])
        oracle_mask = (all_k >= lo) & (all_k <= hi)
        from repro.core.frame import AFrame

        results = {}
        for prune, sess in sessions.items():
            df = AFrame("d", "H", session=sess)
            sel = df[(df["k"] >= lo) & (df["k"] <= hi)]
            results[prune] = {
                "count": len(sel),
                "sum": sel["v"].sum() if oracle_mask.any() else None,
                "rows": sel.sort_values("v").head(7),
            }
        assert results[True]["count"] == results[False]["count"] \
            == int(oracle_mask.sum())
        if oracle_mask.any():
            assert results[True]["sum"] == results[False]["sum"] \
                == int(all_v[oracle_mask].sum())
        for k in results[True]["rows"]:
            np.testing.assert_array_equal(results[True]["rows"][k],
                                          results[False]["rows"][k])

    run()


def test_renamed_column_never_prunes_or_probes_stored_namesake():
    """Regression: a Project rebinding a stored name (df['k'] = df['v']) must
    not let the pruner test the predicate against the STORED k's zone span,
    nor let the count path probe/kernel-read the stored k — both would
    silently return wrong results."""
    from repro.core.expr import Col
    from repro.core.frame import AFrame

    n = 100
    base = {"k": np.arange(n, dtype=np.int32),             # stored k: 0..99
            "v": np.full(n, 500, dtype=np.int32)}          # actual values: 500
    for mode in ("gspmd", "kernel"):
        sess = _session(mode)
        sess.create_dataset("T", Table(dict(base)), dataverse="d",
                            indexes=["k"])
        feed = Feed(sess, "T", "d", flush_rows=50, policy=DEFERRED)
        feed.push({"k": np.arange(1000, 1050, dtype=np.int32),
                   "v": np.full(50, 500, dtype=np.int32)})
        # rename v AS k, then count k >= 400: every row matches (v == 500)
        plan = P.Agg(
            P.Filter(P.Project(P.Scan("T", "d"),
                               [("k", Col("v"))]),
                     Col("k") >= 400),
            [P.AggSpec("count", "count", None)])
        assert sess.execute(plan) == n + 50, mode
        assert sess.last_prune_report["pruned"] == 0, mode
        # no candidate may have read the stored k by the predicate's name
        assert not any(isinstance(p, (PH.IndexOnlyCount, PH.KernelRangeCount))
                       for p in PH.walk(sess.last_physical)), mode


def test_index_probe_survives_column_pruning_project():
    """Regression: the narrow identity Project that column pruning inserts
    must not cost the streaming filter out of its IndexProbe access path."""
    from repro.core.frame import AFrame

    sess = _session("gspmd")
    sess.create_dataset("W", wisconsin.generate(1_000, seed=1), dataverse="d",
                        indexes=["onePercent"])
    df = AFrame("d", "W", session=sess)
    sel = df[(df["onePercent"] >= 10) & (df["onePercent"] <= 20)]
    out = sel["four"].sum()  # Agg prunes columns → Filter(Project(Scan))
    opt = sess.last_optimized
    assert any(isinstance(n, P.Project) for n in P.walk(opt))  # pruned cols
    probes = [n for n in PH.walk(sess.last_physical)
              if isinstance(n, PH.IndexProbe)]
    assert probes and probes[0].index_col == "onePercent"
    t = wisconsin.generate(1_000, seed=1)
    raw = {k: np.asarray(v) for k, v in t.columns.items()}
    m = (raw["onePercent"] >= 10) & (raw["onePercent"] <= 20)
    assert out == int(raw["four"][m].sum())


# -- cost model / executable sharing -----------------------------------------


def test_point_and_range_share_physical_executable_with_pruning():
    """A point == and a >=/<= range on the same indexed column map to the
    same physical shape; with runs in play, executables are shared per
    (physical fingerprint, epoch) across the prune-signature level."""
    sess, _ = _fed_session("gspmd", n_pushes=1)
    from repro.core.frame import AFrame

    df = AFrame("d", "Live", session=sess)
    n1 = len(df[df["onePercent"] == 7])
    compiles = sess.stats["compiles"]
    n2 = len(df[(df["onePercent"] >= 7) & (df["onePercent"] <= 7)])
    assert n1 == n2
    assert sess.stats["compiles"] == compiles  # physical-fingerprint dedup
    assert sess.stats["hits"] >= 1


def test_compiler_has_no_mode_branches_in_lowerings():
    """Acceptance: mode selection lives in the planner / lowering-strategy
    layer; operator lowerings never branch on the execution mode."""
    import inspect

    from repro.core import compiler

    for fn in (compiler._lower_stream, compiler._lower_groupagg,
               compiler._lower_kernel_segment_agg, compiler._lower_terminal,
               compiler._lower_kernel_range_count,
               compiler._lower_index_only_count, compiler._lower_join_count):
        src = inspect.getsource(fn)
        assert "ctx.mode" not in src and "use_kernels" not in src \
            and "distributed" not in src, fn.__name__
