"""Mutation support (anti-matter records): Feed.upsert/Feed.delete with
newest-wins merge semantics through ingest, storage, planner, compiler, and
materialized views.

The acceptance invariant: every query family over a mutated, UNCOMPACTED
dataset (base ∪ runs with anti-matter) is bit-identical to the result after
compaction, in all three execution modes — including group max/min after the
current extremum was retracted, and with zone-map pruning enabled."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import physical as PH
from repro.core import plan as P
from repro.core.frame import AFrame
from repro.core.stats import harvest
from repro.data import wisconsin
from repro.engine import lsm
from repro.engine.ingest import Feed
from repro.engine.session import Session
from repro.engine.table import Table

BASE_ROWS = 3_000
PUSH_ROWS = 700

DEFERRED = lsm.CompactionPolicy(size_ratio=100.0, max_runs=64)  # never auto


def _session(mode):
    if mode == "shard_map":
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        return Session(mesh=mesh, mode="shard_map")
    return Session(mode=mode)


def _assert_same(a, b, label):
    if isinstance(a, dict):
        assert set(a) == set(b), label
        for k in a:
            av, bv = np.asarray(a[k]), np.asarray(b[k])
            assert av.dtype == bv.dtype, (label, k, av.dtype, bv.dtype)
            np.testing.assert_array_equal(av, bv, err_msg=f"{label}:{k}")
    else:
        assert a == b, (label, a, b)


def _mutated_session(mode):
    """Base + appended run + a mutation run that upserts into both older
    components and deletes the dataset's extremes (scalar max key, group
    extremum rows)."""
    sess = _session(mode)
    t = wisconsin.generate(BASE_ROWS, seed=3)
    sess.create_dataset("Live", t, dataverse="d", indexes=["onePercent"],
                        primary="unique2")
    sess.create_dataset("Dim", wisconsin.generate(500, seed=7), dataverse="d")
    feed = Feed(sess, "Live", "d", flush_rows=10**9, policy=DEFERRED)
    extra = wisconsin.generate(PUSH_ROWS, seed=20)
    rows = {k: np.asarray(v) for k, v in extra.columns.items()}
    rows["unique2"] = rows["unique2"] + BASE_ROWS
    feed.push(rows)
    feed.flush()
    # upsert 150 keys from the base and 50 from run0 with fresh values
    up = wisconsin.generate(200, seed=33)
    up_rows = {k: np.asarray(v) for k, v in up.columns.items()}
    up_rows["unique2"] = np.concatenate([
        np.arange(100, 250, dtype=up_rows["unique2"].dtype),
        np.arange(BASE_ROWS + 10, BASE_ROWS + 60,
                  dtype=up_rows["unique2"].dtype)])
    feed.upsert(up_rows)
    # delete the newest keys (the scalar unique2 max lives in run0) plus a
    # spread of base keys — retracting group extremes along the way
    feed.delete(np.arange(BASE_ROWS + PUSH_ROWS - 40, BASE_ROWS + PUSH_ROWS,
                          dtype=np.int32))
    feed.delete(np.arange(0, 90, 7, dtype=np.int32))
    feed.flush()
    return sess, feed


def _query_suite(sess):
    df = AFrame("d", "Live", session=sess)
    dim = AFrame("d", "Dim", session=sess)
    return {
        "len": len(df),
        "filter_count": len(df[(df["ten"] == 3) & (df["two"] == 1)]),
        "indexed_range": len(df[(df["onePercent"] >= 10) & (df["onePercent"] <= 30)]),
        "primary_range": len(df[(df["unique2"] >= 50) & (df["unique2"] <= 400)]),
        "pruning_range": len(df[(df["unique2"] >= BASE_ROWS + 100)
                                & (df["unique2"] <= BASE_ROWS + 300)]),
        "group_count": df.groupby("ten").agg("count"),
        "group_mix": df.groupby("twenty").agg(
            {"four": "sum", "ten": "mean", "two": "max", "onePercent": "min"}),
        "group_extremes": df.groupby("ten").agg(
            {"unique1": "max", "unique2": "min"}),
        "scalar_max": df["unique2"].max(),
        "scalar_min": df["unique1"].min(),
        "scalar_sum": df["four"].sum(),
        "sort_head": df.sort_values("unique1", ascending=False).head(7),
        "head": df.head(5),
        "join_count": len(df.merge(dim, left_on="unique1", right_on="unique1")),
        "project_head": df[["two", "four", "stringu1"]].head(4),
    }


@pytest.mark.parametrize("mode", ["gspmd", "shard_map", "kernel"])
def test_mutated_queries_identical_before_and_after_compaction(mode):
    """THE acceptance criterion: base ∪ runs with anti-matter answers every
    query family bit-identically to the compacted dataset, zone-map pruning
    on, in all three modes."""
    sess, feed = _mutated_session(mode)
    assert feed.stats["tombstones"] > 0 and feed.stats["compactions"] == 0
    before = _query_suite(sess)
    feed.compact()
    after = _query_suite(sess)
    for k in before:
        _assert_same(before[k], after[k], f"{mode}:{k}")
    # the deleted newest keys are really gone
    assert before["scalar_max"] == BASE_ROWS + PUSH_ROWS - 41


def test_newest_wins_semantics():
    """Upsert replaces all older matter with the key; delete kills every
    occurrence (including duplicates push appended); a re-insert after a
    delete survives; within an upsert batch the LAST row wins."""
    sess = Session()
    k = np.arange(10, dtype=np.int32)
    sess.create_dataset("T", Table({"k": k, "v": (k * 10).astype(np.int32)}),
                        dataverse="d", primary="k")
    feed = Feed(sess, "T", "d", flush_rows=10**9, policy=DEFERRED)
    df = AFrame("d", "T", session=sess)
    # duplicate matter for key 3 via plain push, then upsert kills both
    feed.push({"k": np.array([3, 3], np.int32), "v": np.array([1, 2], np.int32)})
    feed.flush()
    assert len(df[df["k"] == 3]) == 3
    feed.upsert({"k": np.array([3, 3], np.int32),
                 "v": np.array([111, 222], np.int32)})
    feed.flush()
    assert len(df[df["k"] == 3]) == 1
    assert df[df["k"] == 3].collect()["v"].tolist() == [222]  # last wins
    # delete, then re-insert in a later flush: the re-insert survives
    feed.delete(np.array([3], np.int32))
    feed.flush()
    assert len(df[df["k"] == 3]) == 0
    feed.push({"k": np.array([3], np.int32), "v": np.array([9], np.int32)})
    feed.flush()
    assert df[df["k"] == 3].collect()["v"].tolist() == [9]
    # interleaving within ONE buffer normalizes host-side: the delete kills
    # the base row (7, 70) AND the just-buffered push; only the later push
    # survives
    feed.push({"k": np.array([7], np.int32), "v": np.array([700], np.int32)})
    feed.delete(np.array([7], np.int32))
    feed.push({"k": np.array([7], np.int32), "v": np.array([71], np.int32)})
    feed.flush()
    assert df[df["k"] == 7].collect()["v"].tolist() == [71]
    feed.compact()
    assert df[df["k"] == 7].collect()["v"].tolist() == [71]
    assert df[df["k"] == 3].collect()["v"].tolist() == [9]


def test_mutations_require_primary_key():
    sess = Session()
    sess.create_dataset("NoPk", Table({"a": np.arange(5, dtype=np.int32)}),
                        dataverse="d")
    feed = Feed(sess, "NoPk", "d")
    with pytest.raises(ValueError, match="primary key"):
        feed.upsert({"a": np.array([1], np.int32)})
    with pytest.raises(ValueError, match="primary key"):
        feed.delete(np.array([1], np.int32))


def test_delete_key_validation():
    sess = Session()
    sess.create_dataset("T", Table({"k": np.arange(5, dtype=np.int32)}),
                        dataverse="d", primary="k")
    feed = Feed(sess, "T", "d", policy=DEFERRED)
    with pytest.raises(ValueError, match="1-d"):
        feed.delete(np.zeros((2, 2), np.int32))
    with pytest.raises(ValueError, match="lossy narrowing"):
        feed.delete(np.array([2**31 + 7], np.int64))
    feed.delete(np.array([999], np.int64))  # absent key, in-range: fine
    feed.flush()
    assert len(AFrame("d", "T", session=sess)) == 5


def test_pruned_run_anti_matter_still_subtracts():
    """Mutation-safe zone-map pruning: a run whose MATTER span misses the
    predicate is pruned, but its tombstones keep annihilating into older
    surviving components — pruned == unpruned == oracle."""
    k = np.arange(50, dtype=np.int32)
    results = {}
    for prune in (True, False):
        sess = Session(enable_prune=prune)
        sess.create_dataset("Z", Table({"k": k.copy(),
                                        "v": (k * 2).astype(np.int32)}),
                            dataverse="d", primary="k")
        feed = Feed(sess, "Z", "d", flush_rows=10**9, policy=DEFERRED)
        # the run's matter (keys 1000+) misses [0, 10]; its anti-matter
        # (keys 1, 2) annihilates INTO the base inside the range
        feed.delete(np.array([1, 2], np.int32))
        feed.push({"k": np.arange(1000, 1005, dtype=np.int32),
                   "v": np.zeros(5, np.int32)})
        feed.flush()
        df = AFrame("d", "Z", session=sess)
        results[prune] = len(df[(df["k"] >= 0) & (df["k"] <= 10)])
        if prune:
            rep = sess.last_prune_report
            assert rep["pruned"] >= 1, rep
            assert rep["tombstones_retained"] >= 2, rep
            feed.compact()
            assert len(df[(df["k"] >= 0) & (df["k"] <= 10)]) == 9
    assert results[True] == results[False] == 9  # 11 keys minus {1, 2}


def test_subtract_scalars_on_index_only_path():
    """A range count on the PRIMARY key of a shadowed component stays
    index-only: the plan subtracts a ShadowProbeCount instead of falling
    back to a full scan."""
    n = 5_000
    k = np.arange(n, dtype=np.int32)
    sess = Session()
    sess.create_dataset("S", Table({"k": k, "v": (k * 2).astype(np.int32)}),
                        dataverse="d", primary="k")
    feed = Feed(sess, "S", "d", flush_rows=10**9, policy=DEFERRED)
    feed.delete(np.array([5, 6, 7], np.int32))
    # tombstone the same key from TWO different runs: it must subtract once
    feed.flush()
    feed.delete(np.array([7, 8], np.int32))
    feed.flush()
    df = AFrame("d", "S", session=sess)
    assert len(df[(df["k"] >= 0) & (df["k"] <= 10)]) == 7  # 11 - {5,6,7,8}
    phys = sess.last_physical
    subs = [x for x in PH.walk(phys) if isinstance(x, PH.SubtractScalars)]
    probes = [x for x in PH.walk(phys) if isinstance(x, PH.ShadowProbeCount)]
    assert subs and probes
    assert any("anti-matter subtraction" in x.note for x in subs)
    # a count bounded on a NON-primary column must not use the index-only
    # path on the shadowed base (the secondary index cannot see deaths)
    sess2 = Session()
    sess2.create_dataset("S2", Table({"k": k.copy(),
                                      "v": (k % 100).astype(np.int32)}),
                         dataverse="d", primary="k", indexes=["v"])
    feed2 = Feed(sess2, "S2", "d", flush_rows=10**9, policy=DEFERRED)
    feed2.delete(np.array([42], np.int32))  # v=42 row dies
    feed2.flush()
    df2 = AFrame("d", "S2", session=sess2)
    assert len(df2[(df2["v"] >= 40) & (df2["v"] <= 44)]) == 5 * 50 - 1
    base_counts = [x for x in PH.walk(sess2.last_physical)
                   if isinstance(x, PH.IndexOnlyCount) and x.dataset == "S2"]
    assert not base_counts


def test_stats_discount_annihilated_rows():
    """TableStats rows/tombstones/shadowed reflect visibility; should_compact
    sees the discounted burden."""
    n = 1_000
    k = np.arange(n, dtype=np.int32)
    sess = Session()
    sess.create_dataset("D", Table({"k": k, "v": k.copy()}), dataverse="d",
                        primary="k")
    feed = Feed(sess, "D", "d", flush_rows=10**9, policy=DEFERRED)
    feed.delete(np.arange(0, 100, dtype=np.int32))
    feed.flush()
    ds = sess.catalog.get("d", "D")
    assert ds.annihilated_rows == 100
    assert ds.num_live_rows == n - 100
    base_stats = harvest(ds)
    assert base_stats.rows == n - 100 and base_stats.shadowed == 100
    run_stats = harvest(sess.catalog.get("d", "D@run0"))
    assert run_stats.tombstones == 100 and run_stats.rows == 0
    assert len(AFrame("d", "D", session=sess)) == n - 100
    # deleting the same keys again must not double-discount
    feed.delete(np.arange(0, 100, dtype=np.int32))
    feed.flush()
    assert ds.annihilated_rows == 100
    assert len(AFrame("d", "D", session=sess)) == n - 100
    # burden counts tombstones + shadowed base rows: triggers compaction
    # even though visible run rows are zero
    assert lsm.should_compact(ds, lsm.CompactionPolicy(size_ratio=0.2))
    assert not lsm.should_compact(ds, lsm.CompactionPolicy(size_ratio=0.5))


def test_leveled_policy_trigger_boundaries():
    """LeveledCompactionPolicy: level-0 fanin merges, cascades to higher
    levels, size_ratio still forces the full fold, size_ratio=0 degenerates
    to compact-every-flush."""
    def feed_with(policy, n_flushes, base_rows=100, batch=10):
        sess = Session()
        sess.create_dataset(
            "L", Table({"k": np.arange(base_rows, dtype=np.int32),
                        "v": np.zeros(base_rows, np.int32)}),
            dataverse="d", primary="k")
        feed = Feed(sess, "L", "d", flush_rows=batch, policy=policy)
        for i in range(n_flushes):
            feed.push({"k": np.arange(base_rows + i * batch,
                                      base_rows + (i + 1) * batch,
                                      dtype=np.int32),
                       "v": np.zeros(batch, np.int32)})
        return sess, feed

    # below the fanin: no merge
    pol = lsm.LeveledCompactionPolicy(size_ratio=1000.0, max_runs=64,
                                      level0_runs=3, level_ratio=2)
    sess, feed = feed_with(pol, 2)
    assert feed.stats["level_merges"] == 0
    assert [r.level for r in sess.catalog.get("d", "L").runs] == [0, 0]
    # at the fanin boundary: the 3rd level-0 run triggers one merge to L1
    sess, feed = feed_with(pol, 3)
    ds = sess.catalog.get("d", "L")
    assert feed.stats["level_merges"] == 1
    assert [r.level for r in ds.runs] == [1]
    assert ds.runs[0].num_live_rows == 30
    # stable component ids: the merged run gets a FRESH uid (3 follows the
    # three flushed runs 0..2) — addresses are never recycled by compaction
    assert [r.name for r in ds.runs] == ["L@run3"]
    # cascade: 6 flushes -> two L1 runs -> one L2 (level_ratio=2)
    sess, feed = feed_with(pol, 6)
    ds = sess.catalog.get("d", "L")
    assert [r.level for r in ds.runs] == [2]
    assert feed.stats["level_merges"] == 3
    assert len(AFrame("d", "L", session=sess)) == 160
    # size-ratio full fold still fires (60 run rows >= 0.5 * 100 base)
    sess, feed = feed_with(lsm.LeveledCompactionPolicy(
        size_ratio=0.5, max_runs=64, level0_runs=10), 5)
    assert feed.stats["compactions"] == 1
    assert not sess.catalog.get("d", "L").runs
    # size_ratio=0 degenerate mode: compact on every flush
    sess, feed = feed_with(lsm.LeveledCompactionPolicy(size_ratio=0.0), 3)
    assert feed.stats["compactions"] == 3
    assert feed.stats["level_merges"] == 0


def test_leveled_merge_preserves_mutation_results():
    """Level merges drop annihilated matter early but keep the anti-key
    union — query results never change across level merges or the final
    fold."""
    sess = Session()
    n = 200
    sess.create_dataset("M", Table({"k": np.arange(n, dtype=np.int32),
                                    "v": np.arange(n, dtype=np.int32)}),
                        dataverse="d", primary="k")
    pol = lsm.LeveledCompactionPolicy(size_ratio=1000.0, max_runs=64,
                                      level0_runs=2, level_ratio=2)
    feed = Feed(sess, "M", "d", flush_rows=10**9, policy=pol)
    df = AFrame("d", "M", session=sess)
    rng = np.random.default_rng(0)
    expect = {int(k): int(k) for k in range(n)}
    for i in range(6):
        ks = rng.integers(0, n, 5).astype(np.int32)
        if i % 3 == 2:
            feed.delete(ks)
            for kk in ks.tolist():
                expect.pop(kk, None)
        else:
            vs = rng.integers(1000, 2000, 5).astype(np.int32)
            feed.upsert({"k": ks, "v": vs})
            seen = {}
            for kk, vv in zip(ks.tolist(), vs.tolist()):
                seen[kk] = vv  # last occurrence wins
            expect.update(seen)
        feed.flush()
    assert feed.stats["level_merges"] >= 1
    assert len(df) == len(expect)
    assert df["v"].sum() == sum(expect.values())
    got = df.sort_values("k").collect()
    np.testing.assert_array_equal(got["k"], sorted(expect))
    np.testing.assert_array_equal(got["v"],
                                  [expect[kk] for kk in sorted(expect)])
    feed.compact()
    assert len(df) == len(expect) and df["v"].sum() == sum(expect.values())


def test_view_retraction_counts_sums_and_extremes():
    """Materialized views learn retraction: deletes feed negative count/sum
    deltas; a retracted group extremum triggers the exact host recompute;
    the view stays bit-identical to the from-scratch query."""
    sess = Session()
    n = 60
    k = np.arange(n, dtype=np.int32)
    sess.create_dataset("V", Table({"k": k, "g": (k % 4).astype(np.int32),
                                    "v": (k * 2).astype(np.int32)}),
                        dataverse="d", primary="k")
    plan = P.GroupAgg(P.Scan("V", "d"), ["g"], [
        P.AggSpec("count", "count", None),
        P.AggSpec("sum_v", "sum", "v"),
        P.AggSpec("mean_v", "mean", "v"),
        P.AggSpec("max_v", "max", "v"),
        P.AggSpec("min_v", "min", "v")])
    view = sess.create_view("by_g", plan)
    feed = Feed(sess, "V", "d", flush_rows=10**9, policy=DEFERRED)
    # delete group 3's maximum (k=59, v=118) and minimum (k=3, v=6)
    feed.delete(np.array([59, 3], np.int32))
    # upsert group 0's maximum away (k=56: v 112 -> 0) and boost another
    feed.upsert({"k": np.array([56, 8], np.int32),
                 "g": np.array([0, 0], np.int32),
                 "v": np.array([0, 5000], np.int32)})
    feed.flush()
    _assert_same(sess.read_view("by_g"), sess.execute(plan), "retracted_view")
    assert view.stats["retractions"] == 1
    assert view.stats["rows_retracted"] == 4
    assert view.stats["extremum_recomputes"] >= 1
    # compaction must not disturb the view
    feed.compact()
    _assert_same(sess.read_view("by_g"), sess.execute(plan), "post_compact")
    # empty a whole group: count drops to 0 and the group leaves the view,
    # then a re-insert re-aggregates from identity
    feed.delete(np.arange(1, n, 4, dtype=np.int32))  # all of group 1
    feed.flush()
    got = sess.read_view("by_g")
    assert 1 not in np.asarray(got["g"]).tolist()
    _assert_same(got, sess.execute(plan), "emptied_group")
    feed.push({"k": np.array([n + 1], np.int32), "g": np.array([1], np.int32),
               "v": np.array([-7], np.int32)})
    feed.flush()
    _assert_same(sess.read_view("by_g"), sess.execute(plan), "reborn_group")


def test_view_with_predicate_retracts_filtered_rows_only():
    sess = Session()
    n = 40
    k = np.arange(n, dtype=np.int32)
    sess.create_dataset("F", Table({"k": k, "g": (k % 2).astype(np.int32),
                                    "v": k.copy()}),
                        dataverse="d", primary="k")
    df = AFrame("d", "F", session=sess)
    plan = df[df["v"] >= 10].groupby("g").agg_plan({"v": "sum"})
    sess.create_view("f", plan)
    feed = Feed(sess, "F", "d", flush_rows=10**9, policy=DEFERRED)
    feed.delete(np.array([5, 20], np.int32))  # 5 fails the predicate: no-op
    feed.flush()
    _assert_same(sess.read_view("f"), sess.execute(plan), "filtered_retract")


def test_mutation_interleavings_match_newest_wins_oracle():
    """Satellite: hypothesis property test — random interleavings of
    push/upsert/delete/flush/compact against a newest-wins oracle, asserted
    equal across gspmd/shard_map/kernel."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    rows_batch = st.lists(st.tuples(st.integers(0, 30), st.integers(-40, 40)),
                          min_size=1, max_size=6)
    op = st.one_of(
        st.tuples(st.just("push"), rows_batch),
        st.tuples(st.just("upsert"), rows_batch),
        st.tuples(st.just("delete"),
                  st.lists(st.integers(0, 30), min_size=1, max_size=5)),
        st.tuples(st.just("flush"), st.just(None)),
        st.tuples(st.just("compact"), st.just(None)),
    )

    def oracle_apply(rows, kind, payload):
        if kind == "push":
            rows.extend(payload)
        elif kind == "upsert":
            for kk, vv in payload:
                rows[:] = [r for r in rows if r[0] != kk]
                rows.append((kk, vv))
        elif kind == "delete":
            dead = set(payload)
            rows[:] = [r for r in rows if r[0] not in dead]

    @settings(max_examples=12, deadline=None)
    @given(st.lists(op, min_size=1, max_size=7))
    def run(ops):
        base = [(int(kk), int(kk) * 3) for kk in range(8)]
        oracle = list(base)
        engines = {}
        for mode in ("gspmd", "shard_map", "kernel"):
            sess = _session(mode)
            sess.create_dataset(
                "H", Table({"k": np.array([r[0] for r in base], np.int32),
                            "v": np.array([r[1] for r in base], np.int32)}),
                dataverse="d", primary="k")
            engines[mode] = (sess, Feed(sess, "H", "d", flush_rows=10**9,
                                        policy=DEFERRED))
        for kind, payload in ops:
            if kind in ("push", "upsert"):
                batch = {"k": np.array([r[0] for r in payload], np.int32),
                         "v": np.array([r[1] for r in payload], np.int32)}
                for _, feed in engines.values():
                    getattr(feed, kind)({c: a.copy()
                                         for c, a in batch.items()})
            elif kind == "delete":
                for _, feed in engines.values():
                    feed.delete(np.array(payload, np.int32))
            else:
                for _, feed in engines.values():
                    getattr(feed, kind)()
            if kind in ("push", "upsert", "delete"):
                oracle_apply(oracle, kind, payload)
        for _, feed in engines.values():
            feed.flush()
        # newest-wins oracle: multiset of surviving (k, v) pairs
        want = sorted(oracle)
        results = {}
        for mode, (sess, feed) in engines.items():
            df = AFrame("d", "H", session=sess)
            got = df.sort_values("k").collect()
            pairs = sorted(zip(got["k"].tolist(), got["v"].tolist()))
            assert pairs == want, (mode, pairs, want)
            results[mode] = {
                "count_lo": len(df[df["k"] <= 10]),
                "group": df.groupby("k").agg({"v": "max"})
                if want else None,
                "sum": df["v"].sum(),
            }
            feed.compact()
            got2 = df.sort_values("k").collect()
            assert sorted(zip(got2["k"].tolist(),
                              got2["v"].tolist())) == want, mode
        for mode in ("shard_map", "kernel"):
            for key in results["gspmd"]:
                _assert_same(results[mode][key], results["gspmd"][key],
                             f"{mode}:{key}")

    run()


def test_open_dataset_mutations_roundtrip():
    """Open (schema-on-read) datasets widen keys to f32; anti-matter probes
    compare in the widened dtype and stay consistent across compaction."""
    n = 300
    k = np.arange(n, dtype=np.int32)
    sess = Session()
    sess.create_dataset("O", Table({"k": k, "v": (k * 2).astype(np.int32)}),
                        dataverse="d", closed=False, primary="k")
    feed = Feed(sess, "O", "d", flush_rows=10**9, policy=DEFERRED)
    feed.upsert({"k": np.array([10], np.int32), "v": np.array([9999], np.int32)})
    feed.delete(np.array([20, 21], np.int32))
    feed.flush()
    df = AFrame("d", "O", session=sess)
    before = (len(df), df["v"].sum(), df["v"].max())
    assert before[0] == n - 2
    feed.compact()
    after = (len(df), df["v"].sum(), df["v"].max())
    assert before == after
