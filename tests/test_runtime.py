"""Checkpoint / fault-tolerant loop / gradient compression."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.compress import (compress_grads, decompress_grads,
                                    init_error_state)
from repro.runtime.fault import (FailureInjector, FaultTolerantLoop,
                                 TrainLoopConfig)


def test_checkpoint_roundtrip_keepn_crc(tmp_path):
    cm = CheckpointManager(tmp_path, keep=2, async_save=True)
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 3))}}
    for s in (1, 2, 3):
        cm.save(s, jax.tree_util.tree_map(lambda x: x * s, tree))
    cm.wait()
    assert cm.steps() == [2, 3]
    s, t = cm.restore(None, tree)
    assert s == 3
    np.testing.assert_allclose(t["a"], np.arange(10.0) * 3)
    s, t = cm.restore(2, tree)
    np.testing.assert_allclose(t["b"]["c"], np.ones((3, 3)) * 2)


def test_checkpoint_corruption_detected(tmp_path):
    cm = CheckpointManager(tmp_path, keep=2, async_save=False)
    cm.save(1, {"a": jnp.arange(4.0)})
    leaf = tmp_path / "step_1" / "leaf_0.npy"
    a = np.load(leaf)
    a[0] = 999.0
    np.save(leaf, a)
    with pytest.raises(IOError, match="crc"):
        cm.restore(1, {"a": jnp.arange(4.0)})


def test_fault_loop_recovers_and_converges(tmp_path):
    def train_step(params, opt, batch):
        g = 2 * (params - batch["x"].mean())
        params = params - 0.1 * g
        return params, opt, {"loss": jnp.mean((params - batch["x"].mean()) ** 2)}

    def data_factory(start):
        def gen():
            i = start
            while True:
                yield {"x": np.full((4,), 3.0, np.float32)}
                i += 1
        return gen()

    cm = CheckpointManager(tmp_path, keep=3)
    loop = FaultTolerantLoop(train_step, cm, TrainLoopConfig(ckpt_every=5),
                             FailureInjector({7: "node", 12: "nan", 15: "straggler"}))
    p, o, log = loop.run(jnp.asarray(10.0), {}, data_factory, 25)
    assert len(loop.events) == 3
    assert float(log[-1][1]) < 1e-3
    assert log[-1][0] == 24


def test_fault_loop_gives_up_on_persistent_failure(tmp_path):
    def bad_step(params, opt, batch):
        return params, opt, {"loss": jnp.asarray(float("nan"))}

    def data_factory(start):
        def gen():
            while True:
                yield {"x": np.ones((2,), np.float32)}
        return gen()

    cm = CheckpointManager(tmp_path)
    loop = FaultTolerantLoop(bad_step, cm, TrainLoopConfig(max_retries_per_step=2))
    with pytest.raises(RuntimeError, match="giving up"):
        loop.run(jnp.asarray(1.0), {}, data_factory, 5)


def test_compress_error_feedback_accumulates_correctly():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(1000,)), jnp.float32)}
    e = init_error_state(g)
    acc_true = np.zeros(1000)
    acc_q = np.zeros(1000)
    for _ in range(50):
        qs, ss, e = compress_grads(g, e)
        acc_true += np.asarray(g["w"])
        acc_q += np.asarray(decompress_grads(qs, ss)["w"])
    rel = np.abs(acc_true - acc_q).max() / np.abs(acc_true).max()
    assert rel < 1e-2


def test_compress_training_convergence():
    """int8 error-feedback grads still minimize a least-squares problem."""
    rng = np.random.default_rng(1)
    A = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    w = jnp.zeros((16,))
    loss = lambda w: jnp.mean((A @ w - y) ** 2)
    gfn = jax.grad(loss)
    err = init_error_state({"w": w})
    for _ in range(200):
        g = {"w": gfn(w)}
        qs, ss, err = compress_grads(g, err)
        w = w - 0.05 * decompress_grads(qs, ss)["w"]
    w_exact = jnp.linalg.lstsq(A, y)[0]
    assert float(loss(w)) < float(loss(w_exact)) * 1.05


def test_elastic_restore_resharding(tmp_path):
    """Checkpoint written unsharded restores under any sharding (1-device
    degenerate here; the 8-device variant runs in test_distributed.py)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_local_mesh

    cm = CheckpointManager(tmp_path, async_save=False)
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    cm.save(5, tree)
    mesh = make_local_mesh(1, 1)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    step, t = cm.restore(None, tree, shardings=sh)
    assert step == 5
    np.testing.assert_allclose(t["w"], tree["w"])
    assert t["w"].sharding == sh["w"]
