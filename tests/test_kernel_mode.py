"""Kernel execution mode: Session(mode="kernel") must produce bit-identical
results to mode="gspmd" for the paper's 12 Wisconsin expressions, with the
Pallas relational kernels actually on the lowered path (dispatch counters /
plan inspection), plan-cache hits on randomized literals, and graceful
fallback for shapes the kernels don't cover."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import physical as PH
from repro.core import plan as P
from repro.core.expr import param_values
from repro.core.frame import AFrame
from repro.data import wisconsin
from repro.engine.session import Session
from repro.kernels import ops

N_ROWS = 8_192


@pytest.fixture(scope="module")
def table():
    return wisconsin.generate(N_ROWS, seed=5)


@pytest.fixture(scope="module")
def raw(table):
    return {k: np.asarray(v) for k, v in table.columns.items()}


def _session(table, mode, **kw):
    sess = Session(mode=mode, **kw)
    sess.create_dataset("data", table, dataverse="bench", closed=True)
    sess.create_dataset("data_r", table, dataverse="bench", closed=True)
    return sess


@pytest.fixture(scope="module")
def sessions(table):
    return {
        "gspmd": _session(table, "gspmd"),
        "kernel": _session(table, "kernel"),
        "kernel-pallas": _session(table, "kernel", kernel_backend="pallas"),
    }


def _frames(sess):
    return (AFrame("bench", "data", session=sess),
            AFrame("bench", "data_r", session=sess))


# one callable per paper expression; literals come from ``rng`` so repeat
# runs exercise the plan cache with fresh predicate constants.
EXPRESSIONS = {
    "1_count": lambda df, dr, rng: len(df),
    "2_project_head": lambda df, dr, rng: df[["two", "four"]].head(),
    "3_filter_count": lambda df, dr, rng: (lambda x: len(
        df[(df["ten"] == x) & (df["twentyPercent"] == x % 5)
           & (df["two"] == x % 2)]))(int(rng.integers(10))),
    "4_group_count": lambda df, dr, rng: df.groupby("oddOnePercent").agg("count"),
    "5_map_head": lambda df, dr, rng: df["stringu1"].map(str.upper).head(),
    "6_max": lambda df, dr, rng: df["unique1"].max(),
    "7_min": lambda df, dr, rng: df["unique1"].min(),
    "8_group_max": lambda df, dr, rng: df.groupby("twenty")["four"].agg("max"),
    "9_sort_head": lambda df, dr, rng: df.sort_values(
        "unique1", ascending=False).head(),
    "10_select_head": lambda df, dr, rng: df[df["ten"] == int(rng.integers(10))].head(),
    "11_range_count": lambda df, dr, rng: (lambda a, b: len(
        df[(df["onePercent"] >= min(a, b)) & (df["onePercent"] <= max(a, b))]))(
        int(rng.integers(100)), int(rng.integers(100))),
    "12_join_count": lambda df, dr, rng: len(df.merge(
        dr, left_on="unique1", right_on="unique1")),
}


def _assert_same(a, b, label):
    if isinstance(a, dict):
        assert set(a) == set(b), label
        for k in a:
            av, bv = np.asarray(a[k]), np.asarray(b[k])
            assert av.dtype == bv.dtype, (label, k, av.dtype, bv.dtype)
            np.testing.assert_array_equal(av, bv, err_msg=f"{label}:{k}")
    else:
        assert a == b, (label, a, b)


@pytest.mark.parametrize("expr", sorted(EXPRESSIONS))
@pytest.mark.parametrize("mode", ["kernel", "kernel-pallas"])
def test_wisconsin_expressions_bit_identical(sessions, expr, mode):
    """Three rounds with randomized literals: results must match gspmd
    bit-for-bit and later rounds must hit the plan cache."""
    fn = EXPRESSIONS[expr]
    base = sessions["gspmd"]
    sess = sessions[mode]
    for round_ in range(3):
        rng = np.random.default_rng(100 + round_)
        want = fn(*_frames(base), rng)
        rng = np.random.default_rng(100 + round_)
        got = fn(*_frames(sess), rng)
        _assert_same(got, want, f"{expr}[{mode}] round {round_}")


def test_kernels_on_lowered_path(table, raw):
    """Each relational kernel family dispatches when its plan shape runs."""
    sess = _session(table, "kernel")
    df, dr = _frames(sess)
    ops.reset_dispatch_counts()

    len(df[(df["ten"] == 4) & (df["twentyPercent"] == 4) & (df["two"] == 0)])
    assert ops.DISPATCH_COUNTS.get("filter_count", 0) >= 1
    assert isinstance(sess.last_physical, PH.KernelRangeCount)

    df.groupby("oddOnePercent").agg("count")
    assert ops.DISPATCH_COUNTS.get("segment_agg", 0) >= 1

    df.sort_values("unique1", ascending=False).head()
    assert ops.DISPATCH_COUNTS.get("topk", 0) >= 1

    len(df.merge(dr, left_on="unique1", right_on="unique1"))
    assert ops.DISPATCH_COUNTS.get("merge_join_count", 0) >= 1


def test_plan_cache_hits_on_literal_changes(table, raw):
    """Randomized predicate literals reuse the executable AND skip the
    optimizer entirely (the raw-fingerprint plan cache)."""
    sess = _session(table, "kernel")
    df, _ = _frames(sess)
    for x in (1, 7, 3):
        n = len(df[(df["ten"] == x) & (df["twentyPercent"] == x % 5)
                   & (df["two"] == x % 2)])
        assert n == int(((raw["ten"] == x) & (raw["twentyPercent"] == x % 5)
                         & (raw["two"] == x % 2)).sum())
    assert sess.stats["compiles"] == 1
    assert sess.stats["hits"] == 2
    assert sess.stats["optimizes"] == 1  # later rounds never saw the optimizer


def test_point_and_range_share_fused_executable(table, raw):
    """== and >=/<= conjuncts on the same column list rewrite to one
    FusedRangeCount shape: bounds are runtime params, so both predicates
    share a single compiled kernel program."""
    sess = _session(table, "kernel")
    df, _ = _frames(sess)
    n_eq = len(df[df["onePercent"] == 3])
    assert n_eq == int((raw["onePercent"] == 3).sum())
    n_rng = len(df[(df["onePercent"] >= 10) & (df["onePercent"] <= 12)])
    assert n_rng == int(((raw["onePercent"] >= 10) & (raw["onePercent"] <= 12)).sum())
    # the range query has 2 conjuncts vs 1: different shape, new executable;
    # but == vs another == on the same column hits.
    n_eq2 = len(df[df["onePercent"] == 77])
    assert n_eq2 == int((raw["onePercent"] == 77).sum())
    assert sess.stats["compiles"] == 2  # eq-shape + range-shape


def test_graceful_fallback_non_range_predicates(table, raw):
    """OR / != / strict bounds / string equality stay on the generic mask
    path (FilterCount), still correct."""
    sess = _session(table, "kernel")
    df, _ = _frames(sess)

    n = len(df[(df["ten"] == 3) | (df["two"] == 0)])
    assert n == int(((raw["ten"] == 3) | (raw["two"] == 0)).sum())
    assert isinstance(sess.last_physical, PH.MaskCount)

    n = len(df[df["ten"] != 3])
    assert n == int((raw["ten"] != 3).sum())
    assert isinstance(sess.last_physical, PH.MaskCount)

    n = len(df[df["onePercent"] < 10])
    assert n == int((raw["onePercent"] < 10).sum())
    assert isinstance(sess.last_physical, PH.MaskCount)


def test_index_still_wins_over_kernel_fusion(table, raw):
    """An indexed range predicate keeps the index-only count path — kernel
    fusion only picks up what the index rules leave behind."""
    sess = Session(mode="kernel")
    sess.create_dataset("data", table, dataverse="ix", closed=True,
                        indexes=["onePercent"])
    df = AFrame("ix", "data", session=sess)
    n = len(df[(df["onePercent"] >= 10) & (df["onePercent"] <= 30)])
    assert n == int(((raw["onePercent"] >= 10) & (raw["onePercent"] <= 30)).sum())
    assert isinstance(sess.last_physical, PH.IndexOnlyCount)
    assert "chosen over" in sess.last_physical.note  # beat the kernel on cost


def test_fused_count_jaxpr_has_no_mask_column(table):
    """The acceptance property: the fused COUNT path materializes no
    intermediate boolean mask column — every predicate comparison lives
    inside the pallas_call."""
    sess = _session(table, "kernel", kernel_backend="pallas")
    df, _ = _frames(sess)
    len(df[(df["ten"] == 2) & (df["two"] == 0)])

    fused = [(key, cq) for key, cq in sess._compiled.items()
             if key[0].startswith("p:krangecount")]
    assert fused, "no fused executable compiled"

    def walk_eqns(jaxpr):
        for e in jaxpr.eqns:
            yield e
            if e.primitive.name == "pallas_call":
                continue  # inside the kernel masks are VMEM-resident
            for v in e.params.values():
                if hasattr(v, "jaxpr"):
                    yield from walk_eqns(v.jaxpr)

    for fp, cq in fused:
        tables = cq.gather_tables(sess.catalog)
        jaxpr = jax.make_jaxpr(cq.raw_fn)(tables, param_values(cq.lits))
        eqns = list(walk_eqns(jaxpr.jaxpr))
        prims = {e.primitive.name for e in eqns}
        assert "pallas_call" in prims
        mask_vecs = [v for e in eqns for v in e.outvars
                     if getattr(v.aval, "dtype", None) == jnp.bool_
                     and getattr(v.aval, "ndim", 0) >= 1]
        assert not mask_vecs, f"mask columns materialized: {mask_vecs}"


def test_group_sum_overflow_falls_back_exactly(table, raw):
    """f32 one-hot-matmul sums are only fused when catalog bounds prove the
    group sums stay under 2^24; unique1 at 8192 rows can sum to ~33M, so the
    kernel mode must take the generic native-int path and match gspmd
    exactly (regression: silent f32 rounding of large integer sums)."""
    results = {}
    ops.reset_dispatch_counts()
    for mode in ("gspmd", "kernel"):
        sess = _session(table, mode)
        df, _ = _frames(sess)
        results[mode] = df.groupby("two")["unique1"].agg("sum")
    assert ops.DISPATCH_COUNTS.get("segment_agg", 0) == 0  # gate refused
    np.testing.assert_array_equal(results["gspmd"]["sum_unique1"],
                                  results["kernel"]["sum_unique1"])
    want = [int(raw["unique1"][raw["two"] == v].sum()) for v in range(2)]
    np.testing.assert_array_equal(results["kernel"]["sum_unique1"], want)


def test_int32_unsafe_columns_fall_back(raw):
    """Columns whose catalog bounds exceed int32 (an int64 deployment) must
    not reach the int32-tile kernels — fused count and kernel join both
    refuse and take the generic path."""
    from repro.engine.table import ColumnMeta, Table

    n = 2_000
    vals = np.arange(n, dtype=np.int64)
    t = Table({"k": vals, "ten": (vals % 10).astype(np.int32)},
              {"k": ColumnMeta(np.dtype(np.int64), 0, 2**40, n),
               "ten": ColumnMeta(np.dtype(np.int32), 0, 9, 10)})
    sess = Session(mode="kernel")
    sess.create_dataset("big", t, dataverse="w", closed=True)
    df = AFrame("w", "big", session=sess)
    df2 = AFrame("w", "big", session=sess)

    ops.reset_dispatch_counts()
    assert len(df[df["k"] >= 5]) == n - 5
    assert isinstance(sess.last_physical, PH.MaskCount)  # not KernelRangeCount
    assert ops.DISPATCH_COUNTS.get("filter_count", 0) == 0

    assert len(df.merge(df2, left_on="k", right_on="k")) == n
    assert ops.DISPATCH_COUNTS.get("merge_join_count", 0) == 0  # gate refused

    # the int32-bounded column still fuses
    assert len(df[df["ten"] == 3]) == int((vals % 10 == 3).sum())
    assert isinstance(sess.last_physical, PH.KernelRangeCount)


def test_group_sum_provenance_traced_through_rename(raw):
    """A Project rename must not let a big-bounded column borrow a
    small-bounded column's exactness proof: the gate traces the aggregated
    name to its ORIGIN table/column (regression: first-Scan name lookup)."""
    from repro.core.expr import Col
    from repro.engine.table import ColumnMeta, Table

    n = 2_000
    g = (np.arange(n) % 4).astype(np.int32)
    small = (np.arange(n) % 3).astype(np.int32)
    big = np.arange(n, dtype=np.int32)
    t = Table({"g": g, "x": small, "huge": big},
              {"g": ColumnMeta(np.dtype(np.int32), 0, 3, 4),
               "x": ColumnMeta(np.dtype(np.int32), 0, 2, 3),
               # claims an int64-deployment bound: sums would exceed 2^24
               "huge": ColumnMeta(np.dtype(np.int32), 0, 2**30, n)})
    res = {}
    for mode in ("gspmd", "kernel"):
        sess = Session(mode=mode)
        sess.create_dataset("t", t, dataverse="pv", closed=True)
        ops.reset_dispatch_counts()
        # project renames 'huge' -> 'x': name says small, values say huge
        plan = P.GroupAgg(
            P.Project(P.Scan("t", "pv"), [("g", Col("g")), ("x", Col("huge"))]),
            ["g"], [P.AggSpec("s", "sum", "x")])
        res[mode] = sess.execute(plan)
        if mode == "kernel":  # provenance check refused the f32 kernel
            assert ops.DISPATCH_COUNTS.get("segment_agg", 0) == 0
    np.testing.assert_array_equal(res["gspmd"]["s"], res["kernel"]["s"])
    want = [int(big[g == v].sum()) for v in range(4)]
    np.testing.assert_array_equal(res["kernel"]["s"], want)


def test_ddl_invalidates_plan_cache(table):
    """Re-registering a dataset name must drop compiled plans: executables
    bake shapes/bounds/optimizer decisions from the old catalog entry."""
    sess = Session(mode="kernel")
    sess.create_dataset("d", wisconsin.generate(2_000, seed=1), dataverse="w")
    df = AFrame("w", "d", session=sess)
    assert len(df) == 2_000
    sess.create_dataset("d", wisconsin.generate(5_000, seed=1), dataverse="w")
    df = AFrame("w", "d", session=sess)
    assert len(df) == 5_000
    assert sess.stats["compiles"] == 2  # second run recompiled, no stale hit


def test_multi_agg_single_kernel_launch(table, raw):
    """agg({a: sum, b: mean, c: count}) fuses into ONE (BLOCK, C) tile —
    a single segment_agg trace — and matches the gspmd result bit-for-bit."""
    sessions = {m: _session(table, m) for m in ("gspmd", "kernel")}
    ops.reset_dispatch_counts()
    results = {}
    for m, sess in sessions.items():
        df, _ = _frames(sess)
        results[m] = df.groupby("ten").agg(
            {"four": "sum", "twenty": "mean", "two": "count"})
    assert ops.DISPATCH_COUNTS.get("segment_agg", 0) == 1
    for k in results["gspmd"]:
        a, b = np.asarray(results["gspmd"][k]), np.asarray(results["kernel"][k])
        assert a.dtype == b.dtype, (k, a.dtype, b.dtype)
        np.testing.assert_array_equal(a, b, err_msg=k)
